//! End-to-end LM training driver (EXPERIMENTS.md §end-to-end).
//!
//! Trains a transformer LM with the EFLA token mixer on the synthetic
//! corpus, logging the loss curve, evaluating held-out perplexity, running
//! the downstream probe suite, and checkpointing — the full system
//! composing: L1 Pallas kernel -> L2 fused train-step graph -> L3 data
//! pipeline, scheduler, metrics, checkpoints.
//!
//! Presets (single-core CPU budgets):
//!   --preset tiny   0.15M params, seconds        (default smoke)
//!   --preset small   11M params, ~minutes
//!   --preset 100m   ~96M params — the "~100M for a few hundred steps"
//!                   end-to-end run; needs `make artifacts-full` and hours
//!                   of CPU. batch 2 x seq 512 per step.
//!
//! Run: cargo run --release --example train_lm -- --preset small --steps 120

use anyhow::Result;
use efla::coordinator::config::{RunConfig, Task};
use efla::coordinator::evaluator;
use efla::coordinator::schedule::Schedule;
use efla::coordinator::session::Session;
use efla::coordinator::trainer;
use efla::runtime::open_backend;
use efla::util::cli::Args;
use efla::util::json::{self, Json};

fn main() -> Result<()> {
    efla::util::logging::init();
    let p = Args::new("train_lm", "end-to-end LM training on synthetic corpus")
        .opt("preset", "small", "tiny | small | 100m")
        .opt("mixer", "efla", "efla | deltanet | efla_adaptive | efla_loose")
        .opt("steps", "120", "training steps")
        .opt("seed", "42", "seed")
        .opt("peak-lr", "0.0008", "peak learning rate")
        .opt("corpus-bytes", "3000000", "synthetic corpus size")
        .opt("eval-batches", "6", "held-out eval batches")
        .opt("out", "runs/train_lm", "output dir for curve + checkpoint")
        .flag("probes", "run the downstream probe suite after training")
        .parse();

    let cfg = RunConfig {
        task: Task::Lm,
        preset: p.get("preset")?.into(),
        mixer: p.get("mixer")?.into(),
        steps: p.u64("steps")?,
        seed: p.u64("seed")?,
        peak_lr: p.f64("peak-lr")?,
        corpus_bytes: p.usize("corpus-bytes")?,
        eval_batches: p.usize("eval-batches")?,
        out_dir: p.get("out")?.into(),
        ..Default::default()
    };

    let backend = open_backend(&cfg.artifact_dir)?;
    let family = cfg.family();
    if !backend.has_family(&family) {
        anyhow::bail!("backend {} cannot build {family}", backend.name());
    }

    let mut session = Session::init(backend.as_ref(), &family, cfg.seed as u32)?;
    log::info!(
        "{} | {:.1}M params | batch {} x seq {} = {} tok/step",
        family,
        session.param_elems() as f64 / 1e6,
        session.batch,
        session.seq,
        session.batch * session.seq
    );

    let (data, bpe) = trainer::lm_data(&cfg, session.batch, session.seq)?;
    let schedule = Schedule::paper_default(cfg.peak_lr, cfg.steps);
    let mut curve_points: Vec<Json> = Vec::new();
    let hist = trainer::train_lm(
        &mut session,
        schedule,
        cfg.steps,
        || data.next(),
        |pt| {
            curve_points.push(Json::arr_f64(&[pt.step as f64, pt.loss as f64]));
        },
    )?;

    // Held-out perplexity (disjoint corpus seed).
    let eval_cfg = RunConfig { seed: cfg.seed + 10_000, ..cfg.clone() };
    let (eval_data, _) = trainer::lm_data(&eval_cfg, session.batch, session.seq)?;
    let stats = evaluator::eval_batches(&session, cfg.eval_batches, || eval_data.next())?;
    log::info!(
        "held-out: ppl {:.2} | token acc {:.3} | {} tokens",
        stats.ppl(),
        stats.accuracy(),
        stats.tokens as u64
    );

    let mut probe_json = Vec::new();
    if p.bool("probes")? {
        for (name, acc) in evaluator::probe_suite(&session, &bpe, cfg.seed + 77, 24)? {
            log::info!("probe {name}: {acc:.3}");
            probe_json.push(Json::obj(vec![
                ("name", Json::Str(name)),
                ("acc", Json::Num(acc)),
            ]));
        }
    }

    // Persist everything.
    let out = cfg.out_dir.join(&family);
    std::fs::create_dir_all(&out)?;
    let tensors = session.export_state()?;
    efla::coordinator::checkpoint::save(&out.join("final.ckpt"), session.steps_done(), &tensors)?;
    json::write_file(
        &out.join("result.json"),
        &Json::obj(vec![
            ("config", cfg.to_json()),
            ("loss_curve", Json::Arr(curve_points)),
            ("final_loss", Json::Num(hist.tail_loss(10) as f64)),
            ("ppl", Json::Num(stats.ppl())),
            ("token_acc", Json::Num(stats.accuracy())),
            ("probes", Json::Arr(probe_json)),
            ("wall_secs", Json::Num(hist.wall_secs)),
            (
                "tokens_per_sec",
                Json::Num(
                    cfg.steps as f64 * hist.tokens_per_step as f64 / hist.wall_secs.max(1e-9),
                ),
            ),
        ]),
    )?;
    log::info!("wrote {}", out.join("result.json").display());
    Ok(())
}
