//! Serving demo: the HTTP front end with continuous batching, driven
//! end-to-end over a real socket.
//!
//! Trains a tiny LM briefly (so generations reflect corpus statistics),
//! binds the front end on an OS-assigned port, then fires a mixed client
//! load at it from plain threads: non-streamed `POST /v1/generate`
//! requests, one streamed request (chunked transfer, one JSON line per
//! token), and a `GET /stats` scrape. When the load finishes, the demo
//! flips the shutdown flag — the same graceful drain SIGTERM triggers —
//! and prints the engine report.
//!
//! Run: cargo run --release --example serve -- --requests 24 --max-new 24

use std::sync::atomic::Ordering;

use anyhow::Result;
use efla::coordinator::config::RunConfig;
use efla::coordinator::schedule::Schedule;
use efla::coordinator::server::ServerConfig;
use efla::coordinator::session::Session;
use efla::coordinator::trainer;
use efla::runtime::open_backend;
use efla::serve::{http, Frontend};
use efla::util::cli::Args;
use efla::util::rng::Rng;

fn main() -> Result<()> {
    efla::util::logging::init();
    let p = Args::new("serve", "HTTP serving engine demo")
        .opt("train-steps", "30", "warmup training steps")
        .opt("requests", "24", "client request count")
        .opt("max-new", "24", "tokens per request")
        .opt("temperature", "0.8", "sampling temperature")
        .opt("prefill-chunk", "64", "prompt tokens per slot per engine step (0 = token-at-a-time)")
        .opt("prefill-budget", "256", "max prompt tokens per engine step (0 = unlimited)")
        .opt("queue-depth", "64", "admission queue bound (full queue answers 429)")
        .opt("seed", "42", "seed")
        .parse();
    let backend = open_backend(std::path::Path::new("artifacts"))?;
    let mut session = Session::init(backend.as_ref(), "lm_tiny_efla", p.u64("seed")? as u32)?;

    let cfg =
        RunConfig { steps: p.u64("train-steps")?, corpus_bytes: 300_000, ..Default::default() };
    if cfg.steps > 0 {
        let (data, _) = trainer::lm_data(&cfg, session.batch, session.seq)?;
        trainer::train_lm(
            &mut session,
            Schedule::paper_default(1e-3, cfg.steps),
            cfg.steps,
            || data.next(),
            |_| {},
        )?;
    }

    let server_cfg = ServerConfig {
        prefill_chunk: p.usize("prefill-chunk")?,
        prefill_token_budget: p.usize("prefill-budget")?,
        queue_depth: p.usize("queue-depth")?,
        ..ServerConfig::default()
    };
    let frontend = Frontend::bind("127.0.0.1:0")?;
    let addr = frontend.local_addr()?.to_string();
    let stop = frontend.shutdown_flag();

    // Client load from a plain thread: the engine needs the main thread
    // (a Session is not Sync), the clients only need the address.
    let n = p.usize("requests")?;
    let max_new = p.usize("max-new")?;
    let temperature = p.f64("temperature")?;
    let seed = p.u64("seed")?;
    let client = std::thread::spawn(move || {
        let out = client_load(&addr, n, max_new, temperature, seed);
        // Done: trigger the graceful drain the way SIGTERM would.
        stop.store(true, Ordering::SeqCst);
        out
    });

    let stats = frontend.run(&session, server_cfg, seed)?;
    let (ok, rejected, sample) = client.join().expect("client thread");

    println!(
        "\nrequests: {ok} ok, {rejected} rejected (429) | slots: {} | wall {:.2}s",
        stats.batch, stats.wall_secs
    );
    println!(
        "engine: {} steps | {:.1} tok/s | {} prefill + {} decode tokens",
        stats.engine_steps,
        stats.tokens_per_sec(),
        stats.prefill_tokens,
        stats.decode_tokens
    );
    println!(
        "latency: mean TTFT {:.1} ms | mean queue wait {:.1} ms | mean e2e {:.1} ms",
        stats.mean_ttft_secs() * 1e3,
        stats.mean_queue_wait_secs() * 1e3,
        stats.mean_e2e_secs() * 1e3
    );
    println!("sample gen: {sample:?}");
    Ok(())
}

/// Fire `n` generate requests (the first one streamed) and scrape
/// `/stats`; returns (ok, rejected, sample generation).
fn client_load(
    addr: &str,
    n: usize,
    max_new: usize,
    temperature: f64,
    seed: u64,
) -> (usize, usize, String) {
    let mut rng = Rng::new(seed ^ 0x5EED);
    let corpus_words = ["the", "naba", "of", "recall", "is", "vora", "wimu"];
    let mut ok = 0usize;
    let mut rejected = 0usize;
    let mut sample = String::new();
    for i in 0..n {
        let mut prompt = String::new();
        for _ in 0..rng.range(2, 8) {
            prompt.push_str(corpus_words[rng.range(0, corpus_words.len())]);
            prompt.push(' ');
        }
        let stream = i == 0;
        let body = format!(
            "{{\"prompt\":{:?},\"max_tokens\":{max_new},\"temperature\":{temperature},\
             \"stream\":{stream}}}",
            prompt
        );
        match http::request(addr, "POST", "/v1/generate", body.as_bytes()) {
            Ok(resp) if resp.status == 200 => {
                ok += 1;
                if sample.is_empty() {
                    sample = resp.text().lines().last().unwrap_or("").to_string();
                }
            }
            Ok(resp) if resp.status == 429 => rejected += 1,
            Ok(resp) => eprintln!("request {i}: unexpected status {}", resp.status),
            Err(e) => eprintln!("request {i}: {e}"),
        }
    }
    if let Ok(stats) = http::request(addr, "GET", "/stats", b"") {
        println!("/stats: {}", stats.text());
    }
    (ok, rejected, sample)
}
