//! Serving demo: chunked parallel prefill + continuous batching on the
//! O(1)-state decode path.
//!
//! Trains a tiny LM briefly (so generations reflect corpus statistics),
//! then drives the slot-based engine with a Poisson-ish arrival pattern
//! of mixed-length requests: prompts ingest in parallel chunks
//! (`--prefill-chunk`), generation runs batched one-token decodes.
//! Reports latency percentiles, TTFT and engine throughput — the serving
//! scenario the paper's intro motivates (long-context/RL inference
//! without a KV cache).
//!
//! Run: cargo run --release --example serve -- --requests 24 --max-new 24

use anyhow::Result;
use efla::coordinator::config::RunConfig;
use efla::coordinator::schedule::Schedule;
use efla::coordinator::server::{GenRequest, Server, ServerConfig};
use efla::coordinator::session::Session;
use efla::coordinator::trainer;
use efla::runtime::open_backend;
use efla::util::bench::{fmt_secs, Stats};
use efla::util::cli::Args;
use efla::util::rng::Rng;

fn main() -> Result<()> {
    efla::util::logging::init();
    let p = Args::new("serve", "batched decode engine demo")
        .opt("train-steps", "30", "warmup training steps")
        .opt("requests", "24", "demo request count")
        .opt("max-new", "24", "tokens per request")
        .opt("temperature", "0.8", "sampling temperature")
        .opt("prefill-chunk", "64", "prompt tokens per slot per engine step (0 = token-at-a-time)")
        .opt("prefill-budget", "256", "max prompt tokens per engine step (0 = unlimited)")
        .opt("seed", "42", "seed")
        .parse();
    let backend = open_backend(std::path::Path::new("artifacts"))?;
    let mut session = Session::init(backend.as_ref(), "lm_tiny_efla", p.u64("seed")? as u32)?;

    let cfg =
        RunConfig { steps: p.u64("train-steps")?, corpus_bytes: 300_000, ..Default::default() };
    if cfg.steps > 0 {
        let (data, _) = trainer::lm_data(&cfg, session.batch, session.seq)?;
        trainer::train_lm(
            &mut session,
            Schedule::paper_default(1e-3, cfg.steps),
            cfg.steps,
            || data.next(),
            |_| {},
        )?;
    }

    let server_cfg = ServerConfig {
        prefill_chunk: p.usize("prefill-chunk")?,
        prefill_token_budget: p.usize("prefill-budget")?,
    };
    let mut server = Server::with_config(&session, p.u64("seed")?, server_cfg)?;
    let mut rng = Rng::new(p.u64("seed")? ^ 0x5EED);
    let n = p.usize("requests")?;
    let max_new = p.usize("max-new")?;
    let corpus_words = ["the", "naba", "of", "recall", "is", "vora", "wimu"];
    for id in 0..n as u64 {
        let mut prompt_text = String::new();
        for _ in 0..rng.range(2, 8) {
            prompt_text.push_str(corpus_words[rng.range(0, corpus_words.len())]);
            prompt_text.push(' ');
        }
        server.submit(GenRequest {
            id,
            prompt: prompt_text.bytes().map(|b| b as i32).collect(),
            max_new,
            temperature: p.f32("temperature")?,
        });
    }

    let t0 = std::time::Instant::now();
    let results = server.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    // Per-request slot-steps as a latency proxy (every step is one engine
    // decode; requests arriving when slots are busy queue first).
    let lat: Vec<f64> = results.iter().map(|r| r.steps as f64).collect();
    let stats = Stats::from_samples(lat);
    println!("\nrequests: {} | slots: {} | wall {:.2}s", results.len(), server.batch_size(), wall);
    println!(
        "engine: {} steps | {:.1} tok/s | mean step {} | prefill_chunk {}",
        server.stats.engine_steps,
        server.stats.tokens_per_sec(),
        fmt_secs(wall / server.stats.engine_steps.max(1) as f64),
        server.config().prefill_chunk,
    );
    println!(
        "tokens: {} prefill + {} decode | mean TTFT {}",
        server.stats.prefill_tokens,
        server.stats.decode_tokens,
        fmt_secs(server.stats.mean_ttft_secs()),
    );
    println!(
        "slot-steps per request: p50 {:.0} | p95 {:.0} | max {:.0}",
        stats.p50, stats.p95, stats.max
    );
    for r in results.iter().take(3) {
        let text: String = r
            .tokens
            .iter()
            .map(|&t| if (32..127).contains(&t) { (t as u8) as char } else { '?' })
            .collect();
        println!("sample gen[{}]: {text:?}", r.id);
    }
    Ok(())
}
