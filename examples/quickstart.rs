//! Quickstart: the whole three-layer stack in one minute.
//!
//! 1. open the best available execution backend (pure-Rust CPU by default,
//!    PJRT over AOT artifacts with `--features xla`);
//! 2. initialize a tiny EFLA language model (seeded init);
//! 3. train a few steps on synthetic text — fused fwd+bwd+AdamW per step;
//! 4. evaluate perplexity;
//! 5. generate a few tokens through the O(1)-state decode path.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use efla::coordinator::config::RunConfig;
use efla::coordinator::schedule::Schedule;
use efla::coordinator::server::{GenRequest, Server};
use efla::coordinator::session::Session;
use efla::coordinator::trainer;
use efla::runtime::open_backend;

fn main() -> Result<()> {
    efla::util::logging::init();

    // 1. the execution backend (CPU fallback needs no artifacts)
    let backend = open_backend(std::path::Path::new("artifacts"))?;
    println!("backend: {} ({} families)", backend.name(), backend.describe().len());

    // 2. a model session: params + AdamW state live backend-side
    let mut session = Session::init(backend.as_ref(), "lm_tiny_efla", 42)?;
    println!(
        "model: {} tensors / {:.2}M params, batch {} x seq {}",
        session.n_params_tensors(),
        session.param_elems() as f64 / 1e6,
        session.batch,
        session.seq
    );

    // 3. train on the synthetic corpus (Zipf text + long-range facts)
    let cfg = RunConfig { steps: 40, corpus_bytes: 300_000, ..Default::default() };
    let (data, _bpe) = trainer::lm_data(&cfg, session.batch, session.seq)?;
    let hist = trainer::train_lm(
        &mut session,
        Schedule::paper_default(1e-3, cfg.steps),
        cfg.steps,
        || data.next(),
        |p| {
            if p.step % 10 == 0 {
                println!("  step {:>3}  loss {:.4}", p.step, p.loss);
            }
        },
    )?;
    println!("trained {} steps in {:.1}s", cfg.steps, hist.wall_secs);

    // 4. held-out perplexity
    let eval_cfg = RunConfig { seed: 1234, ..cfg.clone() };
    let (eval_data, _) = trainer::lm_data(&eval_cfg, session.batch, session.seq)?;
    let stats = efla::coordinator::evaluator::eval_batches(&session, 4, || eval_data.next())?;
    println!("held-out ppl: {:.2} (byte-level)", stats.ppl());

    // 5. batched generation through the recurrent decode path
    let mut server = Server::new(&session, 7)?;
    for id in 0..4 {
        server.submit(GenRequest {
            id,
            prompt: "the naba of ".bytes().map(|b| b as i32).collect(),
            max_new: 16,
            temperature: 0.7,
            deadline: None,
            session_id: None,
        })?;
    }
    let results = server.run_to_completion()?;
    for r in &results {
        let text: String = r.tokens.iter().map(|&t| (t as u8) as char).collect();
        println!("gen[{}]: {:?}", r.id, text);
    }
    println!(
        "decode throughput: {:.1} tok/s across {} slots",
        server.stats.tokens_per_sec(),
        server.batch_size()
    );
    Ok(())
}
