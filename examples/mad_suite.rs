//! Table-2 scenario as a runnable example: train small models on each MAD
//! task for both mixers and print per-task accuracy.
//!
//! Run: cargo run --release --example mad_suite -- --steps 40 --tasks in_context_recall,memorize

use anyhow::Result;
use efla::coordinator::experiments::mad_run;
use efla::data::mad::MadTask;
use efla::runtime::open_backend;
use efla::util::bench::Table;
use efla::util::cli::Args;

fn parse_tasks(spec: &str) -> Vec<MadTask> {
    if spec == "all" {
        return MadTask::all().to_vec();
    }
    spec.split(',')
        .filter_map(|name| MadTask::all().into_iter().find(|t| t.name() == name.trim()))
        .collect()
}

fn main() -> Result<()> {
    efla::util::logging::init();
    let p = Args::new("mad_suite", "MAD synthetic benchmark (paper Table 2)")
        .opt("steps", "40", "training steps per (task, mixer)")
        .opt("eval-batches", "4", "eval batches per accuracy")
        .opt("tasks", "all", "comma list or 'all'")
        .opt("seed", "42", "seed")
        .parse();
    let backend = open_backend(std::path::Path::new("artifacts"))?;
    for m in ["efla", "deltanet"] {
        if !backend.has_family(&format!("lm_mad_{m}")) {
            anyhow::bail!("backend cannot build lm_mad_{m}");
        }
    }
    let tasks = parse_tasks(p.get("tasks")?);
    if tasks.is_empty() {
        anyhow::bail!("no valid tasks in --tasks {:?}", p.get("tasks")?);
    }

    let steps = p.u64("steps")?;
    let eval_batches = p.usize("eval-batches")?;
    let seed = p.u64("seed")?;

    let mut t = Table::new(&["task", "deltanet", "efla", "gap"]);
    for task in &tasks {
        let a_d = mad_run(backend.as_ref(), "deltanet", *task, steps, eval_batches, seed)?;
        let a_e = mad_run(backend.as_ref(), "efla", *task, steps, eval_batches, seed)?;
        t.row(&[
            task.name().to_string(),
            format!("{a_d:.3}"),
            format!("{a_e:.3}"),
            format!("{:+.3}", a_e - a_d),
        ]);
        log::info!("{}: deltanet {a_d:.3} efla {a_e:.3}", task.name());
    }
    println!("\n{}", t.render());
    println!("expected shape (paper Table 2): efla >= deltanet on most tasks.");
    Ok(())
}
