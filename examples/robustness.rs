//! Fig-1 scenario as a runnable example: train the sMNIST classifier with
//! EFLA and DeltaNet mixers, corrupt the inputs three ways, print the
//! degradation curves side by side.
//!
//! Run: cargo run --release --example robustness -- --steps 60

use anyhow::Result;
use efla::coordinator::experiments::{corruption_grid, robustness_run};
use efla::runtime::open_backend;
use efla::util::bench::Table;
use efla::util::cli::Args;

fn main() -> Result<()> {
    efla::util::logging::init();
    let p = Args::new("robustness", "sMNIST corruption robustness (paper Fig. 1)")
        .opt("steps", "60", "training steps per model")
        .opt("lr", "0.003", "learning rate (paper: 3e-3 for the strong row)")
        .opt("eval-batches", "2", "eval batches (x32 examples) per point")
        .parse();
    let backend = open_backend(std::path::Path::new("artifacts"))?;
    for m in ["efla", "deltanet"] {
        if !backend.has_family(&format!("clf_{m}")) {
            anyhow::bail!("backend cannot build clf_{m}");
        }
    }

    let steps = p.u64("steps")?;
    let lr = p.f64("lr")?;
    let eval_batches = p.usize("eval-batches")?;

    let efla_r = robustness_run(backend.as_ref(), "efla", lr, steps, eval_batches, 42)?;
    let delta_r = robustness_run(backend.as_ref(), "deltanet", lr, steps, eval_batches, 42)?;

    println!(
        "\nclean accuracy: efla {:.3} | deltanet {:.3}\n",
        efla_r.clean_acc, delta_r.clean_acc
    );
    for (label, grid) in corruption_grid() {
        let mut t = Table::new(&["corruption", "efla", "deltanet", "gap"]);
        for c in grid {
            let param = c.label();
            let find = |r: &efla::coordinator::experiments::RobustnessResult| {
                r.sweeps
                    .iter()
                    .find(|(k, x, _)| k == label && format!("{}", x) == format!("{}", match c {
                        efla::data::mnist::Corruption::Dropout(p) => p,
                        efla::data::mnist::Corruption::Scale(f) => f as f64,
                        efla::data::mnist::Corruption::Noise(s) => s as f64,
                        efla::data::mnist::Corruption::None => 0.0,
                    }))
                    .map(|(_, _, a)| *a)
                    .unwrap_or(f64::NAN)
            };
            let (ae, ad) = (find(&efla_r), find(&delta_r));
            t.row(&[
                param,
                format!("{ae:.3}"),
                format!("{ad:.3}"),
                format!("{:+.3}", ae - ad),
            ]);
        }
        println!("{}", t.render());
    }
    println!("expected shape: gap (efla - deltanet) grows with interference intensity.");
    Ok(())
}
