//! `efla` — the launcher binary.
//!
//! Subcommands:
//!   train   — train a model per a RunConfig (JSON file + flag overrides)
//!   serve   — run the batched decode demo on a (briefly trained) model
//!   route   — replica-sharded serving: a health-checked router over N
//!             in-process replicas (or remote engines via --backends)
//!   info    — list model families the active backend can build
//!
//! The execution backend is chosen automatically: PJRT when built with
//! `--features xla` and an artifact directory is present, else the
//! always-available pure-Rust CPU backend.
//!
//! Examples:
//!   efla train --task lm --preset tiny --mixer efla --steps 100
//!   efla train --config runs/table1_small_efla.json
//!   efla info
//!
//! Exit codes: 0 ok, 1 runtime failure, 2 command-line usage error.

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use efla::coordinator::config::{RunConfig, Task};
use efla::coordinator::server::{GenRequest, Server, ServerConfig};
use efla::coordinator::session::Session;
use efla::coordinator::trainer;
use efla::runtime::{open_backend, open_backend_threads};
use efla::serve::fault::FaultSpec;
use efla::serve::router::{Router, RouterConfig};
use efla::serve::Frontend;
use efla::util::cli::{Args, CliError};
use efla::util::logging;

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    let result = match cmd {
        "train" => cmd_train(&rest),
        "serve" => cmd_serve(&rest),
        "route" => cmd_route(&rest),
        "info" => cmd_info(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(CliError::new(format!("unknown command '{other}'")).into())
        }
    };
    if let Err(e) = result {
        // --help requests print to stdout and succeed; usage errors get a
        // clean one-liner and exit code 2 (no backtrace); runtime failures
        // render the full anyhow chain and exit 1.
        if let Some(cli) = e.downcast_ref::<CliError>() {
            if cli.is_help {
                println!("{cli}");
                std::process::exit(0);
            }
            eprintln!("{cli}");
            std::process::exit(2);
        }
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    eprintln!(
        "efla — Error-Free Linear Attention launcher\n\n\
         Commands:\n  \
         train   train a model (see `efla train --help`)\n  \
         serve   batched decode demo (see `efla serve --help`)\n  \
         route   replica-sharded router (see `efla route --help`)\n  \
         info    list model families the backend can build\n"
    );
}

fn common_args(program: &str, about: &str) -> Args {
    Args::new(program, about)
        .opt("config", "", "JSON RunConfig file (flags override)")
        .opt("task", "lm", "task: lm | classifier | mad")
        .opt("preset", "tiny", "model preset: tiny | small | mad | 100m")
        .opt("mixer", "efla", "efla | deltanet | efla_adaptive | efla_loose")
        .opt("steps", "100", "training steps")
        .opt("seed", "42", "RNG seed")
        .opt("peak-lr", "0.0003", "peak learning rate")
        .opt("eval-batches", "8", "eval batches at the end")
        .opt("corpus-bytes", "2000000", "synthetic corpus size (LM)")
        .opt("threads", "0", "CPU worker threads (0 = auto / EFLA_NUM_THREADS)")
        .opt(
            "prefill-chunk",
            "64",
            "serve: prompt tokens per slot per engine step (0 = token-at-a-time)",
        )
        .opt(
            "prefill-budget",
            "256",
            "serve: max prompt tokens per engine step across slots (0 = unlimited)",
        )
        .opt("listen", "", "serve: HTTP listen address, e.g. 127.0.0.1:8080 (empty = demo mode)")
        .opt("queue-depth", "64", "serve: admission queue bound (full queue answers 429)")
        .opt("drain-timeout", "5", "serve: seconds to drain in-flight requests on SIGTERM")
        .opt(
            "request-timeout-ms",
            "0",
            "serve/route: default per-request deadline in ms (0 = none)",
        )
        .opt(
            "state-cache-bytes",
            "0",
            "serve: byte bound of the per-session recurrent-state cache (0 = disabled)",
        )
        .opt(
            "state-cache-dir",
            "",
            "serve: spill directory for evicted session state (empty = drop on evict)",
        )
        .opt("replicas", "2", "route: in-process replica count")
        .opt("backends", "", "route: comma-separated engine addresses (instead of --replicas)")
        .opt("fault", "", "fault spec (also EFLA_FAULT; route: scoped 'idx:spec;...')")
        .opt("artifacts", "artifacts", "artifact directory (PJRT backend)")
        .opt("out", "runs", "output directory")
}

fn build_config(p: &efla::util::cli::Parsed) -> Result<RunConfig> {
    let mut cfg = if p.get("config")?.is_empty() {
        RunConfig::default()
    } else {
        RunConfig::from_file(Path::new(p.get("config")?))?
    };
    cfg.task = Task::parse(p.get("task")?).map_err(|e| CliError::new(e.to_string()))?;
    cfg.preset = p.get("preset")?.to_string();
    cfg.mixer = p.get("mixer")?.to_string();
    cfg.steps = p.u64("steps")?;
    cfg.seed = p.u64("seed")?;
    cfg.peak_lr = p.f64("peak-lr")?;
    cfg.eval_batches = p.usize("eval-batches")?;
    cfg.corpus_bytes = p.usize("corpus-bytes")?;
    cfg.threads = p.usize("threads")?;
    cfg.prefill_chunk = p.usize("prefill-chunk")?;
    cfg.prefill_token_budget = p.usize("prefill-budget")?;
    cfg.listen = p.get("listen")?.to_string();
    cfg.queue_depth = p.usize("queue-depth")?;
    cfg.drain_timeout_secs = p.f64("drain-timeout")?;
    cfg.request_timeout_ms = p.u64("request-timeout-ms")?;
    cfg.state_cache_bytes = p.usize("state-cache-bytes")?;
    cfg.state_cache_dir = p.get("state-cache-dir")?.to_string();
    cfg.replicas = p.usize("replicas")?;
    cfg.backends = p.get("backends")?.to_string();
    cfg.fault = p.get("fault")?.to_string();
    if cfg.fault.is_empty() {
        if let Ok(env_spec) = std::env::var("EFLA_FAULT") {
            cfg.fault = env_spec;
        }
    }
    cfg.artifact_dir = PathBuf::from(p.get("artifacts")?);
    cfg.out_dir = PathBuf::from(p.get("out")?);
    Ok(cfg)
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let p = common_args("efla train", "train a model").parse_from(argv)?;
    let cfg = build_config(&p)?;
    let backend = open_backend_threads(&cfg.artifact_dir, cfg.threads)?;
    log::info!("backend: {}", backend.name());
    let hist = trainer::run(backend.as_ref(), &cfg)?;
    log::info!(
        "done: {} steps, final loss {:.4} ({:.1}s, {:.0} tok/s)",
        cfg.steps,
        hist.final_loss(),
        hist.wall_secs,
        cfg.steps as f64 * hist.tokens_per_step as f64 / hist.wall_secs.max(1e-9)
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let p = common_args("efla serve", "HTTP serving / batched decode demo")
        .opt("requests", "16", "demo mode: number of demo requests")
        .opt("max-new", "32", "demo mode: tokens to generate per request")
        .opt("temperature", "0.8", "demo mode: sampling temperature (0 = greedy)")
        .parse_from(argv)?;
    let cfg = build_config(&p)?;
    if cfg.task != Task::Lm {
        bail!("serve only supports --task lm");
    }
    let backend = open_backend_threads(&cfg.artifact_dir, cfg.threads)?;
    log::info!("backend: {}", backend.name());
    let family = cfg.family();
    let mut session = Session::init(backend.as_ref(), &family, cfg.seed as u32)?;

    // Briefly train so generations aren't pure noise.
    if cfg.steps > 0 {
        let (pf, _) = trainer::lm_data(&cfg, session.batch, session.seq)?;
        let schedule =
            efla::coordinator::schedule::Schedule::paper_default(cfg.peak_lr, cfg.steps);
        trainer::train_lm(&mut session, schedule, cfg.steps, || pf.next(), |_| {})?;
    }

    let server_cfg = ServerConfig {
        prefill_chunk: cfg.prefill_chunk,
        prefill_token_budget: cfg.prefill_token_budget,
        queue_depth: cfg.queue_depth,
        drain_timeout_secs: cfg.drain_timeout_secs,
        default_timeout_ms: cfg.request_timeout_ms,
        state_cache_bytes: cfg.state_cache_bytes,
        state_cache_dir: cfg.state_cache_dir.clone(),
    };

    // --listen <addr>: run the HTTP front end with continuous batching
    // until SIGTERM/SIGINT, then drain and exit.
    if !cfg.listen.is_empty() {
        efla::serve::install_signal_handlers();
        let frontend = efla::serve::Frontend::bind(&cfg.listen)?;
        if !cfg.fault.is_empty() {
            let spec = FaultSpec::parse(&cfg.fault).map_err(CliError::new)?;
            log::warn!("fault injection armed: {spec:?}");
            frontend.set_fault_spec(spec);
        }
        let stats = frontend.run(&session, server_cfg, cfg.seed)?;
        log::info!(
            "drained: {} completed | {} engine steps | {:.1} tok/s | mean TTFT {:.1} ms",
            stats.completed,
            stats.engine_steps,
            stats.tokens_per_sec(),
            stats.mean_ttft_secs() * 1e3
        );
        return Ok(());
    }
    let mut server = Server::with_config(&session, cfg.seed, server_cfg)?;
    let n_req = p.usize("requests")?;
    let max_new = p.usize("max-new")?;
    let temp = p.f32("temperature")?;
    let mut rng = efla::util::rng::Rng::new(cfg.seed);
    for id in 0..n_req as u64 {
        let plen = rng.range(4, 24);
        let prompt: Vec<i32> = (0..plen)
            .map(|_| rng.range(97, 123) as i32) // ascii letters for byte-level models
            .collect();
        server.submit(GenRequest {
            id,
            prompt,
            max_new,
            temperature: temp,
            deadline: None,
            session_id: None,
        })?;
    }
    let results = server.run_to_completion()?;
    log::info!(
        "served {} requests | {} engine steps | {:.1} tok/s \
         (batch {}, {} threads, prefill_chunk {}, {:.2} tok/step/slot)",
        results.len(),
        server.stats.engine_steps,
        server.stats.tokens_per_sec(),
        server.batch_size(),
        server.stats.threads,
        server.config().prefill_chunk,
        server.stats.utilization()
    );
    log::info!(
        "prompt/generated split: {} prefill + {} decode tokens | mean TTFT {:.1} ms",
        server.stats.prefill_tokens,
        server.stats.decode_tokens,
        server.stats.mean_ttft_secs() * 1e3
    );
    for r in results.iter().take(4) {
        log::info!(
            "req {}: {} new tokens in {} slot-steps (ttft {:.1} ms)",
            r.id,
            r.tokens.len(),
            r.steps,
            r.ttft_secs * 1e3
        );
    }
    Ok(())
}

fn cmd_route(argv: &[String]) -> Result<()> {
    let p = common_args("efla route", "replica-sharded serving router")
        .opt("health-interval-ms", "200", "route: /healthz probe period per replica, in ms")
        .opt("max-attempts", "3", "route: max replicas tried per request")
        .opt("cooldown-ms", "1000", "route: ejection cooldown before a half-open probe, in ms")
        .opt("affinity", "on", "route: session-affine scheduling, on | off")
        .opt("migrate", "on", "route: state migration on session failover, on | off")
        .parse_from(argv)?;
    let cfg = build_config(&p)?;
    if cfg.task != Task::Lm {
        bail!("route only supports --task lm");
    }
    efla::serve::install_signal_handlers();
    let listen = if cfg.listen.is_empty() { "127.0.0.1:0" } else { cfg.listen.as_str() };
    let rcfg = RouterConfig {
        health_interval_ms: p.u64("health-interval-ms")?,
        max_attempts: p.usize("max-attempts")?,
        cooldown_ms: p.u64("cooldown-ms")?,
        default_timeout_ms: cfg.request_timeout_ms,
        seed: cfg.seed,
        affinity: on_off(p.get("affinity")?, "affinity")?,
        migrate: on_off(p.get("migrate")?, "migrate")?,
        ..RouterConfig::default()
    };

    // --backends: pure proxy mode over already-running engines.
    if !cfg.backends.is_empty() {
        let backends: Vec<String> = cfg
            .backends
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if !cfg.fault.is_empty() {
            bail!("--fault targets in-process replicas; POST /fault to a remote backend instead");
        }
        return Router::bind(listen, backends, rcfg)?.run();
    }

    // In-process replicas: bind every front end first (the router needs
    // the addresses before the replicas finish training), then train and
    // serve each on its own thread. The router sheds with 503 until the
    // first replica starts answering health probes.
    let n = cfg.replicas.max(1);
    let faults = FaultSpec::parse_scoped(&cfg.fault, n).map_err(CliError::new)?;
    let server_cfg = ServerConfig {
        prefill_chunk: cfg.prefill_chunk,
        prefill_token_budget: cfg.prefill_token_budget,
        queue_depth: cfg.queue_depth,
        drain_timeout_secs: cfg.drain_timeout_secs,
        default_timeout_ms: cfg.request_timeout_ms,
        // Each replica gets its own independent state cache; the router
        // keeps sessions pinned to one replica (rendezvous affinity)
        // and migrates parked state across caches on failover.
        state_cache_bytes: cfg.state_cache_bytes,
        state_cache_dir: cfg.state_cache_dir.clone(),
    };
    let mut frontends = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    let mut replica_shutdowns = Vec::with_capacity(n);
    for spec in faults {
        let fe = Frontend::bind("127.0.0.1:0")?;
        if !spec.is_noop() {
            log::warn!("replica {} fault injection armed: {spec:?}", frontends.len());
        }
        fe.set_fault_spec(spec);
        addrs.push(fe.local_addr()?.to_string());
        replica_shutdowns.push(fe.shutdown_flag());
        frontends.push(fe);
    }
    let router = Router::bind(listen, addrs, rcfg)?;
    std::thread::scope(|s| -> Result<()> {
        for (i, fe) in frontends.into_iter().enumerate() {
            let cfg = &cfg;
            let server_cfg = server_cfg.clone();
            s.spawn(move || {
                if let Err(e) = run_replica(i, fe, cfg, server_cfg) {
                    log::error!("replica {i} failed: {e:#}");
                }
            });
        }
        let result = router.run();
        // The router is down (signal or error): drain the replicas too.
        for flag in &replica_shutdowns {
            flag.store(true, std::sync::atomic::Ordering::SeqCst);
        }
        result
    })
}

/// Parse an `on | off` CLI toggle.
fn on_off(v: &str, flag: &str) -> Result<bool> {
    match v {
        "on" => Ok(true),
        "off" => Ok(false),
        other => {
            Err(CliError::new(format!("--{flag} must be 'on' or 'off', got '{other}'")).into())
        }
    }
}

/// One in-process replica: its own backend and its own session, trained
/// identically (same family, seed, steps and threads on every replica ⇒
/// bit-identical weights), then the blocking serve loop. A `Session` is
/// not `Sync`, so each replica builds everything on its own thread.
fn run_replica(
    i: usize,
    frontend: Frontend,
    cfg: &RunConfig,
    server_cfg: ServerConfig,
) -> Result<()> {
    let backend = open_backend_threads(&cfg.artifact_dir, cfg.threads)?;
    let family = cfg.family();
    let mut session = Session::init(backend.as_ref(), &family, cfg.seed as u32)?;
    if cfg.steps > 0 {
        let (pf, _) = trainer::lm_data(cfg, session.batch, session.seq)?;
        let schedule =
            efla::coordinator::schedule::Schedule::paper_default(cfg.peak_lr, cfg.steps);
        trainer::train_lm(&mut session, schedule, cfg.steps, || pf.next(), |_| {})?;
    }
    log::info!("replica {i} ready on {}", frontend.local_addr()?);
    let stats = frontend.run(&session, server_cfg, cfg.seed)?;
    log::info!("replica {i} drained: {} completed", stats.completed);
    Ok(())
}

fn cmd_info(argv: &[String]) -> Result<()> {
    let p = Args::new("efla info", "list model families")
        .opt("artifacts", "artifacts", "artifact directory (PJRT backend)")
        .parse_from(argv)?;
    let backend = open_backend(Path::new(p.get("artifacts")?))?;
    println!("backend: {}", backend.name());
    for line in backend.describe() {
        println!("{line}");
    }
    Ok(())
}
