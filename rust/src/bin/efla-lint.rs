//! `efla-lint` CLI: run the repo-native static analysis over the tree.
//!
//! Usage: `cargo run --bin efla-lint [-- --root <repo-root>]`. Walks
//! `rust/src` and `rust/tests`, prints one line per violation, and exits
//! 0 when clean, 1 on violations, 2 on usage or IO errors.

#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

use efla::lint;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let root = match args.as_slice() {
        [] => lint::repo_root(),
        [flag, path] if flag == "--root" => PathBuf::from(path),
        _ => {
            eprintln!("usage: efla-lint [--root <repo-root>]");
            return ExitCode::from(2);
        }
    };
    let files = match lint::collect_tree(&root) {
        Ok(files) => files,
        Err(e) => {
            eprintln!("efla-lint: failed to read tree under {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let violations = lint::lint_sources(&files);
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("efla-lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("efla-lint: {} violation(s) in {} files", violations.len(), files.len());
        ExitCode::FAILURE
    }
}
