//! Declarative CLI flag parser (no `clap` in the vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help`. Used by the `efla`
//! launcher binary and every example/bench driver.
//!
//! Parsing and the typed getters are `Result`-based: a bad flag value
//! surfaces as a [`CliError`] the caller can render as a clean one-line
//! message (the `efla` binary exits with code 2, no backtrace).

use std::collections::BTreeMap;
use std::fmt;

/// A user-facing command-line error (bad flag, bad value, missing flag) —
/// or an explicit `--help` request (`is_help`), which callers render to
/// stdout and exit 0 instead of treating as a failure.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CliError {
    pub message: String,
    pub is_help: bool,
}

impl CliError {
    pub fn new(message: impl Into<String>) -> Self {
        CliError { message: message.into(), is_help: false }
    }

    fn help(message: String) -> Self {
        CliError { message, is_help: true }
    }
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for CliError {}

/// One declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Declare a boolean flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    /// Parse `std::env::args()` (skipping argv[0]). Prints help to stdout
    /// and exits 0 on `--help`; prints the error and exits 2 otherwise
    /// (example/bench drivers; the `efla` binary threads the `Result`).
    pub fn parse(self) -> Parsed {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(p) => p,
            Err(e) if e.is_help => {
                println!("{e}");
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    /// Parse an explicit argv (testable).
    pub fn parse_from(mut self, argv: &[String]) -> Result<Parsed, CliError> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError::help(self.usage()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| {
                        CliError::new(format!("unknown flag --{name}\n\n{}", self.usage()))
                    })?
                    .clone();
                let val = if opt.is_bool {
                    match inline_val {
                        Some(v) => v,
                        None => "true".to_string(),
                    }
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::new(format!("--{name} requires a value")))?
                        }
                    }
                };
                self.values.insert(name, val);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // defaults + required check
        for o in &self.opts {
            if !self.values.contains_key(&o.name) {
                match &o.default {
                    Some(d) => {
                        self.values.insert(o.name.clone(), d.clone());
                    }
                    None => {
                        return Err(CliError::new(format!(
                            "missing required flag --{}\n\n{}",
                            o.name,
                            self.usage()
                        )))
                    }
                }
            }
        }
        Ok(Parsed {
            values: self.values,
            positionals: self.positionals,
        })
    }

    fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let def = match (&o.default, o.is_bool) {
                (_, true) => " [flag]".to_string(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " [required]".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, def));
        }
        s
    }
}

/// Parsed argument values with typed, `Result`-returning getters.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Result<&str, CliError> {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| CliError::new(format!("flag --{name} not declared")))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.get(name)?;
        v.parse()
            .map_err(|e| CliError::new(format!("--{name}: invalid integer '{v}' ({e})")))
    }

    pub fn u64(&self, name: &str) -> Result<u64, CliError> {
        let v = self.get(name)?;
        v.parse()
            .map_err(|e| CliError::new(format!("--{name}: invalid integer '{v}' ({e})")))
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.get(name)?;
        v.parse()
            .map_err(|e| CliError::new(format!("--{name}: invalid number '{v}' ({e})")))
    }

    pub fn f32(&self, name: &str) -> Result<f32, CliError> {
        Ok(self.f64(name)? as f32)
    }

    pub fn bool(&self, name: &str) -> Result<bool, CliError> {
        Ok(matches!(self.get(name)?, "true" | "1" | "yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "test")
            .opt("steps", "100", "steps")
            .opt("lr", "0.001", "lr")
            .flag("verbose", "verbose")
            .parse_from(&argv(&["--steps", "5", "--verbose"]))
            .unwrap();
        assert_eq!(p.usize("steps").unwrap(), 5);
        assert!((p.f64("lr").unwrap() - 0.001).abs() < 1e-12);
        assert!(p.bool("verbose").unwrap());
    }

    #[test]
    fn equals_syntax_and_positional() {
        let p = Args::new("t", "test")
            .opt("mode", "a", "mode")
            .parse_from(&argv(&["--mode=b", "input.txt"]))
            .unwrap();
        assert_eq!(p.get("mode").unwrap(), "b");
        assert_eq!(p.positionals, vec!["input.txt"]);
    }

    #[test]
    fn required_missing_errors() {
        let r = Args::new("t", "test")
            .req("model", "model name")
            .parse_from(&argv(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        let r = Args::new("t", "test").parse_from(&argv(&["--nope"]));
        assert!(r.is_err());
    }

    #[test]
    fn bad_value_is_an_error_not_a_panic() {
        let p = Args::new("t", "test")
            .opt("steps", "100", "steps")
            .parse_from(&argv(&["--steps", "banana"]))
            .unwrap();
        let err = p.usize("steps").unwrap_err();
        assert!(err.message.contains("--steps"), "{err}");
        assert!(err.message.contains("banana"), "{err}");
        assert!(!err.is_help);
        // undeclared flags error too (no panic path left)
        assert!(p.get("nope").is_err());
    }

    #[test]
    fn help_is_flagged_distinctly() {
        let err = Args::new("t", "test")
            .opt("steps", "1", "steps")
            .parse_from(&argv(&["--help"]))
            .unwrap_err();
        assert!(err.is_help);
        assert!(err.message.contains("--steps"));
    }
}
