//! Declarative CLI flag parser (no `clap` in the vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, defaults, and auto-generated `--help`. Used by the `efla`
//! launcher binary and every example/bench driver.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_bool: false,
        });
        self
    }

    /// Declare a required option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_bool: false,
        });
        self
    }

    /// Declare a boolean flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_bool: true,
        });
        self
    }

    /// Parse `std::env::args()` (skipping argv[0]). Exits on `--help` / error.
    pub fn parse(self) -> Parsed {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse_from(&argv) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }

    /// Parse an explicit argv (testable).
    pub fn parse_from(mut self, argv: &[String]) -> Result<Parsed, String> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?
                    .clone();
                let val = if opt.is_bool {
                    match inline_val {
                        Some(v) => v,
                        None => "true".to_string(),
                    }
                } else {
                    match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{name} requires a value"))?
                        }
                    }
                };
                self.values.insert(name, val);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // defaults + required check
        for o in &self.opts {
            if !self.values.contains_key(&o.name) {
                match &o.default {
                    Some(d) => {
                        self.values.insert(o.name.clone(), d.clone());
                    }
                    None => return Err(format!("missing required flag --{}\n\n{}", o.name, self.usage())),
                }
            }
        }
        Ok(Parsed {
            values: self.values,
            positionals: self.positionals,
        })
    }

    fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let def = match (&o.default, o.is_bool) {
                (_, true) => " [flag]".to_string(),
                (Some(d), _) => format!(" [default: {d}]"),
                (None, _) => " [required]".to_string(),
            };
            s.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, def));
        }
        s
    }
}

/// Parsed argument values with typed getters.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not declared"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: invalid integer ({e})"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: invalid integer ({e})"))
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.get(name)
            .parse()
            .unwrap_or_else(|e| panic!("--{name}: invalid number ({e})"))
    }

    pub fn f32(&self, name: &str) -> f32 {
        self.f64(name) as f32
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "test")
            .opt("steps", "100", "steps")
            .opt("lr", "0.001", "lr")
            .flag("verbose", "verbose")
            .parse_from(&argv(&["--steps", "5", "--verbose"]))
            .unwrap();
        assert_eq!(p.usize("steps"), 5);
        assert!((p.f64("lr") - 0.001).abs() < 1e-12);
        assert!(p.bool("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let p = Args::new("t", "test")
            .opt("mode", "a", "mode")
            .parse_from(&argv(&["--mode=b", "input.txt"]))
            .unwrap();
        assert_eq!(p.get("mode"), "b");
        assert_eq!(p.positionals, vec!["input.txt"]);
    }

    #[test]
    fn required_missing_errors() {
        let r = Args::new("t", "test")
            .req("model", "model name")
            .parse_from(&argv(&[]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        let r = Args::new("t", "test").parse_from(&argv(&["--nope"]));
        assert!(r.is_err());
    }
}
