//! Deterministic RNG substrate (no `rand` crate in the vendor set).
//!
//! [`Rng`] is a SplitMix64-seeded xoshiro256++ generator — fast, 256-bit
//! state, passes BigCrush — with the samplers the data pipeline needs:
//! uniform, normal (Box–Muller), Bernoulli, Zipf, and shuffling. Everything
//! in the repo that needs randomness takes a seed so experiments replay
//! bit-identically.

/// SplitMix64: used to expand a 64-bit seed into the xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker / per-epoch RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * th.sin());
        r * th.cos()
    }

    /// N(mu, sigma^2) as f32.
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal() as f32
    }

    /// Zipf-distributed rank in [0, n) with exponent `s`.
    ///
    /// Convenience wrapper that builds a [`ZipfSampler`] per call — hot loops
    /// should hold a `ZipfSampler` instead.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        ZipfSampler::new(n, s).sample(self)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Vector of standard normals (f32).
    pub fn normal_vec(&mut self, n: usize, mu: f32, sigma: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32(mu, sigma)).collect()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed inverse-CDF Zipf sampler: O(n) build, O(log n) sample.
#[derive(Clone, Debug)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    /// Ranks 0..n with P(k) proportional to 1/(k+1)^s.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n >= 1);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / ((k + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    /// Draw a rank in [0, n).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // Binary search for first cdf >= u. `total_cmp` keeps the search
        // well-defined even if a degenerate build left a NaN in the table
        // (a 0/0 normalization): NaN never panics the comparator.
        match self.cdf.binary_search_by(|c| c.total_cmp(&u)) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng::new(4);
        let hits = (0..100_000).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / 100_000.0;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut r = Rng::new(5);
        let mut counts = vec![0usize; 50];
        for _ in 0..20_000 {
            let k = r.zipf(50, 1.1);
            assert!(k < 50);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[49]);
    }

    #[test]
    fn zipf_sample_survives_nan_cdf_entries() {
        // A degenerate normalization (0/0) can leave NaN in the table; the
        // total_cmp search must stay panic-free and keep ranks in range.
        let z = ZipfSampler {
            cdf: vec![0.25, f64::NAN, 1.0],
        };
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(z.sample(&mut r) < 3);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(8);
        let picks = r.choose_k(20, 10);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(picks.iter().all(|&p| p < 20));
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }
}
