//! Micro/bench harness (criterion is not in the vendor set).
//!
//! [`bench`] runs a closure with warmup + timed iterations and returns
//! [`Stats`] (mean/p50/p95/min/max). [`Table`] renders aligned text tables —
//! every `benches/*.rs` target prints the paper's table/figure rows through
//! it, and writes a machine-readable JSON next to it for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::json::Json;

/// Summary statistics over per-iteration wall times (seconds).
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_samples(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        // total_cmp: a NaN sample (e.g. a zero-duration 0/0 rate upstream)
        // must not panic the sort; positive NaN orders after every finite
        // value, so min/percentiles stay meaningful.
        xs.sort_by(|a, b| a.total_cmp(b));
        let n = xs.len();
        let pct = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            iters: n,
            mean: xs.iter().sum::<f64>() / n as f64,
            p50: pct(0.5),
            p95: pct(0.95),
            min: xs[0],
            max: xs[n - 1],
        }
    }

    /// Throughput given work-per-iteration.
    pub fn per_sec(&self, items_per_iter: f64) -> f64 {
        if self.mean > 0.0 {
            items_per_iter / self.mean
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::Num(self.iters as f64)),
            ("mean_s", Json::Num(self.mean)),
            ("p50_s", Json::Num(self.p50)),
            ("p95_s", Json::Num(self.p95)),
            ("min_s", Json::Num(self.min)),
            ("max_s", Json::Num(self.max)),
        ])
    }
}

/// Run `f` with `warmup` unmeasured and `iters` measured iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Stats::from_samples(samples)
}

/// Run `f` until `budget_secs` elapses (at least `min_iters`).
pub fn bench_for<F: FnMut()>(budget_secs: f64, min_iters: usize, mut f: F) -> Stats {
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < min_iters || start.elapsed().as_secs_f64() < budget_secs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    Stats::from_samples(samples)
}

/// Plain-text aligned table writer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1))));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Also expose rows as JSON (for bench_output artifacts).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("headers", Json::arr_str(&self.headers)),
            (
                "rows",
                Json::Arr(self.rows.iter().map(|r| Json::arr_str(r)).collect()),
            ),
        ])
    }
}

/// Format seconds human-readably (µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.2}s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let s = Stats::from_samples((1..=100).map(|i| i as f64).collect());
        assert_eq!(s.iters, 100);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn stats_tolerate_nan_samples() {
        // Must not panic; f64::NAN is positive, so total_cmp sorts it last
        // and the finite order statistics survive.
        let s = Stats::from_samples(vec![0.5, f64::NAN, 1.0]);
        assert_eq!(s.iters, 3);
        assert_eq!(s.min, 0.5);
        assert_eq!(s.p50, 1.0);
        assert!(s.max.is_nan());
    }

    #[test]
    fn bench_runs_requested_iters() {
        let mut count = 0;
        let s = bench(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["model", "ppl"]);
        t.row(&["efla".into(), "37.01".into()]);
        t.row(&["deltanet".into(), "38.09".into()]);
        let r = t.render();
        assert!(r.contains("model"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }
}
