//! Leveled logging + training progress meters (no external logger backend).
//!
//! A tiny `log`-crate backend writing to stderr with wall-clock timestamps,
//! plus [`Meter`] — a windowed throughput/ETA tracker the trainer and server
//! use for progress lines.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

static INIT: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    start: Instant,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5}] {}", record.level(), record.args());
    }

    fn flush(&self) {}
}

/// Install the stderr logger. Level from `EFLA_LOG` (error..trace), default info.
pub fn init() {
    if INIT.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("EFLA_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        _ => log::LevelFilter::Info,
    };
    let logger = Box::leak(Box::new(StderrLogger { start: Instant::now() }));
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}

/// Windowed progress meter: tracks items/sec over a sliding window and ETA.
pub struct Meter {
    start: Instant,
    window: Vec<(f64, u64)>, // (t, cumulative_items)
    total: Option<u64>,
    done: u64,
    window_secs: f64,
}

impl Meter {
    pub fn new(total: Option<u64>) -> Self {
        Meter {
            start: Instant::now(),
            window: Vec::new(),
            total,
            done: 0,
            window_secs: 30.0,
        }
    }

    /// Record `n` more completed items.
    pub fn add(&mut self, n: u64) {
        self.done += n;
        let t = self.start.elapsed().as_secs_f64();
        self.window.push((t, self.done));
        let cutoff = t - self.window_secs;
        self.window.retain(|&(tt, _)| tt >= cutoff);
    }

    pub fn done(&self) -> u64 {
        self.done
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Items/sec over the sliding window (falls back to lifetime rate).
    pub fn rate(&self) -> f64 {
        if self.window.len() >= 2 {
            let (t0, c0) = self.window[0];
            let (t1, c1) = self.window[self.window.len() - 1];
            if t1 > t0 {
                return (c1 - c0) as f64 / (t1 - t0);
            }
        }
        let e = self.elapsed_secs();
        if e > 0.0 {
            self.done as f64 / e
        } else {
            0.0
        }
    }

    /// Seconds remaining, if a total was given.
    pub fn eta_secs(&self) -> Option<f64> {
        let total = self.total?;
        let r = self.rate();
        if r <= 0.0 || self.done >= total {
            return None;
        }
        Some((total - self.done) as f64 / r)
    }

    /// One-line status, e.g. `step 120/500 | 3.2/s | eta 118s`.
    pub fn line(&self, unit: &str) -> String {
        let mut s = match self.total {
            Some(t) => format!("{} {}/{}", unit, self.done, t),
            None => format!("{} {}", unit, self.done),
        };
        s.push_str(&format!(" | {:.2}/s", self.rate()));
        if let Some(eta) = self.eta_secs() {
            s.push_str(&format!(" | eta {eta:.0}s"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_counts_and_rate() {
        let mut m = Meter::new(Some(10));
        m.add(3);
        m.add(2);
        assert_eq!(m.done(), 5);
        assert!(m.rate() >= 0.0);
        let line = m.line("step");
        assert!(line.contains("step 5/10"), "{line}");
    }

    #[test]
    fn eta_none_when_complete() {
        let mut m = Meter::new(Some(2));
        m.add(2);
        assert!(m.eta_secs().is_none());
    }

    #[test]
    fn init_idempotent() {
        init();
        init();
        log::info!("logging smoke");
    }
}
