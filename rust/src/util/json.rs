//! Minimal JSON substrate (no `serde` in the vendor set).
//!
//! A full recursive-descent parser + serializer for the JSON the system
//! exchanges: the AOT manifest written by `python/compile/aot.py`, experiment
//! config files, golden test vectors, and the metrics/loss-curve logs the
//! coordinator emits.
//!
//! Numbers are kept as `f64` (JSON's native model); helpers expose integer /
//! usize views with range checks.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order for deterministic output.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------- accessors ----------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }

    /// Typed convenience: `get(key)` as &str with error context.
    pub fn str_field(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid string field '{key}'"))
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid integer field '{key}'"))
    }

    pub fn f64_field(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("missing/invalid number field '{key}'"))
    }

    /// Array of usize (shapes etc).
    pub fn usize_array(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("expected integer")))
            .collect()
    }

    /// Array of f64.
    pub fn f64_array(&self) -> anyhow::Result<Vec<f64>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array"))?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    /// Array of f32 (golden vectors).
    pub fn f32_array(&self) -> anyhow::Result<Vec<f32>> {
        Ok(self.f64_array()?.into_iter().map(|x| x as f32).collect())
    }

    // ---------------- constructors ----------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // ---------------- serialization ----------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 9.0e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{}", n);
    } else {
        // JSON has no Inf/NaN; emit null (matches python json.dumps
        // default-ish behaviour for our logs)
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------- parser ----------------

/// Parse a JSON document (full input must be consumed).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pair handling
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("invalid unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid utf8 in \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("invalid hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
}

/// Read + parse a JSON file.
pub fn read_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Serialize + write a JSON file (pretty).
pub fn write_file(path: &std::path::Path, v: &Json) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, v.to_string_pretty())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for (txt, val) in [
            ("null", Json::Null),
            ("true", Json::Bool(true)),
            ("false", Json::Bool(false)),
            ("42", Json::Num(42.0)),
            ("-3.5", Json::Num(-3.5)),
            ("1e3", Json::Num(1000.0)),
            ("\"hi\"", Json::Str("hi".into())),
        ] {
            assert_eq!(parse(txt).unwrap(), val, "{txt}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let txt = r#"{"a": [1, 2, {"b": "x\ny"}], "c": null, "d": [[]]}"#;
        let v = parse(txt).unwrap();
        let re = parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v, Json::Str("é😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"shape": [2, 3], "name": "q", "lr": 0.001, "flag": true}"#).unwrap();
        assert_eq!(v.get("shape").usize_array().unwrap(), vec![2, 3]);
        assert_eq!(v.str_field("name").unwrap(), "q");
        assert!((v.f64_field("lr").unwrap() - 0.001).abs() < 1e-12);
        assert_eq!(v.get("flag").as_bool(), Some(true));
        assert!(v.get("missing").as_str().is_none());
        assert!(v.str_field("missing").is_err());
    }

    #[test]
    fn float_precision_roundtrip() {
        let xs = [1.5e-12, 3.14159265358979, -2.7e8, 0.1];
        let j = Json::arr_f64(&xs);
        let back = parse(&j.to_string()).unwrap().f64_array().unwrap();
        for (a, b) in xs.iter().zip(back.iter()) {
            assert!((a - b).abs() <= f64::EPSILON * a.abs().max(1.0));
        }
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }
}
