//! Shared substrates: RNG, JSON, CLI parsing, logging, bench harness.

#![forbid(unsafe_code)]

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;
