//! SwiGLU MLP layer: y = (silu(x W_gate) * (x W_up)) W_down.

use crate::tensor::{matmul_tn_into, Tensor};

use super::super::ops;
use super::super::params::ParamSet;
use super::{Ctx, Layer};

pub struct SwiGlu {
    w_gate: usize,
    w_up: usize,
    w_down: usize,
}

/// Saved: the normalized input plus both pre-activation branches
/// (g = silu(gpre) and gu = g * up are cheap; the backward recomputes them).
pub struct SwiGluTape {
    x: Vec<f32>,
    gpre: Vec<f32>,
    up: Vec<f32>,
}

impl SwiGlu {
    pub fn new(params: &ParamSet, li: usize) -> SwiGlu {
        SwiGlu {
            w_gate: params.idx(&format!("layer{li}.w_gate")),
            w_up: params.idx(&format!("layer{li}.w_up")),
            w_down: params.idx(&format!("layer{li}.w_down")),
        }
    }

    fn project(&self, ctx: &Ctx, x: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let (d, f, rows) = (ctx.cfg.d_model, ctx.cfg.mlp_width(), ctx.rows());
        let gpre = ops::matmul(ctx.exec, x, ctx.params.tensor(self.w_gate).data(), rows, d, f);
        let up = ops::matmul(ctx.exec, x, ctx.params.tensor(self.w_up).data(), rows, d, f);
        let mut gu = ops::silu_fwd(&gpre);
        for (g, u) in gu.iter_mut().zip(up.iter()) {
            *g *= u;
        }
        let y = ops::matmul(ctx.exec, &gu, ctx.params.tensor(self.w_down).data(), rows, f, d);
        (y, gpre, up)
    }

    /// Forward without a tape (decode path).
    pub fn infer(&self, ctx: &Ctx, x: &[f32]) -> Vec<f32> {
        self.project(ctx, x).0
    }

    /// [`infer`](Self::infer) into a caller-provided buffer, all
    /// intermediates drawn from the executor arena (the allocation-free
    /// serving form — decode and chunked prefill). `out` is overwritten.
    ///
    /// Matmuls go through the slot-batched serving wrappers (class keyed
    /// on `cfg.serve_slots()`), so a row's bits are independent of how
    /// many rows share the call: one decode token, the same token inside
    /// a batched decode step at any occupancy, and the same token inside
    /// a prefill chunk all agree exactly. (For row counts where the
    /// training dispatch picks a different kernel class this can differ
    /// from [`Layer::forward`] in the last bits — the serving paths only
    /// ever compare against themselves.)
    // lint: no-alloc -- intermediates come from the executor arena
    pub fn infer_into(&self, ctx: &Ctx, x: &[f32], out: &mut [f32]) {
        let (d, f) = (ctx.cfg.d_model, ctx.cfg.mlp_width());
        let slots = ctx.cfg.serve_slots();
        let rows = x.len() / d;
        debug_assert_eq!(out.len(), rows * d);
        let w_gate = ctx.params.tensor(self.w_gate);
        let mut gpre = ctx.exec.take(rows * f);
        ops::matmul_acc_serving_batched(ctx.exec, x, w_gate.data(), &mut gpre, rows, d, f, slots);
        let w_up = ctx.params.tensor(self.w_up);
        let mut up = ctx.exec.take(rows * f);
        ops::matmul_acc_serving_batched(ctx.exec, x, w_up.data(), &mut up, rows, d, f, slots);
        // gu = silu(gpre) * up, in place in gpre (same per-element
        // expression as the taped forward).
        for (g, u) in gpre.iter_mut().zip(up.iter()) {
            *g = ops::silu(*g) * *u;
        }
        out.fill(0.0);
        let w_down = ctx.params.tensor(self.w_down);
        ops::matmul_acc_serving_batched(ctx.exec, &gpre, w_down.data(), out, rows, f, d, slots);
        ctx.exec.put(gpre);
        ctx.exec.put(up);
    }
}

impl Layer for SwiGlu {
    type Tape = SwiGluTape;

    fn forward(&self, ctx: &Ctx, x: &[f32]) -> (Vec<f32>, SwiGluTape) {
        let (y, gpre, up) = self.project(ctx, x);
        (y, SwiGluTape { x: x.to_vec(), gpre, up })
    }

    fn backward(
        &self,
        ctx: &Ctx,
        tape: &SwiGluTape,
        dy: &[f32],
        grads: &mut [Tensor],
    ) -> Vec<f32> {
        let (d, f, rows) = (ctx.cfg.d_model, ctx.cfg.mlp_width(), ctx.rows());
        // Recompute the cheap intermediates (g = silu(gpre), gu = g * up).
        let g = ops::silu_fwd(&tape.gpre);
        let mut gu = g.clone();
        for (x, u) in gu.iter_mut().zip(tape.up.iter()) {
            *x *= u;
        }
        matmul_tn_into(&gu, dy, grads[self.w_down].data_mut(), rows, f, d);
        let mut dgu = vec![0.0f32; rows * f];
        ops::matmul_nt_acc(
            ctx.exec,
            dy,
            ctx.params.tensor(self.w_down).data(),
            &mut dgu,
            rows,
            d,
            f,
        );
        let mut dgpre = vec![0.0f32; rows * f];
        let mut dup = vec![0.0f32; rows * f];
        for i in 0..rows * f {
            dgpre[i] = dgu[i] * tape.up[i] * ops::silu_grad(tape.gpre[i]);
            dup[i] = dgu[i] * g[i];
        }
        let mut dx = vec![0.0f32; rows * d];
        ops::matmul_nt_acc(
            ctx.exec,
            &dgpre,
            ctx.params.tensor(self.w_gate).data(),
            &mut dx,
            rows,
            f,
            d,
        );
        ops::matmul_nt_acc(
            ctx.exec,
            &dup,
            ctx.params.tensor(self.w_up).data(),
            &mut dx,
            rows,
            f,
            d,
        );
        matmul_tn_into(&tape.x, &dgpre, grads[self.w_gate].data_mut(), rows, d, f);
        matmul_tn_into(&tape.x, &dup, grads[self.w_up].data_mut(), rows, d, f);
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::config::family_config;
    use super::super::super::exec::Executor;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn layer_backward_matches_finite_differences() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 5);
        let exec = Executor::serial();
        let (b, l) = (1usize, 2usize);
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let layer = SwiGlu::new(&params, 0);

        let mut rng = Rng::new(13);
        let rows = b * l;
        let x = rng.normal_vec(rows * cfg.d_model, 0.0, 1.0);
        let w = rng.normal_vec(rows * cfg.d_model, 0.0, 1.0);
        let loss = |x: &[f32]| -> f64 {
            let y = layer.infer(&ctx, x);
            y.iter().zip(w.iter()).map(|(&a, &g)| a as f64 * g as f64).sum()
        };

        let (_, tape) = layer.forward(&ctx, &x);
        let mut grads = params.zeros_like();
        let dx = layer.backward(&ctx, &tape, &w, &mut grads);

        let h = 1e-2f32;
        for idx in (0..x.len()).step_by(23) {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!(
                (dx[idx] as f64 - n).abs() < 2e-2 * (1.0 + n.abs()),
                "dx[{idx}]: {} vs {n}",
                dx[idx]
            );
        }
        for name in ["layer0.w_gate", "layer0.w_up", "layer0.w_down"] {
            assert!(grads[params.idx(name)].norm() > 0.0, "{name} gradient must flow");
        }
    }

    #[test]
    fn infer_matches_forward() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 6);
        let exec = Executor::serial();
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b: 2, l: 1 };
        let layer = SwiGlu::new(&params, 1);
        let mut rng = Rng::new(14);
        let x = rng.normal_vec(2 * cfg.d_model, 0.0, 1.0);
        let (y, _) = layer.forward(&ctx, &x);
        assert_eq!(y, layer.infer(&ctx, &x));
        // The arena-backed serving form is pinned to the slot-batched
        // kernel class (keyed on serve_slots, not the row count), so it
        // agrees with the training forward only to tolerance — and is
        // stable over a dirty output buffer and a dirty arena.
        let mut serve = vec![7.0f32; y.len()];
        layer.infer_into(&ctx, &x, &mut serve);
        for (i, (&a, &b)) in y.iter().zip(serve.iter()).enumerate() {
            assert!((a - b).abs() <= 1e-4 * (1.0 + a.abs()), "i={i}: {a} vs {b}");
        }
        for _ in 0..2 {
            let mut out = vec![7.0f32; y.len()];
            layer.infer_into(&ctx, &x, &mut out);
            assert_eq!(serve, out);
        }
    }

    #[test]
    fn infer_into_rows_are_occupancy_invariant() {
        // The serving contract: a row's bits must not depend on how many
        // rows share the infer_into call (busy-slot count), because the
        // kernel class is keyed on cfg.serve_slots().
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 6);
        let exec = Executor::serial();
        let layer = SwiGlu::new(&params, 0);
        let mut rng = Rng::new(15);
        let slots = cfg.serve_slots();
        let x = rng.normal_vec(slots * cfg.d_model, 0.0, 1.0);
        let ctx_full = Ctx { cfg: &cfg, params: &params, exec: &exec, b: slots, l: 1 };
        let mut full = vec![0.0f32; slots * cfg.d_model];
        layer.infer_into(&ctx_full, &x, &mut full);
        for busy in 1..=slots {
            let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b: busy, l: 1 };
            let mut part = vec![0.0f32; busy * cfg.d_model];
            layer.infer_into(&ctx, &x[..busy * cfg.d_model], &mut part);
            assert_eq!(part[..], full[..busy * cfg.d_model], "busy={busy}");
        }
    }
}
