//! Row-wise RMSNorm layer (pre-norms, per-head output norm, final norm).

use crate::tensor::Tensor;

use super::super::ops;
use super::super::params::ParamSet;
use super::{Ctx, Layer};

/// RMSNorm over rows of `width` with a learned gain.
pub struct RmsNorm {
    gain: usize,
    width: usize,
}

/// Saved: the input rows and the per-row inverse RMS.
pub struct RmsNormTape {
    x: Vec<f32>,
    inv: Vec<f32>,
}

impl RmsNorm {
    pub fn new(params: &ParamSet, gain_name: &str, width: usize) -> RmsNorm {
        RmsNorm { gain: params.idx(gain_name), width }
    }

    /// Forward without a tape (decode / eval-only paths).
    pub fn infer(&self, ctx: &Ctx, x: &[f32]) -> Vec<f32> {
        let gain = ctx.params.tensor(self.gain).data();
        ops::rms_norm_fwd(x, gain, self.width, ctx.cfg.norm_eps).0
    }

    /// [`infer`](Self::infer) into a caller-provided buffer (overwritten)
    /// — the allocation-free decode form.
    // lint: no-alloc -- writes into the caller's buffer only
    pub fn infer_into(&self, ctx: &Ctx, x: &[f32], y: &mut [f32]) {
        let gain = ctx.params.tensor(self.gain).data();
        ops::rms_norm_into(x, gain, self.width, ctx.cfg.norm_eps, y);
    }
}

impl Layer for RmsNorm {
    type Tape = RmsNormTape;

    fn forward(&self, ctx: &Ctx, x: &[f32]) -> (Vec<f32>, RmsNormTape) {
        let gain = ctx.params.tensor(self.gain).data();
        let (y, inv) = ops::rms_norm_fwd(x, gain, self.width, ctx.cfg.norm_eps);
        (y, RmsNormTape { x: x.to_vec(), inv })
    }

    fn backward(
        &self,
        ctx: &Ctx,
        tape: &RmsNormTape,
        dy: &[f32],
        grads: &mut [Tensor],
    ) -> Vec<f32> {
        let gain = ctx.params.tensor(self.gain).data();
        ops::rms_norm_bwd(
            &tape.x,
            gain,
            &tape.inv,
            dy,
            self.width,
            grads[self.gain].data_mut(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::config::family_config;
    use super::super::super::exec::Executor;
    use super::super::super::params::ParamSet;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn layer_backward_matches_finite_differences() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 3);
        let exec = Executor::serial();
        let (b, l) = (1usize, 3usize);
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let layer = RmsNorm::new(&params, "layer0.norm_attn", cfg.d_model);

        let mut rng = Rng::new(11);
        let x = rng.normal_vec(b * l * cfg.d_model, 0.0, 1.0);
        let w = rng.normal_vec(b * l * cfg.d_model, 0.0, 1.0); // dL/dy
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = layer.forward(&ctx, x);
            y.iter().zip(w.iter()).map(|(&a, &g)| a as f64 * g as f64).sum()
        };

        let (_, tape) = layer.forward(&ctx, &x);
        let mut grads = params.zeros_like();
        let dx = layer.backward(&ctx, &tape, &w, &mut grads);

        let h = 1e-3f32;
        for idx in (0..x.len()).step_by(17) {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!(
                (dx[idx] as f64 - n).abs() < 1e-2 * (1.0 + n.abs()),
                "dx[{idx}]: {} vs {n}",
                dx[idx]
            );
        }
        // Gain gradient flows.
        let gnorm = grads[params.idx("layer0.norm_attn")].norm();
        assert!(gnorm > 0.0, "gain gradient must be nonzero");
    }
}
