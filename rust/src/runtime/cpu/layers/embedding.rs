//! Input embeddings: token-table lookup (LM) and the linear per-pixel
//! embedding of the sMNIST classifier. Inputs are integer/scalar streams
//! rather than f32 activations, so these expose their own paired fwd/bwd
//! instead of the [`super::Layer`] trait.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::super::params::ParamSet;
use super::Ctx;

/// Token-id lookup into the (tied) embedding table.
pub struct TokenEmbedding {
    embed: usize,
}

impl TokenEmbedding {
    pub fn new(params: &ParamSet) -> TokenEmbedding {
        TokenEmbedding { embed: params.idx("embed") }
    }

    /// Validating lookup: tokens (B*L,) -> x (B*L, d).
    pub fn forward(&self, ctx: &Ctx, tokens: &[i32]) -> Result<Vec<f32>> {
        let mut x = vec![0.0f32; tokens.len() * ctx.cfg.d_model];
        self.forward_into(ctx, tokens, &mut x)?;
        Ok(x)
    }

    /// [`forward`](Self::forward) into a caller-provided buffer
    /// (overwritten) — the allocation-free decode form.
    // lint: no-alloc -- pure table-lookup into the caller's buffer
    pub fn forward_into(&self, ctx: &Ctx, tokens: &[i32], x: &mut [f32]) -> Result<()> {
        let d = ctx.cfg.d_model;
        let vocab = ctx.cfg.vocab;
        let table = ctx.params.tensor(self.embed).data();
        debug_assert_eq!(x.len(), tokens.len() * d);
        for (r, &t) in tokens.iter().enumerate() {
            if t < 0 || t as usize >= vocab {
                bail!("token id {t} out of range (vocab {vocab})");
            }
            let t = t as usize;
            x[r * d..(r + 1) * d].copy_from_slice(&table[t * d..(t + 1) * d]);
        }
        Ok(())
    }

    /// Scatter-add dx rows into the embedding gradient.
    pub fn backward(&self, ctx: &Ctx, tokens: &[i32], dx: &[f32], grads: &mut [Tensor]) {
        let d = ctx.cfg.d_model;
        let dembed = grads[self.embed].data_mut();
        for (r, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            let dr = &dx[r * d..(r + 1) * d];
            let er = &mut dembed[t * d..(t + 1) * d];
            for j in 0..d {
                er[j] += dr[j];
            }
        }
    }
}

/// Linear pixel embedding: x_r = px_r * pix_w + pix_b.
pub struct PixelEmbedding {
    pix_w: usize,
    pix_b: usize,
}

impl PixelEmbedding {
    pub fn new(params: &ParamSet) -> PixelEmbedding {
        PixelEmbedding { pix_w: params.idx("pix_w"), pix_b: params.idx("pix_b") }
    }

    /// pixels (B*L,) -> x (B*L, d).
    pub fn forward(&self, ctx: &Ctx, pixels: &[f32]) -> Vec<f32> {
        let d = ctx.cfg.d_model;
        let pw = ctx.params.tensor(self.pix_w).data();
        let pb = ctx.params.tensor(self.pix_b).data();
        let mut x = vec![0.0f32; pixels.len() * d];
        for (r, &px) in pixels.iter().enumerate() {
            let xr = &mut x[r * d..(r + 1) * d];
            for j in 0..d {
                xr[j] = px * pw[j] + pb[j];
            }
        }
        x
    }

    pub fn backward(&self, ctx: &Ctx, pixels: &[f32], dx: &[f32], grads: &mut [Tensor]) {
        let d = ctx.cfg.d_model;
        {
            let dpw = grads[self.pix_w].data_mut();
            for (r, &px) in pixels.iter().enumerate() {
                if px == 0.0 {
                    continue;
                }
                let dr = &dx[r * d..(r + 1) * d];
                for j in 0..d {
                    dpw[j] += px * dr[j];
                }
            }
        }
        let dpb = grads[self.pix_b].data_mut();
        for r in 0..pixels.len() {
            let dr = &dx[r * d..(r + 1) * d];
            for j in 0..d {
                dpb[j] += dr[j];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::config::family_config;
    use super::super::super::exec::Executor;
    use super::*;

    #[test]
    fn token_lookup_and_gradient_scatter() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 2);
        let exec = Executor::serial();
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b: 1, l: 3 };
        let layer = TokenEmbedding::new(&params);
        let tokens = [5i32, 9, 5];
        let x = layer.forward(&ctx, &tokens).unwrap();
        let d = cfg.d_model;
        let table = params.get("embed").data();
        assert_eq!(&x[0..d], &table[5 * d..6 * d]);
        assert_eq!(&x[d..2 * d], &table[9 * d..10 * d]);

        let mut grads = params.zeros_like();
        let dx = vec![1.0f32; 3 * d];
        layer.backward(&ctx, &tokens, &dx, &mut grads);
        let ge = grads[params.idx("embed")].data();
        // token 5 hit twice, token 9 once, everything else untouched
        assert!((ge[5 * d] - 2.0).abs() < 1e-6);
        assert!((ge[9 * d] - 1.0).abs() < 1e-6);
        assert_eq!(ge[0], 0.0);
    }

    #[test]
    fn out_of_range_token_rejected() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 2);
        let exec = Executor::serial();
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b: 1, l: 1 };
        let layer = TokenEmbedding::new(&params);
        assert!(layer.forward(&ctx, &[cfg.vocab as i32]).is_err());
        assert!(layer.forward(&ctx, &[-1]).is_err());
    }

    #[test]
    fn pixel_embedding_is_affine_and_differentiable() {
        let cfg = family_config("clf_efla").unwrap();
        let params = ParamSet::init(&cfg, 3);
        let exec = Executor::serial();
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b: 1, l: 2 };
        let layer = PixelEmbedding::new(&params);
        let pixels = [0.5f32, 0.0];
        let x = layer.forward(&ctx, &pixels);
        let d = cfg.d_model;
        let pw = params.get("pix_w").data();
        let pb = params.get("pix_b").data();
        for j in 0..d {
            assert!((x[j] - (0.5 * pw[j] + pb[j])).abs() < 1e-6);
            assert!((x[d + j] - pb[j]).abs() < 1e-6);
        }
        let mut grads = params.zeros_like();
        let dx = vec![1.0f32; 2 * d];
        layer.backward(&ctx, &pixels, &dx, &mut grads);
        assert!((grads[params.idx("pix_w")].data()[0] - 0.5).abs() < 1e-6);
        assert!((grads[params.idx("pix_b")].data()[0] - 2.0).abs() < 1e-6);
    }
}
