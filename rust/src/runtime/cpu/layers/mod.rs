//! Composable model layers with a saved-activation tape.
//!
//! Every block layer implements the uniform [`Layer`] pair:
//!
//! ```text
//! forward(&self, ctx, x)  -> (y, Tape)      // Tape = saved activations
//! backward(&self, ctx, &Tape, dy, grads) -> dx
//! ```
//!
//! A layer owns nothing but parameter *indices* into the session's
//! [`ParamSet`] (construction is cheap; `grads` is the parallel gradient
//! array, indexed identically). Its `Tape` owns every activation the
//! backward pass replays — including the layer's own input — so the
//! orchestrator in [`super::model`] only threads residual streams.
//!
//! Modules:
//! * [`embedding`] — token lookup (LM) and linear pixel embedding (sMNIST);
//! * [`rmsnorm`]   — row-wise RMSNorm (pre-norms and the final norm);
//! * [`mixer`]     — qkv projections + causal conv + scalar gate + the
//!   chunkwise delta kernel, (batch × head)-parallel via the executor;
//! * [`swiglu`]    — the gated MLP;
//! * [`head`]      — tied-softmax LM head and pooled classifier head
//!   (cross-entropy forward + backward).
//!
//! [`Block`] composes {RMSNorm -> mixer -> residual; RMSNorm -> SwiGLU ->
//! residual} — the repeating unit of both the LM and the classifier.

pub mod embedding;
pub mod head;
pub mod mixer;
pub mod rmsnorm;
pub mod swiglu;

pub use embedding::{PixelEmbedding, TokenEmbedding};
pub use head::{ClfHead, LmHead, LossStats};
pub use mixer::MixerLayer;
pub use rmsnorm::RmsNorm;
pub use swiglu::SwiGlu;

use crate::tensor::Tensor;

use super::config::CpuModelCfg;
use super::exec::Executor;
use super::params::ParamSet;

/// Everything a layer needs to run: static config, parameters, the
/// work-splitting executor and the live batch shape (`l == 1` on the
/// decode path).
pub struct Ctx<'a> {
    pub cfg: &'a CpuModelCfg,
    pub params: &'a ParamSet,
    pub exec: &'a Executor,
    pub b: usize,
    pub l: usize,
}

impl Ctx<'_> {
    /// Token rows in this batch (B * L).
    pub fn rows(&self) -> usize {
        self.b * self.l
    }
}

/// The uniform forward/backward pair every block layer exposes.
pub trait Layer {
    /// Saved activations from `forward`, consumed by `backward`.
    type Tape;

    /// Compute y from x, saving what the backward pass needs.
    fn forward(&self, ctx: &Ctx, x: &[f32]) -> (Vec<f32>, Self::Tape);

    /// Propagate dy back to dx, accumulating parameter gradients into
    /// `grads` (aligned with the [`ParamSet`]).
    fn backward(&self, ctx: &Ctx, tape: &Self::Tape, dy: &[f32], grads: &mut [Tensor])
        -> Vec<f32>;
}

/// One transformer block: pre-norm mixer + residual, pre-norm SwiGLU +
/// residual.
pub struct Block {
    pub norm_attn: RmsNorm,
    pub mixer: MixerLayer,
    pub norm_mlp: RmsNorm,
    pub mlp: SwiGlu,
}

/// Saved activations of one block (one tape per sub-layer).
pub struct BlockTape {
    norm_attn: <RmsNorm as Layer>::Tape,
    mixer: <MixerLayer as Layer>::Tape,
    norm_mlp: <RmsNorm as Layer>::Tape,
    mlp: <SwiGlu as Layer>::Tape,
}

impl Block {
    pub fn new(params: &ParamSet, cfg: &CpuModelCfg, li: usize) -> Block {
        let d = cfg.d_model;
        Block {
            norm_attn: RmsNorm::new(params, &format!("layer{li}.norm_attn"), d),
            mixer: MixerLayer::new(params, cfg, li),
            norm_mlp: RmsNorm::new(params, &format!("layer{li}.norm_mlp"), d),
            mlp: SwiGlu::new(params, li),
        }
    }
}

impl Layer for Block {
    type Tape = BlockTape;

    fn forward(&self, ctx: &Ctx, x: &[f32]) -> (Vec<f32>, BlockTape) {
        let (h_attn, t_norm_attn) = self.norm_attn.forward(ctx, x);
        let (attn_out, t_mixer) = self.mixer.forward(ctx, &h_attn);
        let mut x_mid = x.to_vec();
        for (xm, a) in x_mid.iter_mut().zip(attn_out.iter()) {
            *xm += a;
        }
        let (h_mlp, t_norm_mlp) = self.norm_mlp.forward(ctx, &x_mid);
        let (mlp_out, t_mlp) = self.mlp.forward(ctx, &h_mlp);
        let mut x_out = x_mid;
        for (xo, m) in x_out.iter_mut().zip(mlp_out.iter()) {
            *xo += m;
        }
        (
            x_out,
            BlockTape { norm_attn: t_norm_attn, mixer: t_mixer, norm_mlp: t_norm_mlp, mlp: t_mlp },
        )
    }

    fn backward(
        &self,
        ctx: &Ctx,
        tape: &BlockTape,
        dy: &[f32],
        grads: &mut [Tensor],
    ) -> Vec<f32> {
        // MLP branch: dy flows into both the residual and the MLP input.
        let dh_mlp = self.mlp.backward(ctx, &tape.mlp, dy, grads);
        let dmid_norm = self.norm_mlp.backward(ctx, &tape.norm_mlp, &dh_mlp, grads);
        let mut dx_mid = dy.to_vec();
        for (a, b) in dx_mid.iter_mut().zip(dmid_norm.iter()) {
            *a += b;
        }
        // Mixer branch.
        let dh_attn = self.mixer.backward(ctx, &tape.mixer, &dx_mid, grads);
        let din_norm = self.norm_attn.backward(ctx, &tape.norm_attn, &dh_attn, grads);
        let mut dx_in = dx_mid;
        for (a, b) in dx_in.iter_mut().zip(din_norm.iter()) {
            *a += b;
        }
        dx_in
    }
}

/// One-token inference step of a block over rolling decode state
/// (conv caches + per-head S), all updated in place. `ctx.l` must be 1.
/// Every temporary comes from the executor arenas: the per-token loop is
/// allocation-free in steady state.
impl Block {
    // lint: no-alloc -- the per-token block step stays on the arenas
    pub fn decode_step(
        &self,
        ctx: &Ctx,
        x: &mut [f32],
        cache_q: &mut [f32],
        cache_k: &mut [f32],
        cache_v: &mut [f32],
        s: &mut [f32],
    ) {
        debug_assert_eq!(ctx.l, 1);
        let mut normed = ctx.exec.take(x.len());
        let mut branch = ctx.exec.take(x.len());
        self.norm_attn.infer_into(ctx, x, &mut normed);
        self.mixer.decode_step(ctx, &normed, cache_q, cache_k, cache_v, s, &mut branch);
        for (xv, mv) in x.iter_mut().zip(branch.iter()) {
            *xv += mv;
        }
        // Both infer_into forms overwrite their target, so `normed` and
        // `branch` are safely reused for the MLP half.
        self.norm_mlp.infer_into(ctx, x, &mut normed);
        self.mlp.infer_into(ctx, &normed, &mut branch);
        for (xv, mv) in x.iter_mut().zip(branch.iter()) {
            *xv += mv;
        }
        ctx.exec.put(normed);
        ctx.exec.put(branch);
    }

    /// Chunked-prefill step of a block: the `ctx.l`-token residual stream
    /// `x` (L, d) of **one** sequence (`ctx.b == 1`) advances through the
    /// block while the slot's rolling decode state (conv caches + per-head
    /// S) is consumed and updated in place. Bit-identical to `ctx.l`
    /// successive [`Block::decode_step`] calls — every sub-layer is either
    /// row-local or serving-arithmetic pinned (see
    /// [`MixerLayer::prefill`]).
    // lint: no-alloc -- prefill reuses the decode arena buffers
    pub fn prefill(
        &self,
        ctx: &Ctx,
        x: &mut [f32],
        cache_q: &mut [f32],
        cache_k: &mut [f32],
        cache_v: &mut [f32],
        s: &mut [f32],
    ) {
        debug_assert_eq!(ctx.b, 1);
        let mut normed = ctx.exec.take(x.len());
        let mut branch = ctx.exec.take(x.len());
        self.norm_attn.infer_into(ctx, x, &mut normed);
        self.mixer.prefill(ctx, &normed, cache_q, cache_k, cache_v, s, &mut branch);
        for (xv, mv) in x.iter_mut().zip(branch.iter()) {
            *xv += mv;
        }
        self.norm_mlp.infer_into(ctx, x, &mut normed);
        self.mlp.infer_into(ctx, &normed, &mut branch);
        for (xv, mv) in x.iter_mut().zip(branch.iter()) {
            *xv += mv;
        }
        ctx.exec.put(normed);
        ctx.exec.put(branch);
    }
}
