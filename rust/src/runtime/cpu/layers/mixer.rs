//! Token-mixer layer: q/k/v projections, depthwise causal conv + SiLU,
//! per-head scalar gate (EFLA exact / DeltaNet Euler variants), and the
//! chunkwise delta-rule kernel.
//!
//! The kernel work is independent per (batch, head) pair — forward
//! ([`crate::attention::chunkwise_delta_alpha_into`]), backward
//! ([`crate::attention::delta_bptt_into`], recomputed per pair so peak
//! memory is one head's state trajectory) and the one-token decode update
//! all fan out through the scratch-aware executor shapes
//! ([`Executor::par_rows_scratch`](super::super::exec::Executor::par_rows_scratch),
//! `map_scratch`, `par_rows2_scratch`); results land in task order so
//! numerics are thread-count invariant. Per-task gather buffers and every
//! per-chunk/per-token temporary come from the worker's arena, so the hot
//! loops are allocation-free in steady state.

use crate::attention::backward::delta_bptt_into;
use crate::attention::chunkwise::chunkwise_delta_alpha_into;
use crate::attention::gates::{alpha_efla, alpha_efla_grad, EPS_LAMBDA};
use crate::tensor::{matmul_tn_into, Scratch, Tensor};

use super::super::config::{CpuModelCfg, Mixer, CONV_K};
use super::super::ops;
use super::super::params::ParamSet;
use super::{Ctx, Layer, RmsNorm};

/// Kernel chunk size of the **serving** delta recurrence (decode and
/// prefill). With C = 1 the chunkwise kernel's per-token arithmetic is
/// independent of how a prompt is partitioned into prefill calls, so
/// chunked prefill is bit-identical to token-at-a-time decoding for any
/// `prefill_chunk` — the serving paths trade the intra-chunk matmul
/// batching (which re-associates sums) for that reproducibility. Training
/// keeps the throughput-first WY/UT chunking via `cfg.chunk`.
const SERVE_KERNEL_CHUNK: usize = 1;

pub struct MixerLayer {
    wq: usize,
    wk: usize,
    wv: usize,
    conv_q: usize,
    conv_k: usize,
    conv_v: usize,
    w_beta: usize,
    adecay: usize,
    norm_out: RmsNorm,
    wo: usize,
}

/// Saved activations of one mixer forward.
pub struct MixerTape {
    /// The (normalized) layer input.
    x: Vec<f32>,
    qpre: Vec<f32>,
    kpre: Vec<f32>,
    vpre: Vec<f32>,
    qc: Vec<f32>,
    kc: Vec<f32>,
    vc: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// DeltaNet only: normalized q/k and per-head-row sum-squares.
    qn: Vec<f32>,
    kn: Vec<f32>,
    q_ss: Vec<f32>,
    k_ss: Vec<f32>,
    b_logits: Vec<f32>,
    beta_eff: Vec<f32>,
    alpha: Vec<f32>,
    lambda: Vec<f32>,
    norm_out: <RmsNorm as Layer>::Tape,
    o_norm: Vec<f32>,
}

/// Gather one (batch, head) pair's (L, Dh) rows out of a (B*L, inner)
/// buffer into a caller-provided (scratch) buffer of len `l * dh`.
fn gather_head_into(
    src: &[f32],
    bi: usize,
    hh: usize,
    l: usize,
    inner: usize,
    dh: usize,
    dst: &mut [f32],
) {
    debug_assert_eq!(dst.len(), l * dh);
    for t in 0..l {
        let base = (bi * l + t) * inner + hh * dh;
        dst[t * dh..(t + 1) * dh].copy_from_slice(&src[base..base + dh]);
    }
}

/// Scatter-add the (L, Dh) head rows back into a (B*L, inner) buffer.
fn scatter_head_add(
    dst: &mut [f32],
    src: &[f32],
    bi: usize,
    hh: usize,
    l: usize,
    inner: usize,
    dh: usize,
) {
    for t in 0..l {
        let base = (bi * l + t) * inner + hh * dh;
        for j in 0..dh {
            dst[base + j] += src[t * dh + j];
        }
    }
}

impl MixerLayer {
    pub fn new(params: &ParamSet, cfg: &CpuModelCfg, li: usize) -> MixerLayer {
        let p = |n: &str| format!("layer{li}.{n}");
        MixerLayer {
            wq: params.idx(&p("wq")),
            wk: params.idx(&p("wk")),
            wv: params.idx(&p("wv")),
            conv_q: params.idx(&p("conv_q")),
            conv_k: params.idx(&p("conv_k")),
            conv_v: params.idx(&p("conv_v")),
            w_beta: params.idx(&p("w_beta")),
            adecay: params.idx(&p("adecay")),
            norm_out: RmsNorm::new(params, &p("norm_out"), cfg.head_dim),
            wo: params.idx(&p("wo")),
        }
    }

    /// Resolve the variant-specific effective step size beta for one token.
    fn beta_eff(cfg: &CpuModelCfg, adecay: &[f32], z: f32, hh: usize) -> f32 {
        let mut bv = if cfg.mixer == Mixer::EflaLoose {
            ops::softplus(z)
        } else {
            ops::sigmoid(z)
        };
        if cfg.mixer == Mixer::EflaAdaptive {
            bv *= ops::softplus(adecay[hh]);
        }
        bv
    }

    /// One-token decode: `x` is the normalized (B, d) input; the rolling
    /// conv caches (B, K-1, inner) and the per-head state (B, H, Dh, Dh)
    /// are updated in place; the mixed output lands in the **zeroed**
    /// `out` (B, d). Every temporary comes from the executor arenas, so
    /// the per-token loop is allocation-free in steady state.
    ///
    /// Serving-arithmetic contract: projections go through the slot-batched
    /// [`ops::matmul_acc_serving_batched`] (class keyed on
    /// `cfg.serve_slots()`) and the state update through the chunkwise
    /// kernel at [`SERVE_KERNEL_CHUNK`], so one decode step is
    /// bit-identical per row at any busy-slot count, to a length-1
    /// [`MixerLayer::prefill`] — and a chain of decode steps to a prefill
    /// over the same tokens.
    // lint: no-alloc -- per-token decode draws every temporary from arenas
    pub fn decode_step(
        &self,
        ctx: &Ctx,
        x: &[f32],
        cache_q: &mut [f32],
        cache_k: &mut [f32],
        cache_v: &mut [f32],
        s: &mut [f32],
        out: &mut [f32],
    ) {
        let cfg = ctx.cfg;
        let (d, inner, h, dh) = (cfg.d_model, cfg.inner(), cfg.n_heads, cfg.head_dim);
        let b = ctx.b;
        let p = ctx.params;

        // Projections + rolling conv + SiLU, all through pooled buffers.
        // One packed (b, d) GEMM per projection covers every busy slot.
        let slots = cfg.serve_slots();
        let wq = p.tensor(self.wq);
        let mut qt = ctx.exec.take(b * inner);
        ops::matmul_acc_serving_batched(ctx.exec, x, wq.data(), &mut qt, b, d, inner, slots);
        let wk = p.tensor(self.wk);
        let mut kt = ctx.exec.take(b * inner);
        ops::matmul_acc_serving_batched(ctx.exec, x, wk.data(), &mut kt, b, d, inner, slots);
        let wv = p.tensor(self.wv);
        let mut vt = ctx.exec.take(b * inner);
        ops::matmul_acc_serving_batched(ctx.exec, x, wv.data(), &mut vt, b, d, inner, slots);
        let mut qc = ctx.exec.take(b * inner);
        ops::conv_step_into(&qt, cache_q, p.tensor(self.conv_q).data(), b, inner, CONV_K, &mut qc);
        let mut kc = ctx.exec.take(b * inner);
        ops::conv_step_into(&kt, cache_k, p.tensor(self.conv_k).data(), b, inner, CONV_K, &mut kc);
        let mut vc = ctx.exec.take(b * inner);
        ops::conv_step_into(&vt, cache_v, p.tensor(self.conv_v).data(), b, inner, CONV_K, &mut vc);
        ctx.exec.put(qt);
        ctx.exec.put(kt);
        ctx.exec.put(vt);
        ops::silu_inplace(&mut qc);
        ops::silu_inplace(&mut kc);
        ops::silu_inplace(&mut vc);

        // DeltaNet normalizes q/k per head row.
        let mut qn = Vec::new(); // lint: allow(no-alloc) -- empty Vec, heap-free
        let mut kn = Vec::new(); // lint: allow(no-alloc) -- empty Vec, heap-free
        if cfg.mixer == Mixer::DeltaNet {
            qn = ctx.exec.take(b * inner);
            ops::l2norm_into(&qc, dh, &mut qn);
            kn = ctx.exec.take(b * inner);
            ops::l2norm_into(&kc, dh, &mut kn);
        }
        let q_use: &[f32] = if cfg.mixer == Mixer::DeltaNet { &qn } else { &qc };
        let k_use: &[f32] = if cfg.mixer == Mixer::DeltaNet { &kn } else { &kc };

        let wb = p.tensor(self.w_beta);
        let mut b_logits = ctx.exec.take(b * h);
        ops::matmul_acc_serving_batched(ctx.exec, x, wb.data(), &mut b_logits, b, d, h, slots);
        let adecay = p.tensor(self.adecay).data();

        // One state update per (batch, head): both the state (width dh*dh)
        // and the head outputs (width dh) are contiguous per task in index
        // order i = bi*h + hh, so par_rows2 updates them in place. Per-task
        // work is ~3*dh^2 flops — only fan out when the total clears the
        // spawn cost (results are identical either way).
        let tasks = b * h;
        let mut o_all = ctx.exec.take(b * inner);
        let fan_out = tasks * dh * dh >= 1 << 20 && ctx.exec.threads() > 1;
        let step =
            |r0: usize, r1: usize, s_chunk: &mut [f32], o_chunk: &mut [f32], sc: &mut Scratch| {
                for i in r0..r1 {
                    let (bi, hh) = (i / h, i % h);
                    let bv = Self::beta_eff(cfg, adecay, b_logits[bi * h + hh], hh);
                    let base = bi * inner + hh * dh;
                    let krow = &k_use[base..base + dh];
                    let alpha = if cfg.mixer == Mixer::DeltaNet {
                        bv
                    } else {
                        let lam: f32 = krow.iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
                        alpha_efla(bv, lam)
                    };
                    let li = i - r0;
                    // L = 1 invocation of the chunkwise kernel: same
                    // arithmetic as one token of a prefill segment (see
                    // SERVE_KERNEL_CHUNK).
                    chunkwise_delta_alpha_into(
                        &q_use[base..base + dh],
                        krow,
                        &vc[base..base + dh],
                        &[alpha],
                        dh,
                        dh,
                        SERVE_KERNEL_CHUNK,
                        &mut o_chunk[li * dh..(li + 1) * dh],
                        &mut s_chunk[li * dh * dh..(li + 1) * dh * dh],
                        sc,
                    );
                }
            };
        if fan_out {
            ctx.exec.par_rows2_scratch(tasks, s, &mut o_all, step);
        } else {
            ctx.exec.scratch(|sc| step(0, tasks, s, &mut o_all, sc));
        }
        ctx.exec.put(b_logits);
        ctx.exec.put(qc);
        ctx.exec.put(kc);
        ctx.exec.put(vc);
        ctx.exec.put(qn);
        ctx.exec.put(kn);

        let mut o_norm = ctx.exec.take(b * inner);
        self.norm_out.infer_into(ctx, &o_all, &mut o_norm);
        ctx.exec.put(o_all);
        let wo = p.tensor(self.wo);
        ops::matmul_acc_serving_batched(ctx.exec, &o_norm, wo.data(), out, b, inner, d, slots);
        ctx.exec.put(o_norm);
    }

    /// Chunked prefill: run an `ctx.l`-token prompt segment of **one**
    /// sequence (`ctx.b == 1`) through the full mixer in a single batched
    /// pass — projections as (L, d) slot-class-pinned matmuls, causal conv
    /// warm-started from (and advancing) the rolling caches, and one
    /// seeded chunkwise delta run per head, fanned out over the executor.
    /// The slot's conv caches (K-1, inner) and per-head state (H, Dh, Dh)
    /// advance in place; the mixed output lands in the **zeroed** `out`
    /// (L, d).
    ///
    /// Bit-identical to `ctx.l` successive [`MixerLayer::decode_step`]
    /// calls over the same tokens, for any split of the prompt into
    /// prefill segments: every cross-token reduction either replays the
    /// rolling-cache arithmetic (conv) or runs the chunkwise kernel at
    /// [`SERVE_KERNEL_CHUNK`], and every matmul row is pinned to the
    /// slot-batched kernel class keyed on `cfg.serve_slots()`.
    // lint: no-alloc -- prefill segments reuse the same pooled buffers
    pub fn prefill(
        &self,
        ctx: &Ctx,
        x: &[f32],
        cache_q: &mut [f32],
        cache_k: &mut [f32],
        cache_v: &mut [f32],
        s: &mut [f32],
        out: &mut [f32],
    ) {
        let cfg = ctx.cfg;
        let (d, inner, h, dh) = (cfg.d_model, cfg.inner(), cfg.n_heads, cfg.head_dim);
        debug_assert_eq!(ctx.b, 1, "prefill runs one slot at a time");
        let l = ctx.l;
        let p = ctx.params;

        // Projections over the whole segment, then the warm-started conv.
        let slots = cfg.serve_slots();
        let wq = p.tensor(self.wq);
        let mut qt = ctx.exec.take(l * inner);
        ops::matmul_acc_serving_batched(ctx.exec, x, wq.data(), &mut qt, l, d, inner, slots);
        let wk = p.tensor(self.wk);
        let mut kt = ctx.exec.take(l * inner);
        ops::matmul_acc_serving_batched(ctx.exec, x, wk.data(), &mut kt, l, d, inner, slots);
        let wv = p.tensor(self.wv);
        let mut vt = ctx.exec.take(l * inner);
        ops::matmul_acc_serving_batched(ctx.exec, x, wv.data(), &mut vt, l, d, inner, slots);
        let mut qc = ctx.exec.take(l * inner);
        ops::conv_prefill(&qt, cache_q, p.tensor(self.conv_q).data(), l, inner, CONV_K, &mut qc);
        let mut kc = ctx.exec.take(l * inner);
        ops::conv_prefill(&kt, cache_k, p.tensor(self.conv_k).data(), l, inner, CONV_K, &mut kc);
        let mut vc = ctx.exec.take(l * inner);
        ops::conv_prefill(&vt, cache_v, p.tensor(self.conv_v).data(), l, inner, CONV_K, &mut vc);
        ctx.exec.put(qt);
        ctx.exec.put(kt);
        ctx.exec.put(vt);
        ops::silu_inplace(&mut qc);
        ops::silu_inplace(&mut kc);
        ops::silu_inplace(&mut vc);

        // DeltaNet normalizes q/k per head row.
        let mut qn = Vec::new(); // lint: allow(no-alloc) -- empty Vec, heap-free
        let mut kn = Vec::new(); // lint: allow(no-alloc) -- empty Vec, heap-free
        if cfg.mixer == Mixer::DeltaNet {
            qn = ctx.exec.take(l * inner);
            ops::l2norm_into(&qc, dh, &mut qn);
            kn = ctx.exec.take(l * inner);
            ops::l2norm_into(&kc, dh, &mut kn);
        }
        let q_use: &[f32] = if cfg.mixer == Mixer::DeltaNet { &qn } else { &qc };
        let k_use: &[f32] = if cfg.mixer == Mixer::DeltaNet { &kn } else { &kc };

        // Per-token scalar gate (same expression and summation order as
        // decode_step resolves per token).
        let wb = p.tensor(self.w_beta);
        let mut b_logits = ctx.exec.take(l * h);
        ops::matmul_acc_serving_batched(ctx.exec, x, wb.data(), &mut b_logits, l, d, h, slots);
        let adecay = p.tensor(self.adecay).data();
        let mut alpha = ctx.exec.take(l * h);
        for t in 0..l {
            for hh in 0..h {
                let bv = Self::beta_eff(cfg, adecay, b_logits[t * h + hh], hh);
                alpha[t * h + hh] = if cfg.mixer == Mixer::DeltaNet {
                    bv
                } else {
                    let krow = &k_use[t * inner + hh * dh..t * inner + (hh + 1) * dh];
                    let lam: f32 = krow.iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
                    alpha_efla(bv, lam)
                };
            }
        }

        // One seeded chunkwise run per head: the state rows (H, Dh*Dh) and
        // the head outputs (H, L*Dh) are contiguous per task, so par_rows2
        // advances the slot state in place, exactly like decode_step.
        let width = l * dh;
        let mut o_heads = ctx.exec.take(h * width);
        {
            let alpha = &alpha;
            ctx.exec.par_rows2_scratch(h, s, &mut o_heads, |r0, r1, s_chunk, o_chunk, sc| {
                for hh in r0..r1 {
                    let li = hh - r0;
                    let mut qh = sc.take(width);
                    gather_head_into(q_use, 0, hh, l, inner, dh, &mut qh);
                    let mut kh = sc.take(width);
                    gather_head_into(k_use, 0, hh, l, inner, dh, &mut kh);
                    let mut vh = sc.take(width);
                    gather_head_into(&vc, 0, hh, l, inner, dh, &mut vh);
                    let mut al = sc.take(l);
                    for (t, a) in al.iter_mut().enumerate() {
                        *a = alpha[t * h + hh];
                    }
                    chunkwise_delta_alpha_into(
                        &qh,
                        &kh,
                        &vh,
                        &al,
                        dh,
                        dh,
                        SERVE_KERNEL_CHUNK,
                        &mut o_chunk[li * width..(li + 1) * width],
                        &mut s_chunk[li * dh * dh..(li + 1) * dh * dh],
                        sc,
                    );
                    sc.put(qh);
                    sc.put(kh);
                    sc.put(vh);
                    sc.put(al);
                }
            });
        }
        ctx.exec.put(b_logits);
        ctx.exec.put(alpha);
        ctx.exec.put(qc);
        ctx.exec.put(kc);
        ctx.exec.put(vc);
        ctx.exec.put(qn);
        ctx.exec.put(kn);

        // Head-major (H, L, Dh) -> token-major (L, inner): a pure copy, so
        // the per-token bits match decode_step's direct (B, inner) layout.
        let mut o_all = ctx.exec.take(l * inner);
        for hh in 0..h {
            for t in 0..l {
                o_all[t * inner + hh * dh..t * inner + (hh + 1) * dh]
                    .copy_from_slice(&o_heads[hh * width + t * dh..hh * width + (t + 1) * dh]);
            }
        }
        ctx.exec.put(o_heads);

        let mut o_norm = ctx.exec.take(l * inner);
        self.norm_out.infer_into(ctx, &o_all, &mut o_norm);
        ctx.exec.put(o_all);
        let wo = p.tensor(self.wo);
        ops::matmul_acc_serving_batched(ctx.exec, &o_norm, wo.data(), out, l, inner, d, slots);
        ctx.exec.put(o_norm);
    }
}

impl Layer for MixerLayer {
    type Tape = MixerTape;

    fn forward(&self, ctx: &Ctx, x: &[f32]) -> (Vec<f32>, MixerTape) {
        let cfg = ctx.cfg;
        let (d, inner, h, dh) = (cfg.d_model, cfg.inner(), cfg.n_heads, cfg.head_dim);
        let (b, l, rows) = (ctx.b, ctx.l, ctx.rows());
        let p = ctx.params;

        let qpre = ops::matmul(ctx.exec, x, p.tensor(self.wq).data(), rows, d, inner);
        let kpre = ops::matmul(ctx.exec, x, p.tensor(self.wk).data(), rows, d, inner);
        let vpre = ops::matmul(ctx.exec, x, p.tensor(self.wv).data(), rows, d, inner);
        let qc = ops::conv_fwd(&qpre, p.tensor(self.conv_q).data(), b, l, inner, CONV_K);
        let kc = ops::conv_fwd(&kpre, p.tensor(self.conv_k).data(), b, l, inner, CONV_K);
        let vc = ops::conv_fwd(&vpre, p.tensor(self.conv_v).data(), b, l, inner, CONV_K);
        let q = ops::silu_fwd(&qc);
        let k = ops::silu_fwd(&kc);
        let v = ops::silu_fwd(&vc);

        // DeltaNet normalizes q/k per head row; (rows, inner) is (rows*h, dh).
        let (qn, q_ss, kn, k_ss) = if cfg.mixer == Mixer::DeltaNet {
            let (qn, q_ss) = ops::l2norm_fwd(&q, dh);
            let (kn, k_ss) = ops::l2norm_fwd(&k, dh);
            (qn, q_ss, kn, k_ss)
        } else {
            (Vec::new(), Vec::new(), Vec::new(), Vec::new())
        };

        // Per-token scalar gate.
        let b_logits = ops::matmul(ctx.exec, x, p.tensor(self.w_beta).data(), rows, d, h);
        let adecay = p.tensor(self.adecay).data();
        let mut beta_eff = vec![0.0f32; rows * h];
        for r in 0..rows {
            for hh in 0..h {
                beta_eff[r * h + hh] = Self::beta_eff(cfg, adecay, b_logits[r * h + hh], hh);
            }
        }
        let (lambda, alpha) = if cfg.mixer == Mixer::DeltaNet {
            (Vec::new(), beta_eff.clone())
        } else {
            let mut lambda = vec![0.0f32; rows * h];
            let mut alpha = vec![0.0f32; rows * h];
            for r in 0..rows {
                for hh in 0..h {
                    let krow = &k[r * inner + hh * dh..r * inner + (hh + 1) * dh];
                    let lam: f32 = krow.iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
                    lambda[r * h + hh] = lam;
                    alpha[r * h + hh] = alpha_efla(beta_eff[r * h + hh], lam);
                }
            }
            (lambda, alpha)
        };

        // Chunkwise delta attention: one (batch, head) pair per row of a
        // (B*H, L*Dh) head-output buffer, gathers and per-chunk scratch
        // from the worker arena.
        let q_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &qn } else { &q };
        let k_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &kn } else { &k };
        let width = l * dh;
        let mut o_heads = vec![0.0f32; b * h * width];
        ctx.exec.par_rows_scratch(b * h, &mut o_heads, |r0, r1, chunk_out, sc| {
            for i in r0..r1 {
                let (bi, hh) = (i / h, i % h);
                let mut qh = sc.take(width);
                gather_head_into(q_src, bi, hh, l, inner, dh, &mut qh);
                let mut kh = sc.take(width);
                gather_head_into(k_src, bi, hh, l, inner, dh, &mut kh);
                let mut vh = sc.take(width);
                gather_head_into(&v, bi, hh, l, inner, dh, &mut vh);
                let mut al = sc.take(l);
                for (t, a) in al.iter_mut().enumerate() {
                    *a = alpha[(bi * l + t) * h + hh];
                }
                let mut s_fin = sc.take(dh * dh);
                let oh = &mut chunk_out[(i - r0) * width..(i - r0 + 1) * width];
                chunkwise_delta_alpha_into(
                    &qh, &kh, &vh, &al, dh, dh, cfg.chunk, oh, &mut s_fin, sc,
                );
                sc.put(qh);
                sc.put(kh);
                sc.put(vh);
                sc.put(al);
                sc.put(s_fin);
            }
        });
        let mut o_raw = vec![0.0f32; rows * inner];
        for i in 0..b * h {
            let oh = &o_heads[i * width..(i + 1) * width];
            scatter_head_add(&mut o_raw, oh, i / h, i % h, l, inner, dh);
        }

        // Per-head output norm, merge, project.
        let (o_norm, t_norm_out) = self.norm_out.forward(ctx, &o_raw);
        let y = ops::matmul(ctx.exec, &o_norm, p.tensor(self.wo).data(), rows, inner, d);

        (
            y,
            MixerTape {
                x: x.to_vec(),
                qpre,
                kpre,
                vpre,
                qc,
                kc,
                vc,
                q,
                k,
                v,
                qn,
                kn,
                q_ss,
                k_ss,
                b_logits,
                beta_eff,
                alpha,
                lambda,
                norm_out: t_norm_out,
                o_norm,
            },
        )
    }

    fn backward(
        &self,
        ctx: &Ctx,
        tape: &MixerTape,
        dy: &[f32],
        grads: &mut [Tensor],
    ) -> Vec<f32> {
        let cfg = ctx.cfg;
        let (d, inner, h, dh) = (cfg.d_model, cfg.inner(), cfg.n_heads, cfg.head_dim);
        let (b, l, rows) = (ctx.b, ctx.l, ctx.rows());
        let p = ctx.params;

        // Output projection + per-head norm.
        matmul_tn_into(&tape.o_norm, dy, grads[self.wo].data_mut(), rows, inner, d);
        let mut do_norm = ctx.exec.take(rows * inner);
        ops::matmul_nt_acc(ctx.exec, dy, p.tensor(self.wo).data(), &mut do_norm, rows, d, inner);
        let do_raw = self.norm_out.backward(ctx, &tape.norm_out, &do_norm, grads);
        ctx.exec.put(do_norm);

        // BPTT through the delta recurrence, one task per (batch, head);
        // gathers and the recomputed state trajectory live in the worker
        // arena, only the per-head adjoints are returned.
        let q_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &tape.qn } else { &tape.q };
        let k_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &tape.kn } else { &tape.k };
        let width = l * dh;
        let adjoints: Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> =
            ctx.exec.map_scratch(b * h, |i, sc| {
                let (bi, hh) = (i / h, i % h);
                let mut qh = sc.take(width);
                gather_head_into(q_src, bi, hh, l, inner, dh, &mut qh);
                let mut kh = sc.take(width);
                gather_head_into(k_src, bi, hh, l, inner, dh, &mut kh);
                let mut vh = sc.take(width);
                gather_head_into(&tape.v, bi, hh, l, inner, dh, &mut vh);
                let mut doh = sc.take(width);
                gather_head_into(&do_raw, bi, hh, l, inner, dh, &mut doh);
                let mut al = sc.take(l);
                for (t, a) in al.iter_mut().enumerate() {
                    *a = tape.alpha[(bi * l + t) * h + hh];
                }
                let mut dqh = vec![0.0f32; width];
                let mut dkh = vec![0.0f32; width];
                let mut dvh = vec![0.0f32; width];
                let mut dal = vec![0.0f32; l];
                delta_bptt_into(
                    &qh, &kh, &vh, &al, &doh, dh, dh, &mut dqh, &mut dkh, &mut dvh, &mut dal, sc,
                );
                sc.put(qh);
                sc.put(kh);
                sc.put(vh);
                sc.put(doh);
                sc.put(al);
                (dqh, dkh, dvh, dal)
            });
        let mut dq_post = vec![0.0f32; rows * inner];
        let mut dk_post = vec![0.0f32; rows * inner];
        let mut dv_post = vec![0.0f32; rows * inner];
        let mut dalpha = vec![0.0f32; rows * h];
        for (i, (dqh, dkh, dvh, dal)) in adjoints.iter().enumerate() {
            let (bi, hh) = (i / h, i % h);
            scatter_head_add(&mut dq_post, dqh, bi, hh, l, inner, dh);
            scatter_head_add(&mut dk_post, dkh, bi, hh, l, inner, dh);
            scatter_head_add(&mut dv_post, dvh, bi, hh, l, inner, dh);
            for t in 0..l {
                dalpha[(bi * l + t) * h + hh] += dal[t];
            }
        }

        // Gate backward: alpha -> (beta logits, adecay, lambda -> k).
        let adecay = p.tensor(self.adecay).data().to_vec();
        let mut db_logits = vec![0.0f32; rows * h];
        {
            let dadecay = grads[self.adecay].data_mut();
            for r in 0..rows {
                for hh in 0..h {
                    let da = dalpha[r * h + hh];
                    let z = tape.b_logits[r * h + hh];
                    let dbeta_eff = match cfg.mixer {
                        Mixer::DeltaNet => da,
                        _ => {
                            let lam = tape.lambda[r * h + hh];
                            let be = tape.beta_eff[r * h + hh];
                            let (_a, da_db, da_dl) = alpha_efla_grad(be, lam);
                            let dlam = da * da_dl;
                            if dlam != 0.0 {
                                let base = r * inner + hh * dh;
                                for j in 0..dh {
                                    dk_post[base + j] += dlam * 2.0 * tape.k[base + j];
                                }
                            }
                            da * da_db
                        }
                    };
                    match cfg.mixer {
                        Mixer::EflaLoose => {
                            db_logits[r * h + hh] = dbeta_eff * ops::sigmoid(z);
                        }
                        Mixer::EflaAdaptive => {
                            let sp = ops::softplus(adecay[hh]);
                            let bsig = ops::sigmoid(z);
                            dadecay[hh] += dbeta_eff * bsig * ops::sigmoid(adecay[hh]);
                            db_logits[r * h + hh] = dbeta_eff * sp * bsig * (1.0 - bsig);
                        }
                        _ => {
                            let bsig = ops::sigmoid(z);
                            db_logits[r * h + hh] = dbeta_eff * bsig * (1.0 - bsig);
                        }
                    }
                }
            }
        }

        let mut dx = vec![0.0f32; rows * d];
        ops::matmul_nt_acc(ctx.exec, &db_logits, p.tensor(self.w_beta).data(), &mut dx, rows, h, d);
        matmul_tn_into(&tape.x, &db_logits, grads[self.w_beta].data_mut(), rows, d, h);

        // DeltaNet: through the q/k L2 normalization.
        let (dq_silu, dk_silu) = if cfg.mixer == Mixer::DeltaNet {
            (
                ops::l2norm_bwd(&tape.q, &tape.q_ss, &dq_post, dh),
                ops::l2norm_bwd(&tape.k, &tape.k_ss, &dk_post, dh),
            )
        } else {
            (dq_post, dk_post)
        };

        // SiLU, conv, projections.
        let dqc = ops::silu_bwd(&tape.qc, &dq_silu);
        let dkc = ops::silu_bwd(&tape.kc, &dk_silu);
        let dvc = ops::silu_bwd(&tape.vc, &dv_post);
        let dqpre = ops::conv_bwd(
            &tape.qpre,
            p.tensor(self.conv_q).data(),
            &dqc,
            b,
            l,
            inner,
            CONV_K,
            grads[self.conv_q].data_mut(),
        );
        let dkpre = ops::conv_bwd(
            &tape.kpre,
            p.tensor(self.conv_k).data(),
            &dkc,
            b,
            l,
            inner,
            CONV_K,
            grads[self.conv_k].data_mut(),
        );
        let dvpre = ops::conv_bwd(
            &tape.vpre,
            p.tensor(self.conv_v).data(),
            &dvc,
            b,
            l,
            inner,
            CONV_K,
            grads[self.conv_v].data_mut(),
        );
        matmul_tn_into(&tape.x, &dqpre, grads[self.wq].data_mut(), rows, d, inner);
        matmul_tn_into(&tape.x, &dkpre, grads[self.wk].data_mut(), rows, d, inner);
        matmul_tn_into(&tape.x, &dvpre, grads[self.wv].data_mut(), rows, d, inner);
        ops::matmul_nt_acc(ctx.exec, &dqpre, p.tensor(self.wq).data(), &mut dx, rows, inner, d);
        ops::matmul_nt_acc(ctx.exec, &dkpre, p.tensor(self.wk).data(), &mut dx, rows, inner, d);
        ops::matmul_nt_acc(ctx.exec, &dvpre, p.tensor(self.wv).data(), &mut dx, rows, inner, d);
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::config::family_config;
    use super::super::super::exec::Executor;
    use super::*;
    use crate::util::rng::Rng;

    fn fd_check_family(family: &str, seed: u64) {
        let cfg = family_config(family).unwrap();
        let params = ParamSet::init(&cfg, 17);
        let exec = Executor::serial();
        let (b, l) = (1usize, 4usize);
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let layer = MixerLayer::new(&params, &cfg, 0);

        let mut rng = Rng::new(seed);
        let rows = b * l;
        let x = rng.normal_vec(rows * cfg.d_model, 0.0, 0.5);
        let w = rng.normal_vec(rows * cfg.d_model, 0.0, 1.0);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = layer.forward(&ctx, x);
            y.iter().zip(w.iter()).map(|(&a, &g)| a as f64 * g as f64).sum()
        };

        let (_, tape) = layer.forward(&ctx, &x);
        let mut grads = params.zeros_like();
        let dx = layer.backward(&ctx, &tape, &w, &mut grads);

        let h = 1e-2f32;
        for idx in (0..x.len()).step_by(29) {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!(
                (dx[idx] as f64 - n).abs() < 3e-2 * (1.0 + n.abs()),
                "{family} dx[{idx}]: {} vs {n}",
                dx[idx]
            );
        }
        for name in ["layer0.wq", "layer0.wk", "layer0.wv", "layer0.wo", "layer0.w_beta"] {
            assert!(grads[params.idx(name)].norm() > 0.0, "{family}: {name} gradient must flow");
        }
    }

    #[test]
    fn backward_matches_finite_differences_efla() {
        fd_check_family("lm_tiny_efla", 31);
    }

    #[test]
    fn backward_matches_finite_differences_deltanet() {
        fd_check_family("lm_tiny_deltanet", 32);
    }

    #[test]
    fn parallel_forward_matches_serial_bitwise() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 9);
        let (b, l) = (cfg.batch, 16usize);
        let mut rng = Rng::new(40);
        let x = rng.normal_vec(b * l * cfg.d_model, 0.0, 1.0);
        let e1 = Executor::serial();
        let e4 = Executor::new(4);
        let layer = MixerLayer::new(&params, &cfg, 0);
        let ctx1 = Ctx { cfg: &cfg, params: &params, exec: &e1, b, l };
        let ctx4 = Ctx { cfg: &cfg, params: &params, exec: &e4, b, l };
        let (y1, _) = layer.forward(&ctx1, &x);
        let (y4, _) = layer.forward(&ctx4, &x);
        assert_eq!(y1, y4, "mixer forward must be thread-count invariant");
    }

    #[test]
    fn forward_reuses_executor_arena_without_numeric_drift() {
        // Two identical forwards through the same executor (dirty arena on
        // the second pass) must agree bit for bit.
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 10);
        let (b, l) = (1usize, 12usize);
        let mut rng = Rng::new(41);
        let x = rng.normal_vec(b * l * cfg.d_model, 0.0, 1.0);
        let exec = Executor::new(2);
        let layer = MixerLayer::new(&params, &cfg, 0);
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let (y1, _) = layer.forward(&ctx, &x);
        let (y2, _) = layer.forward(&ctx, &x);
        assert_eq!(y1, y2, "dirty arena must not leak into results");
    }
}
