//! Token-mixer layer: q/k/v projections, depthwise causal conv + SiLU,
//! per-head scalar gate (EFLA exact / DeltaNet Euler variants), and the
//! chunkwise delta-rule kernel.
//!
//! The kernel work is independent per (batch, head) pair — forward
//! ([`crate::attention::chunkwise_delta_alpha`]), backward
//! ([`crate::attention::delta_bptt`], recomputed per pair so peak memory is
//! one head's state trajectory) and the one-token decode update all fan out
//! through [`Executor::map`](super::super::exec::Executor::map); results
//! are scattered back in task order so numerics are thread-count invariant.

use crate::attention::backward::delta_bptt;
use crate::attention::chunkwise::chunkwise_delta_alpha;
use crate::attention::gates::{alpha_efla, alpha_efla_grad, EPS_LAMBDA};
use crate::attention::sequential::delta_step_alpha;
use crate::tensor::{matmul_tn_into, Tensor};

use super::super::config::{CpuModelCfg, Mixer, CONV_K};
use super::super::ops;
use super::super::params::ParamSet;
use super::{Ctx, Layer, RmsNorm};

pub struct MixerLayer {
    wq: usize,
    wk: usize,
    wv: usize,
    conv_q: usize,
    conv_k: usize,
    conv_v: usize,
    w_beta: usize,
    adecay: usize,
    norm_out: RmsNorm,
    wo: usize,
}

/// Saved activations of one mixer forward.
pub struct MixerTape {
    /// The (normalized) layer input.
    x: Vec<f32>,
    qpre: Vec<f32>,
    kpre: Vec<f32>,
    vpre: Vec<f32>,
    qc: Vec<f32>,
    kc: Vec<f32>,
    vc: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// DeltaNet only: normalized q/k and per-head-row sum-squares.
    qn: Vec<f32>,
    kn: Vec<f32>,
    q_ss: Vec<f32>,
    k_ss: Vec<f32>,
    b_logits: Vec<f32>,
    beta_eff: Vec<f32>,
    alpha: Vec<f32>,
    lambda: Vec<f32>,
    norm_out: <RmsNorm as Layer>::Tape,
    o_norm: Vec<f32>,
}

/// Gather one (batch, head) pair's (L, Dh) rows out of a (B*L, inner) buffer.
fn gather_head(src: &[f32], bi: usize, hh: usize, l: usize, inner: usize, dh: usize) -> Tensor {
    let mut out = vec![0.0f32; l * dh];
    for t in 0..l {
        let base = (bi * l + t) * inner + hh * dh;
        out[t * dh..(t + 1) * dh].copy_from_slice(&src[base..base + dh]);
    }
    Tensor::from_vec(&[l, dh], out)
}

/// Scatter-add the (L, Dh) head rows back into a (B*L, inner) buffer.
fn scatter_head_add(
    dst: &mut [f32],
    src: &[f32],
    bi: usize,
    hh: usize,
    l: usize,
    inner: usize,
    dh: usize,
) {
    for t in 0..l {
        let base = (bi * l + t) * inner + hh * dh;
        for j in 0..dh {
            dst[base + j] += src[t * dh + j];
        }
    }
}

impl MixerLayer {
    pub fn new(params: &ParamSet, cfg: &CpuModelCfg, li: usize) -> MixerLayer {
        let p = |n: &str| format!("layer{li}.{n}");
        MixerLayer {
            wq: params.idx(&p("wq")),
            wk: params.idx(&p("wk")),
            wv: params.idx(&p("wv")),
            conv_q: params.idx(&p("conv_q")),
            conv_k: params.idx(&p("conv_k")),
            conv_v: params.idx(&p("conv_v")),
            w_beta: params.idx(&p("w_beta")),
            adecay: params.idx(&p("adecay")),
            norm_out: RmsNorm::new(params, &p("norm_out"), cfg.head_dim),
            wo: params.idx(&p("wo")),
        }
    }

    /// Resolve the variant-specific effective step size beta for one token.
    fn beta_eff(cfg: &CpuModelCfg, adecay: &[f32], z: f32, hh: usize) -> f32 {
        let mut bv = if cfg.mixer == Mixer::EflaLoose {
            ops::softplus(z)
        } else {
            ops::sigmoid(z)
        };
        if cfg.mixer == Mixer::EflaAdaptive {
            bv *= ops::softplus(adecay[hh]);
        }
        bv
    }

    /// One-token decode: `x` is the normalized (B, d) input; the rolling
    /// conv caches (B, K-1, inner) and the per-head state (B, H, Dh, Dh)
    /// are updated in place. Returns the mixed (B, d) output.
    pub fn decode_step(
        &self,
        ctx: &Ctx,
        x: &[f32],
        cache_q: &mut [f32],
        cache_k: &mut [f32],
        cache_v: &mut [f32],
        s: &mut [f32],
    ) -> Vec<f32> {
        let cfg = ctx.cfg;
        let (d, inner, h, dh) = (cfg.d_model, cfg.inner(), cfg.n_heads, cfg.head_dim);
        let b = ctx.b;
        let p = ctx.params;

        let qt = ops::matmul(ctx.exec, x, p.tensor(self.wq).data(), b, d, inner);
        let kt = ops::matmul(ctx.exec, x, p.tensor(self.wk).data(), b, d, inner);
        let vt = ops::matmul(ctx.exec, x, p.tensor(self.wv).data(), b, d, inner);
        let qc = ops::conv_step(&qt, cache_q, p.tensor(self.conv_q).data(), b, inner, CONV_K);
        let kc = ops::conv_step(&kt, cache_k, p.tensor(self.conv_k).data(), b, inner, CONV_K);
        let vc = ops::conv_step(&vt, cache_v, p.tensor(self.conv_v).data(), b, inner, CONV_K);
        let q = ops::silu_fwd(&qc);
        let k = ops::silu_fwd(&kc);
        let v = ops::silu_fwd(&vc);

        let (q_use, k_use) = if cfg.mixer == Mixer::DeltaNet {
            (ops::l2norm_fwd(&q, dh).0, ops::l2norm_fwd(&k, dh).0)
        } else {
            (q.clone(), k.clone())
        };

        let b_logits = ops::matmul(ctx.exec, x, p.tensor(self.w_beta).data(), b, d, h);
        let adecay = p.tensor(self.adecay).data();

        // One state update per (batch, head); the slices are disjoint, so
        // tasks return (o, S') and the scatter below writes them in order.
        // Per-task work is ~3*dh^2 flops — only fan out when the total
        // clears the spawn cost (results are identical either way).
        let tasks = b * h;
        let fan_out = tasks * dh * dh >= 1 << 20;
        let s_ref: &[f32] = s;
        let step = |i: usize| {
            let (bi, hh) = (i / h, i % h);
            let bv = Self::beta_eff(cfg, adecay, b_logits[bi * h + hh], hh);
            let base = bi * inner + hh * dh;
            let krow = &k_use[base..base + dh];
            let alpha = if cfg.mixer == Mixer::DeltaNet {
                bv
            } else {
                let lam: f32 = krow.iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
                alpha_efla(bv, lam)
            };
            let srange = (bi * h + hh) * dh * dh..(bi * h + hh + 1) * dh * dh;
            let mut s_new = s_ref[srange].to_vec();
            let mut o = vec![0.0f32; dh];
            let mut stk = vec![0.0f32; dh];
            delta_step_alpha(
                &mut s_new,
                &q_use[base..base + dh],
                krow,
                &v[base..base + dh],
                alpha,
                &mut o,
                &mut stk,
                dh,
                dh,
            );
            (o, s_new)
        };
        let updates: Vec<(Vec<f32>, Vec<f32>)> = if fan_out {
            ctx.exec.map(tasks, step)
        } else {
            (0..tasks).map(step).collect()
        };
        let mut o_all = vec![0.0f32; b * inner];
        for (i, (oh, s_new)) in updates.into_iter().enumerate() {
            let (bi, hh) = (i / h, i % h);
            let base = bi * inner + hh * dh;
            o_all[base..base + dh].copy_from_slice(&oh);
            s[(bi * h + hh) * dh * dh..(bi * h + hh + 1) * dh * dh].copy_from_slice(&s_new);
        }

        let o_norm = self.norm_out.infer(ctx, &o_all);
        ops::matmul(ctx.exec, &o_norm, p.tensor(self.wo).data(), b, inner, d)
    }
}

impl Layer for MixerLayer {
    type Tape = MixerTape;

    fn forward(&self, ctx: &Ctx, x: &[f32]) -> (Vec<f32>, MixerTape) {
        let cfg = ctx.cfg;
        let (d, inner, h, dh) = (cfg.d_model, cfg.inner(), cfg.n_heads, cfg.head_dim);
        let (b, l, rows) = (ctx.b, ctx.l, ctx.rows());
        let p = ctx.params;

        let qpre = ops::matmul(ctx.exec, x, p.tensor(self.wq).data(), rows, d, inner);
        let kpre = ops::matmul(ctx.exec, x, p.tensor(self.wk).data(), rows, d, inner);
        let vpre = ops::matmul(ctx.exec, x, p.tensor(self.wv).data(), rows, d, inner);
        let qc = ops::conv_fwd(&qpre, p.tensor(self.conv_q).data(), b, l, inner, CONV_K);
        let kc = ops::conv_fwd(&kpre, p.tensor(self.conv_k).data(), b, l, inner, CONV_K);
        let vc = ops::conv_fwd(&vpre, p.tensor(self.conv_v).data(), b, l, inner, CONV_K);
        let q = ops::silu_fwd(&qc);
        let k = ops::silu_fwd(&kc);
        let v = ops::silu_fwd(&vc);

        // DeltaNet normalizes q/k per head row; (rows, inner) is (rows*h, dh).
        let (qn, q_ss, kn, k_ss) = if cfg.mixer == Mixer::DeltaNet {
            let (qn, q_ss) = ops::l2norm_fwd(&q, dh);
            let (kn, k_ss) = ops::l2norm_fwd(&k, dh);
            (qn, q_ss, kn, k_ss)
        } else {
            (Vec::new(), Vec::new(), Vec::new(), Vec::new())
        };

        // Per-token scalar gate.
        let b_logits = ops::matmul(ctx.exec, x, p.tensor(self.w_beta).data(), rows, d, h);
        let adecay = p.tensor(self.adecay).data();
        let mut beta_eff = vec![0.0f32; rows * h];
        for r in 0..rows {
            for hh in 0..h {
                beta_eff[r * h + hh] = Self::beta_eff(cfg, adecay, b_logits[r * h + hh], hh);
            }
        }
        let (lambda, alpha) = if cfg.mixer == Mixer::DeltaNet {
            (Vec::new(), beta_eff.clone())
        } else {
            let mut lambda = vec![0.0f32; rows * h];
            let mut alpha = vec![0.0f32; rows * h];
            for r in 0..rows {
                for hh in 0..h {
                    let krow = &k[r * inner + hh * dh..r * inner + (hh + 1) * dh];
                    let lam: f32 = krow.iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
                    lambda[r * h + hh] = lam;
                    alpha[r * h + hh] = alpha_efla(beta_eff[r * h + hh], lam);
                }
            }
            (lambda, alpha)
        };

        // Chunkwise delta attention, one task per (batch, head).
        let q_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &qn } else { &q };
        let k_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &kn } else { &k };
        let heads: Vec<Tensor> = ctx.exec.map(b * h, |i| {
            let (bi, hh) = (i / h, i % h);
            let qh = gather_head(q_src, bi, hh, l, inner, dh);
            let kh = gather_head(k_src, bi, hh, l, inner, dh);
            let vh = gather_head(&v, bi, hh, l, inner, dh);
            let al: Vec<f32> = (0..l).map(|t| alpha[(bi * l + t) * h + hh]).collect();
            let (oh, _s) = chunkwise_delta_alpha(&qh, &kh, &vh, &al, cfg.chunk);
            oh
        });
        let mut o_raw = vec![0.0f32; rows * inner];
        for (i, oh) in heads.iter().enumerate() {
            scatter_head_add(&mut o_raw, oh.data(), i / h, i % h, l, inner, dh);
        }

        // Per-head output norm, merge, project.
        let (o_norm, t_norm_out) = self.norm_out.forward(ctx, &o_raw);
        let y = ops::matmul(ctx.exec, &o_norm, p.tensor(self.wo).data(), rows, inner, d);

        (
            y,
            MixerTape {
                x: x.to_vec(),
                qpre,
                kpre,
                vpre,
                qc,
                kc,
                vc,
                q,
                k,
                v,
                qn,
                kn,
                q_ss,
                k_ss,
                b_logits,
                beta_eff,
                alpha,
                lambda,
                norm_out: t_norm_out,
                o_norm,
            },
        )
    }

    fn backward(
        &self,
        ctx: &Ctx,
        tape: &MixerTape,
        dy: &[f32],
        grads: &mut [Tensor],
    ) -> Vec<f32> {
        let cfg = ctx.cfg;
        let (d, inner, h, dh) = (cfg.d_model, cfg.inner(), cfg.n_heads, cfg.head_dim);
        let (b, l, rows) = (ctx.b, ctx.l, ctx.rows());
        let p = ctx.params;

        // Output projection + per-head norm.
        matmul_tn_into(&tape.o_norm, dy, grads[self.wo].data_mut(), rows, inner, d);
        let mut do_norm = vec![0.0f32; rows * inner];
        ops::matmul_nt_acc(ctx.exec, dy, p.tensor(self.wo).data(), &mut do_norm, rows, d, inner);
        let do_raw = self.norm_out.backward(ctx, &tape.norm_out, &do_norm, grads);

        // BPTT through the delta recurrence, one task per (batch, head).
        let q_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &tape.qn } else { &tape.q };
        let k_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &tape.kn } else { &tape.k };
        let adjoints: Vec<(Tensor, Tensor, Tensor, Vec<f32>)> = ctx.exec.map(b * h, |i| {
            let (bi, hh) = (i / h, i % h);
            let qh = gather_head(q_src, bi, hh, l, inner, dh);
            let kh = gather_head(k_src, bi, hh, l, inner, dh);
            let vh = gather_head(&tape.v, bi, hh, l, inner, dh);
            let doh = gather_head(&do_raw, bi, hh, l, inner, dh);
            let al: Vec<f32> = (0..l).map(|t| tape.alpha[(bi * l + t) * h + hh]).collect();
            delta_bptt(&qh, &kh, &vh, &al, &doh)
        });
        let mut dq_post = vec![0.0f32; rows * inner];
        let mut dk_post = vec![0.0f32; rows * inner];
        let mut dv_post = vec![0.0f32; rows * inner];
        let mut dalpha = vec![0.0f32; rows * h];
        for (i, (dqh, dkh, dvh, dal)) in adjoints.iter().enumerate() {
            let (bi, hh) = (i / h, i % h);
            scatter_head_add(&mut dq_post, dqh.data(), bi, hh, l, inner, dh);
            scatter_head_add(&mut dk_post, dkh.data(), bi, hh, l, inner, dh);
            scatter_head_add(&mut dv_post, dvh.data(), bi, hh, l, inner, dh);
            for t in 0..l {
                dalpha[(bi * l + t) * h + hh] += dal[t];
            }
        }

        // Gate backward: alpha -> (beta logits, adecay, lambda -> k).
        let adecay = p.tensor(self.adecay).data().to_vec();
        let mut db_logits = vec![0.0f32; rows * h];
        {
            let dadecay = grads[self.adecay].data_mut();
            for r in 0..rows {
                for hh in 0..h {
                    let da = dalpha[r * h + hh];
                    let z = tape.b_logits[r * h + hh];
                    let dbeta_eff = match cfg.mixer {
                        Mixer::DeltaNet => da,
                        _ => {
                            let lam = tape.lambda[r * h + hh];
                            let be = tape.beta_eff[r * h + hh];
                            let (_a, da_db, da_dl) = alpha_efla_grad(be, lam);
                            let dlam = da * da_dl;
                            if dlam != 0.0 {
                                let base = r * inner + hh * dh;
                                for j in 0..dh {
                                    dk_post[base + j] += dlam * 2.0 * tape.k[base + j];
                                }
                            }
                            da * da_db
                        }
                    };
                    match cfg.mixer {
                        Mixer::EflaLoose => {
                            db_logits[r * h + hh] = dbeta_eff * ops::sigmoid(z);
                        }
                        Mixer::EflaAdaptive => {
                            let sp = ops::softplus(adecay[hh]);
                            let bsig = ops::sigmoid(z);
                            dadecay[hh] += dbeta_eff * bsig * ops::sigmoid(adecay[hh]);
                            db_logits[r * h + hh] = dbeta_eff * sp * bsig * (1.0 - bsig);
                        }
                        _ => {
                            let bsig = ops::sigmoid(z);
                            db_logits[r * h + hh] = dbeta_eff * bsig * (1.0 - bsig);
                        }
                    }
                }
            }
        }

        let mut dx = vec![0.0f32; rows * d];
        ops::matmul_nt_acc(ctx.exec, &db_logits, p.tensor(self.w_beta).data(), &mut dx, rows, h, d);
        matmul_tn_into(&tape.x, &db_logits, grads[self.w_beta].data_mut(), rows, d, h);

        // DeltaNet: through the q/k L2 normalization.
        let (dq_silu, dk_silu) = if cfg.mixer == Mixer::DeltaNet {
            (
                ops::l2norm_bwd(&tape.q, &tape.q_ss, &dq_post, dh),
                ops::l2norm_bwd(&tape.k, &tape.k_ss, &dk_post, dh),
            )
        } else {
            (dq_post, dk_post)
        };

        // SiLU, conv, projections.
        let dqc = ops::silu_bwd(&tape.qc, &dq_silu);
        let dkc = ops::silu_bwd(&tape.kc, &dk_silu);
        let dvc = ops::silu_bwd(&tape.vc, &dv_post);
        let dqpre = ops::conv_bwd(
            &tape.qpre,
            p.tensor(self.conv_q).data(),
            &dqc,
            b,
            l,
            inner,
            CONV_K,
            grads[self.conv_q].data_mut(),
        );
        let dkpre = ops::conv_bwd(
            &tape.kpre,
            p.tensor(self.conv_k).data(),
            &dkc,
            b,
            l,
            inner,
            CONV_K,
            grads[self.conv_k].data_mut(),
        );
        let dvpre = ops::conv_bwd(
            &tape.vpre,
            p.tensor(self.conv_v).data(),
            &dvc,
            b,
            l,
            inner,
            CONV_K,
            grads[self.conv_v].data_mut(),
        );
        matmul_tn_into(&tape.x, &dqpre, grads[self.wq].data_mut(), rows, d, inner);
        matmul_tn_into(&tape.x, &dkpre, grads[self.wk].data_mut(), rows, d, inner);
        matmul_tn_into(&tape.x, &dvpre, grads[self.wv].data_mut(), rows, d, inner);
        ops::matmul_nt_acc(ctx.exec, &dqpre, p.tensor(self.wq).data(), &mut dx, rows, inner, d);
        ops::matmul_nt_acc(ctx.exec, &dkpre, p.tensor(self.wk).data(), &mut dx, rows, inner, d);
        ops::matmul_nt_acc(ctx.exec, &dvpre, p.tensor(self.wv).data(), &mut dx, rows, inner, d);
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::config::family_config;
    use super::super::super::exec::Executor;
    use super::*;
    use crate::util::rng::Rng;

    fn fd_check_family(family: &str, seed: u64) {
        let cfg = family_config(family).unwrap();
        let params = ParamSet::init(&cfg, 17);
        let exec = Executor::serial();
        let (b, l) = (1usize, 4usize);
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let layer = MixerLayer::new(&params, &cfg, 0);

        let mut rng = Rng::new(seed);
        let rows = b * l;
        let x = rng.normal_vec(rows * cfg.d_model, 0.0, 0.5);
        let w = rng.normal_vec(rows * cfg.d_model, 0.0, 1.0);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = layer.forward(&ctx, x);
            y.iter().zip(w.iter()).map(|(&a, &g)| a as f64 * g as f64).sum()
        };

        let (_, tape) = layer.forward(&ctx, &x);
        let mut grads = params.zeros_like();
        let dx = layer.backward(&ctx, &tape, &w, &mut grads);

        let h = 1e-2f32;
        for idx in (0..x.len()).step_by(29) {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!(
                (dx[idx] as f64 - n).abs() < 3e-2 * (1.0 + n.abs()),
                "{family} dx[{idx}]: {} vs {n}",
                dx[idx]
            );
        }
        for name in ["layer0.wq", "layer0.wk", "layer0.wv", "layer0.wo", "layer0.w_beta"] {
            assert!(grads[params.idx(name)].norm() > 0.0, "{family}: {name} gradient must flow");
        }
    }

    #[test]
    fn backward_matches_finite_differences_efla() {
        fd_check_family("lm_tiny_efla", 31);
    }

    #[test]
    fn backward_matches_finite_differences_deltanet() {
        fd_check_family("lm_tiny_deltanet", 32);
    }

    #[test]
    fn parallel_forward_matches_serial_bitwise() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 9);
        let (b, l) = (cfg.batch, 16usize);
        let mut rng = Rng::new(40);
        let x = rng.normal_vec(b * l * cfg.d_model, 0.0, 1.0);
        let e1 = Executor::serial();
        let e4 = Executor::new(4);
        let layer = MixerLayer::new(&params, &cfg, 0);
        let ctx1 = Ctx { cfg: &cfg, params: &params, exec: &e1, b, l };
        let ctx4 = Ctx { cfg: &cfg, params: &params, exec: &e4, b, l };
        let (y1, _) = layer.forward(&ctx1, &x);
        let (y4, _) = layer.forward(&ctx4, &x);
        assert_eq!(y1, y4, "mixer forward must be thread-count invariant");
    }
}
