//! Output heads: tied-softmax LM head (masked token-level cross-entropy)
//! and the pooled linear classifier head (example-level cross-entropy).
//! Each pairs a stats-producing forward with a gradient-producing backward.

use anyhow::{bail, Result};

use crate::tensor::{matmul_nt_into, matmul_tn_into, matmul_vec, Tensor};

use super::super::config::{CpuModelCfg, N_CLASSES};
use super::super::ops;
use super::super::params::ParamSet;
use super::{Ctx, Layer, RmsNorm};

/// Loss statistics of one batch (LM: token-level; classifier: example-level).
#[derive(Clone, Copy, Debug)]
pub struct LossStats {
    pub loss_mean: f32,
    pub loss_sum: f32,
    pub count: f32,
    pub correct: f32,
}

/// Final RMSNorm + tied-embedding logits + masked cross-entropy.
pub struct LmHead {
    norm_f: RmsNorm,
    embed: usize,
}

/// Saved: the final-norm tape, the normalized activations, the logits and
/// the per-row log-sum-exp of the scored rows.
pub struct LmHeadTape {
    norm: <RmsNorm as Layer>::Tape,
    xf: Vec<f32>,
    logits: Vec<f32>,
    row_lse: Vec<f32>,
}

impl LmHead {
    pub fn new(params: &ParamSet, cfg: &CpuModelCfg) -> LmHead {
        LmHead { norm_f: RmsNorm::new(params, "norm_f", cfg.d_model), embed: params.idx("embed") }
    }

    /// Decode path: final norm + tied logits, no loss. x: (B, d).
    pub fn logits(&self, ctx: &Ctx, x: &[f32]) -> Vec<f32> {
        let rows = x.len() / ctx.cfg.d_model;
        let mut logits = vec![0.0f32; rows * ctx.cfg.vocab];
        self.logits_into(ctx, x, &mut logits);
        logits
    }

    /// [`logits`](Self::logits) into a caller-provided buffer
    /// (overwritten), the normalized activations drawn from the executor
    /// arena — the allocation-free serving form. The tied-head matmul is
    /// pinned to the slot-batched class (keyed on `cfg.serve_slots()`) so
    /// a slot's logits row is bit-identical whether it comes from a
    /// batched decode step at any occupancy or a single-row prefill call.
    // lint: no-alloc -- normalized activations come from the arena
    pub fn logits_into(&self, ctx: &Ctx, x: &[f32], logits: &mut [f32]) {
        let (d, vocab) = (ctx.cfg.d_model, ctx.cfg.vocab);
        let rows = x.len() / d;
        debug_assert_eq!(logits.len(), rows * vocab);
        let mut xf = ctx.exec.take(x.len());
        self.norm_f.infer_into(ctx, x, &mut xf);
        logits.fill(0.0);
        let embed = ctx.params.tensor(self.embed);
        ops::matmul_nt_acc_serving_batched(
            ctx.exec,
            &xf,
            embed.data(),
            logits,
            rows,
            d,
            vocab,
            ctx.cfg.serve_slots(),
        );
        ctx.exec.put(xf);
    }

    /// Masked CE over targets (-1 = ignored). x: (B*L, d).
    pub fn forward(
        &self,
        ctx: &Ctx,
        x: &[f32],
        targets: &[i32],
    ) -> Result<(LossStats, LmHeadTape)> {
        let (d, vocab, rows) = (ctx.cfg.d_model, ctx.cfg.vocab, ctx.rows());
        let (xf, norm_tape) = self.norm_f.forward(ctx, x);
        let mut logits = vec![0.0f32; rows * vocab];
        ops::matmul_nt_acc(
            ctx.exec,
            &xf,
            ctx.params.tensor(self.embed).data(),
            &mut logits,
            rows,
            d,
            vocab,
        );

        let mut loss_sum = 0f64;
        let mut count = 0f64;
        let mut correct = 0f64;
        let mut row_lse = vec![0.0f32; rows];
        for r in 0..rows {
            let tgt = targets[r];
            if tgt < 0 {
                continue;
            }
            let tgt = tgt as usize;
            if tgt >= vocab {
                bail!("target id {tgt} out of range (vocab {vocab})");
            }
            let lr = &logits[r * vocab..(r + 1) * vocab];
            let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            let mut argmax = 0usize;
            let mut best = f32::NEG_INFINITY;
            for (j, &v) in lr.iter().enumerate() {
                z += (v - mx).exp();
                if v > best {
                    best = v;
                    argmax = j;
                }
            }
            let lse = mx + z.ln();
            row_lse[r] = lse;
            loss_sum += (lse - lr[tgt]) as f64;
            count += 1.0;
            if argmax == tgt {
                correct += 1.0;
            }
        }
        let denom = count.max(1.0);
        let stats = LossStats {
            loss_mean: (loss_sum / denom) as f32,
            loss_sum: loss_sum as f32,
            count: count as f32,
            correct: correct as f32,
        };
        Ok((stats, LmHeadTape { norm: norm_tape, xf, logits, row_lse }))
    }

    /// dL/dx of the mean masked CE; accumulates embed + norm_f gradients.
    pub fn backward(
        &self,
        ctx: &Ctx,
        tape: &LmHeadTape,
        targets: &[i32],
        grads: &mut [Tensor],
    ) -> Vec<f32> {
        let (d, vocab, rows) = (ctx.cfg.d_model, ctx.cfg.vocab, ctx.rows());
        let count = targets.iter().filter(|&&t| t >= 0).count() as f64;
        let inv_count = 1.0 / count.max(1.0) as f32;

        // dlogits = (softmax - onehot) * mask / count; the (rows, vocab)
        // buffer — the largest single gradient temporary in the model —
        // comes from the executor arena.
        let mut dlogits = ctx.exec.take(rows * vocab);
        for r in 0..rows {
            let tgt = targets[r];
            if tgt < 0 {
                continue;
            }
            let lr = &tape.logits[r * vocab..(r + 1) * vocab];
            let dlr = &mut dlogits[r * vocab..(r + 1) * vocab];
            let lse = tape.row_lse[r];
            for j in 0..vocab {
                dlr[j] = (lr[j] - lse).exp() * inv_count;
            }
            dlr[tgt as usize] -= inv_count;
        }

        // Tied head: logits = xf @ embed^T.
        let embed = ctx.params.tensor(self.embed).data();
        let mut dxf = ctx.exec.take(rows * d);
        ops::matmul_acc(ctx.exec, &dlogits, embed, &mut dxf, rows, vocab, d);
        matmul_tn_into(&dlogits, &tape.xf, grads[self.embed].data_mut(), rows, vocab, d);
        ctx.exec.put(dlogits);

        let dx = self.norm_f.backward(ctx, &tape.norm, &dxf, grads);
        ctx.exec.put(dxf);
        dx
    }
}

/// Mean-pool over the sequence + final RMSNorm + linear head + CE.
pub struct ClfHead {
    norm_f: RmsNorm,
    head_w: usize,
    head_b: usize,
}

pub struct ClfHeadTape {
    norm: <RmsNorm as Layer>::Tape,
    xpn: Vec<f32>,
    logits: Vec<f32>,
    row_lse: Vec<f32>,
}

impl ClfHead {
    pub fn new(params: &ParamSet, cfg: &CpuModelCfg) -> ClfHead {
        ClfHead {
            norm_f: RmsNorm::new(params, "norm_f", cfg.d_model),
            head_w: params.idx("head_w"),
            head_b: params.idx("head_b"),
        }
    }

    /// x: (B*L, d) final block activations; labels: (B,).
    pub fn forward(
        &self,
        ctx: &Ctx,
        x: &[f32],
        labels: &[i32],
    ) -> Result<(LossStats, ClfHeadTape)> {
        let (d, b, l) = (ctx.cfg.d_model, ctx.b, ctx.l);
        for &lb in labels {
            if lb < 0 || lb as usize >= N_CLASSES {
                bail!("label {lb} out of range (classes {N_CLASSES})");
            }
        }

        // Mean pool over the sequence.
        let mut xp = vec![0.0f32; b * d];
        let inv_l = 1.0 / l as f32;
        for bi in 0..b {
            let xpr = &mut xp[bi * d..(bi + 1) * d];
            for t in 0..l {
                let xr = &x[(bi * l + t) * d..(bi * l + t + 1) * d];
                for j in 0..d {
                    xpr[j] += xr[j] * inv_l;
                }
            }
        }
        let (xpn, norm_tape) = self.norm_f.forward(ctx, &xp);
        let head_w = ctx.params.tensor(self.head_w).data();
        let head_b = ctx.params.tensor(self.head_b).data();
        let mut logits = matmul_vec(&xpn, head_w, b, d, N_CLASSES);
        for bi in 0..b {
            for j in 0..N_CLASSES {
                logits[bi * N_CLASSES + j] += head_b[j];
            }
        }

        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut row_lse = vec![0.0f32; b];
        for bi in 0..b {
            let lr = &logits[bi * N_CLASSES..(bi + 1) * N_CLASSES];
            let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = lr.iter().map(|&v| (v - mx).exp()).sum();
            let lse = mx + z.ln();
            row_lse[bi] = lse;
            let tgt = labels[bi] as usize;
            loss_sum += (lse - lr[tgt]) as f64;
            // total_cmp: a NaN logit (diverged run) must not panic the
            // eval loop — same total-ordering fallback as tensor::argmax_rows.
            let argmax = lr
                .iter()
                .enumerate()
                .max_by(|a, b_| a.1.total_cmp(b_.1))
                .map(|(j, _)| j)
                .unwrap_or(0);
            if argmax == tgt {
                correct += 1.0;
            }
        }
        let stats = LossStats {
            loss_mean: (loss_sum / b as f64) as f32,
            loss_sum: loss_sum as f32,
            count: b as f32,
            correct: correct as f32,
        };
        Ok((stats, ClfHeadTape { norm: norm_tape, xpn, logits, row_lse }))
    }

    /// dL/dx (un-pooled, (B*L, d)); accumulates head + norm_f gradients.
    pub fn backward(
        &self,
        ctx: &Ctx,
        tape: &ClfHeadTape,
        labels: &[i32],
        grads: &mut [Tensor],
    ) -> Vec<f32> {
        let (d, b, l) = (ctx.cfg.d_model, ctx.b, ctx.l);

        // dlogits = (softmax - onehot) / B (python: nll.mean()).
        let inv_b = 1.0 / b as f32;
        let mut dlogits = vec![0.0f32; b * N_CLASSES];
        for bi in 0..b {
            let lr = &tape.logits[bi * N_CLASSES..(bi + 1) * N_CLASSES];
            let dlr = &mut dlogits[bi * N_CLASSES..(bi + 1) * N_CLASSES];
            for j in 0..N_CLASSES {
                dlr[j] = (lr[j] - tape.row_lse[bi]).exp() * inv_b;
            }
            dlr[labels[bi] as usize] -= inv_b;
        }

        matmul_tn_into(&tape.xpn, &dlogits, grads[self.head_w].data_mut(), b, d, N_CLASSES);
        {
            let dhb = grads[self.head_b].data_mut();
            for bi in 0..b {
                for j in 0..N_CLASSES {
                    dhb[j] += dlogits[bi * N_CLASSES + j];
                }
            }
        }
        let head_w = ctx.params.tensor(self.head_w).data();
        let mut dxpn = vec![0.0f32; b * d];
        matmul_nt_into(&dlogits, head_w, &mut dxpn, b, N_CLASSES, d);
        let dxp = self.norm_f.backward(ctx, &tape.norm, &dxpn, grads);

        // Un-pool: every position gets dxp / L.
        let inv_l = 1.0 / l as f32;
        let mut dx = vec![0.0f32; b * l * d];
        for bi in 0..b {
            let dpr = &dxp[bi * d..(bi + 1) * d];
            for t in 0..l {
                let dxr = &mut dx[(bi * l + t) * d..(bi * l + t + 1) * d];
                for j in 0..d {
                    dxr[j] = dpr[j] * inv_l;
                }
            }
        }
        dx
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::config::family_config;
    use super::super::super::exec::Executor;
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn lm_head_loss_near_ln_vocab_and_fd_gradient() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 21);
        let exec = Executor::serial();
        let (b, l) = (1usize, 8usize);
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let head = LmHead::new(&params, &cfg);
        let mut rng = Rng::new(50);
        let x = rng.normal_vec(b * l * cfg.d_model, 0.0, 1.0);
        let targets: Vec<i32> =
            (0..b * l).map(|_| rng.below(cfg.vocab as u64) as i32).collect();

        let (stats, tape) = head.forward(&ctx, &x, &targets).unwrap();
        let expect = (cfg.vocab as f32).ln();
        assert!(
            (stats.loss_mean - expect).abs() < 2.0,
            "near-uniform CE: {} vs ln(V) {expect}",
            stats.loss_mean
        );

        let mut grads = params.zeros_like();
        let dx = head.backward(&ctx, &tape, &targets, &mut grads);
        let loss = |x: &[f32]| -> f64 {
            head.forward(&ctx, x, &targets).unwrap().0.loss_mean as f64
        };
        let h = 1e-2f32;
        for idx in (0..x.len()).step_by(37) {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!(
                (dx[idx] as f64 - n).abs() < 2e-2 * (1.0 + n.abs()),
                "dx[{idx}]: {} vs {n}",
                dx[idx]
            );
        }
        assert!(grads[params.idx("embed")].norm() > 0.0, "tied embed gradient");
    }

    #[test]
    fn lm_head_masks_ignored_targets() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 22);
        let exec = Executor::serial();
        let (b, l) = (1usize, 4usize);
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let head = LmHead::new(&params, &cfg);
        let x = vec![0.1f32; b * l * cfg.d_model];
        let targets = [3i32, -1, -1, -1];
        let (stats, _) = head.forward(&ctx, &x, &targets).unwrap();
        assert_eq!(stats.count as usize, 1);
        assert!(stats.loss_sum.is_finite());
    }

    #[test]
    fn clf_head_fd_gradient_and_label_validation() {
        let cfg = family_config("clf_efla").unwrap();
        let params = ParamSet::init(&cfg, 23);
        let exec = Executor::serial();
        let (b, l) = (2usize, 4usize); // short sequence is fine for the head
        let ctx = Ctx { cfg: &cfg, params: &params, exec: &exec, b, l };
        let head = ClfHead::new(&params, &cfg);
        let mut rng = Rng::new(51);
        let x = rng.normal_vec(b * l * cfg.d_model, 0.0, 1.0);
        let labels = [3i32, 7];

        assert!(head.forward(&ctx, &x, &[10, 0]).is_err(), "label 10 out of range");

        let (stats, tape) = head.forward(&ctx, &x, &labels).unwrap();
        assert!(stats.loss_mean.is_finite());
        let mut grads = params.zeros_like();
        let dx = head.backward(&ctx, &tape, &labels, &mut grads);
        let loss = |x: &[f32]| -> f64 {
            head.forward(&ctx, x, &labels).unwrap().0.loss_mean as f64
        };
        let h = 1e-2f32;
        for idx in (0..x.len()).step_by(41) {
            let mut xp = x.clone();
            xp[idx] += h;
            let mut xm = x.clone();
            xm[idx] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!(
                (dx[idx] as f64 - n).abs() < 2e-2 * (1.0 + n.abs()),
                "dx[{idx}]: {} vs {n}",
                dx[idx]
            );
        }
        assert!(grads[params.idx("head_w")].norm() > 0.0);
        assert!(grads[params.idx("head_b")].norm() > 0.0);
    }
}
