//! Multi-threaded work-splitter for the CPU backend.
//!
//! The EFLA math is embarrassingly parallel across (batch, head) pairs —
//! the chunkwise kernel, the BPTT recurrence and the decode state update
//! all touch disjoint state per pair — and the big projection matmuls are
//! independent per output row. [`Executor`] fans that work out over plain
//! `std::thread::scope` workers (no dependencies, no persistent pool).
//!
//! **Determinism contract:** every parallel shape offered here produces
//! bit-identical results for any thread count. [`Executor::map`] computes
//! each task independently and the caller scatters/accumulates results in
//! task-index order; [`Executor::par_rows`] splits an output buffer into
//! contiguous row chunks, and each row's computation never depends on
//! which chunk it landed in. No floating-point reduction ever changes its
//! association order with the thread count — that property is pinned by
//! `tests/model_layers.rs`.
//!
//! **Scratch arenas:** the executor owns one [`Scratch`] buffer pool per
//! worker (`arenas[w]`), never shared between concurrently running
//! workers. The `*_scratch` variants hand worker `w` exclusive access to
//! arena `w` for the duration of its chunk, so hot-loop temporaries reuse
//! the same allocations across calls (the pools live as long as the
//! executor — sessions hold one executor for their lifetime). Scratch
//! never influences results: every buffer is re-zeroed when taken. If an
//! arena is unexpectedly busy (nested scratch call), a transient pool is
//! used instead — always correct, just not pooled.
//!
//! The thread count resolves as: explicit knob (`--threads`) >
//! `EFLA_NUM_THREADS` > `std::thread::available_parallelism()`.

use std::sync::{Arc, Mutex};
use std::thread;

use crate::tensor::Scratch;

/// Environment override for the worker-thread count.
pub const ENV_THREADS: &str = "EFLA_NUM_THREADS";

/// Scoped-thread work-splitter with a fixed worker count and one scratch
/// arena per worker.
#[derive(Clone, Debug)]
pub struct Executor {
    threads: usize,
    arenas: Arc<Vec<Mutex<Scratch>>>,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::new(0)
    }
}

impl Executor {
    /// `threads == 0` means auto: `EFLA_NUM_THREADS` if set (and > 0),
    /// else the machine's available parallelism.
    pub fn new(threads: usize) -> Executor {
        let resolved = if threads == 0 { env_or_auto() } else { threads }.max(1);
        let arenas = (0..resolved).map(|_| Mutex::new(Scratch::new())).collect();
        Executor { threads: resolved, arenas: Arc::new(arenas) }
    }

    /// Single-threaded executor (reference numerics / tests).
    pub fn serial() -> Executor {
        Executor::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` with exclusive access to worker `w`'s arena. Falls back to
    /// a transient pool when the arena is already held (nested call) —
    /// results are identical either way, only reuse is lost.
    fn with_arena<R>(&self, w: usize, f: impl FnOnce(&mut Scratch) -> R) -> R {
        match self.arenas[w].try_lock() {
            Ok(mut guard) => f(&mut guard),
            Err(_) => f(&mut Scratch::new()),
        }
    }

    /// Orchestrator-side scratch access (arena 0): for serial hot paths
    /// that want pooled buffers without a parallel shape.
    pub fn scratch<R>(&self, f: impl FnOnce(&mut Scratch) -> R) -> R {
        self.with_arena(0, f)
    }

    /// Check out a zeroed pooled buffer from arena 0 (orchestrator-thread
    /// helper; pair with [`Executor::put`]). Allocates a fresh buffer if
    /// the arena is busy.
    pub fn take(&self, len: usize) -> Vec<f32> {
        match self.arenas[0].try_lock() {
            Ok(mut guard) => guard.take(len),
            Err(_) => vec![0.0; len],
        }
    }

    /// Return a buffer taken with [`Executor::take`] to arena 0's pool.
    pub fn put(&self, buf: Vec<f32>) {
        if let Ok(mut guard) = self.arenas[0].try_lock() {
            guard.put(buf);
        }
    }

    /// Run `f(0), ..., f(n-1)` across the workers and return the results
    /// in task order. Tasks must be independent; each result is computed
    /// exactly as it would be serially, so output is thread-count
    /// invariant.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.map_scratch(n, |i, _| f(i))
    }

    /// [`Executor::map`] with per-worker scratch: worker `w` runs its
    /// whole task stride with exclusive access to arena `w`. Tasks must
    /// not let scratch contents influence results (buffers are zeroed on
    /// take, so this holds by construction).
    pub fn map_scratch<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize, &mut Scratch) -> T + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return self.with_arena(0, |sc| (0..n).map(|i| f(i, sc)).collect());
        }
        let workers = self.threads.min(n);
        let f = &f;
        let run_stride = move |w: usize| {
            self.with_arena(w, |sc| {
                let mut out = Vec::new();
                let mut i = w;
                while i < n {
                    out.push((i, f(i, sc)));
                    i += workers;
                }
                out
            })
        };
        // Fork-join: spawn workers 1.., run stride 0 on the calling thread.
        let per_worker: Vec<Vec<(usize, T)>> = thread::scope(|scope| {
            let handles: Vec<_> =
                (1..workers).map(|w| scope.spawn(move || run_stride(w))).collect();
            let mut all = vec![run_stride(0)];
            all.extend(
                handles
                    .into_iter()
                    .map(|h| h.join().expect("executor worker panicked")),
            );
            all
        });
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for chunk in per_worker {
            for (i, v) in chunk {
                slots[i] = Some(v);
            }
        }
        slots.into_iter().map(|s| s.expect("executor task missing")).collect()
    }

    /// Split `out` (`rows` equal-width rows) into one contiguous chunk per
    /// worker and call `f(row_start, row_end, chunk)` on each. Rows must be
    /// independent (row-parallel matmuls, elementwise maps): per-row
    /// results never depend on the chunking, so output is thread-count
    /// invariant.
    pub fn par_rows<F>(&self, rows: usize, out: &mut [f32], f: F)
    where
        F: Fn(usize, usize, &mut [f32]) + Sync,
    {
        self.par_rows_scratch(rows, out, |r0, r1, chunk, _| f(r0, r1, chunk));
    }

    /// [`Executor::par_rows`] with per-worker scratch.
    pub fn par_rows_scratch<F>(&self, rows: usize, out: &mut [f32], f: F)
    where
        F: Fn(usize, usize, &mut [f32], &mut Scratch) + Sync,
    {
        if rows == 0 {
            return;
        }
        assert_eq!(out.len() % rows, 0, "output length not divisible by rows");
        let width = out.len() / rows;
        let workers = self.threads.min(rows);
        if workers <= 1 {
            self.with_arena(0, |sc| f(0, rows, out, sc));
            return;
        }
        let base = rows / workers;
        let extra = rows % workers;
        let f = &f;
        // Fork-join: spawn all but the last chunk, run the last on the
        // calling thread while the workers run theirs.
        thread::scope(|scope| {
            let mut rest = out;
            let mut row0 = 0usize;
            for w in 0..workers - 1 {
                let nrows = base + usize::from(w < extra);
                // Move the running slice out before splitting so the tail
                // can be reassigned while the chunk is lent to the worker.
                let tmp = rest;
                let (chunk, tail) = tmp.split_at_mut(nrows * width);
                rest = tail;
                let start = row0;
                scope.spawn(move || self.with_arena(w, |sc| f(start, start + nrows, chunk, sc)));
                row0 += nrows;
            }
            self.with_arena(workers - 1, |sc| f(row0, rows, rest, sc));
        });
    }

    /// Two-buffer variant of [`Executor::par_rows_scratch`]: both `a` and
    /// `b` are split by the **same** row partition (widths may differ), so
    /// a task can update paired per-row state — e.g. the decode path's
    /// per-head state matrix alongside its output rows — in place.
    pub fn par_rows2_scratch<F>(&self, rows: usize, a: &mut [f32], b: &mut [f32], f: F)
    where
        F: Fn(usize, usize, &mut [f32], &mut [f32], &mut Scratch) + Sync,
    {
        if rows == 0 {
            return;
        }
        assert_eq!(a.len() % rows, 0, "buffer a length not divisible by rows");
        assert_eq!(b.len() % rows, 0, "buffer b length not divisible by rows");
        let wa = a.len() / rows;
        let wb = b.len() / rows;
        let workers = self.threads.min(rows);
        if workers <= 1 {
            self.with_arena(0, |sc| f(0, rows, a, b, sc));
            return;
        }
        let base = rows / workers;
        let extra = rows % workers;
        let f = &f;
        thread::scope(|scope| {
            let mut rest_a = a;
            let mut rest_b = b;
            let mut row0 = 0usize;
            for w in 0..workers - 1 {
                let nrows = base + usize::from(w < extra);
                let tmp_a = rest_a;
                let (ca, ta) = tmp_a.split_at_mut(nrows * wa);
                rest_a = ta;
                let tmp_b = rest_b;
                let (cb, tb) = tmp_b.split_at_mut(nrows * wb);
                rest_b = tb;
                let start = row0;
                scope.spawn(move || {
                    self.with_arena(w, |sc| f(start, start + nrows, ca, cb, sc))
                });
                row0 += nrows;
            }
            self.with_arena(workers - 1, |sc| f(row0, rows, rest_a, rest_b, sc));
        });
    }
}

fn env_or_auto() -> usize {
    match std::env::var(ENV_THREADS) {
        Ok(v) => v
            .trim()
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .unwrap_or_else(auto_threads),
        Err(_) => auto_threads(),
    }
}

fn auto_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_returns_results_in_task_order() {
        for threads in [1, 2, 4, 7] {
            let ex = Executor::new(threads);
            let out = ex.map(23, |i| i * i);
            let expect: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let ex = Executor::new(4);
        assert!(ex.map(0, |i| i).is_empty());
        assert_eq!(ex.map(1, |i| i + 41), vec![41]);
    }

    #[test]
    fn par_rows_covers_every_row_exactly_once() {
        for threads in [1, 2, 3, 8] {
            let ex = Executor::new(threads);
            let (rows, width) = (11, 5);
            let mut out = vec![0.0f32; rows * width];
            ex.par_rows(rows, &mut out, |r0, r1, chunk| {
                assert_eq!(chunk.len(), (r1 - r0) * width);
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x += (r0 * width + i) as f32;
                }
            });
            let expect: Vec<f32> = (0..rows * width).map(|i| i as f32).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn zero_knob_resolves_to_at_least_one_thread() {
        assert!(Executor::new(0).threads() >= 1);
        assert_eq!(Executor::serial().threads(), 1);
        assert_eq!(Executor::new(3).threads(), 3);
    }

    #[test]
    fn map_scratch_buffers_are_zeroed_and_ordered() {
        for threads in [1, 3, 5] {
            let ex = Executor::new(threads);
            let out = ex.map_scratch(17, |i, sc| {
                let mut buf = sc.take(8);
                assert!(buf.iter().all(|&x| x == 0.0), "dirty scratch buffer");
                buf.iter_mut().for_each(|x| *x = i as f32); // dirty it for the next take
                let tag = buf[0];
                sc.put(buf);
                tag
            });
            let expect: Vec<f32> = (0..17).map(|i| i as f32).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn par_rows2_partitions_both_buffers_consistently() {
        for threads in [1, 2, 4, 9] {
            let ex = Executor::new(threads);
            let (rows, wa, wb) = (13, 3, 7);
            let mut a = vec![0.0f32; rows * wa];
            let mut b = vec![0.0f32; rows * wb];
            ex.par_rows2_scratch(rows, &mut a, &mut b, |r0, r1, ca, cb, sc| {
                assert_eq!(ca.len(), (r1 - r0) * wa);
                assert_eq!(cb.len(), (r1 - r0) * wb);
                let tmp = sc.take(1);
                for (i, x) in ca.iter_mut().enumerate() {
                    *x = (r0 * wa + i) as f32;
                }
                for (i, x) in cb.iter_mut().enumerate() {
                    *x = (r0 * wb + i) as f32 + 0.5;
                }
                sc.put(tmp);
            });
            let ea: Vec<f32> = (0..rows * wa).map(|i| i as f32).collect();
            let eb: Vec<f32> = (0..rows * wb).map(|i| i as f32 + 0.5).collect();
            assert_eq!(a, ea, "threads={threads}");
            assert_eq!(b, eb, "threads={threads}");
        }
    }

    #[test]
    fn take_put_reuses_the_arena_pool() {
        let ex = Executor::new(2);
        let mut buf = ex.take(16);
        assert_eq!(buf, vec![0.0; 16]);
        buf.iter_mut().for_each(|x| *x = 3.0);
        let ptr = buf.as_ptr();
        ex.put(buf);
        let again = ex.take(16);
        assert_eq!(again, vec![0.0; 16]);
        assert_eq!(again.as_ptr(), ptr, "pooled allocation should be reused");
        ex.put(again);
    }
}
