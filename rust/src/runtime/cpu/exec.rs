//! Multi-threaded work-splitter for the CPU backend.
//!
//! The EFLA math is embarrassingly parallel across (batch, head) pairs —
//! the chunkwise kernel, the BPTT recurrence and the decode state update
//! all touch disjoint state per pair — and the big projection matmuls are
//! independent per output row. [`Executor`] fans that work out over plain
//! `std::thread::scope` workers (no dependencies, no persistent pool).
//!
//! **Determinism contract:** every parallel shape offered here produces
//! bit-identical results for any thread count. [`Executor::map`] computes
//! each task independently and the caller scatters/accumulates results in
//! task-index order; [`Executor::par_rows`] splits an output buffer into
//! contiguous row chunks, and each row's computation never depends on
//! which chunk it landed in. No floating-point reduction ever changes its
//! association order with the thread count — that property is pinned by
//! `tests/model_layers.rs`.
//!
//! The thread count resolves as: explicit knob (`--threads`) >
//! `EFLA_NUM_THREADS` > `std::thread::available_parallelism()`.

use std::thread;

/// Environment override for the worker-thread count.
pub const ENV_THREADS: &str = "EFLA_NUM_THREADS";

/// Scoped-thread work-splitter with a fixed worker count.
#[derive(Clone, Debug)]
pub struct Executor {
    threads: usize,
}

impl Default for Executor {
    fn default() -> Self {
        Executor::new(0)
    }
}

impl Executor {
    /// `threads == 0` means auto: `EFLA_NUM_THREADS` if set (and > 0),
    /// else the machine's available parallelism.
    pub fn new(threads: usize) -> Executor {
        let resolved = if threads == 0 { env_or_auto() } else { threads };
        Executor { threads: resolved.max(1) }
    }

    /// Single-threaded executor (reference numerics / tests).
    pub fn serial() -> Executor {
        Executor { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), ..., f(n-1)` across the workers and return the results
    /// in task order. Tasks must be independent; each result is computed
    /// exactly as it would be serially, so output is thread-count
    /// invariant.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if self.threads == 1 || n <= 1 {
            return (0..n).map(f).collect();
        }
        let workers = self.threads.min(n);
        let f = &f;
        let run_stride = move |w: usize| {
            let mut out = Vec::new();
            let mut i = w;
            while i < n {
                out.push((i, f(i)));
                i += workers;
            }
            out
        };
        // Fork-join: spawn workers 1.., run stride 0 on the calling thread.
        let per_worker: Vec<Vec<(usize, T)>> = thread::scope(|scope| {
            let handles: Vec<_> =
                (1..workers).map(|w| scope.spawn(move || run_stride(w))).collect();
            let mut all = vec![run_stride(0)];
            all.extend(
                handles
                    .into_iter()
                    .map(|h| h.join().expect("executor worker panicked")),
            );
            all
        });
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for chunk in per_worker {
            for (i, v) in chunk {
                slots[i] = Some(v);
            }
        }
        slots.into_iter().map(|s| s.expect("executor task missing")).collect()
    }

    /// Split `out` (`rows` equal-width rows) into one contiguous chunk per
    /// worker and call `f(row_start, row_end, chunk)` on each. Rows must be
    /// independent (row-parallel matmuls, elementwise maps): per-row
    /// results never depend on the chunking, so output is thread-count
    /// invariant.
    pub fn par_rows<F>(&self, rows: usize, out: &mut [f32], f: F)
    where
        F: Fn(usize, usize, &mut [f32]) + Sync,
    {
        if rows == 0 {
            return;
        }
        assert_eq!(out.len() % rows, 0, "output length not divisible by rows");
        let width = out.len() / rows;
        let workers = self.threads.min(rows);
        if workers <= 1 {
            f(0, rows, out);
            return;
        }
        let base = rows / workers;
        let extra = rows % workers;
        let f = &f;
        // Fork-join: spawn all but the last chunk, run the last on the
        // calling thread while the workers run theirs.
        thread::scope(|scope| {
            let mut rest = out;
            let mut row0 = 0usize;
            for w in 0..workers - 1 {
                let nrows = base + usize::from(w < extra);
                // Move the running slice out before splitting so the tail
                // can be reassigned while the chunk is lent to the worker.
                let tmp = rest;
                let (chunk, tail) = tmp.split_at_mut(nrows * width);
                rest = tail;
                let start = row0;
                scope.spawn(move || f(start, start + nrows, chunk));
                row0 += nrows;
            }
            f(row0, rows, rest);
        });
    }
}

fn env_or_auto() -> usize {
    match std::env::var(ENV_THREADS) {
        Ok(v) => v
            .trim()
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .unwrap_or_else(auto_threads),
        Err(_) => auto_threads(),
    }
}

fn auto_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_returns_results_in_task_order() {
        for threads in [1, 2, 4, 7] {
            let ex = Executor::new(threads);
            let out = ex.map(23, |i| i * i);
            let expect: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn map_handles_empty_and_single() {
        let ex = Executor::new(4);
        assert!(ex.map(0, |i| i).is_empty());
        assert_eq!(ex.map(1, |i| i + 41), vec![41]);
    }

    #[test]
    fn par_rows_covers_every_row_exactly_once() {
        for threads in [1, 2, 3, 8] {
            let ex = Executor::new(threads);
            let (rows, width) = (11, 5);
            let mut out = vec![0.0f32; rows * width];
            ex.par_rows(rows, &mut out, |r0, r1, chunk| {
                assert_eq!(chunk.len(), (r1 - r0) * width);
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x += (r0 * width + i) as f32;
                }
            });
            let expect: Vec<f32> = (0..rows * width).map(|i| i as f32).collect();
            assert_eq!(out, expect, "threads={threads}");
        }
    }

    #[test]
    fn zero_knob_resolves_to_at_least_one_thread() {
        assert!(Executor::new(0).threads() >= 1);
        assert_eq!(Executor::serial().threads(), 1);
        assert_eq!(Executor::new(3).threads(), 3);
    }
}
