//! CPU-backend model configurations: artifact-family name -> architecture.
//!
//! Mirrors `python/compile/model.py` PRESETS (+ the "mad" preset that
//! `aot.py` registers) and `python/compile/classifier.py` ClassifierConfig,
//! including the batch/seq pairs `aot.py` bakes into each artifact family —
//! so a family trains with the same shapes on either backend.

use anyhow::{anyhow, bail, Result};

/// Short-conv kernel size (paper Appendix A).
pub const CONV_K: usize = 4;

/// Classifier output classes.
pub const N_CLASSES: usize = 10;

/// Token-mixer variant (paper §5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mixer {
    /// Unnormalized keys, exact gate alpha = (1 - e^{-beta*lam}) / lam.
    Efla,
    /// L2-normalized q/k, alpha = beta = sigmoid(w_b x) (Euler gate).
    DeltaNet,
    /// EFLA with learnable per-head decay: beta~ = softplus(a) * beta.
    EflaAdaptive,
    /// EFLA with beta = softplus(w_b x) instead of sigmoid.
    EflaLoose,
}

impl Mixer {
    pub fn parse(s: &str) -> Result<Mixer> {
        Ok(match s {
            "efla" => Mixer::Efla,
            "deltanet" => Mixer::DeltaNet,
            "efla_adaptive" => Mixer::EflaAdaptive,
            "efla_loose" => Mixer::EflaLoose,
            other => bail!("unknown mixer '{other}' (efla|deltanet|efla_adaptive|efla_loose)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Mixer::Efla => "efla",
            Mixer::DeltaNet => "deltanet",
            Mixer::EflaAdaptive => "efla_adaptive",
            Mixer::EflaLoose => "efla_loose",
        }
    }

    pub const ALL: [Mixer; 4] =
        [Mixer::Efla, Mixer::DeltaNet, Mixer::EflaAdaptive, Mixer::EflaLoose];
}

/// Which head the model carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CpuTask {
    /// Next-token LM (also used by the MAD suite).
    Lm,
    /// sMNIST pixel-sequence classifier (Fig. 1 / Fig. 2).
    Classifier,
}

/// Full static architecture + batch shape for one artifact family.
#[derive(Clone, Debug)]
pub struct CpuModelCfg {
    pub task: CpuTask,
    pub mixer: Mixer,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub mlp_mult: usize,
    pub chunk: usize,
    pub norm_eps: f32,
    pub batch: usize,
    pub seq: usize,
    pub decode_batch: usize,
}

impl CpuModelCfg {
    /// q/k/v projection width (n_heads * head_dim).
    pub fn inner(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// SwiGLU hidden width.
    pub fn mlp_width(&self) -> usize {
        self.mlp_mult * self.d_model
    }

    /// Slot capacity that keys the serving kernel class.
    ///
    /// Every serving-path matmul (batched decode over the busy slot set,
    /// single-slot decode, chunked prefill) resolves its kernel class from
    /// this one number, so a row's bits depend only on `(serve_slots, k, n)`
    /// — never on occupancy, arrival order, or thread count. Families with
    /// no recurrent decode graph (`decode_batch == 0`) still get a stable
    /// key of 1.
    pub fn serve_slots(&self) -> usize {
        self.decode_batch.max(1)
    }
}

/// (name, vocab, d_model, n_layers, n_heads, head_dim, chunk, batch, seq,
/// decode_batch) — mirrors model.py PRESETS + aot.py batch shapes.
const LM_PRESETS: [(&str, usize, usize, usize, usize, usize, usize, usize, usize, usize); 6] = [
    ("tiny", 256, 64, 2, 2, 32, 32, 4, 64, 4),
    ("mini", 1024, 192, 4, 3, 64, 32, 8, 128, 4),
    ("small", 2048, 320, 6, 5, 64, 64, 4, 256, 8),
    ("medium", 4096, 512, 8, 8, 64, 64, 4, 256, 4),
    ("100m", 8192, 768, 10, 6, 128, 64, 2, 512, 4),
    ("mad", 64, 128, 2, 2, 64, 32, 16, 128, 4),
];

/// LM preset names the CPU backend knows.
pub fn lm_presets() -> Vec<&'static str> {
    LM_PRESETS.iter().map(|p| p.0).collect()
}

fn lm_config(preset: &str, mixer: Mixer) -> Result<CpuModelCfg> {
    let p = LM_PRESETS
        .iter()
        .find(|p| p.0 == preset)
        .ok_or_else(|| anyhow!("unknown LM preset '{preset}'"))?;
    Ok(CpuModelCfg {
        task: CpuTask::Lm,
        mixer,
        vocab: p.1,
        d_model: p.2,
        n_layers: p.3,
        n_heads: p.4,
        head_dim: p.5,
        mlp_mult: 4,
        chunk: p.6,
        norm_eps: 1e-6,
        batch: p.7,
        seq: p.8,
        decode_batch: p.9,
    })
}

fn clf_config(mixer: Mixer) -> CpuModelCfg {
    CpuModelCfg {
        task: CpuTask::Classifier,
        mixer,
        vocab: N_CLASSES, // head width; the input is embedded linearly
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        head_dim: 32,
        mlp_mult: 4,
        chunk: 56, // 784 = 14 * 56; avoids padding the full sequence
        norm_eps: 1e-6,
        batch: 16,
        seq: 784,
        decode_batch: 0, // no recurrent decode graph for the classifier
    }
}

/// Resolve an artifact family name (`lm_tiny_efla`, `lm_mad_deltanet`,
/// `clf_efla`, ...) to its CPU model configuration.
pub fn family_config(family: &str) -> Result<CpuModelCfg> {
    if let Some(mixer) = family.strip_prefix("clf_") {
        return Ok(clf_config(Mixer::parse(mixer)?));
    }
    if let Some(rest) = family.strip_prefix("lm_") {
        let (preset, mixer) = rest
            .split_once('_')
            .ok_or_else(|| anyhow!("malformed LM family '{family}' (want lm_<preset>_<mixer>)"))?;
        return lm_config(preset, Mixer::parse(mixer)?);
    }
    bail!("unknown family '{family}' (want lm_<preset>_<mixer> or clf_<mixer>)")
}

/// Every family the CPU backend can build (for `efla info`).
pub fn known_families() -> Vec<String> {
    let mut out = Vec::new();
    for p in LM_PRESETS.iter() {
        for m in Mixer::ALL {
            out.push(format!("lm_{}_{}", p.0, m.name()));
        }
    }
    for m in Mixer::ALL {
        out.push(format!("clf_{}", m.name()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_known_families() {
        for f in known_families() {
            let cfg = family_config(&f).unwrap_or_else(|e| panic!("{f}: {e}"));
            assert!(cfg.d_model > 0);
        }
    }

    #[test]
    fn tiny_matches_python_preset() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        assert_eq!(cfg.vocab, 256);
        assert_eq!(cfg.d_model, 64);
        assert_eq!(cfg.n_layers, 2);
        assert_eq!(cfg.n_heads, 2);
        assert_eq!(cfg.head_dim, 32);
        assert_eq!(cfg.batch, 4);
        assert_eq!(cfg.seq, 64);
        assert_eq!(cfg.inner(), 64);
        assert_eq!(cfg.mixer, Mixer::Efla);
    }

    #[test]
    fn underscored_mixer_names_parse() {
        let cfg = family_config("lm_tiny_efla_adaptive").unwrap();
        assert_eq!(cfg.mixer, Mixer::EflaAdaptive);
        let cfg = family_config("lm_mad_efla_loose").unwrap();
        assert_eq!(cfg.mixer, Mixer::EflaLoose);
        assert_eq!(cfg.vocab, 64);
    }

    #[test]
    fn classifier_family() {
        let cfg = family_config("clf_deltanet").unwrap();
        assert_eq!(cfg.task, CpuTask::Classifier);
        assert_eq!(cfg.seq, 784);
        assert_eq!(cfg.batch, 16);
    }

    #[test]
    fn bad_families_rejected() {
        assert!(family_config("lm_tiny").is_err());
        assert!(family_config("lm_huge_efla").is_err());
        assert!(family_config("clf_rwkv").is_err());
        assert!(family_config("diffusion").is_err());
    }
}
