//! Pure-Rust model math: forward, reverse-mode backward, recurrent decode.
//!
//! Architecture mirrors `python/compile/model.py` (LM) and
//! `python/compile/classifier.py` (sMNIST classifier): each block is
//! {RMSNorm -> token mixer -> residual; RMSNorm -> SwiGLU MLP -> residual};
//! the mixer projects q/k/v, applies a depthwise causal conv (K=4) + SiLU,
//! computes a per-head step size beta, and runs the chunkwise delta-rule
//! kernel with the variant-specific gate. The backward pass is hand-written
//! reverse mode; gradients flow through everything including the gate
//! (alpha's beta- and lambda-partials) and the attention recurrence
//! ([`crate::attention::delta_bptt`], recomputed per (batch, head) pair so
//! peak memory is one head's state trajectory).

use anyhow::{bail, Result};

use crate::attention::backward::delta_bptt;
use crate::attention::chunkwise::chunkwise_delta_alpha;
use crate::attention::gates::{alpha_efla, alpha_efla_grad, EPS_LAMBDA};
use crate::attention::sequential::delta_step_alpha;
use crate::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Tensor};

use super::config::{CpuModelCfg, CpuTask, Mixer, CONV_K, N_CLASSES};
use super::params::ParamSet;

/// L2-normalize clamp (mirror of kernels/deltanet.py l2_normalize eps).
const L2_EPS: f32 = 1e-6;

/// Loss statistics of one batch (LM: token-level; classifier: example-level).
#[derive(Clone, Copy, Debug)]
pub struct LossStats {
    pub loss_mean: f32,
    pub loss_sum: f32,
    pub count: f32,
    pub correct: f32,
}

// ----------------------------------------------------------------------
// Elementwise / normalization primitives
// ----------------------------------------------------------------------

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d silu(x) / dx = s(x) * (1 + x * (1 - s(x)))
fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

fn silu_fwd(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| silu(v)).collect()
}

fn silu_bwd(x: &[f32], dy: &[f32]) -> Vec<f32> {
    x.iter().zip(dy.iter()).map(|(&v, &d)| d * silu_grad(v)).collect()
}

/// Row-wise RMSNorm over rows of `width`. Returns (y, inv_rms per row).
fn rms_norm_fwd(x: &[f32], gain: &[f32], width: usize, eps: f32) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(gain.len(), width);
    let rows = x.len() / width;
    let mut y = vec![0.0f32; x.len()];
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / width as f32;
        let iv = 1.0 / (ms + eps).sqrt();
        inv[r] = iv;
        let yr = &mut y[r * width..(r + 1) * width];
        for j in 0..width {
            yr[j] = xr[j] * iv * gain[j];
        }
    }
    (y, inv)
}

/// RMSNorm backward; accumulates into `dgain`, returns dx.
fn rms_norm_bwd(
    x: &[f32],
    gain: &[f32],
    inv: &[f32],
    dy: &[f32],
    width: usize,
    dgain: &mut [f32],
) -> Vec<f32> {
    let rows = x.len() / width;
    let mut dx = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let dyr = &dy[r * width..(r + 1) * width];
        let iv = inv[r];
        let mut dot = 0.0f32; // sum_i dy_i * gain_i * x_i
        for j in 0..width {
            dot += dyr[j] * gain[j] * xr[j];
        }
        let c = iv * iv * iv * dot / width as f32;
        let dxr = &mut dx[r * width..(r + 1) * width];
        for j in 0..width {
            dxr[j] = iv * gain[j] * dyr[j] - c * xr[j];
            dgain[j] += dyr[j] * xr[j] * iv;
        }
    }
    dx
}

/// Row-wise L2 normalize (clamped-square form). Returns (y, sum-square per
/// row) — the clamp decision replays in the backward from the stored ss.
fn l2norm_fwd(x: &[f32], width: usize) -> (Vec<f32>, Vec<f32>) {
    let rows = x.len() / width;
    let mut y = vec![0.0f32; x.len()];
    let mut ss = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let s: f32 = xr.iter().map(|v| v * v).sum();
        ss[r] = s;
        let iv = 1.0 / s.max(L2_EPS * L2_EPS).sqrt();
        let yr = &mut y[r * width..(r + 1) * width];
        for j in 0..width {
            yr[j] = xr[j] * iv;
        }
    }
    (y, ss)
}

fn l2norm_bwd(x: &[f32], ss: &[f32], dy: &[f32], width: usize) -> Vec<f32> {
    let rows = x.len() / width;
    let mut dx = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let dyr = &dy[r * width..(r + 1) * width];
        let s = ss[r];
        let clamped = s <= L2_EPS * L2_EPS;
        let iv = 1.0 / s.max(L2_EPS * L2_EPS).sqrt();
        let dxr = &mut dx[r * width..(r + 1) * width];
        if clamped {
            // r is a constant below the clamp: plain scaling.
            for j in 0..width {
                dxr[j] = iv * dyr[j];
            }
        } else {
            let mut dot = 0.0f32;
            for j in 0..width {
                dot += xr[j] * dyr[j];
            }
            let c = iv * iv * iv * dot;
            for j in 0..width {
                dxr[j] = iv * dyr[j] - c * xr[j];
            }
        }
    }
    dx
}

/// Depthwise causal conv along the sequence: x (B, L, C), w (K, C).
/// out[b, t, c] = sum_j w[j, c] * x[b, t - (K-1) + j, c] (zero-padded).
fn conv_fwd(x: &[f32], w: &[f32], b: usize, l: usize, c: usize, k: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    for bi in 0..b {
        for t in 0..l {
            let yr = &mut y[(bi * l + t) * c..(bi * l + t + 1) * c];
            for j in 0..k {
                let t0 = (t + j).checked_sub(k - 1);
                let t0 = match t0 {
                    Some(v) if v < l => v,
                    _ => continue,
                };
                let wr = &w[j * c..(j + 1) * c];
                let xr = &x[(bi * l + t0) * c..(bi * l + t0 + 1) * c];
                for ch in 0..c {
                    yr[ch] += wr[ch] * xr[ch];
                }
            }
        }
    }
    y
}

/// Conv backward; accumulates into `dw`, returns dx.
fn conv_bwd(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    b: usize,
    l: usize,
    c: usize,
    k: usize,
    dw: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0.0f32; x.len()];
    for bi in 0..b {
        for t in 0..l {
            let dyr = &dy[(bi * l + t) * c..(bi * l + t + 1) * c];
            for j in 0..k {
                let t0 = match (t + j).checked_sub(k - 1) {
                    Some(v) if v < l => v,
                    _ => continue,
                };
                let wr = &w[j * c..(j + 1) * c];
                let xr = &x[(bi * l + t0) * c..(bi * l + t0 + 1) * c];
                let dwr = &mut dw[j * c..(j + 1) * c];
                let dxr = &mut dx[(bi * l + t0) * c..(bi * l + t0 + 1) * c];
                for ch in 0..c {
                    dwr[ch] += dyr[ch] * xr[ch];
                    dxr[ch] += wr[ch] * dyr[ch];
                }
            }
        }
    }
    dx
}

/// Fresh m x n product a @ w.
fn mm(a: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, w, &mut out, m, k, n);
    out
}

// ----------------------------------------------------------------------
// Mixer block (shared between LM and classifier)
// ----------------------------------------------------------------------

/// Activations one block must retain for its backward pass.
struct BlockCache {
    h_attn: Vec<f32>,
    attn_inv: Vec<f32>,
    qpre: Vec<f32>,
    kpre: Vec<f32>,
    vpre: Vec<f32>,
    qc: Vec<f32>,
    kc: Vec<f32>,
    vc: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// DeltaNet only: normalized q/k and per-head-row sum-squares.
    qn: Vec<f32>,
    kn: Vec<f32>,
    q_ss: Vec<f32>,
    k_ss: Vec<f32>,
    b_logits: Vec<f32>,
    beta_eff: Vec<f32>,
    alpha: Vec<f32>,
    lambda: Vec<f32>,
    o_raw: Vec<f32>,
    o_inv: Vec<f32>,
    o_norm: Vec<f32>,
    x_mid: Vec<f32>,
    h_mlp: Vec<f32>,
    mlp_inv: Vec<f32>,
    gpre: Vec<f32>,
    up: Vec<f32>,
}

/// Gather one (batch, head) pair's (L, Dh) rows out of a (B*L, inner) buffer.
fn gather_head(src: &[f32], bi: usize, hh: usize, l: usize, inner: usize, dh: usize) -> Tensor {
    let mut out = vec![0.0f32; l * dh];
    for t in 0..l {
        let base = (bi * l + t) * inner + hh * dh;
        out[t * dh..(t + 1) * dh].copy_from_slice(&src[base..base + dh]);
    }
    Tensor::from_vec(&[l, dh], out)
}

/// Scatter-add the (L, Dh) head rows back into a (B*L, inner) buffer.
fn scatter_head_add(dst: &mut [f32], src: &[f32], bi: usize, hh: usize, l: usize, inner: usize, dh: usize) {
    for t in 0..l {
        let base = (bi * l + t) * inner + hh * dh;
        for j in 0..dh {
            dst[base + j] += src[t * dh + j];
        }
    }
}

fn block_forward(
    cfg: &CpuModelCfg,
    params: &ParamSet,
    li: usize,
    x_in: &[f32],
    b: usize,
    l: usize,
) -> (BlockCache, Vec<f32>) {
    let d = cfg.d_model;
    let inner = cfg.inner();
    let h = cfg.n_heads;
    let dh = cfg.head_dim;
    let rows = b * l;
    let p = |n: &str| format!("layer{li}.{n}");

    let (h_attn, attn_inv) = rms_norm_fwd(x_in, params.get(&p("norm_attn")).data(), d, cfg.norm_eps);

    let qpre = mm(&h_attn, params.get(&p("wq")).data(), rows, d, inner);
    let kpre = mm(&h_attn, params.get(&p("wk")).data(), rows, d, inner);
    let vpre = mm(&h_attn, params.get(&p("wv")).data(), rows, d, inner);
    let qc = conv_fwd(&qpre, params.get(&p("conv_q")).data(), b, l, inner, CONV_K);
    let kc = conv_fwd(&kpre, params.get(&p("conv_k")).data(), b, l, inner, CONV_K);
    let vc = conv_fwd(&vpre, params.get(&p("conv_v")).data(), b, l, inner, CONV_K);
    let q = silu_fwd(&qc);
    let k = silu_fwd(&kc);
    let v = silu_fwd(&vc);

    // DeltaNet normalizes q/k per head row; (rows, inner) is (rows*h, dh).
    let (qn, q_ss, kn, k_ss) = if cfg.mixer == Mixer::DeltaNet {
        let (qn, q_ss) = l2norm_fwd(&q, dh);
        let (kn, k_ss) = l2norm_fwd(&k, dh);
        (qn, q_ss, kn, k_ss)
    } else {
        (Vec::new(), Vec::new(), Vec::new(), Vec::new())
    };

    // Per-token scalar gate.
    let b_logits = mm(&h_attn, params.get(&p("w_beta")).data(), rows, d, h);
    let adecay = params.get(&p("adecay")).data();
    let mut beta_eff = vec![0.0f32; rows * h];
    for r in 0..rows {
        for hh in 0..h {
            let z = b_logits[r * h + hh];
            let mut bv = if cfg.mixer == Mixer::EflaLoose { softplus(z) } else { sigmoid(z) };
            if cfg.mixer == Mixer::EflaAdaptive {
                bv *= softplus(adecay[hh]);
            }
            beta_eff[r * h + hh] = bv;
        }
    }
    let (lambda, alpha) = if cfg.mixer == Mixer::DeltaNet {
        (Vec::new(), beta_eff.clone())
    } else {
        let mut lambda = vec![0.0f32; rows * h];
        let mut alpha = vec![0.0f32; rows * h];
        for r in 0..rows {
            for hh in 0..h {
                let krow = &k[r * inner + hh * dh..r * inner + (hh + 1) * dh];
                let lam: f32 = krow.iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
                lambda[r * h + hh] = lam;
                alpha[r * h + hh] = alpha_efla(beta_eff[r * h + hh], lam);
            }
        }
        (lambda, alpha)
    };

    // Chunkwise delta attention per (batch, head).
    let q_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &qn } else { &q };
    let k_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &kn } else { &k };
    let mut o_raw = vec![0.0f32; rows * inner];
    for bi in 0..b {
        for hh in 0..h {
            let qh = gather_head(q_src, bi, hh, l, inner, dh);
            let kh = gather_head(k_src, bi, hh, l, inner, dh);
            let vh = gather_head(&v, bi, hh, l, inner, dh);
            let al: Vec<f32> = (0..l).map(|t| alpha[(bi * l + t) * h + hh]).collect();
            let (oh, _s) = chunkwise_delta_alpha(&qh, &kh, &vh, &al, cfg.chunk);
            scatter_head_add(&mut o_raw, oh.data(), bi, hh, l, inner, dh);
        }
    }

    // Per-head output norm, merge, project.
    let (o_norm, o_inv) = rms_norm_fwd(&o_raw, params.get(&p("norm_out")).data(), dh, cfg.norm_eps);
    let mixed = mm(&o_norm, params.get(&p("wo")).data(), rows, inner, d);
    let mut x_mid = x_in.to_vec();
    for (xm, mx) in x_mid.iter_mut().zip(mixed.iter()) {
        *xm += mx;
    }

    // SwiGLU MLP.
    let f = cfg.mlp_width();
    let (h_mlp, mlp_inv) = rms_norm_fwd(&x_mid, params.get(&p("norm_mlp")).data(), d, cfg.norm_eps);
    let gpre = mm(&h_mlp, params.get(&p("w_gate")).data(), rows, d, f);
    let up = mm(&h_mlp, params.get(&p("w_up")).data(), rows, d, f);
    let mut gu = silu_fwd(&gpre);
    for (g_, u_) in gu.iter_mut().zip(up.iter()) {
        *g_ *= u_;
    }
    let mlp_out = mm(&gu, params.get(&p("w_down")).data(), rows, f, d);
    let mut x_out = x_mid.clone();
    for (xo, mo) in x_out.iter_mut().zip(mlp_out.iter()) {
        *xo += mo;
    }

    (
        BlockCache {
            h_attn,
            attn_inv,
            qpre,
            kpre,
            vpre,
            qc,
            kc,
            vc,
            q,
            k,
            v,
            qn,
            kn,
            q_ss,
            k_ss,
            b_logits,
            beta_eff,
            alpha,
            lambda,
            o_raw,
            o_inv,
            o_norm,
            x_mid,
            h_mlp,
            mlp_inv,
            gpre,
            up,
        },
        x_out,
    )
}

#[allow(clippy::too_many_arguments)]
fn block_backward(
    cfg: &CpuModelCfg,
    params: &ParamSet,
    li: usize,
    x_in: &[f32],
    cache: &BlockCache,
    dx_out: &[f32],
    b: usize,
    l: usize,
    grads: &mut [Tensor],
) -> Vec<f32> {
    let d = cfg.d_model;
    let inner = cfg.inner();
    let h = cfg.n_heads;
    let dh = cfg.head_dim;
    let f = cfg.mlp_width();
    let rows = b * l;
    let p = |n: &str| format!("layer{li}.{n}");
    let gi = |n: &str| params.idx(&p(n));

    // ---- MLP backward -------------------------------------------------
    // Recompute the cheap intermediates (g = silu(gpre), gu = g * up).
    let g = silu_fwd(&cache.gpre);
    let mut gu = g.clone();
    for (x_, u_) in gu.iter_mut().zip(cache.up.iter()) {
        *x_ *= u_;
    }
    matmul_tn_into(&gu, dx_out, grads[gi("w_down")].data_mut(), rows, f, d);
    let mut dgu = vec![0.0f32; rows * f];
    matmul_nt_into(dx_out, params.get(&p("w_down")).data(), &mut dgu, rows, d, f);
    let mut dgpre = vec![0.0f32; rows * f];
    let mut dup = vec![0.0f32; rows * f];
    for i in 0..rows * f {
        dgpre[i] = dgu[i] * cache.up[i] * silu_grad(cache.gpre[i]);
        dup[i] = dgu[i] * g[i];
    }
    let mut dh_mlp = vec![0.0f32; rows * d];
    matmul_nt_into(&dgpre, params.get(&p("w_gate")).data(), &mut dh_mlp, rows, f, d);
    matmul_nt_into(&dup, params.get(&p("w_up")).data(), &mut dh_mlp, rows, f, d);
    matmul_tn_into(&cache.h_mlp, &dgpre, grads[gi("w_gate")].data_mut(), rows, d, f);
    matmul_tn_into(&cache.h_mlp, &dup, grads[gi("w_up")].data_mut(), rows, d, f);
    let dmid_norm = rms_norm_bwd(
        &cache.x_mid,
        params.get(&p("norm_mlp")).data(),
        &cache.mlp_inv,
        &dh_mlp,
        d,
        grads[gi("norm_mlp")].data_mut(),
    );
    let mut dx_mid = dx_out.to_vec();
    for (a, b_) in dx_mid.iter_mut().zip(dmid_norm.iter()) {
        *a += b_;
    }

    // ---- attention backward -------------------------------------------
    matmul_tn_into(&cache.o_norm, &dx_mid, grads[gi("wo")].data_mut(), rows, inner, d);
    let mut do_norm = vec![0.0f32; rows * inner];
    matmul_nt_into(&dx_mid, params.get(&p("wo")).data(), &mut do_norm, rows, d, inner);
    let do_raw = rms_norm_bwd(
        &cache.o_raw,
        params.get(&p("norm_out")).data(),
        &cache.o_inv,
        &do_norm,
        dh,
        grads[gi("norm_out")].data_mut(),
    );

    // BPTT through the delta recurrence, one (batch, head) at a time.
    let q_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &cache.qn } else { &cache.q };
    let k_src: &[f32] = if cfg.mixer == Mixer::DeltaNet { &cache.kn } else { &cache.k };
    let mut dq_post = vec![0.0f32; rows * inner];
    let mut dk_post = vec![0.0f32; rows * inner];
    let mut dv_post = vec![0.0f32; rows * inner];
    let mut dalpha = vec![0.0f32; rows * h];
    for bi in 0..b {
        for hh in 0..h {
            let qh = gather_head(q_src, bi, hh, l, inner, dh);
            let kh = gather_head(k_src, bi, hh, l, inner, dh);
            let vh = gather_head(&cache.v, bi, hh, l, inner, dh);
            let doh = gather_head(&do_raw, bi, hh, l, inner, dh);
            let al: Vec<f32> = (0..l).map(|t| cache.alpha[(bi * l + t) * h + hh]).collect();
            let (dqh, dkh, dvh, dal) = delta_bptt(&qh, &kh, &vh, &al, &doh);
            scatter_head_add(&mut dq_post, dqh.data(), bi, hh, l, inner, dh);
            scatter_head_add(&mut dk_post, dkh.data(), bi, hh, l, inner, dh);
            scatter_head_add(&mut dv_post, dvh.data(), bi, hh, l, inner, dh);
            for t in 0..l {
                dalpha[(bi * l + t) * h + hh] += dal[t];
            }
        }
    }

    // Gate backward: alpha -> (beta logits, adecay, lambda -> k).
    let adecay = params.get(&p("adecay")).data().to_vec();
    let mut db_logits = vec![0.0f32; rows * h];
    {
        let dadecay = grads[gi("adecay")].data_mut();
        for r in 0..rows {
            for hh in 0..h {
                let da = dalpha[r * h + hh];
                let z = cache.b_logits[r * h + hh];
                let dbeta_eff = match cfg.mixer {
                    Mixer::DeltaNet => da,
                    _ => {
                        let lam = cache.lambda[r * h + hh];
                        let be = cache.beta_eff[r * h + hh];
                        let (_a, da_db, da_dl) = alpha_efla_grad(be, lam);
                        let dlam = da * da_dl;
                        if dlam != 0.0 {
                            let base = r * inner + hh * dh;
                            for j in 0..dh {
                                dk_post[base + j] += dlam * 2.0 * cache.k[base + j];
                            }
                        }
                        da * da_db
                    }
                };
                match cfg.mixer {
                    Mixer::EflaLoose => {
                        db_logits[r * h + hh] = dbeta_eff * sigmoid(z);
                    }
                    Mixer::EflaAdaptive => {
                        let sp = softplus(adecay[hh]);
                        let bsig = sigmoid(z);
                        dadecay[hh] += dbeta_eff * bsig * sigmoid(adecay[hh]);
                        db_logits[r * h + hh] = dbeta_eff * sp * bsig * (1.0 - bsig);
                    }
                    _ => {
                        let bsig = sigmoid(z);
                        db_logits[r * h + hh] = dbeta_eff * bsig * (1.0 - bsig);
                    }
                }
            }
        }
    }

    let mut dh_attn = vec![0.0f32; rows * d];
    matmul_nt_into(&db_logits, params.get(&p("w_beta")).data(), &mut dh_attn, rows, h, d);
    matmul_tn_into(&cache.h_attn, &db_logits, grads[gi("w_beta")].data_mut(), rows, d, h);

    // DeltaNet: through the q/k L2 normalization.
    let (dq_silu, dk_silu) = if cfg.mixer == Mixer::DeltaNet {
        (
            l2norm_bwd(&cache.q, &cache.q_ss, &dq_post, dh),
            l2norm_bwd(&cache.k, &cache.k_ss, &dk_post, dh),
        )
    } else {
        (dq_post, dk_post)
    };

    // SiLU, conv, projections.
    let dqc = silu_bwd(&cache.qc, &dq_silu);
    let dkc = silu_bwd(&cache.kc, &dk_silu);
    let dvc = silu_bwd(&cache.vc, &dv_post);
    let dqpre = conv_bwd(
        &cache.qpre,
        params.get(&p("conv_q")).data(),
        &dqc,
        b,
        l,
        inner,
        CONV_K,
        grads[gi("conv_q")].data_mut(),
    );
    let dkpre = conv_bwd(
        &cache.kpre,
        params.get(&p("conv_k")).data(),
        &dkc,
        b,
        l,
        inner,
        CONV_K,
        grads[gi("conv_k")].data_mut(),
    );
    let dvpre = conv_bwd(
        &cache.vpre,
        params.get(&p("conv_v")).data(),
        &dvc,
        b,
        l,
        inner,
        CONV_K,
        grads[gi("conv_v")].data_mut(),
    );
    matmul_tn_into(&cache.h_attn, &dqpre, grads[gi("wq")].data_mut(), rows, d, inner);
    matmul_tn_into(&cache.h_attn, &dkpre, grads[gi("wk")].data_mut(), rows, d, inner);
    matmul_tn_into(&cache.h_attn, &dvpre, grads[gi("wv")].data_mut(), rows, d, inner);
    matmul_nt_into(&dqpre, params.get(&p("wq")).data(), &mut dh_attn, rows, inner, d);
    matmul_nt_into(&dkpre, params.get(&p("wk")).data(), &mut dh_attn, rows, inner, d);
    matmul_nt_into(&dvpre, params.get(&p("wv")).data(), &mut dh_attn, rows, inner, d);

    let din_norm = rms_norm_bwd(
        x_in,
        params.get(&p("norm_attn")).data(),
        &cache.attn_inv,
        &dh_attn,
        d,
        grads[gi("norm_attn")].data_mut(),
    );
    let mut dx_in = dx_mid;
    for (a, b_) in dx_in.iter_mut().zip(din_norm.iter()) {
        *a += b_;
    }
    dx_in
}

// ----------------------------------------------------------------------
// LM loss (forward + optional backward)
// ----------------------------------------------------------------------

/// Full LM forward: masked cross-entropy stats, plus gradients into
/// `grads` (aligned with the ParamSet) when provided.
///
/// tokens/targets: (B, L) row-major; targets use -1 for ignored positions.
pub fn lm_loss(
    cfg: &CpuModelCfg,
    params: &ParamSet,
    tokens: &[i32],
    targets: &[i32],
    b: usize,
    l: usize,
    grads: Option<&mut [Tensor]>,
) -> Result<LossStats> {
    let d = cfg.d_model;
    let vocab = cfg.vocab;
    let rows = b * l;
    if tokens.len() != rows || targets.len() != rows {
        bail!("lm batch shape mismatch: want {}x{}", b, l);
    }
    for &t in tokens {
        if t < 0 || t as usize >= vocab {
            bail!("token id {t} out of range (vocab {vocab})");
        }
    }
    let embed = params.get("embed");

    // Embedding lookup.
    let mut x = vec![0.0f32; rows * d];
    for r in 0..rows {
        let t = tokens[r] as usize;
        x[r * d..(r + 1) * d].copy_from_slice(&embed.data()[t * d..(t + 1) * d]);
    }

    // Blocks.
    let mut acts: Vec<Vec<f32>> = vec![x];
    let mut caches: Vec<BlockCache> = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let (cache, x_out) = block_forward(cfg, params, li, acts.last().unwrap(), b, l);
        caches.push(cache);
        acts.push(x_out);
    }

    // Final norm + tied logits.
    let x_last = acts.last().unwrap();
    let (xf, f_inv) = rms_norm_fwd(x_last, params.get("norm_f").data(), d, cfg.norm_eps);
    let mut logits = vec![0.0f32; rows * vocab];
    matmul_nt_into(&xf, embed.data(), &mut logits, rows, d, vocab);

    // Masked CE statistics.
    let mut loss_sum = 0f64;
    let mut count = 0f64;
    let mut correct = 0f64;
    let mut row_lse = vec![0.0f32; rows]; // log-sum-exp per scored row
    for r in 0..rows {
        let tgt = targets[r];
        if tgt < 0 {
            continue;
        }
        let tgt = tgt as usize;
        if tgt >= vocab {
            bail!("target id {tgt} out of range (vocab {vocab})");
        }
        let lr = &logits[r * vocab..(r + 1) * vocab];
        let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        let mut argmax = 0usize;
        let mut best = f32::NEG_INFINITY;
        for (j, &v) in lr.iter().enumerate() {
            z += (v - mx).exp();
            if v > best {
                best = v;
                argmax = j;
            }
        }
        let lse = mx + z.ln();
        row_lse[r] = lse;
        loss_sum += (lse - lr[tgt]) as f64;
        count += 1.0;
        if argmax == tgt {
            correct += 1.0;
        }
    }
    let denom = count.max(1.0);
    let stats = LossStats {
        loss_mean: (loss_sum / denom) as f32,
        loss_sum: loss_sum as f32,
        count: count as f32,
        correct: correct as f32,
    };

    let grads: &mut [Tensor] = match grads {
        Some(g) => g,
        None => return Ok(stats),
    };

    // dlogits = (softmax - onehot) * mask / count.
    let inv_count = 1.0 / denom as f32;
    let mut dlogits = vec![0.0f32; rows * vocab];
    for r in 0..rows {
        let tgt = targets[r];
        if tgt < 0 {
            continue;
        }
        let lr = &logits[r * vocab..(r + 1) * vocab];
        let dlr = &mut dlogits[r * vocab..(r + 1) * vocab];
        let lse = row_lse[r];
        for j in 0..vocab {
            dlr[j] = (lr[j] - lse).exp() * inv_count;
        }
        dlr[tgt as usize] -= inv_count;
    }

    // Tied head: logits = xf @ embed^T.
    let i_embed = params.idx("embed");
    let mut dxf = vec![0.0f32; rows * d];
    matmul_into(&dlogits, embed.data(), &mut dxf, rows, vocab, d);
    matmul_tn_into(&dlogits, &xf, grads[i_embed].data_mut(), rows, vocab, d);

    let mut dx = rms_norm_bwd(
        x_last,
        params.get("norm_f").data(),
        &f_inv,
        &dxf,
        d,
        grads[params.idx("norm_f")].data_mut(),
    );
    for li in (0..cfg.n_layers).rev() {
        dx = block_backward(cfg, params, li, &acts[li], &caches[li], &dx, b, l, grads);
    }

    // Embedding lookup backward.
    {
        let dembed = grads[i_embed].data_mut();
        for r in 0..rows {
            let t = tokens[r] as usize;
            let dr = &dx[r * d..(r + 1) * d];
            let er = &mut dembed[t * d..(t + 1) * d];
            for j in 0..d {
                er[j] += dr[j];
            }
        }
    }
    Ok(stats)
}

// ----------------------------------------------------------------------
// Classifier loss (forward + optional backward)
// ----------------------------------------------------------------------

/// sMNIST classifier forward: pixels (B, 784) f32 -> 10-way CE over the
/// mean-pooled sequence; gradients into `grads` when provided.
pub fn clf_loss(
    cfg: &CpuModelCfg,
    params: &ParamSet,
    pixels: &[f32],
    labels: &[i32],
    b: usize,
    grads: Option<&mut [Tensor]>,
) -> Result<LossStats> {
    let d = cfg.d_model;
    let l = cfg.seq;
    let rows = b * l;
    if pixels.len() != rows || labels.len() != b {
        bail!("classifier batch shape mismatch: want {}x{}", b, l);
    }
    for &lb in labels {
        if lb < 0 || lb as usize >= N_CLASSES {
            bail!("label {lb} out of range (classes {N_CLASSES})");
        }
    }

    // Linear pixel embedding: x = px * pix_w + pix_b.
    let pix_w = params.get("pix_w");
    let pix_b = params.get("pix_b");
    let mut x = vec![0.0f32; rows * d];
    for r in 0..rows {
        let px = pixels[r];
        let xr = &mut x[r * d..(r + 1) * d];
        for j in 0..d {
            xr[j] = px * pix_w.data()[j] + pix_b.data()[j];
        }
    }

    let mut acts: Vec<Vec<f32>> = vec![x];
    let mut caches: Vec<BlockCache> = Vec::with_capacity(cfg.n_layers);
    for li in 0..cfg.n_layers {
        let (cache, x_out) = block_forward(cfg, params, li, acts.last().unwrap(), b, l);
        caches.push(cache);
        acts.push(x_out);
    }

    // Mean pool over the sequence, final norm, linear head.
    let x_last = acts.last().unwrap();
    let mut xp = vec![0.0f32; b * d];
    let inv_l = 1.0 / l as f32;
    for bi in 0..b {
        let xpr = &mut xp[bi * d..(bi + 1) * d];
        for t in 0..l {
            let xr = &x_last[(bi * l + t) * d..(bi * l + t + 1) * d];
            for j in 0..d {
                xpr[j] += xr[j] * inv_l;
            }
        }
    }
    let (xpn, p_inv) = rms_norm_fwd(&xp, params.get("norm_f").data(), d, cfg.norm_eps);
    let head_w = params.get("head_w");
    let head_b = params.get("head_b");
    let mut logits = vec![0.0f32; b * N_CLASSES];
    matmul_into(&xpn, head_w.data(), &mut logits, b, d, N_CLASSES);
    for bi in 0..b {
        for j in 0..N_CLASSES {
            logits[bi * N_CLASSES + j] += head_b.data()[j];
        }
    }

    let mut loss_sum = 0f64;
    let mut correct = 0f64;
    let mut row_lse = vec![0.0f32; b];
    for bi in 0..b {
        let lr = &logits[bi * N_CLASSES..(bi + 1) * N_CLASSES];
        let mx = lr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = lr.iter().map(|&v| (v - mx).exp()).sum();
        let lse = mx + z.ln();
        row_lse[bi] = lse;
        let tgt = labels[bi] as usize;
        loss_sum += (lse - lr[tgt]) as f64;
        let argmax = lr
            .iter()
            .enumerate()
            .max_by(|a, b_| a.1.partial_cmp(b_.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0);
        if argmax == tgt {
            correct += 1.0;
        }
    }
    let stats = LossStats {
        loss_mean: (loss_sum / b as f64) as f32,
        loss_sum: loss_sum as f32,
        count: b as f32,
        correct: correct as f32,
    };

    let grads: &mut [Tensor] = match grads {
        Some(g) => g,
        None => return Ok(stats),
    };

    // dlogits = (softmax - onehot) / B  (python: nll.mean()).
    let inv_b = 1.0 / b as f32;
    let mut dlogits = vec![0.0f32; b * N_CLASSES];
    for bi in 0..b {
        let lr = &logits[bi * N_CLASSES..(bi + 1) * N_CLASSES];
        let dlr = &mut dlogits[bi * N_CLASSES..(bi + 1) * N_CLASSES];
        for j in 0..N_CLASSES {
            dlr[j] = (lr[j] - row_lse[bi]).exp() * inv_b;
        }
        dlr[labels[bi] as usize] -= inv_b;
    }

    // Head backward.
    matmul_tn_into(&xpn, &dlogits, grads[params.idx("head_w")].data_mut(), b, d, N_CLASSES);
    {
        let dhb = grads[params.idx("head_b")].data_mut();
        for bi in 0..b {
            for j in 0..N_CLASSES {
                dhb[j] += dlogits[bi * N_CLASSES + j];
            }
        }
    }
    let mut dxpn = vec![0.0f32; b * d];
    matmul_nt_into(&dlogits, head_w.data(), &mut dxpn, b, N_CLASSES, d);
    let dxp = rms_norm_bwd(
        &xp,
        params.get("norm_f").data(),
        &p_inv,
        &dxpn,
        d,
        grads[params.idx("norm_f")].data_mut(),
    );

    // Un-pool: every position gets dxp / L.
    let mut dx = vec![0.0f32; rows * d];
    for bi in 0..b {
        let dpr = &dxp[bi * d..(bi + 1) * d];
        for t in 0..l {
            let dxr = &mut dx[(bi * l + t) * d..(bi * l + t + 1) * d];
            for j in 0..d {
                dxr[j] = dpr[j] * inv_l;
            }
        }
    }
    for li in (0..cfg.n_layers).rev() {
        dx = block_backward(cfg, params, li, &acts[li], &caches[li], &dx, b, l, grads);
    }

    // Pixel embedding backward.
    {
        let dpw = grads[params.idx("pix_w")].data_mut();
        for r in 0..rows {
            let px = pixels[r];
            if px == 0.0 {
                continue;
            }
            let dr = &dx[r * d..(r + 1) * d];
            for j in 0..d {
                dpw[j] += px * dr[j];
            }
        }
    }
    {
        let dpb = grads[params.idx("pix_b")].data_mut();
        for r in 0..rows {
            let dr = &dx[r * d..(r + 1) * d];
            for j in 0..d {
                dpb[j] += dr[j];
            }
        }
    }
    Ok(stats)
}

// ----------------------------------------------------------------------
// Recurrent decode (O(1)-state serving path)
// ----------------------------------------------------------------------

/// Per-layer recurrent state shapes, in order:
/// cache_q, cache_k, cache_v (B, K-1, inner), s (B, H, Dk, Dv).
pub fn decode_state_shapes(cfg: &CpuModelCfg) -> Vec<Vec<usize>> {
    let b = cfg.decode_batch;
    let mut out = Vec::new();
    for _ in 0..cfg.n_layers {
        for _ in 0..3 {
            out.push(vec![b, CONV_K - 1, cfg.inner()]);
        }
        out.push(vec![b, cfg.n_heads, cfg.head_dim, cfg.head_dim]);
    }
    out
}

/// One-token batched decode. `state` borrows the flat f32 tensors in
/// [`decode_state_shapes`] order (the caller keeps them host-resident —
/// no copy on the serving hot path); returns (logits (B, vocab), new state).
pub fn lm_decode(
    cfg: &CpuModelCfg,
    params: &ParamSet,
    state: &[&[f32]],
    tokens: &[i32],
) -> Result<(Tensor, Vec<Vec<f32>>)> {
    if cfg.task != CpuTask::Lm {
        bail!("decode is only available for LM families");
    }
    let b = cfg.decode_batch;
    let d = cfg.d_model;
    let inner = cfg.inner();
    let h = cfg.n_heads;
    let dh = cfg.head_dim;
    let vocab = cfg.vocab;
    if tokens.len() != b {
        bail!("decode expects {b} tokens, got {}", tokens.len());
    }
    if state.len() != 4 * cfg.n_layers {
        bail!("decode expects {} state tensors, got {}", 4 * cfg.n_layers, state.len());
    }
    for &t in tokens {
        if t < 0 || t as usize >= vocab {
            bail!("token id {t} out of range (vocab {vocab})");
        }
    }

    let embed = params.get("embed");
    let mut x = vec![0.0f32; b * d];
    for bi in 0..b {
        let t = tokens[bi] as usize;
        x[bi * d..(bi + 1) * d].copy_from_slice(&embed.data()[t * d..(t + 1) * d]);
    }

    let mut new_state: Vec<Vec<f32>> = Vec::with_capacity(state.len());
    for li in 0..cfg.n_layers {
        let p = |n: &str| format!("layer{li}.{n}");
        let (hx, _) = rms_norm_fwd(&x, params.get(&p("norm_attn")).data(), d, cfg.norm_eps);

        let qt = mm(&hx, params.get(&p("wq")).data(), b, d, inner);
        let kt = mm(&hx, params.get(&p("wk")).data(), b, d, inner);
        let vt = mm(&hx, params.get(&p("wv")).data(), b, d, inner);

        // Single-token causal conv over the (K-1)-deep caches.
        let conv1 = |pre: &[f32], cache: &[f32], w: &[f32]| -> (Vec<f32>, Vec<f32>) {
            let kk = CONV_K;
            let mut out = vec![0.0f32; b * inner];
            let mut nc = vec![0.0f32; b * (kk - 1) * inner];
            for bi in 0..b {
                let crow = &cache[bi * (kk - 1) * inner..(bi + 1) * (kk - 1) * inner];
                let prow = &pre[bi * inner..(bi + 1) * inner];
                let orow = &mut out[bi * inner..(bi + 1) * inner];
                for j in 0..kk - 1 {
                    let wr = &w[j * inner..(j + 1) * inner];
                    let xr = &crow[j * inner..(j + 1) * inner];
                    for c in 0..inner {
                        orow[c] += wr[c] * xr[c];
                    }
                }
                let wlast = &w[(kk - 1) * inner..kk * inner];
                for c in 0..inner {
                    orow[c] += wlast[c] * prow[c];
                }
                // shift cache left, append the fresh pre-conv projection
                let ncrow = &mut nc[bi * (kk - 1) * inner..(bi + 1) * (kk - 1) * inner];
                ncrow[..(kk - 2) * inner].copy_from_slice(&crow[inner..(kk - 1) * inner]);
                ncrow[(kk - 2) * inner..].copy_from_slice(prow);
            }
            (out, nc)
        };
        let si = 4 * li;
        let (qc, ncq) = conv1(&qt, state[si], params.get(&p("conv_q")).data());
        let (kc, nck) = conv1(&kt, state[si + 1], params.get(&p("conv_k")).data());
        let (vc, ncv) = conv1(&vt, state[si + 2], params.get(&p("conv_v")).data());
        let q = silu_fwd(&qc);
        let k = silu_fwd(&kc);
        let v = silu_fwd(&vc);

        let (q_use, k_use) = if cfg.mixer == Mixer::DeltaNet {
            (l2norm_fwd(&q, dh).0, l2norm_fwd(&k, dh).0)
        } else {
            (q.clone(), k.clone())
        };

        let b_logits = mm(&hx, params.get(&p("w_beta")).data(), b, d, h);
        let adecay = params.get(&p("adecay")).data();

        let mut s_new = state[si + 3].to_vec();
        let mut o = vec![0.0f32; b * inner];
        let mut stk = vec![0.0f32; dh]; // shared scratch for the state updates
        for bi in 0..b {
            for hh in 0..h {
                let z = b_logits[bi * h + hh];
                let mut bv =
                    if cfg.mixer == Mixer::EflaLoose { softplus(z) } else { sigmoid(z) };
                if cfg.mixer == Mixer::EflaAdaptive {
                    bv *= softplus(adecay[hh]);
                }
                let base = bi * inner + hh * dh;
                let krow = &k_use[base..base + dh];
                let alpha = if cfg.mixer == Mixer::DeltaNet {
                    bv
                } else {
                    let lam: f32 =
                        krow.iter().map(|x_| x_ * x_).sum::<f32>().max(EPS_LAMBDA);
                    alpha_efla(bv, lam)
                };
                let srange = ((bi * h) + hh) * dh * dh..((bi * h) + hh + 1) * dh * dh;
                delta_step_alpha(
                    &mut s_new[srange],
                    &q_use[base..base + dh],
                    krow,
                    &v[base..base + dh],
                    alpha,
                    &mut o[base..base + dh],
                    &mut stk,
                    dh,
                    dh,
                );
            }
        }

        let (o_norm, _) = rms_norm_fwd(&o, params.get(&p("norm_out")).data(), dh, cfg.norm_eps);
        let mixed = mm(&o_norm, params.get(&p("wo")).data(), b, inner, d);
        for (xv, mv) in x.iter_mut().zip(mixed.iter()) {
            *xv += mv;
        }

        let f = cfg.mlp_width();
        let (hm, _) = rms_norm_fwd(&x, params.get(&p("norm_mlp")).data(), d, cfg.norm_eps);
        let gpre = mm(&hm, params.get(&p("w_gate")).data(), b, d, f);
        let up = mm(&hm, params.get(&p("w_up")).data(), b, d, f);
        let mut gu = silu_fwd(&gpre);
        for (g_, u_) in gu.iter_mut().zip(up.iter()) {
            *g_ *= u_;
        }
        let mlp_out = mm(&gu, params.get(&p("w_down")).data(), b, f, d);
        for (xv, mv) in x.iter_mut().zip(mlp_out.iter()) {
            *xv += mv;
        }

        new_state.push(ncq);
        new_state.push(nck);
        new_state.push(ncv);
        new_state.push(s_new);
    }

    let (xn, _) = rms_norm_fwd(&x, params.get("norm_f").data(), d, cfg.norm_eps);
    let mut logits = vec![0.0f32; b * vocab];
    matmul_nt_into(&xn, embed.data(), &mut logits, b, d, vocab);
    Ok((Tensor::from_vec(&[b, vocab], logits), new_state))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::cpu::config::family_config;
    use crate::util::rng::Rng;

    fn tiny() -> (CpuModelCfg, ParamSet) {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let params = ParamSet::init(&cfg, 42);
        (cfg, params)
    }

    fn lm_batch(cfg: &CpuModelCfg, seed: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let rows = cfg.batch * cfg.seq;
        let toks: Vec<i32> = (0..rows).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
        let tgts: Vec<i32> = (0..rows).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
        (toks, tgts)
    }

    #[test]
    fn lm_forward_loss_near_uniform_at_init() {
        let (cfg, params) = tiny();
        let (toks, tgts) = lm_batch(&cfg, 1);
        let stats =
            lm_loss(&cfg, &params, &toks, &tgts, cfg.batch, cfg.seq, None).unwrap();
        assert!(stats.loss_mean.is_finite());
        // Untrained model on uniform random targets: mean CE near ln(vocab).
        let expect = (cfg.vocab as f32).ln();
        assert!(
            (stats.loss_mean - expect).abs() < 1.5,
            "loss {} vs ln(V) {expect}",
            stats.loss_mean
        );
        assert_eq!(stats.count as usize, cfg.batch * cfg.seq);
    }

    #[test]
    fn lm_gradients_are_finite_and_nonzero() {
        for family in ["lm_tiny_efla", "lm_tiny_deltanet", "lm_tiny_efla_adaptive", "lm_tiny_efla_loose"] {
            let cfg = family_config(family).unwrap();
            let params = ParamSet::init(&cfg, 7);
            let (toks, tgts) = lm_batch(&cfg, 2);
            let mut grads = params.zeros_like();
            lm_loss(&cfg, &params, &toks, &tgts, cfg.batch, cfg.seq, Some(&mut grads))
                .unwrap();
            let mut total = 0f64;
            for (g, name) in grads.iter().zip(params.names()) {
                for &x in g.data() {
                    assert!(x.is_finite(), "{family}: non-finite grad in {name}");
                }
                total += g.data().iter().map(|&x| (x as f64).abs()).sum::<f64>();
            }
            assert!(total > 0.0, "{family}: all-zero gradients");
            // embedding (tied head) must receive gradient
            let ge = &grads[params.idx("embed")];
            assert!(ge.norm() > 0.0, "{family}: embed grad zero");
        }
    }

    #[test]
    fn masked_targets_are_ignored() {
        let (cfg, params) = tiny();
        let (toks, mut tgts) = lm_batch(&cfg, 3);
        for t in tgts.iter_mut().skip(1) {
            *t = -1;
        }
        let stats =
            lm_loss(&cfg, &params, &toks, &tgts, cfg.batch, cfg.seq, None).unwrap();
        assert_eq!(stats.count as usize, 1);
        assert!(stats.loss_sum.is_finite());
    }

    #[test]
    fn out_of_range_tokens_rejected() {
        let (cfg, params) = tiny();
        let (mut toks, tgts) = lm_batch(&cfg, 4);
        toks[0] = cfg.vocab as i32;
        assert!(lm_loss(&cfg, &params, &toks, &tgts, cfg.batch, cfg.seq, None).is_err());
    }

    #[test]
    fn decode_state_advances_and_logits_finite() {
        let (cfg, params) = tiny();
        let shapes = decode_state_shapes(&cfg);
        let zeros: Vec<Vec<f32>> = shapes
            .iter()
            .map(|s| vec![0.0f32; s.iter().product()])
            .collect();
        let state: Vec<&[f32]> = zeros.iter().map(|v| v.as_slice()).collect();
        let tokens = vec![65i32; cfg.decode_batch];
        let (logits1, state1) = lm_decode(&cfg, &params, &state, &tokens).unwrap();
        assert_eq!(logits1.shape(), &[cfg.decode_batch, cfg.vocab]);
        assert!(logits1.data().iter().all(|x| x.is_finite()));
        let state1_refs: Vec<&[f32]> = state1.iter().map(|v| v.as_slice()).collect();
        let (logits2, _) = lm_decode(&cfg, &params, &state1_refs, &tokens).unwrap();
        assert!(
            logits1.max_abs_diff(&logits2) > 1e-7,
            "state must advance between decode steps"
        );
    }
}
