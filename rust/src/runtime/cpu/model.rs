//! Thin orchestrator over the composable layer stack.
//!
//! The actual math lives in [`super::layers`] (one module per block layer,
//! each a paired `forward`/`backward` over a saved-activation tape) built
//! on the primitives in [`super::ops`]; the embarrassingly-parallel
//! (batch, head) kernel work and the large matmuls fan out through the
//! [`super::exec::Executor`]. This module only composes layers into the
//! three entry points the session needs:
//!
//! * [`lm_loss`]  — token embedding -> blocks -> tied-softmax CE head;
//! * [`clf_loss`] — pixel embedding -> blocks -> pooled classifier head;
//! * [`LmStack::decode`] — one-token recurrent decode over in-place
//!   state (the session prebuilds the [`LmStack`] once);
//! * [`LmStack::decode_slots`] — batched decode over the busy subset of
//!   serving slots: gathers their state rows into contiguous scratch and
//!   advances them all in one pass, bit-identical per slot to
//!   [`LmStack::decode`] at any occupancy;
//! * [`LmStack::prefill`] — chunked prompt prefill for one serving slot,
//!   bit-identical to the equivalent chain of decode steps.
//!
//! Architecture mirrors `python/compile/model.py` (LM) and
//! `python/compile/classifier.py` (sMNIST): each block is {RMSNorm ->
//! token mixer -> residual; RMSNorm -> SwiGLU -> residual}.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::config::{CpuModelCfg, CpuTask, CONV_K};
use super::exec::Executor;
use super::layers::{Block, ClfHead, Ctx, Layer, LmHead, PixelEmbedding, TokenEmbedding};
use super::params::ParamSet;

pub use super::layers::LossStats;

/// Build the block stack for a config (cheap: layers hold param indices).
fn blocks(params: &ParamSet, cfg: &CpuModelCfg) -> Vec<Block> {
    (0..cfg.n_layers).map(|li| Block::new(params, cfg, li)).collect()
}

/// Full LM forward: masked cross-entropy stats, plus gradients into
/// `grads` (aligned with the ParamSet) when provided.
///
/// tokens/targets: (B, L) row-major; targets use -1 for ignored positions.
pub fn lm_loss(
    cfg: &CpuModelCfg,
    params: &ParamSet,
    exec: &Executor,
    tokens: &[i32],
    targets: &[i32],
    b: usize,
    l: usize,
    grads: Option<&mut [Tensor]>,
) -> Result<LossStats> {
    let rows = b * l;
    if tokens.len() != rows || targets.len() != rows {
        bail!("lm batch shape mismatch: want {}x{}", b, l);
    }
    // Fail fast on bad targets before the (expensive) forward runs; the
    // head re-checks as defense in depth.
    for &t in targets {
        if t >= cfg.vocab as i32 {
            bail!("target id {t} out of range (vocab {})", cfg.vocab);
        }
    }
    let ctx = Ctx { cfg, params, exec, b, l };
    let embed = TokenEmbedding::new(params);
    let stack = blocks(params, cfg);
    let head = LmHead::new(params, cfg);

    let mut x = embed.forward(&ctx, tokens)?;
    let mut tapes = Vec::with_capacity(stack.len());
    for blk in &stack {
        let (y, tape) = blk.forward(&ctx, &x);
        tapes.push(tape);
        x = y;
    }
    let (stats, head_tape) = head.forward(&ctx, &x, targets)?;

    let grads = match grads {
        Some(g) => g,
        None => return Ok(stats),
    };
    let mut dx = head.backward(&ctx, &head_tape, targets, grads);
    for (blk, tape) in stack.iter().zip(tapes.iter()).rev() {
        dx = blk.backward(&ctx, tape, &dx, grads);
    }
    embed.backward(&ctx, tokens, &dx, grads);
    Ok(stats)
}

/// sMNIST classifier forward: pixels (B, 784) f32 -> 10-way CE over the
/// mean-pooled sequence; gradients into `grads` when provided.
pub fn clf_loss(
    cfg: &CpuModelCfg,
    params: &ParamSet,
    exec: &Executor,
    pixels: &[f32],
    labels: &[i32],
    b: usize,
    grads: Option<&mut [Tensor]>,
) -> Result<LossStats> {
    let l = cfg.seq;
    if pixels.len() != b * l || labels.len() != b {
        bail!("classifier batch shape mismatch: want {}x{}", b, l);
    }
    // Fail fast on bad labels before the (expensive) forward runs; the
    // head re-checks as defense in depth.
    for &lb in labels {
        if lb < 0 || lb as usize >= super::config::N_CLASSES {
            bail!("label {lb} out of range (classes {})", super::config::N_CLASSES);
        }
    }
    let ctx = Ctx { cfg, params, exec, b, l };
    let embed = PixelEmbedding::new(params);
    let stack = blocks(params, cfg);
    let head = ClfHead::new(params, cfg);

    let mut x = embed.forward(&ctx, pixels);
    let mut tapes = Vec::with_capacity(stack.len());
    for blk in &stack {
        let (y, tape) = blk.forward(&ctx, &x);
        tapes.push(tape);
        x = y;
    }
    let (stats, head_tape) = head.forward(&ctx, &x, labels)?;

    let grads = match grads {
        Some(g) => g,
        None => return Ok(stats),
    };
    let mut dx = head.backward(&ctx, &head_tape, labels, grads);
    for (blk, tape) in stack.iter().zip(tapes.iter()).rev() {
        dx = blk.backward(&ctx, tape, &dx, grads);
    }
    embed.backward(&ctx, pixels, &dx, grads);
    Ok(stats)
}

/// Copy the `slots`-indexed rows (stride `row`) of `src` into the dense
/// prefix of `dst` — the slot-gather half of batched decode.
// lint: no-alloc -- pure slice copies on the decode hot path
fn gather_rows(src: &[f32], slots: &[usize], row: usize, dst: &mut [f32]) {
    for (i, &s) in slots.iter().enumerate() {
        dst[i * row..(i + 1) * row].copy_from_slice(&src[s * row..(s + 1) * row]);
    }
}

/// Copy the dense rows of `src` back to their `slots` positions in `dst`
/// — the scatter half; rows not listed in `slots` are left untouched.
// lint: no-alloc -- pure slice copies on the decode hot path
fn scatter_rows(src: &[f32], slots: &[usize], row: usize, dst: &mut [f32]) {
    for (i, &s) in slots.iter().enumerate() {
        dst[s * row..(s + 1) * row].copy_from_slice(&src[i * row..(i + 1) * row]);
    }
}

/// Per-layer recurrent state shapes, in order:
/// cache_q, cache_k, cache_v (B, K-1, inner), s (B, H, Dk, Dv).
pub fn decode_state_shapes(cfg: &CpuModelCfg) -> Vec<Vec<usize>> {
    let b = cfg.decode_batch;
    let mut out = Vec::new();
    for _ in 0..cfg.n_layers {
        for _ in 0..3 {
            out.push(vec![b, CONV_K - 1, cfg.inner()]);
        }
        out.push(vec![b, cfg.n_heads, cfg.head_dim, cfg.head_dim]);
    }
    out
}

/// Prebuilt LM layer stack for the decode hot path. Layers hold only
/// `ParamSet` indices, so a session builds this once and reuses it for
/// every decoded token instead of re-resolving parameter names per step.
pub struct LmStack {
    embed: TokenEmbedding,
    blocks: Vec<Block>,
    head: LmHead,
}

impl LmStack {
    pub fn new(params: &ParamSet, cfg: &CpuModelCfg) -> Result<LmStack> {
        if cfg.task != CpuTask::Lm {
            bail!("decode is only available for LM families");
        }
        Ok(LmStack {
            embed: TokenEmbedding::new(params),
            blocks: blocks(params, cfg),
            head: LmHead::new(params, cfg),
        })
    }

    /// One-token batched decode. `state` borrows the flat f32 tensors in
    /// [`decode_state_shapes`] order and advances them **in place** (the
    /// caller keeps them host-resident — no copy, no reallocation on the
    /// serving hot path); returns logits (B, vocab).
    // lint: no-alloc -- only the returned logits buffer may allocate
    pub fn decode(
        &self,
        cfg: &CpuModelCfg,
        params: &ParamSet,
        exec: &Executor,
        state: &mut [&mut [f32]],
        tokens: &[i32],
    ) -> Result<Tensor> {
        let b = cfg.decode_batch;
        if tokens.len() != b {
            bail!("decode expects {b} tokens, got {}", tokens.len());
        }
        if state.len() != 4 * cfg.n_layers {
            bail!("decode expects {} state tensors, got {}", 4 * cfg.n_layers, state.len());
        }
        let cache_len = b * (CONV_K - 1) * cfg.inner();
        let s_len = b * cfg.n_heads * cfg.head_dim * cfg.head_dim;
        for (i, t) in state.iter().enumerate() {
            let want = if i % 4 == 3 { s_len } else { cache_len };
            if t.len() != want {
                bail!("state tensor {i}: {} elements, expected {want}", t.len());
            }
        }

        // The residual stream comes from the executor arena; only the
        // returned logits tensor is allocated per token.
        let ctx = Ctx { cfg, params, exec, b, l: 1 };
        let mut x = exec.take(b * cfg.d_model);
        if let Err(e) = self.embed.forward_into(&ctx, tokens, &mut x) {
            exec.put(x);
            return Err(e);
        }
        for (blk, chunk) in self.blocks.iter().zip(state.chunks_mut(4)) {
            let [cq, ck, cv, s] = chunk else { unreachable!("state is chunked by 4") };
            blk.decode_step(&ctx, &mut x, cq, ck, cv, s);
        }
        let mut logits = vec![0.0f32; b * cfg.vocab]; // lint: allow(no-alloc) -- returned buffer
        self.head.logits_into(&ctx, &x, &mut logits);
        exec.put(x);
        Ok(Tensor::from_vec(&[b, cfg.vocab], logits))
    }

    /// Batched decode over the **busy subset** of serving slots. `state`
    /// borrows the same full-capacity tensors as [`LmStack::decode`];
    /// `slots` lists the busy slot ids (strictly increasing, all below
    /// `cfg.decode_batch`) and `tokens[i]` is the next token for
    /// `slots[i]`. Each layer gathers the listed slots' state rows into
    /// contiguous arena scratch, advances all of them in one pass (the
    /// dense projections run as one packed `(busy, d)` GEMM), and
    /// scatters the rows back; untouched slots are never read or
    /// written. Returns logits (busy, vocab), row i belonging to
    /// `slots[i]`.
    ///
    /// Bit-exactness contract: because every serving matmul is pinned to
    /// the slot-batched kernel class keyed on `cfg.serve_slots()`, slot
    /// s's logits and state advance are bit-identical whatever subset of
    /// slots shares the call — one busy slot, any partial occupancy, or
    /// the full batch (which matches [`LmStack::decode`] exactly).
    // lint: no-alloc -- only the returned logits buffer may allocate
    pub fn decode_slots(
        &self,
        cfg: &CpuModelCfg,
        params: &ParamSet,
        exec: &Executor,
        state: &mut [&mut [f32]],
        slots: &[usize],
        tokens: &[i32],
    ) -> Result<Tensor> {
        let cap = cfg.decode_batch;
        let m = slots.len();
        if m == 0 || m > cap {
            bail!("decode_slots expects 1..={cap} busy slots, got {m}");
        }
        for w in slots.windows(2) {
            if w[1] <= w[0] {
                bail!("decode_slots expects strictly increasing slot ids, got {slots:?}");
            }
        }
        if slots[m - 1] >= cap {
            bail!("slot id {} out of range (capacity {cap})", slots[m - 1]);
        }
        if tokens.len() != m {
            bail!("decode_slots expects {m} tokens, got {}", tokens.len());
        }
        if state.len() != 4 * cfg.n_layers {
            bail!("decode_slots expects {} state tensors, got {}", 4 * cfg.n_layers, state.len());
        }
        let crow = (CONV_K - 1) * cfg.inner();
        let srow = cfg.n_heads * cfg.head_dim * cfg.head_dim;
        for (i, t) in state.iter().enumerate() {
            let want = cap * if i % 4 == 3 { srow } else { crow };
            if t.len() != want {
                bail!("state tensor {i}: {} elements, expected {want}", t.len());
            }
        }

        let ctx = Ctx { cfg, params, exec, b: m, l: 1 };
        let mut x = exec.take(m * cfg.d_model);
        if let Err(e) = self.embed.forward_into(&ctx, tokens, &mut x) {
            exec.put(x);
            return Err(e);
        }
        // Per-layer slot gather: the busy rows become one contiguous
        // (m, row) block so decode_step sees exactly the layout a
        // full-batch decode would, then scatter back in place.
        let mut gcq = exec.take(m * crow);
        let mut gck = exec.take(m * crow);
        let mut gcv = exec.take(m * crow);
        let mut gs = exec.take(m * srow);
        for (blk, chunk) in self.blocks.iter().zip(state.chunks_mut(4)) {
            let [cq, ck, cv, s] = chunk else { unreachable!("state is chunked by 4") };
            gather_rows(cq, slots, crow, &mut gcq);
            gather_rows(ck, slots, crow, &mut gck);
            gather_rows(cv, slots, crow, &mut gcv);
            gather_rows(s, slots, srow, &mut gs);
            blk.decode_step(&ctx, &mut x, &mut gcq, &mut gck, &mut gcv, &mut gs);
            scatter_rows(&gcq, slots, crow, cq);
            scatter_rows(&gck, slots, crow, ck);
            scatter_rows(&gcv, slots, crow, cv);
            scatter_rows(&gs, slots, srow, s);
        }
        exec.put(gcq);
        exec.put(gck);
        exec.put(gcv);
        exec.put(gs);
        let mut logits = vec![0.0f32; m * cfg.vocab]; // lint: allow(no-alloc) -- returned buffer
        self.head.logits_into(&ctx, &x, &mut logits);
        exec.put(x);
        Ok(Tensor::from_vec(&[m, cfg.vocab], logits))
    }

    /// Chunked prompt prefill for **one** serving slot: run `tokens` (a
    /// whole prompt or any contiguous chunk of it) through the stack in a
    /// single batched pass, seeded from the slot's state slices — the
    /// caller passes the per-slot rows of the [`decode_state_shapes`]
    /// tensors, in order — which advance in place. Returns the logits of
    /// the **last** position only, shape (1, vocab).
    ///
    /// Bit-exactness contract: for any prompt and any split into prefill
    /// calls, the resulting logits and final slot state are identical to
    /// feeding the same tokens one at a time through [`LmStack::decode`]
    /// (the layers pin their serving arithmetic — see
    /// `layers/mixer.rs::SERVE_KERNEL_CHUNK`).
    // lint: no-alloc -- only the returned logits buffer may allocate
    pub fn prefill(
        &self,
        cfg: &CpuModelCfg,
        params: &ParamSet,
        exec: &Executor,
        state: &mut [&mut [f32]],
        tokens: &[i32],
    ) -> Result<Tensor> {
        let l = tokens.len();
        if l == 0 {
            bail!("prefill needs at least one token");
        }
        if state.len() != 4 * cfg.n_layers {
            bail!("prefill expects {} state tensors, got {}", 4 * cfg.n_layers, state.len());
        }
        let cache_len = (CONV_K - 1) * cfg.inner();
        let s_len = cfg.n_heads * cfg.head_dim * cfg.head_dim;
        for (i, t) in state.iter().enumerate() {
            let want = if i % 4 == 3 { s_len } else { cache_len };
            if t.len() != want {
                bail!("slot state tensor {i}: {} elements, expected {want}", t.len());
            }
        }

        let ctx = Ctx { cfg, params, exec, b: 1, l };
        let mut x = exec.take(l * cfg.d_model);
        if let Err(e) = self.embed.forward_into(&ctx, tokens, &mut x) {
            exec.put(x);
            return Err(e);
        }
        for (blk, chunk) in self.blocks.iter().zip(state.chunks_mut(4)) {
            let [cq, ck, cv, s] = chunk else { unreachable!("state is chunked by 4") };
            blk.prefill(&ctx, &mut x, cq, ck, cv, s);
        }
        // Last-position logits only (the head derives its row count from
        // the activation slice, so this is a single pinned-class row).
        let mut logits = vec![0.0f32; cfg.vocab]; // lint: allow(no-alloc) -- returned buffer
        self.head.logits_into(&ctx, &x[(l - 1) * cfg.d_model..], &mut logits);
        exec.put(x);
        Ok(Tensor::from_vec(&[1, cfg.vocab], logits))
    }
}

