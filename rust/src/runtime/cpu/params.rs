//! Flat, deterministically-ordered parameter set + the AdamW mirror.
//!
//! Parameter names/shapes/init kinds mirror `python/compile/model.py::
//! _param_specs` (LM) and `python/compile/classifier.py::_param_specs`
//! (classifier) so checkpoints and manifests stay cross-referenceable. The
//! optimizer mirrors `python/compile/train.py::adamw_update` exactly:
//! global-norm clip, bias correction, decoupled weight decay on matrices.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::config::{CpuModelCfg, CpuTask, CONV_K, N_CLASSES};

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.95;
pub const ADAM_EPS: f32 = 1e-8;
pub const WEIGHT_DECAY: f32 = 0.1; // paper Appendix A
pub const GRAD_CLIP: f32 = 1.0; // paper Appendix A

/// How a parameter is initialized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum InitKind {
    /// N(0, 1) * fan_in^-0.5 (fan_in = shape[0]).
    Normal,
    /// Near-identity causal conv: 0.02 * N(0,1), last tap += 1.
    Conv,
    Ones,
    Zeros,
}

fn param_specs(cfg: &CpuModelCfg) -> Vec<(String, Vec<usize>, InitKind)> {
    let d = cfg.d_model;
    let inner = cfg.inner();
    let h = cfg.n_heads;
    let mut specs = Vec::new();
    match cfg.task {
        CpuTask::Lm => {
            specs.push(("embed".to_string(), vec![cfg.vocab, d], InitKind::Normal));
        }
        CpuTask::Classifier => {
            specs.push(("pix_w".to_string(), vec![1, d], InitKind::Normal));
            specs.push(("pix_b".to_string(), vec![d], InitKind::Zeros));
        }
    }
    for i in 0..cfg.n_layers {
        let p = format!("layer{i}.");
        specs.push((format!("{p}norm_attn"), vec![d], InitKind::Ones));
        specs.push((format!("{p}wq"), vec![d, inner], InitKind::Normal));
        specs.push((format!("{p}wk"), vec![d, inner], InitKind::Normal));
        specs.push((format!("{p}wv"), vec![d, inner], InitKind::Normal));
        specs.push((format!("{p}conv_q"), vec![CONV_K, inner], InitKind::Conv));
        specs.push((format!("{p}conv_k"), vec![CONV_K, inner], InitKind::Conv));
        specs.push((format!("{p}conv_v"), vec![CONV_K, inner], InitKind::Conv));
        specs.push((format!("{p}w_beta"), vec![d, h], InitKind::Normal));
        specs.push((format!("{p}adecay"), vec![h], InitKind::Zeros));
        specs.push((format!("{p}norm_out"), vec![cfg.head_dim], InitKind::Ones));
        specs.push((format!("{p}wo"), vec![inner, d], InitKind::Normal));
        specs.push((format!("{p}norm_mlp"), vec![d], InitKind::Ones));
        specs.push((format!("{p}w_gate"), vec![d, cfg.mlp_width()], InitKind::Normal));
        specs.push((format!("{p}w_up"), vec![d, cfg.mlp_width()], InitKind::Normal));
        specs.push((format!("{p}w_down"), vec![cfg.mlp_width(), d], InitKind::Normal));
    }
    specs.push(("norm_f".to_string(), vec![d], InitKind::Ones));
    if cfg.task == CpuTask::Classifier {
        specs.push(("head_w".to_string(), vec![d, N_CLASSES], InitKind::Normal));
        specs.push(("head_b".to_string(), vec![N_CLASSES], InitKind::Zeros));
    }
    specs
}

/// Flat named parameter set in spec order.
pub struct ParamSet {
    names: Vec<String>,
    tensors: Vec<Tensor>,
    index: HashMap<String, usize>,
}

impl ParamSet {
    /// Seeded deterministic init.
    pub fn init(cfg: &CpuModelCfg, seed: u32) -> ParamSet {
        let specs = param_specs(cfg);
        let mut rng = Rng::new(0xEF1A_0000_0000_0000 ^ seed as u64);
        let mut names = Vec::with_capacity(specs.len());
        let mut tensors = Vec::with_capacity(specs.len());
        let mut index = HashMap::new();
        for (name, shape, kind) in specs {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = match kind {
                InitKind::Normal => {
                    let scale = (shape[0] as f32).powf(-0.5);
                    rng.normal_vec(n, 0.0, scale)
                }
                InitKind::Conv => {
                    let mut w = rng.normal_vec(n, 0.0, 0.02);
                    // last tap ~ identity
                    let cols = shape[1];
                    for x in w[n - cols..].iter_mut() {
                        *x += 1.0;
                    }
                    w
                }
                InitKind::Ones => vec![1.0; n],
                InitKind::Zeros => vec![0.0; n],
            };
            index.insert(name.clone(), tensors.len());
            names.push(name);
            tensors.push(Tensor::from_vec(&shape, data));
        }
        ParamSet { names, tensors, index }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total f32 element count.
    pub fn elems(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn tensor(&self, i: usize) -> &Tensor {
        &self.tensors[i]
    }

    pub fn tensor_mut(&mut self, i: usize) -> &mut Tensor {
        &mut self.tensors[i]
    }

    /// Index of a named parameter (panics on unknown internal name).
    pub fn idx(&self, name: &str) -> usize {
        *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("internal: unknown parameter '{name}'"))
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.tensors[self.idx(name)]
    }

    /// Zero tensors shaped like every parameter (gradient / moment buffers).
    pub fn zeros_like(&self) -> Vec<Tensor> {
        self.tensors.iter().map(|t| Tensor::zeros(t.shape())).collect()
    }

    /// Replace all tensors (shape-checked, checkpoint restore).
    pub fn set_all(&mut self, tensors: &[Tensor]) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            bail!("expected {} parameter tensors, got {}", self.tensors.len(), tensors.len());
        }
        for (i, t) in tensors.iter().enumerate() {
            if t.shape() != self.tensors[i].shape() {
                bail!(
                    "parameter '{}': shape {:?} != expected {:?}",
                    self.names[i],
                    t.shape(),
                    self.tensors[i].shape()
                );
            }
        }
        self.tensors = tensors.to_vec();
        Ok(())
    }
}

/// AdamW with bias correction + decoupled weight decay + global-norm clip
/// (exact mirror of `python/compile/train.py::adamw_update`).
///
/// `step` is the 1-based step counter. Returns the pre-clip gradient norm.
pub fn adamw_update(
    params: &mut ParamSet,
    grads: &[Tensor],
    m: &mut [Tensor],
    v: &mut [Tensor],
    step: u64,
    lr: f32,
) -> f32 {
    debug_assert_eq!(grads.len(), params.len());
    debug_assert_eq!(m.len(), params.len());
    debug_assert_eq!(v.len(), params.len());
    let mut sq = 0f64;
    for g in grads {
        for &x in g.data() {
            sq += (x as f64) * (x as f64);
        }
    }
    let gnorm = sq.sqrt() as f32;
    let scale = (GRAD_CLIP / gnorm.max(1e-12)).min(1.0);
    let stepf = step as f64;
    let bc1 = (1.0 - (ADAM_B1 as f64).powf(stepf)) as f32;
    let bc2 = (1.0 - (ADAM_B2 as f64).powf(stepf)) as f32;
    for i in 0..grads.len() {
        let decay = params.tensor(i).ndim() >= 2;
        let g = grads[i].data();
        let mi = m[i].data_mut();
        let vi = v[i].data_mut();
        let p = params.tensor_mut(i).data_mut();
        for j in 0..p.len() {
            let gj = g[j] * scale;
            let mj = ADAM_B1 * mi[j] + (1.0 - ADAM_B1) * gj;
            let vj = ADAM_B2 * vi[j] + (1.0 - ADAM_B2) * gj * gj;
            mi[j] = mj;
            vi[j] = vj;
            let mut update = (mj / bc1) / ((vj / bc2).sqrt() + ADAM_EPS);
            if decay {
                update += WEIGHT_DECAY * p[j];
            }
            p[j] -= lr * update;
        }
    }
    gnorm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::cpu::config::family_config;

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let a = ParamSet::init(&cfg, 7);
        let b = ParamSet::init(&cfg, 7);
        let c = ParamSet::init(&cfg, 8);
        for i in 0..a.len() {
            assert_eq!(a.tensor(i), b.tensor(i), "{}", a.names()[i]);
        }
        let diff = (0..a.len()).any(|i| a.tensor(i) != c.tensor(i));
        assert!(diff, "different seeds must differ");
    }

    #[test]
    fn spec_names_mirror_python_layout() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let p = ParamSet::init(&cfg, 1);
        assert_eq!(p.names()[0], "embed");
        assert_eq!(p.names().last().unwrap(), "norm_f");
        assert_eq!(p.get("layer0.wq").shape(), &[64, 64]);
        assert_eq!(p.get("layer1.w_down").shape(), &[256, 64]);
        assert_eq!(p.get("layer0.conv_q").shape(), &[CONV_K, 64]);
        assert_eq!(p.get("layer0.w_beta").shape(), &[64, 2]);
        // near-identity conv init: mean of last tap ~ 1
        let conv = p.get("layer0.conv_q");
        let cols = conv.shape()[1];
        let last = &conv.data()[(CONV_K - 1) * cols..];
        let mean: f32 = last.iter().sum::<f32>() / cols as f32;
        assert!((mean - 1.0).abs() < 0.05, "conv last tap mean {mean}");
    }

    #[test]
    fn classifier_has_head_params() {
        let cfg = family_config("clf_efla").unwrap();
        let p = ParamSet::init(&cfg, 1);
        assert_eq!(p.get("pix_w").shape(), &[1, 64]);
        assert_eq!(p.get("head_w").shape(), &[64, N_CLASSES]);
        assert_eq!(p.get("head_b").shape(), &[N_CLASSES]);
    }

    #[test]
    fn adamw_descends_a_quadratic() {
        // minimize f(p) = 0.5 * ||p||^2 with grads = p: must shrink.
        let cfg = family_config("lm_tiny_efla").unwrap();
        let mut params = ParamSet::init(&cfg, 3);
        let mut m = params.zeros_like();
        let mut v = params.zeros_like();
        let norm0: f32 = params.tensors().iter().map(|t| t.norm().powi(2)).sum::<f32>().sqrt();
        for step in 1..=20u64 {
            let grads: Vec<Tensor> = params.tensors().to_vec();
            let gnorm = adamw_update(&mut params, &grads, &mut m, &mut v, step, 1e-2);
            assert!(gnorm.is_finite() && gnorm > 0.0);
        }
        let norm1: f32 = params.tensors().iter().map(|t| t.norm().powi(2)).sum::<f32>().sqrt();
        assert!(norm1 < norm0, "{norm1} >= {norm0}");
    }

    #[test]
    fn set_all_rejects_shape_mismatch() {
        let cfg = family_config("lm_tiny_efla").unwrap();
        let mut p = ParamSet::init(&cfg, 1);
        let mut ts = p.tensors().to_vec();
        ts[0] = Tensor::zeros(&[1, 1]);
        assert!(p.set_all(&ts).is_err());
        let good = ParamSet::init(&cfg, 2).tensors().to_vec();
        p.set_all(&good).unwrap();
    }
}
