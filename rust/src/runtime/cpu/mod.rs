//! The pure-Rust CPU execution backend.
//!
//! Always available (no external runtime, no AOT artifacts). The model is
//! a composable layer stack ([`layers`], built on the fwd/bwd primitive
//! pairs in [`ops`]) orchestrated by [`model`]; the embarrassingly-parallel
//! (batch, head) kernel work and the large matmuls fan out over a
//! [`exec::Executor`] work-splitter (thread count: `--threads` /
//! `EFLA_NUM_THREADS` / auto, numerics bit-identical at any setting).
//! Families are resolved from their names (`lm_<preset>_<mixer>`,
//! `clf_<mixer>`) using the same preset table `python/compile/model.py`
//! bakes into artifacts, so CPU sessions train with the same shapes the
//! PJRT backend would.

#![forbid(unsafe_code)]

pub mod config;
pub mod exec;
pub mod layers;
pub mod model;
pub mod ops;
pub mod params;

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;

use super::backend::{Backend, ModelSession, StepMetrics};
use super::value::HostValue;

use config::{family_config, known_families, CpuModelCfg, CpuTask};
use exec::Executor;
use model::{clf_loss, decode_state_shapes, lm_loss, LmStack};
use params::{adamw_update, ParamSet};

/// The always-available pure-Rust backend.
#[derive(Debug, Default)]
pub struct CpuBackend {
    /// Worker threads per session (0 = auto: `EFLA_NUM_THREADS` or the
    /// machine's available parallelism).
    threads: usize,
}

impl CpuBackend {
    pub fn new() -> Self {
        CpuBackend { threads: 0 }
    }

    /// Backend with an explicit worker-thread count (0 = auto).
    pub fn with_threads(threads: usize) -> Self {
        CpuBackend { threads }
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &str {
        "cpu"
    }

    fn has_family(&self, family: &str) -> bool {
        family_config(family).is_ok()
    }

    fn describe(&self) -> Vec<String> {
        known_families()
    }

    fn open_session(&self, family: &str, seed: u32) -> Result<Box<dyn ModelSession>> {
        let cfg = family_config(family)?;
        Ok(Box::new(CpuSession::init(family, cfg, seed, Executor::new(self.threads))))
    }
}

/// Parameters + AdamW moments, resident as host tensors.
pub struct CpuSession {
    family: String,
    cfg: CpuModelCfg,
    params: ParamSet,
    exec: Executor,
    /// Prebuilt decode layer stack (LM tasks only) — layers hold only
    /// parameter indices, so one build serves every decoded token.
    lm_stack: Option<LmStack>,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    step_count: u64,
}

impl CpuSession {
    pub fn init(family: &str, cfg: CpuModelCfg, seed: u32, exec: Executor) -> CpuSession {
        let params = ParamSet::init(&cfg, seed);
        let m = params.zeros_like();
        let v = params.zeros_like();
        let lm_stack = LmStack::new(&params, &cfg).ok();
        CpuSession {
            family: family.to_string(),
            cfg,
            params,
            exec,
            lm_stack,
            m,
            v,
            step_count: 0,
        }
    }

    /// Unpack (d0, d1) for the LM tasks: tokens + targets, both (B, L) i32.
    fn lm_batch<'a>(&self, d0: &'a HostValue, d1: &'a HostValue) -> Result<(&'a [i32], &'a [i32])> {
        let (s0, tokens) = d0.as_i32()?;
        let (s1, targets) = d1.as_i32()?;
        let want = [self.cfg.batch, self.cfg.seq];
        if s0 != want || s1 != want {
            bail!(
                "{}: batch shapes {:?}/{:?}, expected {:?}",
                self.family,
                s0,
                s1,
                want
            );
        }
        Ok((tokens, targets))
    }

    /// Unpack (d0, d1) for the classifier: pixels (B, 784) f32 + labels (B,).
    fn clf_batch<'a>(
        &self,
        d0: &'a HostValue,
        d1: &'a HostValue,
    ) -> Result<(&'a [f32], &'a [i32])> {
        let pixels = d0.as_f32()?;
        if pixels.shape() != [self.cfg.batch, self.cfg.seq] {
            bail!(
                "{}: pixel shape {:?}, expected {:?}",
                self.family,
                pixels.shape(),
                [self.cfg.batch, self.cfg.seq]
            );
        }
        let (s1, labels) = d1.as_i32()?;
        if s1 != [self.cfg.batch] {
            bail!("{}: label shape {:?}, expected [{}]", self.family, s1, self.cfg.batch);
        }
        Ok((pixels.data(), labels))
    }
}

impl ModelSession for CpuSession {
    fn family(&self) -> &str {
        &self.family
    }

    fn batch(&self) -> usize {
        self.cfg.batch
    }

    fn seq(&self) -> usize {
        self.cfg.seq
    }

    fn n_param_tensors(&self) -> usize {
        self.params.len()
    }

    fn param_elems(&self) -> usize {
        self.params.elems()
    }

    fn steps_done(&self) -> u64 {
        self.step_count
    }

    fn threads(&self) -> usize {
        self.exec.threads()
    }

    fn step(&mut self, d0: &HostValue, d1: &HostValue, lr: f32) -> Result<StepMetrics> {
        let mut grads = self.params.zeros_like();
        let stats = match self.cfg.task {
            CpuTask::Lm => {
                let (tokens, targets) = self.lm_batch(d0, d1)?;
                lm_loss(
                    &self.cfg,
                    &self.params,
                    &self.exec,
                    tokens,
                    targets,
                    self.cfg.batch,
                    self.cfg.seq,
                    Some(&mut grads),
                )?
            }
            CpuTask::Classifier => {
                let (pixels, labels) = self.clf_batch(d0, d1)?;
                clf_loss(
                    &self.cfg,
                    &self.params,
                    &self.exec,
                    pixels,
                    labels,
                    self.cfg.batch,
                    Some(&mut grads),
                )?
            }
        };
        self.step_count += 1;
        let gnorm = adamw_update(
            &mut self.params,
            &grads,
            &mut self.m,
            &mut self.v,
            self.step_count,
            lr,
        );
        Ok(StepMetrics { loss: stats.loss_mean, grad_norm: gnorm })
    }

    fn eval(&self, d0: &HostValue, d1: &HostValue) -> Result<Vec<f32>> {
        match self.cfg.task {
            CpuTask::Lm => {
                let (tokens, targets) = self.lm_batch(d0, d1)?;
                let s = lm_loss(
                    &self.cfg,
                    &self.params,
                    &self.exec,
                    tokens,
                    targets,
                    self.cfg.batch,
                    self.cfg.seq,
                    None,
                )?;
                Ok(vec![s.loss_sum, s.count, s.correct])
            }
            CpuTask::Classifier => {
                let (pixels, labels) = self.clf_batch(d0, d1)?;
                let s = clf_loss(
                    &self.cfg,
                    &self.params,
                    &self.exec,
                    pixels,
                    labels,
                    self.cfg.batch,
                    None,
                )?;
                Ok(vec![s.loss_sum, s.correct])
            }
        }
    }

    fn export_params(&self) -> Result<Vec<Tensor>> {
        Ok(self.params.tensors().to_vec())
    }

    fn export_state(&self) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(3 * self.params.len());
        out.extend(self.params.tensors().iter().cloned());
        out.extend(self.m.iter().cloned());
        out.extend(self.v.iter().cloned());
        Ok(out)
    }

    fn import_state(&mut self, tensors: &[Tensor], step: u64) -> Result<()> {
        let n = self.params.len();
        if tensors.len() != 3 * n {
            bail!("checkpoint has {} tensors, session needs {}", tensors.len(), 3 * n);
        }
        self.params.set_all(&tensors[..n])?;
        for (dst, src) in self.m.iter_mut().zip(tensors[n..2 * n].iter()) {
            if dst.shape() != src.shape() {
                bail!("optimizer m shape mismatch: {:?} vs {:?}", src.shape(), dst.shape());
            }
            *dst = src.clone();
        }
        for (dst, src) in self.v.iter_mut().zip(tensors[2 * n..].iter()) {
            if dst.shape() != src.shape() {
                bail!("optimizer v shape mismatch: {:?} vs {:?}", src.shape(), dst.shape());
            }
            *dst = src.clone();
        }
        self.step_count = step;
        Ok(())
    }

    fn decode_batch(&self) -> Result<usize> {
        if self.cfg.task != CpuTask::Lm {
            bail!("{}: decode is only available for LM families", self.family);
        }
        Ok(self.cfg.decode_batch)
    }

    fn vocab(&self) -> Result<usize> {
        if self.cfg.task != CpuTask::Lm {
            bail!("{}: vocab is only defined for LM families", self.family);
        }
        Ok(self.cfg.vocab)
    }

    fn decode_state(&self) -> Result<Vec<HostValue>> {
        self.decode_batch()?; // validates the task
        Ok(decode_state_shapes(&self.cfg)
            .into_iter()
            .map(|shape| HostValue::F32(Tensor::zeros(&shape)))
            .collect())
    }

    fn decode(&self, state: &mut [HostValue], tokens: &[i32]) -> Result<Tensor> {
        let stack = self
            .lm_stack
            .as_ref()
            .ok_or_else(|| anyhow!("{}: decode is only available for LM families", self.family))?;
        let shapes = decode_state_shapes(&self.cfg);
        if state.len() != shapes.len() {
            bail!(
                "{}: decode expects {} state tensors, got {}",
                self.family,
                shapes.len(),
                state.len()
            );
        }
        // Mutably borrow the state tensors directly — decode advances them
        // in place, so the serving hot path never copies or reallocates.
        let mut flat: Vec<&mut [f32]> = state
            .iter_mut()
            .enumerate()
            .map(|(i, hv)| {
                let t = hv
                    .as_f32_mut()
                    .map_err(|e| anyhow!("state tensor {i}: {e}"))?;
                if t.shape() != shapes[i].as_slice() {
                    bail!("state tensor {i}: shape {:?}, expected {:?}", t.shape(), shapes[i]);
                }
                Ok(t.data_mut())
            })
            .collect::<Result<_>>()?;
        stack.decode(&self.cfg, &self.params, &self.exec, &mut flat, tokens)
    }

    fn supports_batched_decode(&self) -> bool {
        self.lm_stack.is_some()
    }

    fn decode_slots(
        &self,
        state: &mut [HostValue],
        slots: &[usize],
        tokens: &[i32],
    ) -> Result<Tensor> {
        let stack = self.lm_stack.as_ref().ok_or_else(|| {
            anyhow!("{}: batched decode is only available for LM families", self.family)
        })?;
        let shapes = decode_state_shapes(&self.cfg);
        if state.len() != shapes.len() {
            bail!(
                "{}: decode_slots expects {} state tensors, got {}",
                self.family,
                shapes.len(),
                state.len()
            );
        }
        // Same in-place borrow of the full-capacity tensors as decode();
        // the stack gathers/scatters only the listed slots' rows.
        let mut flat: Vec<&mut [f32]> = state
            .iter_mut()
            .enumerate()
            .map(|(i, hv)| {
                let t = hv
                    .as_f32_mut()
                    .map_err(|e| anyhow!("state tensor {i}: {e}"))?;
                if t.shape() != shapes[i].as_slice() {
                    bail!("state tensor {i}: shape {:?}, expected {:?}", t.shape(), shapes[i]);
                }
                Ok(t.data_mut())
            })
            .collect::<Result<_>>()?;
        stack.decode_slots(&self.cfg, &self.params, &self.exec, &mut flat, slots, tokens)
    }

    fn supports_prefill(&self) -> bool {
        self.lm_stack.is_some()
    }

    fn prefill(&self, state: &mut [HostValue], slot: usize, tokens: &[i32]) -> Result<Tensor> {
        let stack = self
            .lm_stack
            .as_ref()
            .ok_or_else(|| anyhow!("{}: prefill is only available for LM families", self.family))?;
        let b = self.cfg.decode_batch;
        if slot >= b {
            bail!("{}: prefill slot {slot} out of range (decode batch {b})", self.family);
        }
        let shapes = decode_state_shapes(&self.cfg);
        if state.len() != shapes.len() {
            bail!(
                "{}: prefill expects {} state tensors, got {}",
                self.family,
                shapes.len(),
                state.len()
            );
        }
        // Slice out the slot's rows of each (decode_batch, ...) tensor —
        // prefill advances exactly this slot's state in place and never
        // touches the other rows.
        let mut flat: Vec<&mut [f32]> = state
            .iter_mut()
            .enumerate()
            .map(|(i, hv)| {
                let t = hv
                    .as_f32_mut()
                    .map_err(|e| anyhow!("state tensor {i}: {e}"))?;
                if t.shape() != shapes[i].as_slice() {
                    bail!("state tensor {i}: shape {:?}, expected {:?}", t.shape(), shapes[i]);
                }
                let row = t.len() / b;
                Ok(&mut t.data_mut()[slot * row..(slot + 1) * row])
            })
            .collect::<Result<_>>()?;
        stack.prefill(&self.cfg, &self.params, &self.exec, &mut flat, tokens)
    }

    fn supports_state_io(&self) -> bool {
        self.lm_stack.is_some()
    }

    fn export_slot_state(&self, state: &[HostValue], slot: usize) -> Result<Vec<Vec<f32>>> {
        if self.lm_stack.is_none() {
            bail!("{}: slot state export is only available for LM families", self.family);
        }
        let b = self.cfg.decode_batch;
        if slot >= b {
            bail!("{}: export slot {slot} out of range (decode batch {b})", self.family);
        }
        let shapes = decode_state_shapes(&self.cfg);
        if state.len() != shapes.len() {
            bail!(
                "{}: export expects {} state tensors, got {}",
                self.family,
                shapes.len(),
                state.len()
            );
        }
        // One raw row per state tensor: the exact f32 bits of this slot's
        // slice of each (decode_batch, ...) tensor.
        state
            .iter()
            .enumerate()
            .map(|(i, hv)| {
                let t = hv.as_f32().map_err(|e| anyhow!("state tensor {i}: {e}"))?;
                if t.shape() != shapes[i].as_slice() {
                    bail!("state tensor {i}: shape {:?}, expected {:?}", t.shape(), shapes[i]);
                }
                let row = t.len() / b;
                Ok(t.data()[slot * row..(slot + 1) * row].to_vec())
            })
            .collect()
    }

    // The restore side sits on the serving hot path (every cached-session
    // admit runs it), so it copies into the live state in place.
    // lint: no-alloc
    fn import_slot_state(
        &self,
        state: &mut [HostValue],
        slot: usize,
        rows: &[Vec<f32>],
    ) -> Result<()> {
        if self.lm_stack.is_none() {
            bail!("{}: slot state import is only available for LM families", self.family);
        }
        let b = self.cfg.decode_batch;
        if slot >= b {
            bail!("{}: import slot {slot} out of range (decode batch {b})", self.family);
        }
        let shapes = decode_state_shapes(&self.cfg);
        if state.len() != shapes.len() {
            bail!(
                "{}: import expects {} state tensors, got {}",
                self.family,
                shapes.len(),
                state.len()
            );
        }
        if rows.len() != state.len() {
            bail!("{}: import expects {} rows, got {}", self.family, state.len(), rows.len());
        }
        for (i, hv) in state.iter_mut().enumerate() {
            let t = hv.as_f32_mut().map_err(|e| anyhow!("state tensor {i}: {e}"))?;
            if t.shape() != shapes[i].as_slice() {
                bail!("state tensor {i}: shape {:?}, expected {:?}", t.shape(), shapes[i]);
            }
            let row = t.len() / b;
            if rows[i].len() != row {
                bail!("state row {i}: {} elements, expected {row}", rows[i].len());
            }
            t.data_mut()[slot * row..(slot + 1) * row].copy_from_slice(&rows[i]);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_trains_on_a_fixed_batch() {
        let backend = CpuBackend::new();
        let mut session = backend.open_session("lm_tiny_efla", 42).unwrap();
        assert_eq!(session.batch(), 4);
        assert_eq!(session.seq(), 64);
        let rows = session.batch() * session.seq();
        let shape = [session.batch(), session.seq()];
        let tokens = HostValue::i32(&shape, (0..rows).map(|i| (i % 251) as i32).collect());
        let targets = HostValue::i32(&shape, (0..rows).map(|i| ((i + 1) % 251) as i32).collect());
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..8 {
            let m = session.step(&tokens, &targets, 3e-3).unwrap();
            assert!(m.loss.is_finite());
            assert!(m.grad_norm.is_finite() && m.grad_norm > 0.0);
            first.get_or_insert(m.loss);
            last = m.loss;
        }
        let first = first.unwrap();
        assert!(last < first, "loss must drop on a fixed batch: {first} -> {last}");
        assert_eq!(session.steps_done(), 8);
    }

    #[test]
    fn state_roundtrip_preserves_training() {
        let backend = CpuBackend::new();
        let mut a = backend.open_session("lm_tiny_efla", 1).unwrap();
        let state = a.export_state().unwrap();
        assert_eq!(state.len(), 3 * a.n_param_tensors());
        let mut b = backend.open_session("lm_tiny_efla", 2).unwrap();
        b.import_state(&state, 5).unwrap();
        assert_eq!(b.steps_done(), 5);
        let pa = a.export_params().unwrap();
        let pb = b.export_params().unwrap();
        for (x, y) in pa.iter().zip(pb.iter()) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn unknown_family_is_rejected() {
        let backend = CpuBackend::new();
        assert!(backend.open_session("lm_nope_efla", 1).is_err());
        assert!(!backend.has_family("lm_nope_efla"));
        assert!(backend.has_family("lm_mad_deltanet"));
    }

    #[test]
    fn classifier_has_no_decode() {
        let backend = CpuBackend::new();
        let s = backend.open_session("clf_efla", 1).unwrap();
        assert!(s.decode_batch().is_err());
        assert!(s.decode_state().is_err());
    }

    #[test]
    fn explicit_thread_knob_reaches_the_session() {
        let backend = CpuBackend::with_threads(3);
        let s = backend.open_session("lm_tiny_efla", 1).unwrap();
        assert_eq!(s.threads(), 3);
        let auto = CpuBackend::new().open_session("lm_tiny_efla", 1).unwrap();
        assert!(auto.threads() >= 1);
    }

    #[test]
    fn prefill_capability_and_validation() {
        let backend = CpuBackend::with_threads(1);
        let session = backend.open_session("lm_tiny_efla", 5).unwrap();
        assert!(session.supports_prefill());
        let mut state = session.decode_state().unwrap();
        // Slot out of range and empty prompts are rejected cleanly.
        let b = session.decode_batch().unwrap();
        assert!(session.prefill(&mut state, b, &[1, 2, 3]).is_err());
        assert!(session.prefill(&mut state, 0, &[]).is_err());
        // A valid call returns (1, vocab) logits and only touches the
        // requested slot's rows.
        let before: Vec<Vec<f32>> = state
            .iter()
            .map(|hv| hv.as_f32().unwrap().data().to_vec())
            .collect();
        let logits = session.prefill(&mut state, 1, &[7, 8, 9, 10]).unwrap();
        assert_eq!(logits.shape(), &[1, session.vocab().unwrap()]);
        assert!(logits.data().iter().all(|x| x.is_finite()));
        for (hv, old) in state.iter().zip(before.iter()) {
            let t = hv.as_f32().unwrap();
            let row = t.len() / b;
            for s in 0..b {
                let same = t.data()[s * row..(s + 1) * row] == old[s * row..(s + 1) * row];
                if s == 1 {
                    assert!(!same, "prefilled slot must advance");
                } else {
                    assert!(same, "slot {s} must be untouched");
                }
            }
        }

        let clf = backend.open_session("clf_efla", 5).unwrap();
        assert!(!clf.supports_prefill());
    }

    #[test]
    fn decode_advances_state_in_place() {
        let backend = CpuBackend::with_threads(1);
        let session = backend.open_session("lm_tiny_efla", 7).unwrap();
        let mut state = session.decode_state().unwrap();
        let before: Vec<f32> = state
            .iter()
            .map(|hv| hv.as_f32().unwrap().data().iter().map(|x| x.abs()).sum::<f32>())
            .collect();
        let tokens = vec![65i32; session.decode_batch().unwrap()];
        let logits1 = session.decode(&mut state, &tokens).unwrap();
        assert!(logits1.data().iter().all(|x| x.is_finite()));
        let after: Vec<f32> = state
            .iter()
            .map(|hv| hv.as_f32().unwrap().data().iter().map(|x| x.abs()).sum::<f32>())
            .collect();
        assert_ne!(before, after, "decode must mutate the state in place");
        let logits2 = session.decode(&mut state, &tokens).unwrap();
        assert!(
            logits1.max_abs_diff(&logits2) > 1e-7,
            "state must advance between decode steps"
        );
    }
}
