//! Elementwise / normalization / convolution primitives for the CPU model
//! layers, each with a paired forward and backward.
//!
//! Every layer in [`super::layers`] is built from these plus the matmul
//! primitives in [`crate::tensor`] (one set of matmul kernels shared with
//! the attention kernels — no private duplicates). The executor-aware
//! wrappers ([`matmul`], [`matmul_nt_acc`]) split large products into
//! row-parallel chunks; small products run inline so the decode hot path
//! never pays thread-spawn overhead. Row splitting never changes a row's
//! arithmetic, so results are bit-identical for any thread count.

use crate::tensor::gemm;
use crate::tensor::{matmul_into, matmul_nt_into};

use super::exec::Executor;

/// L2-normalize clamp (mirror of kernels/deltanet.py l2_normalize eps).
pub const L2_EPS: f32 = 1e-6;

/// Minimum flop count (m*k*n) before a matmul is worth fanning out.
const PAR_MIN_FLOPS: usize = 1 << 18;

// ----------------------------------------------------------------------
// Scalar activations
// ----------------------------------------------------------------------

pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub fn softplus(x: f32) -> f32 {
    if x > 20.0 {
        x
    } else if x < -20.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

pub fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// d silu(x) / dx = s(x) * (1 + x * (1 - s(x)))
pub fn silu_grad(x: f32) -> f32 {
    let s = sigmoid(x);
    s * (1.0 + x * (1.0 - s))
}

pub fn silu_fwd(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| silu(v)).collect()
}

/// In-place SiLU (decode hot path: no fresh buffer).
pub fn silu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = silu(*v);
    }
}

pub fn silu_bwd(x: &[f32], dy: &[f32]) -> Vec<f32> {
    x.iter().zip(dy.iter()).map(|(&v, &d)| d * silu_grad(v)).collect()
}

// ----------------------------------------------------------------------
// Normalizations
// ----------------------------------------------------------------------

/// Row-wise RMSNorm over rows of `width`. Returns (y, inv_rms per row).
pub fn rms_norm_fwd(x: &[f32], gain: &[f32], width: usize, eps: f32) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(gain.len(), width);
    let rows = x.len() / width;
    let mut y = vec![0.0f32; x.len()];
    let mut inv = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / width as f32;
        let iv = 1.0 / (ms + eps).sqrt();
        inv[r] = iv;
        let yr = &mut y[r * width..(r + 1) * width];
        for j in 0..width {
            yr[j] = xr[j] * iv * gain[j];
        }
    }
    (y, inv)
}

/// Tape-free RMSNorm into a caller-provided buffer (decode path:
/// per-row inverse RMS is not saved). Overwrites `y`.
pub fn rms_norm_into(x: &[f32], gain: &[f32], width: usize, eps: f32, y: &mut [f32]) {
    debug_assert_eq!(gain.len(), width);
    debug_assert_eq!(y.len(), x.len());
    let rows = x.len() / width;
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / width as f32;
        let iv = 1.0 / (ms + eps).sqrt();
        let yr = &mut y[r * width..(r + 1) * width];
        for j in 0..width {
            yr[j] = xr[j] * iv * gain[j];
        }
    }
}

/// RMSNorm backward; accumulates into `dgain`, returns dx.
pub fn rms_norm_bwd(
    x: &[f32],
    gain: &[f32],
    inv: &[f32],
    dy: &[f32],
    width: usize,
    dgain: &mut [f32],
) -> Vec<f32> {
    let rows = x.len() / width;
    let mut dx = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let dyr = &dy[r * width..(r + 1) * width];
        let iv = inv[r];
        let mut dot = 0.0f32; // sum_i dy_i * gain_i * x_i
        for j in 0..width {
            dot += dyr[j] * gain[j] * xr[j];
        }
        let c = iv * iv * iv * dot / width as f32;
        let dxr = &mut dx[r * width..(r + 1) * width];
        for j in 0..width {
            dxr[j] = iv * gain[j] * dyr[j] - c * xr[j];
            dgain[j] += dyr[j] * xr[j] * iv;
        }
    }
    dx
}

/// Row-wise L2 normalize (clamped-square form). Returns (y, sum-square per
/// row) — the clamp decision replays in the backward from the stored ss.
pub fn l2norm_fwd(x: &[f32], width: usize) -> (Vec<f32>, Vec<f32>) {
    let rows = x.len() / width;
    let mut y = vec![0.0f32; x.len()];
    let mut ss = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let s: f32 = xr.iter().map(|v| v * v).sum();
        ss[r] = s;
        let iv = 1.0 / s.max(L2_EPS * L2_EPS).sqrt();
        let yr = &mut y[r * width..(r + 1) * width];
        for j in 0..width {
            yr[j] = xr[j] * iv;
        }
    }
    (y, ss)
}

/// Tape-free row-wise L2 normalize into a caller-provided buffer (decode
/// path: the per-row sum-square is not saved). Overwrites `y`.
pub fn l2norm_into(x: &[f32], width: usize, y: &mut [f32]) {
    debug_assert_eq!(y.len(), x.len());
    let rows = x.len() / width;
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let s: f32 = xr.iter().map(|v| v * v).sum();
        let iv = 1.0 / s.max(L2_EPS * L2_EPS).sqrt();
        let yr = &mut y[r * width..(r + 1) * width];
        for j in 0..width {
            yr[j] = xr[j] * iv;
        }
    }
}

pub fn l2norm_bwd(x: &[f32], ss: &[f32], dy: &[f32], width: usize) -> Vec<f32> {
    let rows = x.len() / width;
    let mut dx = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * width..(r + 1) * width];
        let dyr = &dy[r * width..(r + 1) * width];
        let s = ss[r];
        let clamped = s <= L2_EPS * L2_EPS;
        let iv = 1.0 / s.max(L2_EPS * L2_EPS).sqrt();
        let dxr = &mut dx[r * width..(r + 1) * width];
        if clamped {
            // r is a constant below the clamp: plain scaling.
            for j in 0..width {
                dxr[j] = iv * dyr[j];
            }
        } else {
            let mut dot = 0.0f32;
            for j in 0..width {
                dot += xr[j] * dyr[j];
            }
            let c = iv * iv * iv * dot;
            for j in 0..width {
                dxr[j] = iv * dyr[j] - c * xr[j];
            }
        }
    }
    dx
}

// ----------------------------------------------------------------------
// Depthwise causal conv
// ----------------------------------------------------------------------

/// Depthwise causal conv along the sequence: x (B, L, C), w (K, C).
/// out[b, t, c] = sum_j w[j, c] * x[b, t - (K-1) + j, c] (zero-padded).
pub fn conv_fwd(x: &[f32], w: &[f32], b: usize, l: usize, c: usize, k: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; x.len()];
    for bi in 0..b {
        for t in 0..l {
            let yr = &mut y[(bi * l + t) * c..(bi * l + t + 1) * c];
            for j in 0..k {
                let t0 = (t + j).checked_sub(k - 1);
                let t0 = match t0 {
                    Some(v) if v < l => v,
                    _ => continue,
                };
                let wr = &w[j * c..(j + 1) * c];
                let xr = &x[(bi * l + t0) * c..(bi * l + t0 + 1) * c];
                for ch in 0..c {
                    yr[ch] += wr[ch] * xr[ch];
                }
            }
        }
    }
    y
}

/// Conv backward; accumulates into `dw`, returns dx.
pub fn conv_bwd(
    x: &[f32],
    w: &[f32],
    dy: &[f32],
    b: usize,
    l: usize,
    c: usize,
    k: usize,
    dw: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0.0f32; x.len()];
    for bi in 0..b {
        for t in 0..l {
            let dyr = &dy[(bi * l + t) * c..(bi * l + t + 1) * c];
            for j in 0..k {
                let t0 = match (t + j).checked_sub(k - 1) {
                    Some(v) if v < l => v,
                    _ => continue,
                };
                let wr = &w[j * c..(j + 1) * c];
                let xr = &x[(bi * l + t0) * c..(bi * l + t0 + 1) * c];
                let dwr = &mut dw[j * c..(j + 1) * c];
                let dxr = &mut dx[(bi * l + t0) * c..(bi * l + t0 + 1) * c];
                for ch in 0..c {
                    dwr[ch] += dyr[ch] * xr[ch];
                    dxr[ch] += wr[ch] * dyr[ch];
                }
            }
        }
    }
    dx
}

/// Causal conv over an `l`-token segment warm-started from a rolling
/// (K-1)-deep cache — the chunked-prefill form for a single sequence.
/// Token `t` sees the last K-1 pre-conv rows: from `cache` for positions
/// before the segment, from `pre` inside it, with the additions in the
/// same order as a chain of [`conv_step`] calls, so streaming a prompt
/// through any mix of prefill segments and single-token steps yields
/// bit-identical activations. The cache is advanced in place to hold the
/// segment's last K-1 pre-conv rows. pre: (L, C); cache: (K-1, C);
/// out: (L, C), **zeroed** by the caller.
pub fn conv_prefill(
    pre: &[f32],
    cache: &mut [f32],
    w: &[f32],
    l: usize,
    c: usize,
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(pre.len(), l * c);
    debug_assert_eq!(cache.len(), (k - 1) * c);
    debug_assert_eq!(w.len(), k * c);
    debug_assert_eq!(out.len(), l * c);
    for t in 0..l {
        let orow = &mut out[t * c..(t + 1) * c];
        for j in 0..k - 1 {
            // History position t - (K-1) + j; negative = initial cache row
            // t + j (the cache stores the K-1 rows before the segment,
            // oldest first — exactly conv_step's rolling layout).
            let xr = match (t + j).checked_sub(k - 1) {
                Some(h) => &pre[h * c..(h + 1) * c],
                None => &cache[(t + j) * c..(t + j + 1) * c],
            };
            let wr = &w[j * c..(j + 1) * c];
            for ch in 0..c {
                orow[ch] += wr[ch] * xr[ch];
            }
        }
        let wlast = &w[(k - 1) * c..k * c];
        let xr = &pre[t * c..(t + 1) * c];
        for ch in 0..c {
            orow[ch] += wlast[ch] * xr[ch];
        }
    }
    // Advance the cache to the segment's trailing K-1 pre-conv rows
    // (shift-and-append when the segment is shorter than the window).
    if l >= k - 1 {
        cache.copy_from_slice(&pre[(l - (k - 1)) * c..l * c]);
    } else {
        cache.copy_within(l * c.., 0);
        cache[(k - 1 - l) * c..].copy_from_slice(pre);
    }
}

/// Single-token causal conv over a rolling (K-1)-deep cache, cache updated
/// in place (shift left, append `pre`) — the O(1)-state decode form.
/// pre: (B, C) fresh pre-conv projection; cache: (B, K-1, C).
pub fn conv_step(
    pre: &[f32],
    cache: &mut [f32],
    w: &[f32],
    b: usize,
    c: usize,
    k: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; b * c];
    conv_step_into(pre, cache, w, b, c, k, &mut out);
    out
}

/// [`conv_step`] into a caller-provided **zeroed** output buffer (the
/// allocation-free decode form).
pub fn conv_step_into(
    pre: &[f32],
    cache: &mut [f32],
    w: &[f32],
    b: usize,
    c: usize,
    k: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(pre.len(), b * c);
    debug_assert_eq!(cache.len(), b * (k - 1) * c);
    debug_assert_eq!(w.len(), k * c);
    debug_assert_eq!(out.len(), b * c);
    for bi in 0..b {
        let crow = &cache[bi * (k - 1) * c..(bi + 1) * (k - 1) * c];
        let prow = &pre[bi * c..(bi + 1) * c];
        let orow = &mut out[bi * c..(bi + 1) * c];
        for j in 0..k - 1 {
            let wr = &w[j * c..(j + 1) * c];
            let xr = &crow[j * c..(j + 1) * c];
            for ch in 0..c {
                orow[ch] += wr[ch] * xr[ch];
            }
        }
        let wlast = &w[(k - 1) * c..k * c];
        for ch in 0..c {
            orow[ch] += wlast[ch] * prow[ch];
        }
    }
    for bi in 0..b {
        let crow = &mut cache[bi * (k - 1) * c..(bi + 1) * (k - 1) * c];
        crow.copy_within(c.., 0);
        crow[(k - 2) * c..].copy_from_slice(&pre[bi * c..(bi + 1) * c]);
    }
}

// ----------------------------------------------------------------------
// Executor-aware matmul wrappers
// ----------------------------------------------------------------------

/// Fresh m x n product a @ b, row-parallel when large enough.
pub fn matmul(exec: &Executor, a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_acc(exec, a, b, &mut out, m, k, n);
    out
}

/// out += a @ b, row-parallel when large enough (out: (m, n) accumulated
/// in place — pass a zeroed buffer, e.g. from the executor arena, for a
/// fresh product).
pub fn matmul_acc(
    exec: &Executor,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m * k * n < PAR_MIN_FLOPS || exec.threads() == 1 {
        matmul_into(a, b, out, m, k, n);
    } else {
        // Pin every row chunk to the kernel class of the full shape:
        // re-dispatching per chunk would change summation order with the
        // thread count (chunks can fall under the packing cutoffs).
        let class = gemm::matmul_class(m, k, n);
        exec.par_rows(m, out, |r0, r1, chunk| {
            gemm::matmul_into_class(class, &a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
        });
    }
}

/// out += a @ b^T, row-parallel when large enough
/// (out: (m, n) accumulated in place; b: (n, k) row-major).
pub fn matmul_nt_acc(
    exec: &Executor,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    if m * k * n < PAR_MIN_FLOPS || exec.threads() == 1 {
        matmul_nt_into(a, b, out, m, k, n);
    } else {
        // Same full-shape class pinning as matmul_acc (see there).
        let class = gemm::matmul_nt_class(m, k, n);
        exec.par_rows(m, out, |r0, r1, chunk| {
            gemm::matmul_nt_into_class(class, &a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
        });
    }
}

// ----------------------------------------------------------------------
// Serving matmuls: slot-batched class-pinned wrappers
// ----------------------------------------------------------------------

/// out += a @ b with every row's arithmetic pinned to the **slot-batched**
/// serving kernel class: the class is resolved from `slots`, the engine's
/// configured slot capacity (`decode_batch`), so the bits of row r depend
/// only on (slots, k, n) — never on how many busy rows share the call,
/// which executor chunk a row lands in, or the thread count. The serving
/// paths (batched decode over the busy slot set, single-slot decode, and
/// chunked prefill) route every projection through this, so a token's
/// trajectory is bit-identical whether it is ingested one token at a
/// time, inside a batched decode step at any occupancy, or as part of a
/// single-slot prompt chunk of any size.
// lint: no-alloc -- the serving matmuls never touch the allocator
pub fn matmul_acc_serving_batched(
    exec: &Executor,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    slots: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let class = gemm::serving_class(slots, k, n);
    if m * k * n < PAR_MIN_FLOPS || exec.threads() == 1 {
        gemm::matmul_into_class(class, a, b, out, m, k, n);
    } else {
        exec.par_rows(m, out, |r0, r1, chunk| {
            gemm::matmul_into_class(class, &a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
        });
    }
}

/// out += a @ b^T with the same slot-batched class pinning as
/// [`matmul_acc_serving_batched`] (b: (n, k) row-major).
// lint: no-alloc -- the serving matmuls never touch the allocator
pub fn matmul_nt_acc_serving_batched(
    exec: &Executor,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    slots: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let class = gemm::serving_nt_class(slots, k, n);
    if m * k * n < PAR_MIN_FLOPS || exec.threads() == 1 {
        gemm::matmul_nt_into_class(class, a, b, out, m, k, n);
    } else {
        exec.par_rows(m, out, |r0, r1, chunk| {
            gemm::matmul_nt_into_class(class, &a[r0 * k..r1 * k], b, chunk, r1 - r0, k, n);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn fd(mut f: impl FnMut(f32) -> f32, x: f32, h: f32) -> f32 {
        (f(x + h) - f(x - h)) / (2.0 * h)
    }

    #[test]
    fn silu_grad_matches_finite_differences() {
        for x in [-3.0f32, -0.5, 0.0, 0.7, 4.0] {
            let a = silu_grad(x);
            let n = fd(silu, x, 1e-3);
            assert!((a - n).abs() < 1e-3, "x={x}: {a} vs {n}");
        }
    }

    #[test]
    fn rms_norm_bwd_matches_finite_differences() {
        let mut rng = Rng::new(5);
        let width = 6;
        let x = rng.normal_vec(2 * width, 0.0, 1.0);
        let gain = rng.normal_vec(width, 1.0, 0.2);
        let w = rng.normal_vec(2 * width, 0.0, 1.0); // dL/dy
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = rms_norm_fwd(x, &gain, width, 1e-6);
            y.iter().zip(w.iter()).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let (_, inv) = rms_norm_fwd(&x, &gain, width, 1e-6);
        let mut dgain = vec![0.0f32; width];
        let dx = rms_norm_bwd(&x, &gain, &inv, &w, width, &mut dgain);
        let h = 1e-3f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!((dx[i] as f64 - n).abs() < 1e-2 * (1.0 + n.abs()), "dx[{i}]");
        }
    }

    #[test]
    fn l2norm_bwd_matches_finite_differences() {
        let mut rng = Rng::new(6);
        let width = 5;
        let x = rng.normal_vec(3 * width, 0.0, 1.0);
        let w = rng.normal_vec(3 * width, 0.0, 1.0);
        let loss = |x: &[f32]| -> f64 {
            let (y, _) = l2norm_fwd(x, width);
            y.iter().zip(w.iter()).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let (_, ss) = l2norm_fwd(&x, width);
        let dx = l2norm_bwd(&x, &ss, &w, width);
        let h = 1e-3f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let n = (loss(&xp) - loss(&xm)) / (2.0 * h as f64);
            assert!((dx[i] as f64 - n).abs() < 1e-2 * (1.0 + n.abs()), "dx[{i}]");
        }
    }

    #[test]
    fn conv_bwd_matches_finite_differences() {
        let mut rng = Rng::new(7);
        let (b, l, c, k) = (2, 5, 3, 4);
        let x = rng.normal_vec(b * l * c, 0.0, 1.0);
        let wk = rng.normal_vec(k * c, 0.0, 0.5);
        let dy = rng.normal_vec(b * l * c, 0.0, 1.0);
        let loss = |x: &[f32], wk: &[f32]| -> f64 {
            conv_fwd(x, wk, b, l, c, k)
                .iter()
                .zip(dy.iter())
                .map(|(&a, &g)| a as f64 * g as f64)
                .sum()
        };
        let mut dw = vec![0.0f32; k * c];
        let dx = conv_bwd(&x, &wk, &dy, b, l, c, k, &mut dw);
        let h = 1e-3f32;
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += h;
            let mut xm = x.clone();
            xm[i] -= h;
            let n = (loss(&xp, &wk) - loss(&xm, &wk)) / (2.0 * h as f64);
            assert!((dx[i] as f64 - n).abs() < 1e-2 * (1.0 + n.abs()), "dx[{i}]");
        }
        for i in 0..wk.len() {
            let mut wp = wk.clone();
            wp[i] += h;
            let mut wm = wk.clone();
            wm[i] -= h;
            let n = (loss(&x, &wp) - loss(&x, &wm)) / (2.0 * h as f64);
            assert!((dw[i] as f64 - n).abs() < 1e-2 * (1.0 + n.abs()), "dw[{i}]");
        }
    }

    #[test]
    fn conv_step_matches_full_conv_tail() {
        // Streaming the sequence token by token through conv_step must
        // reproduce conv_fwd exactly.
        let mut rng = Rng::new(8);
        let (b, l, c, k) = (2, 7, 3, 4);
        let x = rng.normal_vec(b * l * c, 0.0, 1.0);
        let wk = rng.normal_vec(k * c, 0.0, 0.5);
        let full = conv_fwd(&x, &wk, b, l, c, k);
        let mut cache = vec![0.0f32; b * (k - 1) * c];
        for t in 0..l {
            let mut pre = vec![0.0f32; b * c];
            for bi in 0..b {
                pre[bi * c..(bi + 1) * c]
                    .copy_from_slice(&x[(bi * l + t) * c..(bi * l + t + 1) * c]);
            }
            let out = conv_step(&pre, &mut cache, &wk, b, c, k);
            for bi in 0..b {
                let want = &full[(bi * l + t) * c..(bi * l + t + 1) * c];
                let got = &out[bi * c..(bi + 1) * c];
                for (a, e) in got.iter().zip(want.iter()) {
                    assert!((a - e).abs() < 1e-5, "t={t} bi={bi}");
                }
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_forms() {
        let mut rng = Rng::new(12);
        let width = 6;
        let x = rng.normal_vec(4 * width, 0.0, 1.0);
        let gain = rng.normal_vec(width, 1.0, 0.2);

        let (y_ref, _) = rms_norm_fwd(&x, &gain, width, 1e-6);
        let mut y = vec![7.0f32; x.len()]; // dirty: must be overwritten
        rms_norm_into(&x, &gain, width, 1e-6, &mut y);
        assert_eq!(y, y_ref);

        let (l2_ref, _) = l2norm_fwd(&x, width);
        let mut l2 = vec![7.0f32; x.len()];
        l2norm_into(&x, width, &mut l2);
        assert_eq!(l2, l2_ref);

        let z = rng.normal_vec(3 * width, 0.0, 1.0);
        let mut zi = z.clone();
        silu_inplace(&mut zi);
        assert_eq!(zi, silu_fwd(&z));
    }

    #[test]
    fn conv_step_into_matches_conv_step() {
        let mut rng = Rng::new(13);
        let (b, c, k) = (2, 5, 4);
        let wk = rng.normal_vec(k * c, 0.0, 0.5);
        let mut cache1 = rng.normal_vec(b * (k - 1) * c, 0.0, 1.0);
        let mut cache2 = cache1.clone();
        let pre = rng.normal_vec(b * c, 0.0, 1.0);
        let out_ref = conv_step(&pre, &mut cache1, &wk, b, c, k);
        let mut out = vec![0.0f32; b * c];
        conv_step_into(&pre, &mut cache2, &wk, b, c, k, &mut out);
        assert_eq!(out, out_ref);
        assert_eq!(cache1, cache2);
    }

    #[test]
    fn conv_prefill_matches_conv_step_chain_bitwise() {
        // Any split of a sequence into prefill segments (including
        // single-token segments == conv_step) must give the same outputs
        // and the same trailing cache, bit for bit.
        let mut rng = Rng::new(19);
        let (l, c, k) = (11, 5, 4);
        let x = rng.normal_vec(l * c, 0.0, 1.0);
        let wk = rng.normal_vec(k * c, 0.0, 0.5);

        // Reference: token-by-token conv_step chain (b = 1).
        let mut cache_ref = vec![0.0f32; (k - 1) * c];
        let mut out_ref = Vec::new();
        for t in 0..l {
            out_ref.extend(conv_step(&x[t * c..(t + 1) * c], &mut cache_ref, &wk, 1, c, k));
        }

        for split in [1usize, 2, 3, 5, 11] {
            let mut cache = vec![0.0f32; (k - 1) * c];
            let mut out = vec![0.0f32; l * c];
            let mut pos = 0;
            while pos < l {
                let end = (pos + split).min(l);
                conv_prefill(
                    &x[pos * c..end * c],
                    &mut cache,
                    &wk,
                    end - pos,
                    c,
                    k,
                    &mut out[pos * c..end * c],
                );
                pos = end;
            }
            assert_eq!(out, out_ref, "split {split}");
            assert_eq!(cache, cache_ref, "split {split}");
        }
    }

    #[test]
    fn serving_matmul_rows_are_occupancy_and_thread_invariant() {
        // The whole point of the slot-batched serving wrappers: row r's
        // bits must not depend on how many rows share the call (busy-slot
        // count vs prompt chunk length) or on the thread count, as long
        // as the configured slot capacity (`slots`) is the same.
        let mut rng = Rng::new(20);
        // 20*64*256 flops clears PAR_MIN_FLOPS, so threads > 1 exercises
        // the row-parallel split under the pinned class.
        let (k, n) = (64, 256);
        let slots = 20usize;
        let a = rng.normal_vec(slots * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let bt = rng.normal_vec(n * k, 0.0, 1.0);

        // Reference: every row computed in its own single-row call under
        // the same slot-capacity key.
        let exec1 = Executor::serial();
        let mut row_by_row = vec![0.0f32; slots * n];
        let mut row_by_row_nt = vec![0.0f32; slots * n];
        for r in 0..slots {
            matmul_acc_serving_batched(
                &exec1,
                &a[r * k..(r + 1) * k],
                &b,
                &mut row_by_row[r * n..(r + 1) * n],
                1,
                k,
                n,
                slots,
            );
            matmul_nt_acc_serving_batched(
                &exec1,
                &a[r * k..(r + 1) * k],
                &bt,
                &mut row_by_row_nt[r * n..(r + 1) * n],
                1,
                k,
                n,
                slots,
            );
        }
        // Every partial occupancy (a prefix of the slot block) and the
        // full batch must reproduce those rows bit-for-bit.
        for busy in [1usize, 7, slots] {
            for threads in [1usize, 2, 5] {
                let exec = Executor::new(threads);
                let mut full = vec![0.0f32; busy * n];
                matmul_acc_serving_batched(&exec, &a[..busy * k], &b, &mut full, busy, k, n, slots);
                assert_eq!(full, row_by_row[..busy * n], "nn busy={busy} threads={threads}");
                let mut full_nt = vec![0.0f32; busy * n];
                matmul_nt_acc_serving_batched(
                    &exec,
                    &a[..busy * k],
                    &bt,
                    &mut full_nt,
                    busy,
                    k,
                    n,
                    slots,
                );
                assert_eq!(
                    full_nt,
                    row_by_row_nt[..busy * n],
                    "nt busy={busy} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn matmul_acc_accumulates_and_matches_matmul() {
        let mut rng = Rng::new(14);
        let (m, k, n) = (5, 8, 7);
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let exec = Executor::serial();
        let fresh = matmul(&exec, &a, &b, m, k, n);
        let base: Vec<f32> = (0..m * n).map(|i| i as f32 * 0.1).collect();
        let mut acc = base.clone();
        matmul_acc(&exec, &a, &b, &mut acc, m, k, n);
        for i in 0..m * n {
            assert!((acc[i] - (base[i] + fresh[i])).abs() < 1e-5, "i={i}");
        }
    }

    #[test]
    fn parallel_matmul_bit_identical_even_with_tiny_row_chunks() {
        // Regression: 48 workers split m=128 into 2-3-row chunks, which
        // fall under the packed-kernel cutoffs. The kernel class must be
        // resolved from the full shape, not per chunk, or the summation
        // order (and hence the bits) would change with the thread count.
        let mut rng = Rng::new(15);
        let (m, k, n) = (128, 64, 64); // 512k flops: clears PAR_MIN_FLOPS
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let serial = matmul(&Executor::serial(), &a, &b, m, k, n);
        let par = matmul(&Executor::new(48), &a, &b, m, k, n);
        assert_eq!(serial, par);

        let bt = rng.normal_vec(n * k, 0.0, 1.0);
        let mut out1 = vec![0.0f32; m * n];
        matmul_nt_acc(&Executor::serial(), &a, &bt, &mut out1, m, k, n);
        let mut out48 = vec![0.0f32; m * n];
        matmul_nt_acc(&Executor::new(48), &a, &bt, &mut out48, m, k, n);
        assert_eq!(out1, out48);
    }

    #[test]
    fn parallel_matmul_is_bit_identical_to_serial() {
        let mut rng = Rng::new(9);
        // Big enough to clear PAR_MIN_FLOPS: 128 * 64 * 64 = 512k flops.
        let (m, k, n) = (128, 64, 64);
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let serial = matmul(&Executor::serial(), &a, &b, m, k, n);
        for threads in [2, 3, 4] {
            let par = matmul(&Executor::new(threads), &a, &b, m, k, n);
            assert_eq!(serial, par, "threads={threads}");
        }
        let bt = rng.normal_vec(n * k, 0.0, 1.0);
        let mut out1 = rng.normal_vec(m * n, 0.0, 0.1);
        let mut out4 = out1.clone();
        matmul_nt_acc(&Executor::serial(), &a, &bt, &mut out1, m, k, n);
        matmul_nt_acc(&Executor::new(4), &a, &bt, &mut out4, m, k, n);
        assert_eq!(out1, out4);
    }
}
