//! Typed host arrays + conversions to/from `xla::Literal`.

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;

use super::manifest::IoSpec;

/// Element dtypes crossing the runtime boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "s32" | "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A typed host array (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum HostValue {
    F32(Tensor),
    I32(Vec<usize>, Vec<i32>),
    U32(Vec<usize>, Vec<u32>),
}

impl HostValue {
    pub fn scalar_f32(x: f32) -> Self {
        HostValue::F32(Tensor::scalar(x))
    }

    pub fn scalar_u32(x: u32) -> Self {
        HostValue::U32(vec![], vec![x])
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostValue::I32(vec![], vec![x])
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32(shape.to_vec(), data)
    }

    pub fn zeros_like_spec(spec: &IoSpec) -> Self {
        let n: usize = spec.shape.iter().product();
        match spec.dtype {
            DType::F32 => HostValue::F32(Tensor::zeros(&spec.shape)),
            DType::I32 => HostValue::I32(spec.shape.clone(), vec![0; n]),
            DType::U32 => HostValue::U32(spec.shape.clone(), vec![0; n]),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostValue::F32(_) => DType::F32,
            HostValue::I32(..) => DType::I32,
            HostValue::U32(..) => DType::U32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32(s, _) => s,
            HostValue::U32(s, _) => s,
        }
    }

    /// Borrow as f32 tensor (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => Err(anyhow!("expected f32 value, got {:?}", other.dtype())),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => Err(anyhow!("expected f32 value, got {:?}", other.dtype())),
        }
    }

    /// Scalar f32 view.
    pub fn scalar(&self) -> Result<f32> {
        Ok(self.as_f32()?.item())
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, shape, bytes): (xla::ElementType, &[usize], &[u8]) = match self {
            HostValue::F32(t) => (xla::ElementType::F32, t.shape(), bytemuck_f32(t.data())),
            HostValue::I32(s, d) => (xla::ElementType::S32, s, bytemuck_i32(d)),
            HostValue::U32(s, d) => (xla::ElementType::U32, s, bytemuck_u32(d)),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, shape, bytes)
            .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }

    /// Read a literal back according to the manifest spec (shape is taken
    /// from the spec; dtype is checked against the literal's).
    pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<Self> {
        let n: usize = spec.shape.iter().product();
        match spec.dtype {
            DType::F32 => {
                let v = lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e:?}"))?;
                if v.len() != n {
                    bail!("output '{}': expected {} elems, got {}", spec.name, n, v.len());
                }
                Ok(HostValue::F32(Tensor::from_vec(&spec.shape, v)))
            }
            DType::I32 => {
                let v = lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e:?}"))?;
                if v.len() != n {
                    bail!("output '{}': expected {} elems, got {}", spec.name, n, v.len());
                }
                Ok(HostValue::I32(spec.shape.clone(), v))
            }
            DType::U32 => {
                let v = lit.to_vec::<u32>().map_err(|e| anyhow!("literal->u32: {e:?}"))?;
                if v.len() != n {
                    bail!("output '{}': expected {} elems, got {}", spec.name, n, v.len());
                }
                Ok(HostValue::U32(spec.shape.clone(), v))
            }
        }
    }
}

fn bytemuck_f32(x: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

fn bytemuck_i32(x: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

fn bytemuck_u32(x: &[u32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("s32").unwrap(), DType::I32);
        assert_eq!(DType::parse("u32").unwrap(), DType::U32);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn shapes_and_scalars() {
        let v = HostValue::scalar_f32(2.5);
        assert_eq!(v.shape(), &[] as &[usize]);
        assert!((v.scalar().unwrap() - 2.5).abs() < 1e-6);
        let t = HostValue::i32(&[2, 2], vec![1, 2, 3, 4]);
        assert_eq!(t.shape(), &[2, 2]);
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let v = HostValue::F32(t.clone());
        let lit = v.to_literal().unwrap();
        let spec = IoSpec { name: "x".into(), shape: vec![2, 3], dtype: DType::F32 };
        let back = HostValue::from_literal(&lit, &spec).unwrap();
        assert_eq!(back.as_f32().unwrap(), &t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let v = HostValue::i32(&[4], vec![-1, 0, 7, 42]);
        let lit = v.to_literal().unwrap();
        let spec = IoSpec { name: "t".into(), shape: vec![4], dtype: DType::I32 };
        let back = HostValue::from_literal(&lit, &spec).unwrap();
        assert_eq!(back, v);
    }
}
