//! Typed host arrays crossing the backend boundary.
//!
//! [`HostValue`] is the data currency of the [`super::Backend`] interface:
//! batches, decode state and scalar knobs all travel as typed host arrays.
//! The PJRT backend (feature `xla`) converts these to/from `xla::Literal`
//! at its edge; the CPU backend consumes them directly.

#![forbid(unsafe_code)]

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;

use super::manifest::IoSpec;

/// Element dtypes crossing the runtime boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "s32" | "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// A typed host array (row-major).
#[derive(Clone, Debug, PartialEq)]
pub enum HostValue {
    F32(Tensor),
    I32(Vec<usize>, Vec<i32>),
    U32(Vec<usize>, Vec<u32>),
}

impl HostValue {
    pub fn scalar_f32(x: f32) -> Self {
        HostValue::F32(Tensor::scalar(x))
    }

    pub fn scalar_u32(x: u32) -> Self {
        HostValue::U32(vec![], vec![x])
    }

    pub fn scalar_i32(x: i32) -> Self {
        HostValue::I32(vec![], vec![x])
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostValue::I32(shape.to_vec(), data)
    }

    pub fn zeros_like_spec(spec: &IoSpec) -> Self {
        let n: usize = spec.shape.iter().product();
        match spec.dtype {
            DType::F32 => HostValue::F32(Tensor::zeros(&spec.shape)),
            DType::I32 => HostValue::I32(spec.shape.clone(), vec![0; n]),
            DType::U32 => HostValue::U32(spec.shape.clone(), vec![0; n]),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostValue::F32(_) => DType::F32,
            HostValue::I32(..) => DType::I32,
            HostValue::U32(..) => DType::U32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32(s, _) => s,
            HostValue::U32(s, _) => s,
        }
    }

    /// Element count.
    pub fn elems(&self) -> usize {
        match self {
            HostValue::F32(t) => t.len(),
            HostValue::I32(_, d) => d.len(),
            HostValue::U32(_, d) => d.len(),
        }
    }

    /// Borrow as f32 tensor (errors on dtype mismatch).
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => Err(anyhow!("expected f32 value, got {:?}", other.dtype())),
        }
    }

    /// Mutably borrow as f32 tensor (errors on dtype mismatch).
    pub fn as_f32_mut(&mut self) -> Result<&mut Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => Err(anyhow!("expected f32 value, got {:?}", other.dtype())),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            other => Err(anyhow!("expected f32 value, got {:?}", other.dtype())),
        }
    }

    /// Borrow as an i32 array: (shape, data).
    pub fn as_i32(&self) -> Result<(&[usize], &[i32])> {
        match self {
            HostValue::I32(s, d) => Ok((s, d)),
            other => Err(anyhow!("expected i32 value, got {:?}", other.dtype())),
        }
    }

    /// Scalar f32 view.
    pub fn scalar(&self) -> Result<f32> {
        Ok(self.as_f32()?.item())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("s32").unwrap(), DType::I32);
        assert_eq!(DType::parse("u32").unwrap(), DType::U32);
        assert!(DType::parse("f64").is_err());
    }

    #[test]
    fn shapes_and_scalars() {
        let v = HostValue::scalar_f32(2.5);
        assert_eq!(v.shape(), &[] as &[usize]);
        assert!((v.scalar().unwrap() - 2.5).abs() < 1e-6);
        let t = HostValue::i32(&[2, 2], vec![1, 2, 3, 4]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.elems(), 4);
        assert!(t.as_f32().is_err());
        let (s, d) = t.as_i32().unwrap();
        assert_eq!(s, &[2, 2]);
        assert_eq!(d, &[1, 2, 3, 4]);
    }

    #[test]
    fn zeros_like_spec_shapes() {
        let spec = IoSpec { name: "x".into(), shape: vec![2, 3], dtype: DType::F32 };
        let v = HostValue::zeros_like_spec(&spec);
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.elems(), 6);
    }
}
