//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! The only bridge between the Rust coordinator and the compute graphs that
//! Python lowered at build time.  Flow per artifact:
//!
//!   artifacts/<name>.hlo.txt --HloModuleProto::from_text_file-->
//!   XlaComputation --PjRtClient::compile--> PjRtLoadedExecutable
//!
//! plus `artifacts/manifest.json` describing every input/output (name,
//! shape, dtype) in the flat order both sides agree on.  Executables are
//! cached per name; [`Executable::run`] validates shapes, executes, and
//! decomposes the tuple result back into typed host values.
//!
//! HLO *text* (not serialized protos) is load-bearing: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md + /opt/xla-example/README.md).

mod manifest;
mod value;

pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use value::{DType, HostValue};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

/// Lazily-compiling executable registry over an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        log::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.names().len()
        );
        Ok(Runtime { client, dir: dir.to_path_buf(), manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// True if the manifest knows this artifact.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    /// Load + compile (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let e = Rc::new(Executable { name: name.to_string(), spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }
}

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    name: String,
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with host values; returns outputs in manifest order.
    ///
    /// Validates input arity/shape/dtype against the manifest before
    /// touching PJRT so mismatches fail with a useful message instead of an
    /// XLA shape-check error.
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(self.spec.inputs.iter()) {
            if v.dtype() != spec.dtype || v.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|v| v.to_literal()).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute pre-built literals (hot path: caller reuses literals).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<HostValue>> {
        let parts = self.run_raw(literals)?;
        parts
            .into_iter()
            .zip(self.spec.outputs.iter())
            .map(|(lit, spec)| HostValue::from_literal(&lit, spec))
            .collect()
    }

    /// Execute and return raw literals in manifest output order.
    ///
    /// This is the training hot path: parameters and optimizer state stay as
    /// `xla::Literal`s across steps and are never converted to host vectors.
    pub fn run_raw(&self, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_raw_borrowed(&refs)
    }

    /// Borrowed-input variant of [`run_raw`] (avoids cloning literals when
    /// the caller owns a mixed set of long-lived and per-step inputs).
    pub fn run_raw_borrowed(&self, literals: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if literals.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                literals.len()
            );
        }
        let bufs = self
            .exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow!("{}: execute: {e:?}", self.name))?;
        let result = bufs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.name))?;
        let mut tuple = result
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e:?}", self.name))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("{}: decompose: {e:?}", self.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }
}
