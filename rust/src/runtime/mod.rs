//! Execution runtime: the [`Backend`] abstraction and its implementations.
//!
//! * [`backend`] — the `Backend` / `ModelSession` traits every coordinator
//!   component is written against.
//! * [`cpu`]     — the always-available pure-Rust backend (forward/backward,
//!   AdamW, eval, O(1)-state decode on top of `tensor::` + `attention::`).
//! * `pjrt`      — the PJRT/XLA backend over AOT HLO-text artifacts, behind
//!   the off-by-default `xla` feature (needs a vendored `xla` crate).
//! * [`manifest`] / [`value`] — the typed host-array + artifact-manifest
//!   contract shared by both backends.

pub mod backend;
pub mod cpu;
mod manifest;
mod value;

#[cfg(feature = "xla")]
pub mod pjrt;

pub use backend::{Backend, ModelSession, StepMetrics};
pub use cpu::CpuBackend;
pub use manifest::{ArtifactSpec, IoSpec, Manifest, ModelMeta};
pub use value::{DType, HostValue};

#[cfg(feature = "xla")]
pub use pjrt::{Executable, Runtime};

use std::path::Path;

use anyhow::Result;

/// Open the best available backend for an artifact directory.
///
/// With the `xla` feature and a `manifest.json` present, the PJRT backend
/// is used; otherwise the pure-Rust CPU backend (which needs no artifacts —
/// families are built from their names). The CPU executor resolves its
/// thread count from `EFLA_NUM_THREADS` / the machine; use
/// [`open_backend_threads`] to pin it explicitly.
pub fn open_backend(artifact_dir: &Path) -> Result<Box<dyn Backend>> {
    open_backend_threads(artifact_dir, 0)
}

/// [`open_backend`] with an explicit CPU worker-thread count
/// (0 = auto: `EFLA_NUM_THREADS` if set, else available parallelism).
pub fn open_backend_threads(artifact_dir: &Path, threads: usize) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "xla")]
    {
        if artifact_dir.join("manifest.json").exists() {
            return Ok(Box::new(pjrt::Runtime::open(artifact_dir)?));
        }
        log::info!(
            "no PJRT artifacts at {}; falling back to the CPU backend",
            artifact_dir.display()
        );
    }
    #[cfg(not(feature = "xla"))]
    let _ = artifact_dir;
    Ok(Box::new(CpuBackend::with_threads(threads)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_backend_falls_back_to_cpu() {
        let b = open_backend(Path::new("/definitely/not/an/artifact/dir")).unwrap();
        assert!(b.has_family("lm_tiny_efla"));
        #[cfg(not(feature = "xla"))]
        assert_eq!(b.name(), "cpu");
    }
}
