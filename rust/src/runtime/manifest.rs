//! AOT manifest: the contract `python/compile/aot.py` writes and the Rust
//! runtime honors.  One [`ArtifactSpec`] per lowered graph.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::util::json::{self, Json};

use super::value::DType;

/// One input or output of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(IoSpec {
            name: j.str_field("name")?.to_string(),
            shape: j.get("shape").usize_array()?,
            dtype: DType::parse(j.str_field("dtype")?)?,
        })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Static model configuration recorded for LM/classifier artifacts.
#[derive(Clone, Debug, Default)]
pub struct ModelMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub chunk: usize,
}

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub task: String,   // "lm" | "classifier"
    pub graph: String,  // "init" | "step" | "eval" | "logits_last" | "decode" | "prefill"
    pub preset: String, // "tiny" | "small" | ...
    pub mixer: String,  // "efla" | "deltanet" | ...
    pub batch: usize,
    pub seq: usize,
    pub param_names: Vec<String>,
    pub state_names: Vec<String>,
    pub model: ModelMeta,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        let io = |key: &str| -> Result<Vec<IoSpec>> {
            j.get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("artifact missing '{key}'"))?
                .iter()
                .map(IoSpec::from_json)
                .collect()
        };
        let names = |key: &str| -> Vec<String> {
            j.get(key)
                .as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        let cfg = j.get("config");
        let model = ModelMeta {
            vocab: cfg.get("vocab").as_usize().unwrap_or(0),
            d_model: cfg.get("d_model").as_usize().unwrap_or(0),
            n_layers: cfg.get("n_layers").as_usize().unwrap_or(0),
            n_heads: cfg.get("n_heads").as_usize().unwrap_or(0),
            head_dim: cfg.get("head_dim").as_usize().unwrap_or(0),
            chunk: cfg.get("chunk").as_usize().unwrap_or(0),
        };
        Ok(ArtifactSpec {
            file: j.str_field("file")?.to_string(),
            task: j.get("task").as_str().unwrap_or("").to_string(),
            graph: j.get("graph").as_str().unwrap_or("").to_string(),
            preset: j.get("preset").as_str().unwrap_or("").to_string(),
            mixer: j.get("mixer").as_str().unwrap_or("").to_string(),
            batch: j.get("batch").as_usize().unwrap_or(0),
            seq: j.get("seq").as_usize().unwrap_or(0),
            param_names: names("param_names"),
            state_names: names("state_names"),
            model,
            inputs: io("inputs")?,
            outputs: io("outputs")?,
        })
    }

    /// Number of model parameters (f32 elements across param inputs).
    pub fn param_elems(&self) -> usize {
        let pset: std::collections::HashSet<String> =
            self.param_names.iter().map(|n| format!("p.{n}")).collect();
        self.inputs.iter().filter(|i| pset.contains(&i.name)).map(|i| i.elems()).sum()
    }

    /// Input index of a named input.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| anyhow!("no input named '{name}'"))
    }

    /// Output index of a named output.
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| anyhow!("no output named '{name}'"))
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let j = json::read_file(path)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let arts = j
            .get("artifacts")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' object"))?;
        let mut artifacts = BTreeMap::new();
        for (name, spec) in arts {
            artifacts.insert(
                name.clone(),
                ArtifactSpec::from_json(spec)
                    .map_err(|e| anyhow!("artifact '{name}': {e}"))?,
            );
        }
        Ok(Manifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    /// All artifacts for a (task, preset, mixer) triple.
    pub fn family(&self, task: &str, preset: &str, mixer: &str) -> Vec<(&str, &ArtifactSpec)> {
        self.artifacts
            .iter()
            .filter(|(_, a)| a.task == task && a.preset == preset && a.mixer == mixer)
            .map(|(n, a)| (n.as_str(), a))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        json::parse(
            r#"{
              "version": 1,
              "artifacts": {
                "lm_tiny_efla_step": {
                  "file": "lm_tiny_efla_step.hlo.txt",
                  "task": "lm", "graph": "step", "preset": "tiny", "mixer": "efla",
                  "batch": 4, "seq": 64,
                  "param_names": ["embed", "norm_f"],
                  "config": {"vocab": 256, "d_model": 64, "n_layers": 2,
                             "n_heads": 2, "head_dim": 32, "chunk": 32},
                  "inputs": [
                    {"name": "p.embed", "shape": [256, 64], "dtype": "f32"},
                    {"name": "p.norm_f", "shape": [64], "dtype": "f32"},
                    {"name": "tokens", "shape": [4, 64], "dtype": "s32"}
                  ],
                  "outputs": [
                    {"name": "loss", "shape": [], "dtype": "f32"}
                  ]
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_manifest() {
        let m = Manifest::from_json(&sample()).unwrap();
        let a = m.get("lm_tiny_efla_step").unwrap();
        assert_eq!(a.batch, 4);
        assert_eq!(a.model.vocab, 256);
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[2].dtype, DType::I32);
        assert_eq!(a.param_elems(), 256 * 64 + 64);
        assert_eq!(a.input_index("tokens").unwrap(), 2);
        assert!(a.input_index("nope").is_err());
        assert_eq!(m.family("lm", "tiny", "efla").len(), 1);
        assert!(m.get("missing").is_none());
    }
}
