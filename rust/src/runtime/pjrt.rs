//! PJRT/XLA backend: load AOT HLO-text artifacts and execute them.
//!
//! Compiled only with the off-by-default `xla` feature (requires a vendored
//! `xla` crate — see README). Flow per artifact:
//!
//!   artifacts/<name>.hlo.txt --HloModuleProto::from_text_file-->
//!   XlaComputation --PjRtClient::compile--> PjRtLoadedExecutable
//!
//! plus `artifacts/manifest.json` describing every input/output (name,
//! shape, dtype) in the flat order both sides agree on.  Executables are
//! cached per name; [`Executable::run`] validates shapes, executes, and
//! decomposes the tuple result back into typed host values.
//!
//! HLO *text* (not serialized protos) is load-bearing: jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see DESIGN.md + /opt/xla-example/README.md).
//!
//! [`Runtime`] implements [`Backend`], binding each artifact *family* to a
//! [`PjrtSession`] whose parameters and AdamW moments stay resident as
//! `xla::Literal`s across steps (never converted to host vectors on the
//! hot path).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

use super::backend::{Backend, ModelSession, StepMetrics};
use super::manifest::{ArtifactSpec, IoSpec, Manifest};
use super::value::{DType, HostValue};

/// HostValue -> literal at the PJRT edge.
pub fn to_literal(v: &HostValue) -> Result<xla::Literal> {
    let (ty, shape, bytes): (xla::ElementType, &[usize], &[u8]) = match v {
        HostValue::F32(t) => (xla::ElementType::F32, t.shape(), bytemuck_f32(t.data())),
        HostValue::I32(s, d) => (xla::ElementType::S32, s, bytemuck_i32(d)),
        HostValue::U32(s, d) => (xla::ElementType::U32, s, bytemuck_u32(d)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, shape, bytes)
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
}

/// Literal -> HostValue according to the manifest spec (shape is taken from
/// the spec; dtype is checked against the literal's).
pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<HostValue> {
    let n: usize = spec.shape.iter().product();
    match spec.dtype {
        DType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e:?}"))?;
            if v.len() != n {
                bail!("output '{}': expected {} elems, got {}", spec.name, n, v.len());
            }
            Ok(HostValue::F32(Tensor::from_vec(&spec.shape, v)))
        }
        DType::I32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e:?}"))?;
            if v.len() != n {
                bail!("output '{}': expected {} elems, got {}", spec.name, n, v.len());
            }
            Ok(HostValue::I32(spec.shape.clone(), v))
        }
        DType::U32 => {
            let v = lit.to_vec::<u32>().map_err(|e| anyhow!("literal->u32: {e:?}"))?;
            if v.len() != n {
                bail!("output '{}': expected {} elems, got {}", spec.name, n, v.len());
            }
            Ok(HostValue::U32(spec.shape.clone(), v))
        }
    }
}

fn bytemuck_f32(x: &[f32]) -> &[u8] {
    // SAFETY: u8 has no alignment or validity requirements; the byte view
    // covers exactly the 4*len bytes of `x` and inherits its lifetime.
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

fn bytemuck_i32(x: &[i32]) -> &[u8] {
    // SAFETY: as bytemuck_f32 — in-bounds, u8-aligned, borrow-preserving.
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

fn bytemuck_u32(x: &[u32]) -> &[u8] {
    // SAFETY: as bytemuck_f32 — in-bounds, u8-aligned, borrow-preserving.
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

/// Lazily-compiling executable registry over an artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        log::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.names().len()
        );
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// True if the manifest knows this artifact.
    pub fn has(&self, name: &str) -> bool {
        self.manifest.get(name).is_some()
    }

    /// Load + compile (cached).
    pub fn load(&self, name: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        log::info!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let e = Rc::new(Executable { name: name.to_string(), spec, exe });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }
}

impl Backend for Runtime {
    fn name(&self) -> &str {
        "pjrt"
    }

    fn has_family(&self, family: &str) -> bool {
        self.has(&format!("{family}_step")) && self.has(&format!("{family}_init"))
    }

    fn describe(&self) -> Vec<String> {
        self.manifest
            .names()
            .into_iter()
            .map(|n| {
                let a = self.manifest.get(n).expect("listed artifact");
                format!(
                    "{n:<34} params {:>8}  batch {:>4} x seq {:>4}  {}",
                    a.param_elems(),
                    a.batch,
                    a.seq,
                    a.graph
                )
            })
            .collect()
    }

    fn open_session(&self, family: &str, seed: u32) -> Result<Box<dyn ModelSession>> {
        Ok(Box::new(PjrtSession::init(self, family, seed)?))
    }
}

/// A compiled artifact plus its manifest spec.
pub struct Executable {
    name: String,
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with host values; returns outputs in manifest order.
    ///
    /// Validates input arity/shape/dtype against the manifest before
    /// touching PJRT so mismatches fail with a useful message instead of an
    /// XLA shape-check error.
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, spec) in inputs.iter().zip(self.spec.inputs.iter()) {
            if v.dtype() != spec.dtype || v.shape() != spec.shape.as_slice() {
                bail!(
                    "{}: input '{}' expects {:?}{:?}, got {:?}{:?}",
                    self.name,
                    spec.name,
                    spec.dtype,
                    spec.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        self.run_literals(&literals)
    }

    /// Execute pre-built literals (hot path: caller reuses literals).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> Result<Vec<HostValue>> {
        let parts = self.run_raw(literals)?;
        parts
            .into_iter()
            .zip(self.spec.outputs.iter())
            .map(|(lit, spec)| from_literal(&lit, spec))
            .collect()
    }

    /// Execute and return raw literals in manifest output order.
    ///
    /// This is the training hot path: parameters and optimizer state stay as
    /// `xla::Literal`s across steps and are never converted to host vectors.
    pub fn run_raw(&self, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_raw_borrowed(&refs)
    }

    /// Borrowed-input variant of [`run_raw`](Self::run_raw) (avoids cloning
    /// literals when the caller owns a mixed set of long-lived and per-step
    /// inputs).
    pub fn run_raw_borrowed(&self, literals: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if literals.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                literals.len()
            );
        }
        let bufs = self
            .exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow!("{}: execute: {e:?}", self.name))?;
        let result = bufs
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: empty execution result", self.name))?;
        let mut tuple = result
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e:?}", self.name))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("{}: decompose: {e:?}", self.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: manifest promises {} outputs, executable returned {}",
                self.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }
}

/// Parameters + AdamW moments threaded through the AOT step executable as
/// raw literals.
pub struct PjrtSession {
    family: String,
    step_exe: Rc<Executable>,
    eval_exe: Option<Rc<Executable>>,
    decode_exe: Option<Rc<Executable>>,
    /// Flattened params, then m, then v — exactly the step graph's prefix.
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    n_params: usize,
    step_count: u64,
    batch: usize,
    seq: usize,
}

impl PjrtSession {
    /// Initialize from artifacts: runs `<family>_init` with `seed`.
    pub fn init(rt: &Runtime, family: &str, seed: u32) -> Result<Self> {
        let init_exe = rt.load(&format!("{family}_init"))?;
        let step_exe = rt.load(&format!("{family}_step"))?;
        let eval_exe = match rt.has(&format!("{family}_eval")) {
            true => Some(rt.load(&format!("{family}_eval"))?),
            false => None,
        };
        let decode_exe = match rt.has(&format!("{family}_decode")) {
            true => Some(rt.load(&format!("{family}_decode"))?),
            false => None,
        };
        let seed_lit = to_literal(&HostValue::scalar_u32(seed))?;
        let params = init_exe.run_raw(&[seed_lit])?;
        let n_params = params.len();

        // Zero AdamW moments shaped like the step graph's m./v. inputs.
        let spec = step_exe.spec();
        let expected = 3 * n_params + 4;
        if spec.inputs.len() != expected {
            bail!(
                "{family}_step: expected {expected} inputs (3x{n_params} state + step/tokens/targets/lr), manifest has {}",
                spec.inputs.len()
            );
        }
        let zeros = |range: std::ops::Range<usize>| -> Result<Vec<xla::Literal>> {
            range
                .map(|i| to_literal(&HostValue::zeros_like_spec(&spec.inputs[i])))
                .collect()
        };
        let m = zeros(n_params..2 * n_params)?;
        let v = zeros(2 * n_params..3 * n_params)?;

        Ok(PjrtSession {
            family: family.to_string(),
            batch: spec.batch,
            seq: spec.seq,
            step_exe,
            eval_exe,
            decode_exe,
            params,
            m,
            v,
            n_params,
            step_count: 0,
        })
    }

    fn decode_exe(&self) -> Result<&Rc<Executable>> {
        self.decode_exe
            .as_ref()
            .ok_or_else(|| anyhow!("{}: no decode artifact", self.family))
    }
}

impl ModelSession for PjrtSession {
    fn family(&self) -> &str {
        &self.family
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn n_param_tensors(&self) -> usize {
        self.n_params
    }

    fn param_elems(&self) -> usize {
        self.step_exe.spec().param_elems()
    }

    fn steps_done(&self) -> u64 {
        self.step_count
    }

    fn step(&mut self, d0: &HostValue, d1: &HostValue, lr: f32) -> Result<StepMetrics> {
        self.step_count += 1;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 * self.n_params + 4);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        let step_lit = to_literal(&HostValue::scalar_f32(self.step_count as f32))?;
        let lr_lit = to_literal(&HostValue::scalar_f32(lr))?;
        let d0_lit = to_literal(d0)?;
        let d1_lit = to_literal(d1)?;
        inputs.push(&step_lit);
        inputs.push(&d0_lit);
        inputs.push(&d1_lit);
        inputs.push(&lr_lit);

        // Borrow-based execute avoids cloning literals.
        let outs = self.step_exe.run_raw_borrowed(&inputs)?;
        let n = self.n_params;
        if outs.len() != 3 * n + 2 {
            bail!("step returned {} outputs, expected {}", outs.len(), 3 * n + 2);
        }
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n).collect();
        self.m = (&mut it).take(n).collect();
        self.v = (&mut it).take(n).collect();
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss"))?
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?;
        let gnorm = it
            .next()
            .ok_or_else(|| anyhow!("missing gnorm"))?
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("gnorm: {e:?}"))?;
        Ok(StepMetrics { loss, grad_norm: gnorm })
    }

    fn eval(&self, d0: &HostValue, d1: &HostValue) -> Result<Vec<f32>> {
        let exe = self
            .eval_exe
            .as_ref()
            .ok_or_else(|| anyhow!("{}: no eval artifact", self.family))?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.n_params + 2);
        inputs.extend(self.params.iter());
        let d0_lit = to_literal(d0)?;
        let d1_lit = to_literal(d1)?;
        inputs.push(&d0_lit);
        inputs.push(&d1_lit);
        let outs = exe.run_raw_borrowed(&inputs)?;
        outs.into_iter()
            .map(|l| l.get_first_element::<f32>().map_err(|e| anyhow!("eval out: {e:?}")))
            .collect()
    }

    fn export_params(&self) -> Result<Vec<Tensor>> {
        let spec = self.step_exe.spec();
        self.params
            .iter()
            .enumerate()
            .map(|(i, lit)| from_literal(lit, &spec.inputs[i])?.into_f32())
            .collect()
    }

    fn export_state(&self) -> Result<Vec<Tensor>> {
        let spec = self.step_exe.spec();
        let mut out = Vec::with_capacity(3 * self.n_params);
        for (off, group) in
            [(0usize, &self.params), (self.n_params, &self.m), (2 * self.n_params, &self.v)]
        {
            for (i, lit) in group.iter().enumerate() {
                out.push(from_literal(lit, &spec.inputs[off + i])?.into_f32()?);
            }
        }
        Ok(out)
    }

    fn import_state(&mut self, tensors: &[Tensor], step_count: u64) -> Result<()> {
        if tensors.len() != 3 * self.n_params {
            bail!(
                "checkpoint has {} tensors, session needs {}",
                tensors.len(),
                3 * self.n_params
            );
        }
        let lits: Vec<xla::Literal> = tensors
            .iter()
            .map(|t| to_literal(&HostValue::F32(t.clone())))
            .collect::<Result<_>>()?;
        let mut it = lits.into_iter();
        self.params = (&mut it).take(self.n_params).collect();
        self.m = (&mut it).take(self.n_params).collect();
        self.v = (&mut it).take(self.n_params).collect();
        self.step_count = step_count;
        Ok(())
    }

    fn decode_batch(&self) -> Result<usize> {
        let spec = self.decode_exe()?.spec();
        let batch = spec
            .inputs
            .last()
            .map(|t| t.shape.first().copied().unwrap_or(0))
            .unwrap_or(0);
        if batch == 0 {
            bail!("{}_decode: cannot infer decode batch", self.family);
        }
        Ok(batch)
    }

    fn vocab(&self) -> Result<usize> {
        let spec = self.decode_exe()?.spec();
        let vocab = spec.outputs[0].shape.last().copied().unwrap_or(0);
        if vocab == 0 {
            bail!("{}_decode: cannot infer vocab", self.family);
        }
        Ok(vocab)
    }

    fn decode_state(&self) -> Result<Vec<HostValue>> {
        let spec = self.decode_exe()?.spec();
        // State inputs sit between params and the trailing token input.
        let n_state = spec.state_names.len();
        let state_specs = &spec.inputs[spec.inputs.len() - 1 - n_state..spec.inputs.len() - 1];
        Ok(state_specs.iter().map(HostValue::zeros_like_spec).collect())
    }

    fn decode(&self, state: &mut [HostValue], tokens: &[i32]) -> Result<Tensor> {
        let exe = self.decode_exe()?.clone();
        let spec = exe.spec();
        let batch = self.decode_batch()?;
        if tokens.len() != batch {
            bail!("{}_decode: expected {batch} tokens, got {}", self.family, tokens.len());
        }
        let mut extra: Vec<xla::Literal> =
            state.iter().map(to_literal).collect::<Result<_>>()?;
        extra.push(to_literal(&HostValue::i32(&[batch], tokens.to_vec()))?);

        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.n_params + extra.len());
        inputs.extend(self.params.iter());
        inputs.extend(extra.iter());
        let outs = exe.run_raw_borrowed(&inputs)?;

        if outs.len() != state.len() + 1 {
            bail!(
                "{}_decode: graph returned {} outputs, expected logits + {} state tensors",
                self.family,
                outs.len(),
                state.len()
            );
        }
        let logits = from_literal(&outs[0], &spec.outputs[0])?.into_f32()?;
        // The PJRT graph returns fresh state tensors. Convert them all
        // before touching the caller's slots, so a mid-conversion failure
        // never leaves the live decode state half old / half new.
        let mut fresh = Vec::with_capacity(state.len());
        for (i, lit) in outs.iter().enumerate().skip(1) {
            fresh.push(from_literal(lit, &spec.outputs[i])?);
        }
        for (slot, value) in state.iter_mut().zip(fresh) {
            *slot = value;
        }
        Ok(logits)
    }
}
