//! The execution-backend abstraction.
//!
//! The EFLA/DeltaNet math is backend-agnostic, so the coordinator is too:
//! everything above this layer (trainer, evaluator, server, experiments,
//! the `efla` binary) talks to a [`Backend`] that opens [`ModelSession`]s
//! for artifact *families* (`lm_tiny_efla`, `clf_deltanet`, ...), and a
//! session exposes the five operations the system needs:
//!
//! * `step`  — one fused fwd+bwd+AdamW optimizer step;
//! * `eval`  — forward-only loss/accuracy statistics;
//! * `decode` — one-token recurrent decode over host-resident state
//!   (the O(1)-state serving path);
//! * `decode_slots` — batched decode over the busy subset of serving
//!   slots in one pass (optional; probed via `supports_batched_decode`);
//! * `prefill` — chunked prompt ingestion for one serving slot through
//!   the parallel forward path (optional; probed via `supports_prefill`);
//! * `export_state` / `import_state` — checkpointing.
//!
//! Implementations:
//! * [`crate::runtime::cpu::CpuBackend`] — always available, pure Rust on
//!   top of `tensor::` + `attention::`;
//! * `crate::runtime::pjrt::Runtime` — PJRT/XLA over AOT HLO-text
//!   artifacts, behind the off-by-default `xla` feature.

#![forbid(unsafe_code)]

use anyhow::Result;

use crate::tensor::Tensor;

use super::value::HostValue;

/// Scalar training metrics returned by one optimizer step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub grad_norm: f32,
}

/// An execution backend: a factory of model sessions.
pub trait Backend {
    /// Short backend name for logs ("cpu", "pjrt").
    fn name(&self) -> &str;

    /// True if this backend can build the family (e.g. `lm_tiny_efla`).
    fn has_family(&self, family: &str) -> bool;

    /// Human-readable list of available families / artifacts (`efla info`).
    fn describe(&self) -> Vec<String>;

    /// Initialize a model session (seeded parameter init).
    fn open_session(&self, family: &str, seed: u32) -> Result<Box<dyn ModelSession>>;
}

/// A model bound to a backend: parameters + optimizer state + the graphs.
pub trait ModelSession {
    fn family(&self) -> &str;

    /// Training batch dimensions.
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;

    fn n_param_tensors(&self) -> usize;

    /// Total parameter element count.
    fn param_elems(&self) -> usize;

    fn steps_done(&self) -> u64;

    /// Worker threads the session's executor uses (1 for backends without
    /// a host-side work-splitter).
    fn threads(&self) -> usize {
        1
    }

    /// One optimizer step. `d0`/`d1` are the two data slots of the step
    /// graph (tokens/targets for LM+MAD, pixels/labels for the classifier).
    fn step(&mut self, d0: &HostValue, d1: &HostValue, lr: f32) -> Result<StepMetrics>;

    /// Forward-only eval statistics on one batch: LM returns
    /// `[loss_sum, token_count, correct]`, the classifier
    /// `[loss_sum, correct]`.
    fn eval(&self, d0: &HostValue, d1: &HostValue) -> Result<Vec<f32>>;

    /// Export parameters to host tensors (inspection).
    fn export_params(&self) -> Result<Vec<Tensor>>;

    /// Export full optimizer state (params, m, v) for checkpointing.
    fn export_state(&self) -> Result<Vec<Tensor>>;

    /// Restore state exported by `export_state` (sets step counter too).
    fn import_state(&mut self, tensors: &[Tensor], step: u64) -> Result<()>;

    // ---- recurrent decode (serving) path -----------------------------

    /// Decode slot count (fixed batch of the decode graph).
    fn decode_batch(&self) -> Result<usize>;

    /// Vocabulary size of the decode logits.
    fn vocab(&self) -> Result<usize>;

    /// Zeroed per-slot recurrent state (one `HostValue` per state tensor,
    /// each shaped `(decode_batch, ...)` so slot rows can be cleared
    /// host-side between requests).
    fn decode_state(&self) -> Result<Vec<HostValue>>;

    /// One batched decode step: feed one token per slot, advance `state`
    /// **in place** (shapes are preserved; the serving loop never copies
    /// state between steps), return logits `(decode_batch, vocab)`.
    fn decode(&self, state: &mut [HostValue], tokens: &[i32]) -> Result<Tensor>;

    /// True when [`ModelSession::decode_slots`] is implemented — the
    /// serving engine falls back to full-batch [`ModelSession::decode`]
    /// otherwise.
    fn supports_batched_decode(&self) -> bool {
        false
    }

    /// Batched decode over the **busy subset** of slots: `slots` lists
    /// the busy slot ids (strictly increasing, below `decode_batch`) and
    /// `tokens[i]` is the next token for `slots[i]`. Advances only the
    /// listed slots' state rows **in place** and returns logits
    /// `(slots.len(), vocab)`, row i belonging to `slots[i]`.
    ///
    /// Contract: slot s's logits and state advance are bit-identical
    /// whatever subset of slots shares the call — a solo call, any
    /// partial occupancy, or the full batch (which matches
    /// [`ModelSession::decode`] exactly). Batching is a pure throughput
    /// optimization, never a numerics change.
    fn decode_slots(
        &self,
        state: &mut [HostValue],
        slots: &[usize],
        tokens: &[i32],
    ) -> Result<Tensor> {
        let _ = (state, slots, tokens);
        anyhow::bail!("{}: batched decode is not supported by this backend", self.family())
    }

    /// True when [`ModelSession::prefill`] is implemented — the serving
    /// engine falls back to token-at-a-time prompt ingestion otherwise.
    fn supports_prefill(&self) -> bool {
        false
    }

    /// Chunked prompt prefill: run `tokens` (a whole prompt or a chunk of
    /// it) through the parallel forward path for one `slot`, seeded from
    /// that slot's rows of `state` (advanced **in place**; all other
    /// slots' rows are untouched), and return the last-position logits,
    /// shape `(1, vocab)`.
    ///
    /// Contract: for any prompt and any split into prefill calls, the
    /// final slot state and logits are bit-identical to feeding the same
    /// tokens one per step through [`ModelSession::decode`] — chunking is
    /// a pure throughput optimization, never a numerics change.
    fn prefill(&self, state: &mut [HostValue], slot: usize, tokens: &[i32]) -> Result<Tensor> {
        let _ = (state, slot, tokens);
        anyhow::bail!("{}: prefill is not supported by this backend", self.family())
    }

    /// True when [`ModelSession::export_slot_state`] /
    /// [`ModelSession::import_slot_state`] are implemented — the serving
    /// engine disables the session state cache otherwise.
    fn supports_state_io(&self) -> bool {
        false
    }

    /// Export one serving slot's recurrent state: that slot's row of
    /// every decode-state tensor, in [`ModelSession::decode_state`]
    /// order, as raw f32 bits. Because the EFLA state is an exact pure
    /// function of the tokens fed through the slot, the exported rows
    /// fully determine future decode behavior: importing them into any
    /// slot reproduces it bit-for-bit.
    fn export_slot_state(&self, state: &[HostValue], slot: usize) -> Result<Vec<Vec<f32>>> {
        let _ = (state, slot);
        anyhow::bail!("{}: slot state export is not supported by this backend", self.family())
    }

    /// Restore rows captured by [`ModelSession::export_slot_state`] into
    /// `slot` — any slot, not necessarily the one they came from; state
    /// rows are slot-position independent. Every other slot's rows are
    /// left untouched.
    fn import_slot_state(
        &self,
        state: &mut [HostValue],
        slot: usize,
        rows: &[Vec<f32>],
    ) -> Result<()> {
        let _ = (state, slot, rows);
        anyhow::bail!("{}: slot state import is not supported by this backend", self.family())
    }
}
