//! Binary checkpoints: JSON header + raw little-endian f32 payload.
//!
//! Format:
//!   [u32 magic "EFLA"] [u32 header_len] [header JSON bytes] [f32 data...]
//! Header: {"step": N, "tensors": [{"shape": [...]}, ...]} — tensor order is
//! the session's export order (params, m, v).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::tensor::Tensor;
use crate::util::json::{self, Json};

/// File/wire magic ("EFLA"). Shared with the state-cache wire form
/// ([`crate::serve::state_cache::CachedState::to_wire`]), which mirrors
/// this layout into a byte buffer for the `/v1/state/{session}`
/// transfer endpoints.
pub const MAGIC: u32 = 0x45464C41;

/// Write a checkpoint.
pub fn save(path: &Path, step: u64, tensors: &[Tensor]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let header = Json::obj(vec![
        ("step", Json::Num(step as f64)),
        (
            "tensors",
            Json::Arr(
                tensors
                    .iter()
                    .map(|t| Json::obj(vec![("shape", Json::arr_usize(t.shape()))]))
                    .collect(),
            ),
        ),
    ])
    .to_string();

    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(&MAGIC.to_le_bytes())?;
    f.write_all(&(header.len() as u32).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for t in tensors {
        for x in t.data() {
            f.write_all(&x.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

/// Read a checkpoint; returns (step, tensors).
pub fn load(path: &Path) -> Result<(u64, Vec<Tensor>)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).map_err(|e| anyhow!("open {}: {e}", path.display()))?,
    );
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    if u32::from_le_bytes(u32buf) != MAGIC {
        bail!("{}: not an EFLA checkpoint (bad magic)", path.display());
    }
    f.read_exact(&mut u32buf)?;
    let hlen = u32::from_le_bytes(u32buf) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = json::parse(std::str::from_utf8(&hbuf)?)
        .map_err(|e| anyhow!("checkpoint header: {e}"))?;
    let step = header.usize_field("step")? as u64;
    let specs = header
        .get("tensors")
        .as_arr()
        .ok_or_else(|| anyhow!("checkpoint header missing tensors"))?;

    let mut tensors = Vec::with_capacity(specs.len());
    for spec in specs {
        let shape = spec.get("shape").usize_array()?;
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        tensors.push(Tensor::from_vec(&shape, data));
    }
    // Must be at EOF.
    let mut extra = [0u8; 1];
    if f.read(&mut extra)? != 0 {
        bail!("{}: trailing bytes after tensors", path.display());
    }
    Ok((step, tensors))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("efla_ckpt_test_{}", std::process::id()));
        let path = dir.join("test.ckpt");
        let tensors = vec![
            Tensor::from_vec(&[2, 3], vec![1., -2., 3.5, 0., 1e-9, 7.]),
            Tensor::scalar(42.0),
            Tensor::zeros(&[4]),
        ];
        save(&path, 123, &tensors).unwrap();
        let (step, back) = load(&path).unwrap();
        assert_eq!(step, 123);
        assert_eq!(back.len(), 3);
        for (a, b) in tensors.iter().zip(back.iter()) {
            assert_eq!(a, b);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("efla_ckpt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
