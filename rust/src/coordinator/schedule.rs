//! Learning-rate schedules. The authoritative schedule lives here (L3 owns
//! time); the HLO step graph takes `lr` as a scalar input each step.
//!
//! Paper Appendix A: AdamW, peak 3e-4, cosine decay to 3e-5 with linear
//! warmup (1B tokens for the 340M run — we scale warmup to our step count).

/// A learning-rate schedule.
#[derive(Clone, Copy, Debug)]
pub enum Schedule {
    Constant { lr: f64 },
    /// Linear warmup to `peak`, cosine decay to `floor` at `total`.
    CosineWarmup { peak: f64, floor: f64, warmup: u64, total: u64 },
}

impl Schedule {
    /// Paper-style default scaled to `total` steps (10% warmup).
    pub fn paper_default(peak: f64, total: u64) -> Schedule {
        Schedule::CosineWarmup {
            peak,
            floor: peak / 10.0,
            warmup: (total / 10).max(1),
            total,
        }
    }

    /// LR at 1-based step `t`.
    pub fn lr(&self, t: u64) -> f64 {
        match *self {
            Schedule::Constant { lr } => lr,
            Schedule::CosineWarmup { peak, floor, warmup, total } => {
                let t = t as f64;
                let (warmup, total) = (warmup as f64, total as f64);
                if t < warmup {
                    return peak * t / warmup.max(1.0);
                }
                let prog = ((t - warmup) / (total - warmup).max(1.0)).min(1.0);
                floor + 0.5 * (peak - floor) * (1.0 + (std::f64::consts::PI * prog).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { lr: 1e-3 };
        assert_eq!(s.lr(1), 1e-3);
        assert_eq!(s.lr(1000), 1e-3);
    }

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::CosineWarmup { peak: 1.0, floor: 0.1, warmup: 100, total: 1000 };
        assert!((s.lr(50) - 0.5).abs() < 1e-9);
        assert!((s.lr(100) - 1.0).abs() < 1e-2);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = Schedule::CosineWarmup { peak: 1.0, floor: 0.1, warmup: 10, total: 100 };
        assert!((s.lr(100) - 0.1).abs() < 1e-6);
        assert!(s.lr(55) < s.lr(20));
        assert!(s.lr(2000) >= 0.1 - 1e-9); // clamps past total
    }

    #[test]
    fn monotone_decay_after_peak() {
        let s = Schedule::paper_default(3e-4, 500);
        let mut last = f64::INFINITY;
        for t in (51..=500).step_by(10) {
            let lr = s.lr(t);
            assert!(lr <= last + 1e-12);
            last = lr;
        }
    }
}
