//! Experiment registry: every paper table/figure as a runnable plan.
//!
//! | id     | paper artifact | bench target |
//! |--------|----------------|--------------|
//! | fig1   | sMNIST robustness curves (EFLA vs DeltaNet)  | benches/fig1_robustness.rs |
//! | fig2   | EFLA robustness vs learning rate             | benches/fig2_lr_scaling.rs |
//! | table1 | LM ppl + downstream accuracy (4 variants)    | benches/table1_lm.rs |
//! | table2 | MAD suite (6 tasks x 2 mixers)               | benches/table2_mad.rs |
//! | §3/§6  | integrator error / spectral analysis         | benches/kernel_throughput.rs |
//!
//! Step counts are scaled to this CPU testbed; the *shape* of the paper's
//! results (who wins, how gaps move with interference) is the reproduction
//! target, not absolute numbers (DESIGN.md §4).

use anyhow::Result;

use crate::attention::{chunkwise_delta, sequential_delta, Gate};
use crate::coordinator::config::{RunConfig, Task};
use crate::coordinator::evaluator::{self, EvalStats};
use crate::coordinator::schedule::Schedule;
use crate::coordinator::session::Session;
use crate::coordinator::trainer::{self, clf_data, lm_data, mad_data};
use crate::data::mad::MadTask;
use crate::data::mnist::{Corruption, Smnist, SEQ};
use crate::runtime::{Backend, HostValue};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

// ------------------------------------------------------------------
// Fig. 1 / Fig. 2 — classifier robustness
// ------------------------------------------------------------------

/// Accuracy of a trained classifier session under a corruption.
pub fn clf_accuracy_under(
    session: &Session,
    corruption: Corruption,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let mut gen = Smnist::new(seed);
    let mut rng = Rng::new(seed ^ 0xE7A1);
    let batch = session.batch;
    let mut correct = 0f64;
    let mut total = 0f64;
    for _ in 0..n_batches {
        let (mut px, ls) = gen.batch(batch);
        for row in px.chunks_mut(SEQ) {
            corruption.apply(row, &mut rng);
        }
        let outs = session.eval([
            HostValue::F32(Tensor::from_vec(&[batch, SEQ], px)),
            HostValue::i32(&[batch], ls),
        ])?;
        correct += outs[1] as f64;
        total += batch as f64;
    }
    Ok(correct / total.max(1.0))
}

/// One trained classifier + its robustness curves.
#[derive(Clone, Debug)]
pub struct RobustnessResult {
    pub mixer: String,
    pub lr: f64,
    pub train_curve: Vec<(u64, f32)>,
    pub clean_acc: f64,
    /// (sweep label, parameter value, accuracy)
    pub sweeps: Vec<(String, f64, f64)>,
}

/// The corruption grids of Fig. 1 / Fig. 2.
pub fn corruption_grid() -> Vec<(&'static str, Vec<Corruption>)> {
    vec![
        (
            "dropout",
            [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]
                .iter()
                .map(|&p| Corruption::Dropout(p))
                .collect(),
        ),
        (
            "scale",
            [1.0, 2.0, 4.0, 8.0, 16.0, 32.0]
                .iter()
                .map(|&f| Corruption::Scale(f))
                .collect(),
        ),
        (
            "noise",
            [0.0, 0.2, 0.4, 0.6, 0.8, 1.0]
                .iter()
                .map(|&s| Corruption::Noise(s))
                .collect(),
        ),
    ]
}

fn corruption_param(c: Corruption) -> f64 {
    match c {
        Corruption::None => 0.0,
        Corruption::Dropout(p) => p,
        Corruption::Scale(f) => f as f64,
        Corruption::Noise(s) => s as f64,
    }
}

/// Train one classifier and sweep all corruptions (one Fig-1 cell row).
pub fn robustness_run(
    backend: &dyn Backend,
    mixer: &str,
    lr: f64,
    steps: u64,
    eval_batches: usize,
    seed: u64,
) -> Result<RobustnessResult> {
    let family = format!("clf_{mixer}");
    let mut session = Session::init(backend, &family, seed as u32)?;
    let pf = clf_data(session.batch, seed, Corruption::None);
    let mut curve = Vec::new();
    trainer::train_lm(
        &mut session,
        Schedule::Constant { lr },
        steps,
        || pf.next(),
        |p| {
            if p.step % 10 == 0 {
                curve.push((p.step, p.loss));
            }
        },
    )?;
    let clean_acc = clf_accuracy_under(&session, Corruption::None, eval_batches, seed + 999)?;
    let mut sweeps = Vec::new();
    for (label, grid) in corruption_grid() {
        for c in grid {
            let acc = clf_accuracy_under(&session, c, eval_batches, seed + 999)?;
            sweeps.push((label.to_string(), corruption_param(c), acc));
        }
    }
    Ok(RobustnessResult { mixer: mixer.to_string(), lr, train_curve: curve, clean_acc, sweeps })
}

// ------------------------------------------------------------------
// Table 1 — language modeling
// ------------------------------------------------------------------

/// One Table-1 row.
#[derive(Clone, Debug)]
pub struct LmRow {
    pub mixer: String,
    pub train_loss: f32,
    pub ppl: f64,
    pub probe_acc: Vec<(String, f64)>,
    pub steps: u64,
    pub wall_secs: f64,
}

/// Train one LM variant and evaluate ppl + probes (one Table-1 row).
#[allow(clippy::too_many_arguments)]
pub fn lm_run(
    backend: &dyn Backend,
    preset: &str,
    mixer: &str,
    steps: u64,
    eval_batches: usize,
    seed: u64,
    peak_lr: f64,
) -> Result<LmRow> {
    let cfg = RunConfig {
        task: Task::Lm,
        preset: preset.into(),
        mixer: mixer.into(),
        steps,
        seed,
        peak_lr,
        ..RunConfig::default()
    };
    let family = cfg.family();
    let mut session = Session::init(backend, &family, seed as u32)?;
    let (pf, bpe) = lm_data(&cfg, session.batch, session.seq)?;
    let schedule = Schedule::paper_default(cfg.peak_lr, steps);
    let hist = trainer::train_lm(&mut session, schedule, steps, || pf.next(), |_| {})?;

    // Held-out ppl: same corpus distribution, different seed.
    let eval_cfg = RunConfig { seed: seed + 10_000, ..cfg.clone() };
    let (eval_pf, _) = lm_data(&eval_cfg, session.batch, session.seq)?;
    let stats: EvalStats =
        evaluator::eval_batches(&session, eval_batches, || eval_pf.next())?;

    let probe_acc = evaluator::probe_suite(&session, &bpe, seed + 77, 16)?;
    Ok(LmRow {
        mixer: mixer.to_string(),
        train_loss: hist.tail_loss(10),
        ppl: stats.ppl(),
        probe_acc,
        steps,
        wall_secs: hist.wall_secs,
    })
}

// ------------------------------------------------------------------
// Table 2 — MAD suite
// ------------------------------------------------------------------

/// Accuracy per MAD task for one mixer.
pub fn mad_run(
    backend: &dyn Backend,
    mixer: &str,
    task: MadTask,
    steps: u64,
    eval_batches: usize,
    seed: u64,
) -> Result<f64> {
    let family = format!("lm_mad_{mixer}");
    let mut session = Session::init(backend, &family, seed as u32)?;
    let pf = mad_data(task, session.batch, session.seq, seed);
    trainer::train_lm(
        &mut session,
        Schedule::Constant { lr: 1e-3 },
        steps,
        || pf.next(),
        |_| {},
    )?;
    let eval_pf = mad_data(task, session.batch, session.seq, seed + 1);
    let stats = evaluator::eval_batches(&session, eval_batches, || eval_pf.next())?;
    Ok(stats.accuracy())
}

// ------------------------------------------------------------------
// §3/§6 — integrator error analysis (pure Rust, no artifacts needed)
// ------------------------------------------------------------------

/// Max |out - exact| over a sequence, for one gate at one stiffness level.
///
/// Stiffness x = beta*lambda is controlled through the key scale: keys are
/// N(0, sigma^2 I) with sigma chosen so E[lambda] * beta ~= x.
pub fn integrator_error(gate: Gate, stiffness: f64, l: usize, d: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let beta = 0.9f32;
    let sigma = ((stiffness / beta as f64) / d as f64).sqrt() as f32;
    let q = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
    let k = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, sigma));
    let v = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
    let betas = vec![beta; l];
    let (out, _) = sequential_delta(gate, &q, &k, &v, &betas);
    let (exact, _) = sequential_delta(Gate::Efla, &q, &k, &v, &betas);
    out.max_abs_diff(&exact) as f64
}

/// Verify chunkwise == sequential for a gate (consistency metric used by
/// the kernel bench to demonstrate the parallel form is error-free too).
pub fn chunkwise_consistency(gate: Gate, l: usize, d: usize, chunk: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let q = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
    let k = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.7));
    let v = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 1.0));
    let betas: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
    let (o1, _) = sequential_delta(gate, &q, &k, &v, &betas);
    let (o2, _) = chunkwise_delta(gate, &q, &k, &v, &betas, chunk);
    o1.max_abs_diff(&o2) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_increases_with_stiffness_and_decreases_with_order() {
        // the paper's central numerical claim, on the pure-Rust substrate.
        // d=16 concentrates lambda so the per-token stiffness stays in the
        // regime where higher order => lower truncation error (for very
        // large beta*lambda the RK polynomials blow up in their own way —
        // that's exactly the paper's instability argument, tested elsewhere).
        let e_euler_lo = integrator_error(Gate::Euler, 0.4, 64, 16, 1);
        let e_euler_hi = integrator_error(Gate::Euler, 1.2, 64, 16, 1);
        assert!(e_euler_hi > e_euler_lo, "{e_euler_hi} <= {e_euler_lo}");
        let e_rk2 = integrator_error(Gate::Rk(2), 1.2, 64, 16, 1);
        let e_rk4 = integrator_error(Gate::Rk(4), 1.2, 64, 16, 1);
        assert!(e_rk2 < e_euler_hi, "rk2 {e_rk2} vs euler {e_euler_hi}");
        assert!(e_rk4 < e_rk2, "rk4 {e_rk4} vs rk2 {e_rk2}");
        let e_exact = integrator_error(Gate::Efla, 1.2, 64, 16, 1);
        assert!(e_exact == 0.0);
    }

    #[test]
    fn chunkwise_is_consistent_for_all_gates() {
        for gate in [Gate::Euler, Gate::Rk(2), Gate::Rk(4), Gate::Efla] {
            let err = chunkwise_consistency(gate, 48, 8, 16, 3);
            assert!(err < 5e-4, "{gate:?}: {err}");
        }
    }

    #[test]
    fn corruption_grid_shapes() {
        let g = corruption_grid();
        assert_eq!(g.len(), 3);
        for (_, sweep) in g {
            assert_eq!(sweep.len(), 6);
        }
    }
}
