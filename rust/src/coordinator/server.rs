//! Batched generation server: chunked parallel prefill + O(1)-state decode.
//!
//! The serving win of (error-free) linear attention: no KV cache, just a
//! fixed-size per-sequence state (conv caches + S per layer). This module
//! implements a vLLM-style *continuously batched* engine over the fixed-B
//! decode path of any backend:
//!
//! * B slots, each holding one request's recurrent state rows;
//! * admitted slots first consume their prompt in chunks of
//!   [`ServerConfig::prefill_chunk`] tokens per engine step through the
//!   backend's **prefill** path — the whole chunk runs through the
//!   parallel forward in one call, seeded from the slot's state (a
//!   per-step token budget keeps decode-phase slots from starving behind
//!   long prompts);
//! * generating slots then advance together through ONE slot-batched
//!   decode per engine step (`decode_slots` gathers only the busy slots'
//!   state rows and runs the dense projections as one packed GEMM),
//!   sampling from the returned logits; backends without batched decode
//!   fall back to the full fixed-batch `decode`;
//! * finished slots are immediately refilled from the queue (continuous
//!   batching), their state rows zeroed in place;
//! * with a session state cache armed ([`ServerConfig::state_cache_bytes`]
//!   + a request `session_id`), a finishing slot's state rows are parked
//!   in [`crate::serve::state_cache::StateCache`] and a follow-up turn of
//!   the same session restores them into whatever slot seats it,
//!   prefilling only the suffix past the cached transcript — bit-identical
//!   to a cold full-transcript prefill, because the EFLA state is an exact
//!   pure function of the tokens fed. Two turns of one session are never
//!   seated concurrently (the snapshot is taken at finish).
//!
//! Chunked prefill and slot-batched decode are pure throughput
//! optimizations: for any prompt, any `prefill_chunk`, and any busy-slot
//! occupancy, the produced logits and slot state are bit-identical to the
//! token-at-a-time single-slot path — every serving matmul is pinned to
//! the kernel class keyed on the slot capacity, never the live row count.
//!
//! State lives host-side between steps (row surgery is trivial there); the
//! backend's [`Session::decode`] / [`Session::decode_slots`] /
//! [`Session::prefill`] are the only compute.

use std::collections::{BTreeSet, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::session::Session;
use crate::runtime::HostValue;
use crate::serve::state_cache::{CachedState, SharedStateCache, StateCache};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Scheduler knobs of the serving engine.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max prompt tokens one slot ingests per engine step through the
    /// parallel prefill path. 0 = token-at-a-time ingestion through the
    /// decode path (the legacy behavior, and the fallback for backends
    /// without prefill support).
    pub prefill_chunk: usize,
    /// Max total prompt tokens ingested per engine step across all slots,
    /// so decode-phase slots are not starved behind long prompts.
    /// 0 = unlimited.
    pub prefill_token_budget: usize,
    /// Network front end ([`crate::serve`]): bound of the admission queue
    /// between connection workers and the engine thread. Requests arriving
    /// while the queue is full are rejected with HTTP 429. Clamped to >= 1.
    pub queue_depth: usize,
    /// Network front end: seconds to wait for in-flight slots (and already
    /// accepted queued requests) to finish after a shutdown signal before
    /// giving up on the drain.
    pub drain_timeout_secs: f64,
    /// Default per-request deadline in milliseconds, applied at
    /// [`Server::submit_at`] to requests that did not carry their own
    /// [`GenRequest::deadline`]. 0 = no default deadline (a request
    /// without one can hold a slot until `max_new` tokens are produced).
    pub default_timeout_ms: u64,
    /// Byte bound of the per-session recurrent-state cache's memory tier
    /// (`efla serve --state-cache-bytes`). 0 = cache disabled: requests
    /// with a `session_id` run exactly like requests without one.
    pub state_cache_bytes: usize,
    /// Spill directory of the state cache (`--state-cache-dir`): evicted
    /// entries are written through the checkpoint serialization and
    /// restored transparently. Empty = evictions drop the state and the
    /// session falls back to a cold full prefill.
    pub state_cache_dir: String,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            prefill_chunk: 64,
            prefill_token_budget: 256,
            queue_depth: 64,
            drain_timeout_secs: 5.0,
            default_timeout_ms: 0,
            state_cache_bytes: 0,
            state_cache_dir: String::new(),
        }
    }
}

/// A rejected [`Server::submit`]: the request never entered the queue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The prompt has no tokens; the engine needs at least one to seed
    /// generation (the legacy path asserted and took the process down).
    EmptyPrompt { id: u64 },
    /// `max_new == 0`: the request could never produce a token and would
    /// occupy a slot forever (the decode loop only frees slots on
    /// `generated.len() >= max_new`).
    ZeroMaxNew { id: u64 },
    /// The id is already live (queued, in a slot, or finished but not yet
    /// taken via [`Server::take_results`]). Results are keyed by id, so a
    /// duplicate would make one of the two generations unaddressable.
    DuplicateId { id: u64 },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::EmptyPrompt { id } => write!(f, "request {id}: empty prompt"),
            SubmitError::ZeroMaxNew { id } => {
                write!(f, "request {id}: max_new must be at least 1")
            }
            SubmitError::DuplicateId { id } => {
                write!(f, "request {id}: id is already queued or in flight")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
    /// Absolute deadline: past this instant the engine stops working on
    /// the request (whether still queued or holding a slot) and finishes
    /// it with [`FinishReason::Timeout`] and whatever tokens exist. `None`
    /// falls back to [`ServerConfig::default_timeout_ms`].
    pub deadline: Option<Instant>,
    /// Client conversation key for the session state cache. `Some` opts
    /// the request in: on completion the slot's recurrent state is parked
    /// under this key, and a follow-up turn whose prompt extends the
    /// cached transcript resumes from it instead of re-prefilling the
    /// whole conversation. `None` never touches the cache.
    pub session_id: Option<String>,
}

/// Why a generation finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced its full `max_new` tokens.
    Length,
    /// Deadline expired while queued or mid-generation; the result carries
    /// the tokens produced so far (possibly none).
    Timeout,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Timeout => "timeout",
        }
    }
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Engine steps this request occupied a slot (prefill calls + decodes).
    pub steps: usize,
    /// Wall seconds from submission to the first generated token.
    pub ttft_secs: f64,
    /// Wall seconds the request waited in the queue before a slot seated it.
    pub queue_wait_secs: f64,
    /// Wall seconds from submission to completion.
    pub e2e_secs: f64,
    /// Why the engine released the request.
    pub finish_reason: FinishReason,
}

/// One freshly generated token, in engine-step order. Captured only when
/// [`Server::enable_events`] was called (the streaming front end drains
/// them after every step); batch-mode callers pay nothing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenEvent {
    pub id: u64,
    pub token: i32,
}

#[derive(Clone, Debug)]
struct Slot {
    id: u64,
    prompt: Vec<i32>,
    consumed: usize,
    generated: Vec<i32>,
    max_new: usize,
    temperature: f32,
    steps: usize,
    submitted: Instant,
    deadline: Option<Instant>,
    ttft_secs: f64,
    queue_wait_secs: f64,
    session_id: Option<String>,
}

/// Engine statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub engine_steps: u64,
    /// Total tokens processed (prompt + generated).
    pub tokens_processed: u64,
    /// Prompt tokens ingested (through prefill calls, or through decode
    /// steps when running token-at-a-time).
    pub prefill_tokens: u64,
    /// Generated tokens produced by decode steps.
    pub decode_tokens: u64,
    pub completed: u64,
    pub wall_secs: f64,
    /// Decode slots of the engine (fixed batch of the decode graph).
    pub batch: usize,
    /// Executor worker threads the backend session decodes with.
    pub threads: usize,
    /// Sum of per-request time-to-first-token (seconds), over
    /// `ttft_count` requests that produced a first token so far.
    pub ttft_sum_secs: f64,
    pub ttft_count: u64,
    /// Requests seated into a slot so far.
    pub admitted: u64,
    /// Sum of per-request queue wait (submission -> slot), over `admitted`.
    pub queue_wait_sum_secs: f64,
    /// Sum of per-request end-to-end latency (submission -> completion),
    /// over `completed`.
    pub e2e_sum_secs: f64,
    /// Requests finished with [`FinishReason::Timeout`] (deadline expired
    /// in the queue or mid-generation). Also counted in `completed`.
    pub timed_out: u64,
    /// Session state cache: successful restores (memory or disk tier).
    pub cache_hits: u64,
    /// Session state cache: `session_id` lookups that found no usable
    /// parked state (first turn, evicted, or diverged transcript).
    pub cache_misses: u64,
    /// Session state cache: entries evicted from memory at the byte bound.
    pub cache_evictions: u64,
    /// Session state cache: evicted entries written to the disk tier.
    pub cache_spills: u64,
    /// Session state cache: hits restored from disk (also in `cache_hits`).
    pub cache_disk_hits: u64,
    /// Session state cache: entries currently parked in memory.
    pub cache_entries: usize,
    /// Session state cache: bytes currently resident in memory.
    pub cache_bytes: usize,
}

impl ServerStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.tokens_processed as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Mean tokens per engine step per slot. With token-at-a-time
    /// ingestion this is the slot occupancy in [0, 1]; with chunked
    /// prefill a single step can ingest many prompt tokens per slot, so
    /// values above 1 are exactly the prefill speedup showing up.
    pub fn utilization(&self) -> f64 {
        let cap = (self.engine_steps as f64) * (self.batch as f64);
        if cap > 0.0 {
            self.tokens_processed as f64 / cap
        } else {
            0.0
        }
    }

    /// Mean time-to-first-token over the requests that reached one.
    pub fn mean_ttft_secs(&self) -> f64 {
        if self.ttft_count > 0 {
            self.ttft_sum_secs / self.ttft_count as f64
        } else {
            0.0
        }
    }

    /// Mean queue wait (submission -> slot) over admitted requests.
    pub fn mean_queue_wait_secs(&self) -> f64 {
        if self.admitted > 0 {
            self.queue_wait_sum_secs / self.admitted as f64
        } else {
            0.0
        }
    }

    /// Mean end-to-end latency (submission -> completion) over completions.
    pub fn mean_e2e_secs(&self) -> f64 {
        if self.completed > 0 {
            self.e2e_sum_secs / self.completed as f64
        } else {
            0.0
        }
    }
}

/// The batched prefill + decode engine.
pub struct Server<'a> {
    session: &'a Session,
    /// Host-side recurrent state, one HostValue per state tensor (B, ...).
    state: Vec<HostValue>,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(GenRequest, Instant)>,
    results: Vec<GenResult>,
    rng: Rng,
    batch: usize,
    vocab: usize,
    cfg: ServerConfig,
    /// Round-robin start of the prefill budget scan, so low-index slots
    /// can't monopolize `prefill_token_budget` across steps.
    prefill_start: usize,
    /// Ids that are queued, seated, or finished-but-not-taken — the
    /// duplicate-id guard of [`Server::submit`].
    live: BTreeSet<u64>,
    /// Per-token events since the last [`Server::take_events`] drain.
    events: Vec<TokenEvent>,
    events_enabled: bool,
    /// Parked per-session recurrent state (disabled unless
    /// [`ServerConfig::state_cache_bytes`] > 0 and the backend has state
    /// export/import). Shared: the HTTP front end holds the same handle
    /// for the `/v1/state/{session}` migration endpoints, which only
    /// ever touch *parked* entries — live slots stay engine-private.
    cache: SharedStateCache,
    pub stats: ServerStats,
}

impl<'a> Server<'a> {
    /// Build from a trained session with the default scheduler config
    /// (chunked prefill when the backend supports it).
    pub fn new(session: &'a Session, seed: u64) -> Result<Self> {
        Self::with_config(session, seed, ServerConfig::default())
    }

    /// Build with explicit scheduler knobs. `prefill_chunk` silently drops
    /// to 0 (token-at-a-time) when the backend has no prefill path.
    pub fn with_config(session: &'a Session, seed: u64, mut cfg: ServerConfig) -> Result<Self> {
        let batch = session.decode_batch()?;
        if batch == 0 {
            bail!("{}: zero decode batch", session.family());
        }
        let vocab = session.vocab()?;
        let state = session.decode_state()?;
        if !session.supports_prefill() {
            cfg.prefill_chunk = 0;
        }
        if cfg.state_cache_bytes > 0 && !session.supports_state_io() {
            log::warn!(
                "{}: backend has no slot state export/import; session state cache disabled",
                session.family()
            );
            cfg.state_cache_bytes = 0;
        }
        let cache =
            Arc::new(Mutex::new(StateCache::new(cfg.state_cache_bytes, &cfg.state_cache_dir)));
        let stats = ServerStats { batch, threads: session.threads(), ..ServerStats::default() };
        Ok(Server {
            session,
            state,
            slots: vec![None; batch],
            queue: VecDeque::new(),
            results: Vec::new(),
            rng: Rng::new(seed),
            batch,
            vocab,
            cfg,
            prefill_start: 0,
            live: BTreeSet::new(),
            events: Vec::new(),
            events_enabled: false,
            cache,
            stats,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Shared handle to the session state cache. The network front end
    /// publishes it ([`crate::serve::engine::EngineShared`]) so the
    /// `/v1/state/{session}` transfer endpoints can export/import parked
    /// entries concurrently with the engine loop. Exporting while the
    /// same session has a turn in flight is safe: a seated turn has
    /// already *consumed* its entry (`take`), so the cache holds either
    /// nothing or a stale snapshot a strict-prefix check would reject.
    pub fn state_cache(&self) -> SharedStateCache {
        Arc::clone(&self.cache)
    }

    /// The scheduler config in effect (after the capability fallbacks).
    pub fn config(&self) -> ServerConfig {
        self.cfg.clone()
    }

    /// Enqueue a request, stamped as submitted now.
    pub fn submit(&mut self, req: GenRequest) -> Result<(), SubmitError> {
        self.submit_at(req, Instant::now())
    }

    /// Enqueue a request with an explicit submission timestamp — the
    /// network front end stamps arrival at the socket, so queue-wait and
    /// TTFT include the time spent in the admission channel.
    pub fn submit_at(&mut self, req: GenRequest, submitted: Instant) -> Result<(), SubmitError> {
        if req.prompt.is_empty() {
            return Err(SubmitError::EmptyPrompt { id: req.id });
        }
        if req.max_new == 0 {
            return Err(SubmitError::ZeroMaxNew { id: req.id });
        }
        let mut req = req;
        if req.deadline.is_none() && self.cfg.default_timeout_ms > 0 {
            req.deadline = Some(submitted + Duration::from_millis(self.cfg.default_timeout_ms));
        }
        if !self.live.insert(req.id) {
            return Err(SubmitError::DuplicateId { id: req.id });
        }
        self.queue.push_back((req, submitted));
        Ok(())
    }

    /// Requests waiting in the internal queue (not yet seated in a slot).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Slots currently holding a request.
    pub fn occupied_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Slots free to seat a queued request at the next engine step.
    pub fn free_slots(&self) -> usize {
        self.batch - self.occupied_slots()
    }

    /// True while any request is queued or in flight.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(|s| s.is_some())
    }

    /// Turn on per-token event capture ([`Server::take_events`]). Off by
    /// default so batch-mode callers don't accumulate an unbounded buffer.
    pub fn enable_events(&mut self) {
        self.events_enabled = true;
    }

    /// Drain the per-token events generated since the last call.
    pub fn take_events(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.events)
    }

    /// Drain finished generations (completion order). Frees their ids for
    /// reuse by future submissions.
    pub fn take_results(&mut self) -> Vec<GenResult> {
        let out = std::mem::take(&mut self.results);
        for r in &out {
            self.live.remove(&r.id);
        }
        out
    }

    fn push_event(&mut self, id: u64, token: i32) {
        if self.events_enabled {
            self.events.push(TokenEvent { id, token });
        }
    }

    /// Zero all state rows for slot `s`.
    fn clear_slot_state(&mut self, s: usize) {
        for hv in &mut self.state {
            if let HostValue::F32(t) = hv {
                let row = t.len() / self.batch;
                t.data_mut()[s * row..(s + 1) * row].iter_mut().for_each(|x| *x = 0.0);
            }
        }
    }

    /// Admit queued requests into free slots. Queued requests whose
    /// deadline already passed are finished with a timeout result instead
    /// of wasting a slot on work nobody is waiting for, and a request
    /// whose session already occupies a slot stays queued (per-session
    /// serialization: its state snapshot only exists once that turn
    /// finishes), letting later arrivals seat ahead of it.
    fn admit(&mut self, now: Instant) {
        for s in 0..self.batch {
            if self.slots[s].is_some() {
                continue;
            }
            if !self.seat_from_queue(s, now) {
                // Nothing seatable; later free slots see the same queue.
                return;
            }
        }
    }

    /// Seat the first eligible queued request into free slot `s`,
    /// expiring dead requests on the way. Returns false when no queued
    /// request can seat right now.
    fn seat_from_queue(&mut self, s: usize, now: Instant) -> bool {
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].0.deadline.is_some_and(|d| d <= now) {
                let (req, submitted) = self.queue.remove(i).expect("index checked");
                self.expire_queued(req, submitted, now);
                continue;
            }
            if self.session_in_flight(self.queue[i].0.session_id.as_deref()) {
                i += 1;
                continue;
            }
            let (req, submitted) = self.queue.remove(i).expect("index checked");
            self.seat(s, req, submitted, now);
            return true;
        }
        false
    }

    /// True when a turn of `session` currently occupies a slot.
    fn session_in_flight(&self, session: Option<&str>) -> bool {
        match session {
            None => false,
            Some(sid) => {
                self.slots.iter().flatten().any(|slot| slot.session_id.as_deref() == Some(sid))
            }
        }
    }

    /// Seat a dequeued request into free slot `s`: restore its session's
    /// parked state when the cache holds a usable snapshot (prefill then
    /// starts past the cached transcript), zero the slot's rows otherwise.
    fn seat(&mut self, s: usize, req: GenRequest, submitted: Instant, now: Instant) {
        let restored = self.restore_slot_state(s, req.session_id.as_deref(), &req.prompt);
        if restored == 0 {
            self.clear_slot_state(s);
        }
        let queue_wait_secs = (now - submitted).as_secs_f64();
        self.stats.admitted += 1;
        self.stats.queue_wait_sum_secs += queue_wait_secs;
        self.slots[s] = Some(Slot {
            id: req.id,
            prompt: req.prompt,
            consumed: restored,
            generated: Vec::new(),
            max_new: req.max_new,
            temperature: req.temperature,
            steps: 0,
            submitted,
            deadline: req.deadline,
            ttft_secs: 0.0,
            queue_wait_secs,
            session_id: req.session_id,
        });
    }

    /// Try to restore `session`'s parked state into slot `s`; returns how
    /// many leading prompt tokens the restored state already covers (0 =
    /// cold start). The restored rows are the exact bits the slot held
    /// after absorbing the cached transcript, so continuing from them is
    /// bit-identical to re-prefilling the whole prompt.
    fn restore_slot_state(&mut self, s: usize, session: Option<&str>, prompt: &[i32]) -> usize {
        let Some(sid) = session else { return 0 };
        let cached = {
            let mut cache = self.cache.lock().expect("state cache lock");
            if !cache.enabled() {
                return 0;
            }
            cache.take(sid, prompt)
        };
        let restored = match cached {
            None => 0,
            Some(cached) => match self.session.import_slot_state(&mut self.state, s, &cached.rows)
            {
                Ok(()) => cached.transcript.len(),
                Err(e) => {
                    log::warn!("session {sid}: state restore failed, cold prefill: {e:#}");
                    0
                }
            },
        };
        self.publish_cache_stats();
        restored
    }

    /// Park the finishing slot's recurrent state under its session key.
    /// The cached transcript is exactly the token sequence the state has
    /// absorbed: the consumed prompt plus every generated token that was
    /// fed back through decode — the final sampled token never was, so it
    /// is excluded (the follow-up turn's prompt supplies it).
    fn snapshot_slot(&mut self, s: usize) {
        if !self.cache.lock().expect("state cache lock").enabled() {
            return;
        }
        let slot = self.slots[s].as_ref().expect("snapshotting an occupied slot");
        let Some(sid) = slot.session_id.clone() else { return };
        let fed_gen = if slot.consumed == slot.prompt.len() {
            slot.generated.len().saturating_sub(1)
        } else {
            0
        };
        let mut transcript = Vec::with_capacity(slot.consumed + fed_gen);
        transcript.extend_from_slice(&slot.prompt[..slot.consumed]);
        transcript.extend_from_slice(&slot.generated[..fed_gen]);
        if transcript.is_empty() {
            return;
        }
        match self.session.export_slot_state(&self.state, s) {
            Ok(rows) => self
                .cache
                .lock()
                .expect("state cache lock")
                .insert(&sid, CachedState { transcript, rows }),
            Err(e) => log::warn!("session {sid}: state snapshot failed: {e:#}"),
        }
        self.publish_cache_stats();
    }

    /// Mirror the cache's counters into [`ServerStats`] (Copy-snapshotted
    /// by the front end after every engine step).
    fn publish_cache_stats(&mut self) {
        let cs = self.cache.lock().expect("state cache lock").stats();
        self.stats.cache_hits = cs.hits;
        self.stats.cache_misses = cs.misses;
        self.stats.cache_evictions = cs.evictions;
        self.stats.cache_spills = cs.spills;
        self.stats.cache_disk_hits = cs.disk_hits;
        self.stats.cache_entries = cs.entries;
        self.stats.cache_bytes = cs.resident_bytes;
    }

    /// Finish a request whose deadline expired before it ever got a slot.
    fn expire_queued(&mut self, req: GenRequest, submitted: Instant, now: Instant) {
        let e2e_secs = (now - submitted).as_secs_f64();
        self.stats.completed += 1;
        self.stats.timed_out += 1;
        self.stats.e2e_sum_secs += e2e_secs;
        self.results.push(GenResult {
            id: req.id,
            tokens: Vec::new(),
            steps: 0,
            ttft_secs: 0.0,
            queue_wait_secs: e2e_secs,
            e2e_secs,
            finish_reason: FinishReason::Timeout,
        });
    }

    /// Finish every occupied slot whose deadline passed, releasing the
    /// slot with the tokens generated so far.
    fn expire_slots(&mut self, now: Instant) {
        for s in 0..self.batch {
            let expired = matches!(
                &self.slots[s],
                Some(slot) if slot.deadline.is_some_and(|d| d <= now)
            );
            if expired {
                self.finish_slot(s, FinishReason::Timeout);
            }
        }
    }

    fn sample(rng: &mut Rng, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            // total_cmp: a NaN logit (diverged run) must not panic the
            // serving loop — same total-ordering fallback as
            // tensor::argmax_rows.
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
        }
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> =
            logits.iter().map(|&l| (((l - mx) / temperature) as f64).exp()).collect();
        rng.categorical(&weights) as i32
    }

    /// Move a finished slot's generation into the results, parking its
    /// recurrent state in the session cache first (while the slot's rows
    /// are still intact — the next admit zeroes or overwrites them).
    fn finish_slot(&mut self, s: usize, finish_reason: FinishReason) {
        self.snapshot_slot(s);
        let done = self.slots[s].take().expect("finishing an occupied slot");
        let e2e_secs = done.submitted.elapsed().as_secs_f64();
        self.stats.completed += 1;
        self.stats.e2e_sum_secs += e2e_secs;
        if finish_reason == FinishReason::Timeout {
            self.stats.timed_out += 1;
        }
        self.results.push(GenResult {
            id: done.id,
            tokens: done.generated,
            steps: done.steps,
            ttft_secs: done.ttft_secs,
            queue_wait_secs: done.queue_wait_secs,
            e2e_secs,
            finish_reason,
        });
    }

    /// Record a freshly sampled first token's latency on slot `s`.
    fn record_ttft(stats: &mut ServerStats, slot: &mut Slot) {
        let ttft = slot.submitted.elapsed().as_secs_f64();
        slot.ttft_secs = ttft;
        stats.ttft_sum_secs += ttft;
        stats.ttft_count += 1;
    }

    /// One engine step: prefill phase (prompt chunks through the parallel
    /// path, budget-capped) then decode phase (one batched decode for
    /// every other occupied slot — generating slots advance one token,
    /// and budget-starved mid-prompt slots piggyback their next prompt
    /// token, so every occupied slot makes progress every step). Returns
    /// the number of tokens processed.
    pub fn engine_step(&mut self) -> Result<usize> {
        let now = Instant::now();
        self.expire_slots(now);
        self.admit(now);
        let mut processed = 0usize;
        let mut prefilled = vec![false; self.batch];

        // ---- prefill phase: consume prompt chunks -------------------
        if self.cfg.prefill_chunk > 0 {
            let mut budget = if self.cfg.prefill_token_budget == 0 {
                usize::MAX
            } else {
                self.cfg.prefill_token_budget
            };
            // Round-robin over the slots starting after the last slot the
            // budget reached, so a saturated engine spreads prompt
            // ingestion fairly instead of starving high-index slots.
            let start = self.prefill_start;
            for off in 0..self.batch {
                let s = (start + off) % self.batch;
                if budget == 0 {
                    break;
                }
                let (consumed, pending) = match &self.slots[s] {
                    Some(slot) if slot.consumed < slot.prompt.len() => {
                        (slot.consumed, slot.prompt.len() - slot.consumed)
                    }
                    _ => continue,
                };
                self.prefill_start = (s + 1) % self.batch;
                let take = self.cfg.prefill_chunk.min(pending).min(budget);
                let logits = {
                    let slot = self.slots[s].as_ref().expect("slot checked above");
                    let chunk = &slot.prompt[consumed..consumed + take];
                    self.session.prefill(&mut self.state, s, chunk)?
                };
                budget -= take;
                processed += take;
                self.stats.prefill_tokens += take as u64;
                prefilled[s] = true;
                let slot = self.slots[s].as_mut().expect("slot checked above");
                slot.consumed += take;
                slot.steps += 1;
                if slot.consumed == slot.prompt.len() {
                    // The prompt's last-position logits seed generation.
                    let t = Self::sample(&mut self.rng, logits.data(), slot.temperature);
                    slot.generated.push(t);
                    Self::record_ttft(&mut self.stats, slot);
                    let (id, done) = (slot.id, slot.generated.len() >= slot.max_new);
                    self.push_event(id, t);
                    if done {
                        self.finish_slot(s, FinishReason::Length);
                    }
                }
            }
        }

        // ---- decode phase: one slot-batched decode -------------------
        // Every occupied slot that didn't prefill this step joins the
        // batched decode: generating slots feed their last sampled token,
        // and mid-prompt slots (token-at-a-time mode, or budget-starved
        // under chunked prefill) piggyback their next prompt token —
        // single-token ingestion is bit-identical to a prefill chunk, so
        // this is progress for free. Backends with `decode_slots` advance
        // only the busy slots as one packed GEMM over their gathered
        // rows; others fall back to the full fixed-batch decode. Either
        // way a slot's bits are identical at any occupancy, because the
        // serving matmuls are pinned to the slot-capacity kernel class.
        let active: Vec<usize> =
            (0..self.batch).filter(|&s| !prefilled[s] && self.slots[s].is_some()).collect();
        if processed == 0 && active.is_empty() {
            return Ok(0);
        }
        if !active.is_empty() {
            let batched = self.session.supports_batched_decode();
            let logits = if batched {
                let mut tokens = vec![0i32; active.len()];
                for (i, &s) in active.iter().enumerate() {
                    let slot = self.slots[s].as_ref().expect("active slot is occupied");
                    tokens[i] = if slot.consumed < slot.prompt.len() {
                        slot.prompt[slot.consumed]
                    } else {
                        *slot.generated.last().expect("generating slot has a last token")
                    };
                }
                self.session.decode_slots(&mut self.state, &active, &tokens)?
            } else {
                let mut tokens = vec![0i32; self.batch];
                for &s in &active {
                    let slot = self.slots[s].as_ref().expect("active slot is occupied");
                    tokens[s] = if slot.consumed < slot.prompt.len() {
                        slot.prompt[slot.consumed]
                    } else {
                        *slot.generated.last().expect("generating slot has a last token")
                    };
                }
                self.session.decode(&mut self.state, &tokens)?
            };

            for (i, &s) in active.iter().enumerate() {
                // Batched decode returns one logits row per busy slot
                // (row i for active[i]); the full-batch fallback returns
                // a row per slot.
                let row_idx = if batched { i } else { s };
                let slot = self.slots[s].as_mut().expect("active slot is occupied");
                slot.steps += 1;
                let mut emitted = None;
                if slot.consumed < slot.prompt.len() {
                    slot.consumed += 1;
                    self.stats.prefill_tokens += 1;
                    // When the whole prompt is consumed, the logits at its
                    // last token give the first generated token.
                    if slot.consumed == slot.prompt.len() {
                        let row = &logits.data()[row_idx * self.vocab..(row_idx + 1) * self.vocab];
                        let t = Self::sample(&mut self.rng, row, slot.temperature);
                        slot.generated.push(t);
                        Self::record_ttft(&mut self.stats, slot);
                        emitted = Some(t);
                    }
                } else {
                    let row = &logits.data()[row_idx * self.vocab..(row_idx + 1) * self.vocab];
                    let t = Self::sample(&mut self.rng, row, slot.temperature);
                    slot.generated.push(t);
                    self.stats.decode_tokens += 1;
                    emitted = Some(t);
                }
                let (id, done) = (slot.id, slot.generated.len() >= slot.max_new);
                if let Some(t) = emitted {
                    self.push_event(id, t);
                }
                if done {
                    self.finish_slot(s, FinishReason::Length);
                }
            }
            processed += active.len();
        }

        self.stats.engine_steps += 1;
        self.stats.tokens_processed += processed as u64;
        Ok(processed)
    }

    /// Run until queue + slots drain; returns all results (by request id).
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let t0 = std::time::Instant::now();
        loop {
            let n = self.engine_step()?;
            if n == 0 && self.queue.is_empty() {
                break;
            }
        }
        self.stats.wall_secs += t0.elapsed().as_secs_f64();
        let mut out = self.take_results();
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}

/// Decode state tensors are (B, ...) rows — helper for tests.
pub fn state_rows(t: &Tensor, batch: usize) -> usize {
    t.len() / batch
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(1);
        let logits = vec![0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(Server::sample(&mut rng, &logits, 0.0), 1);
    }

    #[test]
    fn greedy_sampling_survives_nan_logits() {
        // Regression: the old partial_cmp().unwrap() panicked on NaN
        // logits (a diverged run would take the whole engine down).
        let mut rng = Rng::new(1);
        let logits = vec![0.5f32, f32::NAN, 2.0];
        let t = Server::sample(&mut rng, &logits, 0.0);
        assert!((0..3).contains(&t));
        let all_nan = vec![f32::NAN; 4];
        let t = Server::sample(&mut rng, &all_nan, 0.0);
        assert!((0..4).contains(&t));
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Rng::new(2);
        let logits = vec![0.0f32, 10.0];
        let hits = (0..100)
            .filter(|_| Server::sample(&mut rng, &logits, 1.0) == 1)
            .count();
        assert!(hits > 95, "peaked logits should dominate, got {hits}");
    }

    fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> GenRequest {
        GenRequest { id, prompt, max_new, temperature: 0.0, deadline: None, session_id: None }
    }

    fn drive(server: &mut Server<'_>, n_req: u64, seed: u64) -> Vec<GenResult> {
        let mut rng = Rng::new(seed);
        for id in 0..n_req {
            let prompt: Vec<i32> =
                (0..rng.range(3, 8)).map(|_| rng.below(256) as i32).collect();
            server.submit(req(id, prompt, 3)).unwrap();
        }
        server.run_to_completion().unwrap()
    }

    #[test]
    fn server_serves_on_the_cpu_backend() {
        use crate::runtime::CpuBackend;
        let backend = CpuBackend::new();
        let session =
            crate::coordinator::session::Session::init(&backend, "lm_tiny_efla", 5).unwrap();
        let mut server = Server::new(&session, 99).unwrap();
        assert!(server.config().prefill_chunk > 0, "CPU backend supports prefill");
        // more requests than slots: exercises continuous batching
        let n_req = server.batch_size() as u64 + 2;
        let results = drive(&mut server, n_req, 1);
        assert_eq!(results.len(), n_req as usize);
        for r in &results {
            assert_eq!(r.tokens.len(), 3);
            assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
            assert!(r.ttft_secs >= 0.0);
        }
        assert_eq!(server.stats.completed, n_req);
        assert_eq!(server.stats.batch, server.batch_size());
        assert!(server.stats.threads >= 1);
        // Token accounting: the prefill/decode split covers everything.
        assert_eq!(
            server.stats.prefill_tokens + server.stats.decode_tokens,
            server.stats.tokens_processed
        );
        assert_eq!(server.stats.ttft_count, n_req);
        assert!(server.stats.mean_ttft_secs() >= 0.0);
        // Chunked prefill ingests several prompt tokens per step, so the
        // per-step token rate clears what token-at-a-time could reach.
        let util = server.stats.utilization();
        assert!(util > 0.5, "tokens per step per slot {util}");
    }

    #[test]
    fn token_at_a_time_mode_keeps_slot_occupancy_bounded() {
        use crate::runtime::CpuBackend;
        let backend = CpuBackend::new();
        let session =
            crate::coordinator::session::Session::init(&backend, "lm_tiny_efla", 5).unwrap();
        let cfg =
            ServerConfig { prefill_chunk: 0, prefill_token_budget: 0, ..ServerConfig::default() };
        let mut server = Server::with_config(&session, 99, cfg).unwrap();
        let n_req = server.batch_size() as u64 + 2;
        let results = drive(&mut server, n_req, 1);
        assert_eq!(results.len(), n_req as usize);
        // One token per slot per step: occupancy stays in (0, 1].
        let util = server.stats.utilization();
        assert!(util > 0.5 && util <= 1.0, "slot occupancy {util}");
        assert_eq!(
            server.stats.prefill_tokens + server.stats.decode_tokens,
            server.stats.tokens_processed
        );
    }

    fn tiny_server(session: &Session) -> Server<'_> {
        Server::new(session, 3).unwrap()
    }

    fn tiny_session() -> Session {
        use crate::runtime::CpuBackend;
        let backend = CpuBackend::new();
        Session::init(&backend, "lm_tiny_efla", 5).unwrap()
    }

    #[test]
    fn submit_rejects_empty_prompt_and_zero_max_new() {
        // Regression: an empty prompt used to assert! and take the whole
        // engine down; max_new == 0 silently occupied a slot forever.
        let session = tiny_session();
        let mut server = tiny_server(&session);
        let err = server.submit(req(1, vec![], 3)).unwrap_err();
        assert_eq!(err, SubmitError::EmptyPrompt { id: 1 });
        let err = server.submit(req(2, vec![5], 0)).unwrap_err();
        assert_eq!(err, SubmitError::ZeroMaxNew { id: 2 });
        // Nothing entered the queue; the ids are free for valid reuse.
        assert_eq!(server.queue_len(), 0);
        server.submit(req(1, vec![5], 1)).unwrap();
        assert_eq!(server.queue_len(), 1);
    }

    #[test]
    fn submit_rejects_duplicate_live_ids() {
        let session = tiny_session();
        let mut server = tiny_server(&session);
        let req = req(7, vec![1, 2, 3], 2);
        server.submit(req.clone()).unwrap();
        // Duplicate while queued.
        assert_eq!(server.submit(req.clone()).unwrap_err(), SubmitError::DuplicateId { id: 7 });
        // Still duplicate while finished-but-untaken.
        while server.has_work() {
            server.engine_step().unwrap();
        }
        assert_eq!(server.submit(req.clone()).unwrap_err(), SubmitError::DuplicateId { id: 7 });
        // take_results frees the id.
        let results = server.take_results();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].id, 7);
        server.submit(req).unwrap();
    }

    #[test]
    fn more_requests_than_slots_all_complete_without_stalling() {
        // Regression guard for the continuous-batching queue: 3x the slot
        // count must drain through engine_step without run_to_completion.
        let session = tiny_session();
        let mut server = tiny_server(&session);
        let n_req = 3 * server.batch_size() as u64;
        for id in 0..n_req {
            server.submit(req(id, vec![9, 8, 7], 2)).unwrap();
        }
        let mut got = Vec::new();
        let mut steps = 0;
        while server.has_work() {
            server.engine_step().unwrap();
            got.extend(server.take_results());
            steps += 1;
            assert!(steps < 10_000, "engine stalled with {} results", got.len());
        }
        assert_eq!(got.len(), n_req as usize);
        assert_eq!(server.stats.admitted, n_req);
        assert!(server.stats.mean_queue_wait_secs() >= 0.0);
        assert!(server.stats.mean_e2e_secs() > 0.0);
        for r in &got {
            assert!(r.e2e_secs >= r.queue_wait_secs);
        }
    }

    #[test]
    fn token_events_match_results_when_enabled() {
        let session = tiny_session();
        let mut server = tiny_server(&session);
        server.enable_events();
        for id in 0..2u64 {
            server.submit(req(id, vec![4, 4, 4], 3)).unwrap();
        }
        let mut by_id: std::collections::BTreeMap<u64, Vec<i32>> = Default::default();
        while server.has_work() {
            server.engine_step().unwrap();
            for ev in server.take_events() {
                by_id.entry(ev.id).or_default().push(ev.token);
            }
        }
        for r in server.take_results() {
            assert_eq!(by_id.get(&r.id), Some(&r.tokens), "events must mirror result {}", r.id);
        }
    }

    #[test]
    fn events_are_not_captured_by_default() {
        let session = tiny_session();
        let mut server = tiny_server(&session);
        server.submit(req(0, vec![1], 2)).unwrap();
        server.run_to_completion().unwrap();
        assert!(server.take_events().is_empty());
    }

    #[test]
    fn expired_queued_request_times_out_without_taking_a_slot() {
        let session = tiny_session();
        let mut server = tiny_server(&session);
        let mut expired = req(1, vec![1, 2, 3], 4);
        expired.deadline = Some(Instant::now() - Duration::from_millis(1));
        server.submit(expired).unwrap();
        server.submit(req(2, vec![1, 2, 3], 2)).unwrap();
        let results = server.run_to_completion().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].finish_reason, FinishReason::Timeout);
        assert!(results[0].tokens.is_empty());
        assert_eq!(results[1].finish_reason, FinishReason::Length);
        assert_eq!(results[1].tokens.len(), 2);
        assert_eq!(server.stats.timed_out, 1);
        assert_eq!(server.stats.completed, 2);
        // The expired request never occupied a slot.
        assert_eq!(server.stats.admitted, 1);
    }

    #[test]
    fn mid_generation_deadline_releases_the_slot_with_partial_tokens() {
        let session = tiny_session();
        let mut server = tiny_server(&session);
        let mut r = req(1, vec![1, 2, 3], 1_000_000);
        r.deadline = Some(Instant::now() + Duration::from_millis(60));
        server.submit(r).unwrap();
        let mut steps = 0u64;
        while server.has_work() {
            server.engine_step().unwrap();
            steps += 1;
            assert!(steps < 10_000_000, "deadline never released the slot");
        }
        let results = server.take_results();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].finish_reason, FinishReason::Timeout);
        // The slot generated for ~60ms before the deadline reaped it —
        // far short of the absurd max_new.
        assert!(results[0].tokens.len() < 1_000_000);
        assert_eq!(server.stats.timed_out, 1);
        assert_eq!(server.free_slots(), server.batch_size());
    }

    #[test]
    fn default_timeout_ms_applies_when_request_has_no_deadline() {
        let session = tiny_session();
        let cfg = ServerConfig { default_timeout_ms: 40, ..ServerConfig::default() };
        let mut server = Server::with_config(&session, 3, cfg).unwrap();
        server.submit(req(1, vec![1, 2, 3], 1_000_000)).unwrap();
        let results = server.run_to_completion().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].finish_reason, FinishReason::Timeout);
        assert_eq!(server.stats.timed_out, 1);
    }
}
