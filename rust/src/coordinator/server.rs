//! Batched generation server on the O(1)-state recurrent decode path.
//!
//! The serving win of (error-free) linear attention: no KV cache, just a
//! fixed-size per-sequence state (conv caches + S per layer). This module
//! implements a vLLM-style *continuously batched* decode loop over the
//! fixed-B decode path of any backend:
//!
//! * B slots, each holding one request's recurrent state rows;
//! * every engine step executes ONE decode for all B slots;
//! * slots still consuming their prompt feed the next prompt token
//!   (piggy-backed prefill — exact, since slot states are independent);
//! * generating slots sample from the returned logits;
//! * finished slots are immediately refilled from the queue (continuous
//!   batching), their state rows zeroed in place.
//!
//! State lives host-side between steps (row surgery is trivial there); the
//! backend's [`Session::decode`] is the only compute.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::coordinator::session::Session;
use crate::runtime::HostValue;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
    /// 0.0 = greedy; otherwise softmax temperature sampling.
    pub temperature: f32,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Engine steps this request occupied a slot (prompt + decode).
    pub steps: usize,
}

#[derive(Clone, Debug)]
struct Slot {
    id: u64,
    prompt: Vec<i32>,
    consumed: usize,
    generated: Vec<i32>,
    max_new: usize,
    temperature: f32,
    steps: usize,
}

/// Engine statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub engine_steps: u64,
    pub tokens_processed: u64,
    pub completed: u64,
    pub wall_secs: f64,
    /// Decode slots of the engine (fixed batch of the decode graph).
    pub batch: usize,
    /// Executor worker threads the backend session decodes with.
    pub threads: usize,
}

impl ServerStats {
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_secs > 0.0 {
            self.tokens_processed as f64 / self.wall_secs
        } else {
            0.0
        }
    }

    /// Mean per-step slot occupancy in [0, 1] (1.0 = every decode slot —
    /// and hence every parallel (slot, head) work item — busy each step).
    pub fn utilization(&self) -> f64 {
        let cap = (self.engine_steps as f64) * (self.batch as f64);
        if cap > 0.0 {
            self.tokens_processed as f64 / cap
        } else {
            0.0
        }
    }
}

/// The batched decode engine.
pub struct Server<'a> {
    session: &'a Session,
    /// Host-side recurrent state, one HostValue per state tensor (B, ...).
    state: Vec<HostValue>,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<GenRequest>,
    results: Vec<GenResult>,
    rng: Rng,
    batch: usize,
    vocab: usize,
    pub stats: ServerStats,
}

impl<'a> Server<'a> {
    /// Build from a trained session with a decode path.
    pub fn new(session: &'a Session, seed: u64) -> Result<Self> {
        let batch = session.decode_batch()?;
        if batch == 0 {
            bail!("{}: zero decode batch", session.family());
        }
        let vocab = session.vocab()?;
        let state = session.decode_state()?;
        let stats =
            ServerStats { batch, threads: session.threads(), ..ServerStats::default() };
        Ok(Server {
            session,
            state,
            slots: vec![None; batch],
            queue: VecDeque::new(),
            results: Vec::new(),
            rng: Rng::new(seed),
            batch,
            vocab,
            stats,
        })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Enqueue a request.
    pub fn submit(&mut self, req: GenRequest) {
        assert!(!req.prompt.is_empty(), "empty prompt");
        self.queue.push_back(req);
    }

    /// Zero all state rows for slot `s`.
    fn clear_slot_state(&mut self, s: usize) {
        for hv in &mut self.state {
            if let HostValue::F32(t) = hv {
                let row = t.len() / self.batch;
                t.data_mut()[s * row..(s + 1) * row].iter_mut().for_each(|x| *x = 0.0);
            }
        }
    }

    /// Admit queued requests into free slots.
    fn admit(&mut self) {
        for s in 0..self.batch {
            if self.slots[s].is_none() {
                if let Some(req) = self.queue.pop_front() {
                    self.clear_slot_state(s);
                    self.slots[s] = Some(Slot {
                        id: req.id,
                        prompt: req.prompt,
                        consumed: 0,
                        generated: Vec::new(),
                        max_new: req.max_new,
                        temperature: req.temperature,
                        steps: 0,
                    });
                }
            }
        }
    }

    fn sample(rng: &mut Rng, logits: &[f32], temperature: f32) -> i32 {
        if temperature <= 0.0 {
            return logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32)
                .unwrap_or(0);
        }
        let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let weights: Vec<f64> =
            logits.iter().map(|&l| (((l - mx) / temperature) as f64).exp()).collect();
        rng.categorical(&weights) as i32
    }

    /// One engine step: feed every active slot one token, collect outputs.
    /// Returns the number of active slots processed.
    pub fn engine_step(&mut self) -> Result<usize> {
        self.admit();
        let active: Vec<usize> =
            (0..self.batch).filter(|&s| self.slots[s].is_some()).collect();
        if active.is_empty() {
            return Ok(0);
        }

        // Build the per-slot input token.
        let mut tokens = vec![0i32; self.batch];
        for &s in &active {
            let slot = self.slots[s].as_ref().unwrap();
            tokens[s] = if slot.consumed < slot.prompt.len() {
                slot.prompt[slot.consumed]
            } else {
                *slot.generated.last().expect("generating slot has a last token")
            };
        }

        // Execute one batched decode over the host-resident state — the
        // backend advances the slot rows in place (no per-step copy).
        let logits = self.session.decode(&mut self.state, &tokens)?;

        // Advance slots.
        self.stats.engine_steps += 1;
        self.stats.tokens_processed += active.len() as u64;
        for &s in &active {
            let slot = self.slots[s].as_mut().unwrap();
            slot.steps += 1;
            if slot.consumed < slot.prompt.len() {
                slot.consumed += 1;
                // When the whole prompt is consumed, the logits at its last
                // token give the first generated token.
                if slot.consumed == slot.prompt.len() {
                    let row = &logits.data()[s * self.vocab..(s + 1) * self.vocab];
                    let t = Self::sample(&mut self.rng, row, slot.temperature);
                    slot.generated.push(t);
                }
            } else {
                let row = &logits.data()[s * self.vocab..(s + 1) * self.vocab];
                let t = Self::sample(&mut self.rng, row, slot.temperature);
                slot.generated.push(t);
            }
            if slot.generated.len() >= slot.max_new {
                let done = self.slots[s].take().unwrap();
                self.results.push(GenResult {
                    id: done.id,
                    tokens: done.generated,
                    steps: done.steps,
                });
                self.stats.completed += 1;
            }
        }
        Ok(active.len())
    }

    /// Run until queue + slots drain; returns all results (by request id).
    pub fn run_to_completion(&mut self) -> Result<Vec<GenResult>> {
        let t0 = std::time::Instant::now();
        loop {
            let n = self.engine_step()?;
            if n == 0 && self.queue.is_empty() {
                break;
            }
        }
        self.stats.wall_secs += t0.elapsed().as_secs_f64();
        let mut out = std::mem::take(&mut self.results);
        out.sort_by_key(|r| r.id);
        Ok(out)
    }
}

/// Decode state tensors are (B, ...) rows — helper for tests.
pub fn state_rows(t: &Tensor, batch: usize) -> usize {
    t.len() / batch
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_sampling_is_argmax() {
        let mut rng = Rng::new(1);
        let logits = vec![0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(Server::sample(&mut rng, &logits, 0.0), 1);
    }

    #[test]
    fn temperature_sampling_respects_distribution() {
        let mut rng = Rng::new(2);
        let logits = vec![0.0f32, 10.0];
        let hits = (0..100)
            .filter(|_| Server::sample(&mut rng, &logits, 1.0) == 1)
            .count();
        assert!(hits > 95, "peaked logits should dominate, got {hits}");
    }

    #[test]
    fn server_serves_on_the_cpu_backend() {
        use crate::runtime::CpuBackend;
        let backend = CpuBackend::new();
        let session =
            crate::coordinator::session::Session::init(&backend, "lm_tiny_efla", 5).unwrap();
        let mut server = Server::new(&session, 99).unwrap();
        let mut rng = Rng::new(1);
        // more requests than slots: exercises continuous batching
        let n_req = server.batch_size() as u64 + 2;
        for id in 0..n_req {
            let prompt: Vec<i32> =
                (0..rng.range(3, 8)).map(|_| rng.below(256) as i32).collect();
            server.submit(GenRequest { id, prompt, max_new: 3, temperature: 0.0 });
        }
        let results = server.run_to_completion().unwrap();
        assert_eq!(results.len(), n_req as usize);
        for r in &results {
            assert_eq!(r.tokens.len(), 3);
            assert!(r.tokens.iter().all(|&t| (0..256).contains(&t)));
        }
        assert_eq!(server.stats.completed, n_req);
        // Utilization telemetry: the queue outnumbers the slots, so most
        // steps run a full batch.
        assert_eq!(server.stats.batch, server.batch_size());
        assert!(server.stats.threads >= 1);
        let util = server.stats.utilization();
        assert!(util > 0.5 && util <= 1.0, "slot occupancy {util}");
    }
}
