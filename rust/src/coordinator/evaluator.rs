//! Evaluation harness: perplexity, masked accuracy, multi-choice probes.
//!
//! All evals reuse the `<family>_eval` artifact (loss_sum / token count /
//! argmax-correct over targets >= 0), so adding a probe costs no new graphs.

use anyhow::Result;

use crate::coordinator::session::Session;
use crate::data::probes::{ProbeItem, ProbeKind, Probes};
use crate::data::tokenizer::Bpe;
use crate::runtime::HostValue;

/// Aggregate eval statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalStats {
    pub loss_sum: f64,
    pub tokens: f64,
    pub correct: f64,
}

impl EvalStats {
    pub fn add_lm(&mut self, outs: &[f32]) {
        self.loss_sum += outs[0] as f64;
        self.tokens += outs[1] as f64;
        self.correct += outs[2] as f64;
    }

    pub fn ppl(&self) -> f64 {
        (self.loss_sum / self.tokens.max(1.0)).exp()
    }

    pub fn mean_loss(&self) -> f64 {
        self.loss_sum / self.tokens.max(1.0)
    }

    pub fn accuracy(&self) -> f64 {
        self.correct / self.tokens.max(1.0)
    }
}

/// Perplexity + masked accuracy over `n_batches` from a batch source.
pub fn eval_batches<F>(session: &Session, n_batches: usize, mut next: F) -> Result<EvalStats>
where
    F: FnMut() -> (HostValue, HostValue),
{
    let mut stats = EvalStats::default();
    for _ in 0..n_batches {
        let (t, y) = next();
        let outs = session.eval([t, y])?;
        stats.add_lm(&outs);
    }
    Ok(stats)
}

/// Pack probe items into fixed-size eval batches (padding rows have all
/// targets masked so they contribute nothing).
fn pack_items(items: &[ProbeItem], batch: usize, seq: usize) -> Vec<(HostValue, HostValue)> {
    let mut out = Vec::new();
    for chunk in items.chunks(batch) {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut tgts = Vec::with_capacity(batch * seq);
        for item in chunk {
            toks.extend_from_slice(&item.tokens);
            tgts.extend_from_slice(&item.targets);
        }
        for _ in chunk.len()..batch {
            toks.extend(std::iter::repeat(0).take(seq));
            tgts.extend(std::iter::repeat(-1).take(seq));
        }
        out.push((
            HostValue::i32(&[batch, seq], toks),
            HostValue::i32(&[batch, seq], tgts),
        ));
    }
    out
}

/// Accuracy on argmax-scored probes (FinalWord, BoolQuery) — token-level
/// accuracy restricted to scored positions.
pub fn probe_accuracy(session: &Session, items: &[ProbeItem]) -> Result<EvalStats> {
    let mut stats = EvalStats::default();
    for (t, y) in pack_items(items, session.batch, session.seq) {
        let outs = session.eval([t, y])?;
        stats.add_lm(&outs);
    }
    Ok(stats)
}

/// Multi-choice accuracy: per-group, the candidate with the lower mean
/// masked loss wins; accuracy = fraction of groups won by the correct one.
///
/// Per-item losses need isolated eval calls (the eval graph sums over the
/// batch); items are scored one per batch with the remaining rows masked.
pub fn multichoice_accuracy(session: &Session, items: &[ProbeItem]) -> Result<f64> {
    let (batch, seq) = (session.batch, session.seq);
    let mut scored: Vec<(usize, bool, f64)> = Vec::with_capacity(items.len());
    for item in items {
        let mut toks = item.tokens.clone();
        let mut tgts = item.targets.clone();
        toks.resize(batch * seq, 0);
        tgts.resize(batch * seq, -1);
        let outs = session.eval([
            HostValue::i32(&[batch, seq], toks),
            HostValue::i32(&[batch, seq], tgts),
        ])?;
        let mean_loss = outs[0] as f64 / (outs[1] as f64).max(1.0);
        scored.push((item.group, item.is_correct, mean_loss));
    }
    let groups: std::collections::BTreeSet<usize> = scored.iter().map(|s| s.0).collect();
    let mut wins = 0usize;
    let mut total = 0usize;
    for g in groups {
        let members: Vec<_> = scored.iter().filter(|s| s.0 == g).collect();
        if members.len() < 2 {
            continue;
        }
        let Some(best) = best_member(&members) else {
            continue;
        };
        total += 1;
        if best.1 {
            wins += 1;
        }
    }
    Ok(wins as f64 / total.max(1) as f64)
}

/// Lowest-loss member of a multi-choice group. A NaN loss (an item whose
/// eval scored zero tokens) is dropped up front so it can neither win nor
/// poison the comparison; the survivors are ordered with `total_cmp`.
fn best_member<'a>(members: &[&'a (usize, bool, f64)]) -> Option<&'a (usize, bool, f64)> {
    members
        .iter()
        .filter(|m| !m.2.is_nan())
        .min_by(|a, b| a.2.total_cmp(&b.2))
        .copied()
}

/// Run the full downstream probe suite (Table 1 accuracy stand-ins).
/// Returns (probe name, accuracy in [0,1]).
pub fn probe_suite(
    session: &Session,
    bpe: &Bpe,
    seed: u64,
    n_items: usize,
) -> Result<Vec<(String, f64)>> {
    let mut results = Vec::new();
    for kind in ProbeKind::all() {
        let mut probes = Probes::new(seed, session.seq);
        let items = probes.build(kind, bpe, n_items);
        let acc = match kind {
            ProbeKind::MultiChoice => multichoice_accuracy(session, &items)?,
            _ => probe_accuracy(session, &items)?.accuracy(),
        };
        results.push((kind.name().to_string(), acc));
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_stats_math() {
        let mut s = EvalStats::default();
        s.add_lm(&[20.0, 10.0, 5.0]);
        s.add_lm(&[10.0, 10.0, 7.0]);
        assert!((s.mean_loss() - 1.5).abs() < 1e-9);
        assert!((s.ppl() - 1.5f64.exp()).abs() < 1e-9);
        assert!((s.accuracy() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn best_member_ignores_nan_losses() {
        let a = (0usize, true, f64::NAN);
        let b = (0usize, false, 0.7);
        let c = (0usize, true, 0.3);
        let members = vec![&a, &b, &c];
        let best = best_member(&members).expect("finite members present");
        assert!(best.1);
        assert!((best.2 - 0.3).abs() < 1e-12);

        let x = (0usize, true, f64::NAN);
        let y = (0usize, false, f64::NAN);
        let all_nan = vec![&x, &y];
        assert!(best_member(&all_nan).is_none());
    }

    #[test]
    fn pack_items_pads_with_masked_rows() {
        let items = vec![ProbeItem {
            tokens: vec![1; 8],
            targets: vec![-1, 2, -1, -1, -1, -1, -1, -1],
            group: 0,
            is_correct: true,
        }];
        let packed = pack_items(&items, 4, 8);
        assert_eq!(packed.len(), 1);
        let (t, y) = &packed[0];
        assert_eq!(t.shape(), &[4, 8]);
        match y {
            HostValue::I32(_, data) => {
                assert_eq!(data.iter().filter(|&&x| x >= 0).count(), 1);
            }
            _ => panic!("targets must be i32"),
        }
    }
}
