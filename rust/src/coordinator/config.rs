//! Run configuration: JSON file + programmatic construction.
//!
//! A [`RunConfig`] fully determines a run (model family, data seed, steps,
//! optimizer schedule, output locations), making every experiment in
//! EXPERIMENTS.md a one-liner to reproduce.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use crate::util::json::{self, Json};

/// Which task family a run trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    Lm,
    Classifier,
    Mad,
}

impl Task {
    pub fn parse(s: &str) -> Result<Task> {
        Ok(match s {
            "lm" => Task::Lm,
            "classifier" | "clf" => Task::Classifier,
            "mad" => Task::Mad,
            other => bail!("unknown task '{other}' (lm|classifier|mad)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Task::Lm => "lm",
            Task::Classifier => "classifier",
            Task::Mad => "mad",
        }
    }
}

/// Full run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub task: Task,
    /// Artifact preset ("tiny", "small", "mad", "100m"; classifier ignores).
    pub preset: String,
    /// Token mixer variant ("efla", "deltanet", "efla_adaptive", "efla_loose").
    pub mixer: String,
    pub steps: u64,
    pub seed: u64,
    pub peak_lr: f64,
    /// Eval every N steps (0 = only at the end).
    pub eval_every: u64,
    pub eval_batches: usize,
    /// Corpus bytes to synthesize for LM runs.
    pub corpus_bytes: usize,
    /// CPU-backend worker threads (0 = auto: `EFLA_NUM_THREADS` or the
    /// machine's available parallelism).
    pub threads: usize,
    /// Serving: prompt tokens one slot ingests per engine step through
    /// the parallel prefill path (0 = token-at-a-time ingestion).
    pub prefill_chunk: usize,
    /// Serving: max total prompt tokens ingested per engine step across
    /// slots, so decoding slots aren't starved (0 = unlimited).
    pub prefill_token_budget: usize,
    /// Serving: address the HTTP front end binds (`efla serve --listen`),
    /// e.g. `127.0.0.1:8080` (`:0` = OS-assigned port). Empty = no
    /// network front end (the in-process serve demo).
    pub listen: String,
    /// Serving: admission-queue bound of the HTTP front end; requests
    /// beyond slots + this bound are rejected with 429.
    pub queue_depth: usize,
    /// Serving: seconds the front end drains in-flight requests after
    /// SIGTERM/SIGINT before giving up.
    pub drain_timeout_secs: f64,
    /// Serving: default per-request deadline in ms applied when a request
    /// carries no `timeout_ms` of its own (0 = none). The engine abandons
    /// the slot and answers `finish_reason: "timeout"` at the deadline.
    pub request_timeout_ms: u64,
    /// Serving: byte bound of the per-session recurrent-state cache's
    /// memory tier (`efla serve --state-cache-bytes`). 0 = disabled.
    pub state_cache_bytes: usize,
    /// Serving: spill directory for state-cache evictions
    /// (`--state-cache-dir`). Empty = evicted session state is dropped.
    pub state_cache_dir: String,
    /// Routing (`efla route`): in-process replica count, each an engine
    /// loop on its own thread with its own identically trained session.
    pub replicas: usize,
    /// Routing: comma-separated remote engine addresses
    /// (`host:port,host:port`). Non-empty ⇒ route to these instead of
    /// spawning in-process replicas.
    pub backends: String,
    /// Fault injection spec (`--fault` / `EFLA_FAULT`): the
    /// [`crate::serve::fault::FaultSpec`] grammar; for `efla route`, the
    /// scoped per-replica grammar (`idx:spec;...`). Empty = no faults.
    pub fault: String,
    pub artifact_dir: PathBuf,
    pub out_dir: PathBuf,
    /// Optional checkpoint interval (0 = none).
    pub ckpt_every: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            task: Task::Lm,
            preset: "tiny".into(),
            mixer: "efla".into(),
            steps: 100,
            seed: 42,
            peak_lr: 3e-4,
            eval_every: 0,
            eval_batches: 8,
            corpus_bytes: 2_000_000,
            threads: 0,
            prefill_chunk: 64,
            prefill_token_budget: 256,
            listen: String::new(),
            queue_depth: 64,
            drain_timeout_secs: 5.0,
            request_timeout_ms: 0,
            state_cache_bytes: 0,
            state_cache_dir: String::new(),
            replicas: 2,
            backends: String::new(),
            fault: String::new(),
            artifact_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("runs"),
            ckpt_every: 0,
        }
    }
}

impl RunConfig {
    /// Artifact base name, e.g. `lm_small_efla`.
    pub fn family(&self) -> String {
        match self.task {
            Task::Classifier => format!("clf_{}", self.mixer),
            Task::Mad => format!("lm_mad_{}", self.mixer),
            Task::Lm => format!("lm_{}_{}", self.preset, self.mixer),
        }
    }

    pub fn artifact(&self, graph: &str) -> String {
        format!("{}_{}", self.family(), graph)
    }

    /// Load from a JSON file, falling back to defaults per missing field.
    pub fn from_file(path: &Path) -> Result<Self> {
        let j = json::read_file(path)?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let d = RunConfig::default();
        Ok(RunConfig {
            task: Task::parse(j.get("task").as_str().unwrap_or("lm"))?,
            preset: j.get("preset").as_str().unwrap_or(&d.preset).to_string(),
            mixer: j.get("mixer").as_str().unwrap_or(&d.mixer).to_string(),
            steps: j.get("steps").as_usize().unwrap_or(d.steps as usize) as u64,
            seed: j.get("seed").as_usize().unwrap_or(d.seed as usize) as u64,
            peak_lr: j.get("peak_lr").as_f64().unwrap_or(d.peak_lr),
            eval_every: j.get("eval_every").as_usize().unwrap_or(0) as u64,
            eval_batches: j.get("eval_batches").as_usize().unwrap_or(d.eval_batches),
            corpus_bytes: j.get("corpus_bytes").as_usize().unwrap_or(d.corpus_bytes),
            threads: j.get("threads").as_usize().unwrap_or(d.threads),
            prefill_chunk: j.get("prefill_chunk").as_usize().unwrap_or(d.prefill_chunk),
            prefill_token_budget: j
                .get("prefill_token_budget")
                .as_usize()
                .unwrap_or(d.prefill_token_budget),
            listen: j.get("listen").as_str().unwrap_or(&d.listen).to_string(),
            queue_depth: j.get("queue_depth").as_usize().unwrap_or(d.queue_depth),
            drain_timeout_secs: j
                .get("drain_timeout_secs")
                .as_f64()
                .unwrap_or(d.drain_timeout_secs),
            request_timeout_ms: j
                .get("request_timeout_ms")
                .as_usize()
                .unwrap_or(d.request_timeout_ms as usize) as u64,
            state_cache_bytes: j
                .get("state_cache_bytes")
                .as_usize()
                .unwrap_or(d.state_cache_bytes),
            state_cache_dir: j
                .get("state_cache_dir")
                .as_str()
                .unwrap_or(&d.state_cache_dir)
                .to_string(),
            replicas: j.get("replicas").as_usize().unwrap_or(d.replicas),
            backends: j.get("backends").as_str().unwrap_or(&d.backends).to_string(),
            fault: j.get("fault").as_str().unwrap_or(&d.fault).to_string(),
            artifact_dir: PathBuf::from(
                j.get("artifact_dir").as_str().unwrap_or("artifacts"),
            ),
            out_dir: PathBuf::from(j.get("out_dir").as_str().unwrap_or("runs")),
            ckpt_every: j.get("ckpt_every").as_usize().unwrap_or(0) as u64,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::Str(self.task.name().into())),
            ("preset", Json::Str(self.preset.clone())),
            ("mixer", Json::Str(self.mixer.clone())),
            ("steps", Json::Num(self.steps as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("peak_lr", Json::Num(self.peak_lr)),
            ("eval_every", Json::Num(self.eval_every as f64)),
            ("eval_batches", Json::Num(self.eval_batches as f64)),
            ("corpus_bytes", Json::Num(self.corpus_bytes as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("prefill_chunk", Json::Num(self.prefill_chunk as f64)),
            ("prefill_token_budget", Json::Num(self.prefill_token_budget as f64)),
            ("listen", Json::Str(self.listen.clone())),
            ("queue_depth", Json::Num(self.queue_depth as f64)),
            ("drain_timeout_secs", Json::Num(self.drain_timeout_secs)),
            ("request_timeout_ms", Json::Num(self.request_timeout_ms as f64)),
            ("state_cache_bytes", Json::Num(self.state_cache_bytes as f64)),
            ("state_cache_dir", Json::Str(self.state_cache_dir.clone())),
            ("replicas", Json::Num(self.replicas as f64)),
            ("backends", Json::Str(self.backends.clone())),
            ("fault", Json::Str(self.fault.clone())),
            (
                "artifact_dir",
                Json::Str(self.artifact_dir.to_string_lossy().into_owned()),
            ),
            ("out_dir", Json::Str(self.out_dir.to_string_lossy().into_owned())),
            ("ckpt_every", Json::Num(self.ckpt_every as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_names() {
        let mut c = RunConfig::default();
        assert_eq!(c.family(), "lm_tiny_efla");
        assert_eq!(c.artifact("step"), "lm_tiny_efla_step");
        c.task = Task::Classifier;
        c.mixer = "deltanet".into();
        assert_eq!(c.family(), "clf_deltanet");
        c.task = Task::Mad;
        assert_eq!(c.family(), "lm_mad_deltanet");
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default();
        c.steps = 777;
        c.mixer = "efla_loose".into();
        c.peak_lr = 1e-3;
        c.threads = 6;
        let j = c.to_json();
        let c2 = RunConfig::from_json(&j).unwrap();
        assert_eq!(c2.steps, 777);
        assert_eq!(c2.mixer, "efla_loose");
        assert!((c2.peak_lr - 1e-3).abs() < 1e-12);
        assert_eq!(c2.task, Task::Lm);
        assert_eq!(c2.threads, 6);
    }

    #[test]
    fn prefill_knobs_roundtrip_and_default() {
        let d = RunConfig::default();
        assert_eq!(d.prefill_chunk, 64);
        assert_eq!(d.prefill_token_budget, 256);
        let c = RunConfig {
            prefill_chunk: 0,
            prefill_token_budget: 1024,
            ..RunConfig::default()
        };
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.prefill_chunk, 0);
        assert_eq!(c2.prefill_token_budget, 1024);
    }

    #[test]
    fn serve_knobs_roundtrip_and_default() {
        let d = RunConfig::default();
        assert_eq!(d.listen, "");
        assert_eq!(d.queue_depth, 64);
        assert!((d.drain_timeout_secs - 5.0).abs() < 1e-12);
        let c = RunConfig {
            listen: "127.0.0.1:0".into(),
            queue_depth: 3,
            drain_timeout_secs: 0.5,
            ..RunConfig::default()
        };
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.listen, "127.0.0.1:0");
        assert_eq!(c2.queue_depth, 3);
        assert!((c2.drain_timeout_secs - 0.5).abs() < 1e-12);
    }

    #[test]
    fn router_knobs_roundtrip_and_default() {
        let d = RunConfig::default();
        assert_eq!(d.request_timeout_ms, 0);
        assert_eq!(d.replicas, 2);
        assert_eq!(d.backends, "");
        assert_eq!(d.fault, "");
        let c = RunConfig {
            request_timeout_ms: 1500,
            replicas: 3,
            backends: "127.0.0.1:8001,127.0.0.1:8002".into(),
            fault: "0:stall_ms=100;seed=7".into(),
            ..RunConfig::default()
        };
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.request_timeout_ms, 1500);
        assert_eq!(c2.replicas, 3);
        assert_eq!(c2.backends, "127.0.0.1:8001,127.0.0.1:8002");
        assert_eq!(c2.fault, "0:stall_ms=100;seed=7");
    }

    #[test]
    fn state_cache_knobs_roundtrip_and_default() {
        let d = RunConfig::default();
        assert_eq!(d.state_cache_bytes, 0);
        assert_eq!(d.state_cache_dir, "");
        let c = RunConfig {
            state_cache_bytes: 8 << 20,
            state_cache_dir: "/tmp/efla-state".into(),
            ..RunConfig::default()
        };
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.state_cache_bytes, 8 << 20);
        assert_eq!(c2.state_cache_dir, "/tmp/efla-state");
    }

    #[test]
    fn bad_task_rejected() {
        let j = json::parse(r#"{"task": "diffusion"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }
}
