//! A model session: parameters + optimizer state threaded through the AOT
//! step executable as raw literals (never converted to host vectors on the
//! hot path).

use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::runtime::{Executable, HostValue, Runtime};
use crate::tensor::Tensor;

/// Scalar training metrics returned by one step.
#[derive(Clone, Copy, Debug)]
pub struct StepMetrics {
    pub loss: f32,
    pub grad_norm: f32,
}

/// Parameters + AdamW moments bound to step/eval executables.
pub struct Session {
    family: String,
    step_exe: Rc<Executable>,
    eval_exe: Option<Rc<Executable>>,
    /// Flattened params, then m, then v — exactly the step graph's prefix.
    params: Vec<xla::Literal>,
    m: Vec<xla::Literal>,
    v: Vec<xla::Literal>,
    n_params: usize,
    step_count: u64,
    pub batch: usize,
    pub seq: usize,
}

impl Session {
    /// Initialize from artifacts: runs `<family>_init` with `seed`.
    pub fn init(rt: &Runtime, family: &str, seed: u32) -> Result<Self> {
        let init_exe = rt.load(&format!("{family}_init"))?;
        let step_exe = rt.load(&format!("{family}_step"))?;
        let eval_exe = match rt.has(&format!("{family}_eval")) {
            true => Some(rt.load(&format!("{family}_eval"))?),
            false => None,
        };
        let seed_lit = HostValue::scalar_u32(seed).to_literal()?;
        let params = init_exe.run_raw(&[seed_lit])?;
        let n_params = params.len();

        // Zero AdamW moments shaped like the step graph's m./v. inputs.
        let spec = step_exe.spec();
        let expected = 3 * n_params + 4;
        if spec.inputs.len() != expected {
            bail!(
                "{family}_step: expected {expected} inputs (3x{n_params} state + step/tokens/targets/lr), manifest has {}",
                spec.inputs.len()
            );
        }
        let zeros = |range: std::ops::Range<usize>| -> Result<Vec<xla::Literal>> {
            range
                .map(|i| HostValue::zeros_like_spec(&spec.inputs[i]).to_literal())
                .collect()
        };
        let m = zeros(n_params..2 * n_params)?;
        let v = zeros(2 * n_params..3 * n_params)?;

        Ok(Session {
            family: family.to_string(),
            batch: spec.batch,
            seq: spec.seq,
            step_exe,
            eval_exe,
            params,
            m,
            v,
            n_params,
            step_count: 0,
        })
    }

    pub fn family(&self) -> &str {
        &self.family
    }

    pub fn steps_done(&self) -> u64 {
        self.step_count
    }

    pub fn n_params_tensors(&self) -> usize {
        self.n_params
    }

    /// Total parameter element count (from the manifest).
    pub fn param_elems(&self) -> usize {
        self.step_exe.spec().param_elems()
    }

    /// One optimizer step. `data` are the two data literals of the step
    /// graph (tokens/targets for LM+MAD, pixels/labels for the classifier).
    pub fn step(&mut self, data: [xla::Literal; 2], lr: f32) -> Result<StepMetrics> {
        self.step_count += 1;
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(3 * self.n_params + 4);
        inputs.extend(self.params.iter());
        inputs.extend(self.m.iter());
        inputs.extend(self.v.iter());
        let step_lit = HostValue::scalar_f32(self.step_count as f32).to_literal()?;
        let lr_lit = HostValue::scalar_f32(lr).to_literal()?;
        let [d0, d1] = &data;
        inputs.push(&step_lit);
        inputs.push(d0);
        inputs.push(d1);
        inputs.push(&lr_lit);

        // Borrow-based execute avoids cloning literals.
        let outs = self.step_exe.run_raw_borrowed(&inputs)?;
        let n = self.n_params;
        if outs.len() != 3 * n + 2 {
            bail!("step returned {} outputs, expected {}", outs.len(), 3 * n + 2);
        }
        let mut it = outs.into_iter();
        self.params = (&mut it).take(n).collect();
        self.m = (&mut it).take(n).collect();
        self.v = (&mut it).take(n).collect();
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("missing loss"))?
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("loss: {e:?}"))?;
        let gnorm = it
            .next()
            .ok_or_else(|| anyhow!("missing gnorm"))?
            .get_first_element::<f32>()
            .map_err(|e| anyhow!("gnorm: {e:?}"))?;
        Ok(StepMetrics { loss, grad_norm: gnorm })
    }

    /// Run the eval graph on one batch; returns the raw scalar outputs
    /// (LM: loss_sum/count/correct; classifier: loss_sum/correct).
    pub fn eval(&self, data: [xla::Literal; 2]) -> Result<Vec<f32>> {
        let exe = self
            .eval_exe
            .as_ref()
            .ok_or_else(|| anyhow!("{}: no eval artifact", self.family))?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(self.n_params + 2);
        inputs.extend(self.params.iter());
        let [d0, d1] = &data;
        inputs.push(d0);
        inputs.push(d1);
        let outs = exe.run_raw_borrowed(&inputs)?;
        outs.into_iter()
            .map(|l| l.get_first_element::<f32>().map_err(|e| anyhow!("eval out: {e:?}")))
            .collect()
    }

    /// Run an auxiliary graph of this family (e.g. `logits_last`, `prefill`)
    /// with the current params followed by `extra` inputs.
    pub fn run_aux(
        &self,
        exe: &Executable,
        extra: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let mut inputs: Vec<&xla::Literal> =
            Vec::with_capacity(self.n_params + extra.len());
        inputs.extend(self.params.iter());
        inputs.extend(extra.iter());
        exe.run_raw_borrowed(&inputs)
    }

    /// Export parameters to host tensors (checkpointing / inspection).
    pub fn export_params(&self) -> Result<Vec<Tensor>> {
        let spec = self.step_exe.spec();
        self.params
            .iter()
            .enumerate()
            .map(|(i, lit)| {
                Ok(HostValue::from_literal(lit, &spec.inputs[i])?
                    .into_f32()
                    .expect("params are f32"))
            })
            .collect()
    }

    /// Export full optimizer state (params, m, v) for checkpointing.
    pub fn export_state(&self) -> Result<Vec<Tensor>> {
        let spec = self.step_exe.spec();
        let mut out = Vec::with_capacity(3 * self.n_params);
        for (off, group) in [(0usize, &self.params), (self.n_params, &self.m), (2 * self.n_params, &self.v)]
        {
            for (i, lit) in group.iter().enumerate() {
                out.push(
                    HostValue::from_literal(lit, &spec.inputs[off + i])?
                        .into_f32()
                        .expect("state is f32"),
                );
            }
        }
        Ok(out)
    }

    /// Restore state exported by [`export_state`] (sets step counter too).
    pub fn import_state(&mut self, tensors: &[Tensor], step_count: u64) -> Result<()> {
        if tensors.len() != 3 * self.n_params {
            bail!(
                "checkpoint has {} tensors, session needs {}",
                tensors.len(),
                3 * self.n_params
            );
        }
        let lits: Vec<xla::Literal> = tensors
            .iter()
            .map(|t| HostValue::F32(t.clone()).to_literal())
            .collect::<Result<_>>()?;
        let mut it = lits.into_iter();
        self.params = (&mut it).take(self.n_params).collect();
        self.m = (&mut it).take(self.n_params).collect();
        self.v = (&mut it).take(self.n_params).collect();
        self.step_count = step_count;
        Ok(())
    }
}
