//! A model session: thin, backend-agnostic wrapper over
//! [`crate::runtime::ModelSession`].
//!
//! The coordinator (trainer / evaluator / server / experiments) only ever
//! sees this type; whether the math runs through the pure-Rust CPU backend
//! or a PJRT executable is decided once, when the backend is opened.

use anyhow::Result;

use crate::runtime::{Backend, HostValue, ModelSession};
use crate::tensor::Tensor;

pub use crate::runtime::StepMetrics;

/// Parameters + optimizer state bound to a backend's step/eval/decode.
pub struct Session {
    inner: Box<dyn ModelSession>,
    pub batch: usize,
    pub seq: usize,
}

impl Session {
    /// Initialize a family (e.g. `lm_tiny_efla`) on a backend with `seed`.
    pub fn init(backend: &dyn Backend, family: &str, seed: u32) -> Result<Self> {
        let inner = backend.open_session(family, seed)?;
        Ok(Session { batch: inner.batch(), seq: inner.seq(), inner })
    }

    pub fn family(&self) -> &str {
        self.inner.family()
    }

    pub fn steps_done(&self) -> u64 {
        self.inner.steps_done()
    }

    /// Worker threads the backend session's executor uses.
    pub fn threads(&self) -> usize {
        self.inner.threads()
    }

    pub fn n_params_tensors(&self) -> usize {
        self.inner.n_param_tensors()
    }

    /// Total parameter element count.
    pub fn param_elems(&self) -> usize {
        self.inner.param_elems()
    }

    /// One optimizer step. `data` are the two data slots of the step graph
    /// (tokens/targets for LM+MAD, pixels/labels for the classifier).
    pub fn step(&mut self, data: [HostValue; 2], lr: f32) -> Result<StepMetrics> {
        let [d0, d1] = &data;
        self.inner.step(d0, d1, lr)
    }

    /// Run the eval graph on one batch; returns the raw scalar outputs
    /// (LM: loss_sum/count/correct; classifier: loss_sum/correct).
    pub fn eval(&self, data: [HostValue; 2]) -> Result<Vec<f32>> {
        let [d0, d1] = &data;
        self.inner.eval(d0, d1)
    }

    /// Export parameters to host tensors (checkpointing / inspection).
    pub fn export_params(&self) -> Result<Vec<Tensor>> {
        self.inner.export_params()
    }

    /// Export full optimizer state (params, m, v) for checkpointing.
    pub fn export_state(&self) -> Result<Vec<Tensor>> {
        self.inner.export_state()
    }

    /// Restore state exported by [`export_state`](Self::export_state).
    pub fn import_state(&mut self, tensors: &[Tensor], step_count: u64) -> Result<()> {
        self.inner.import_state(tensors, step_count)
    }

    // ---- recurrent decode (serving) path -----------------------------

    pub fn decode_batch(&self) -> Result<usize> {
        self.inner.decode_batch()
    }

    pub fn vocab(&self) -> Result<usize> {
        self.inner.vocab()
    }

    /// Zeroed per-slot recurrent state.
    pub fn decode_state(&self) -> Result<Vec<HostValue>> {
        self.inner.decode_state()
    }

    /// One batched decode step: advances `state` in place, returns logits
    /// (decode_batch, vocab).
    pub fn decode(&self, state: &mut [HostValue], tokens: &[i32]) -> Result<Tensor> {
        self.inner.decode(state, tokens)
    }

    /// True when the backend implements the slot-batched decode path.
    pub fn supports_batched_decode(&self) -> bool {
        self.inner.supports_batched_decode()
    }

    /// Batched decode over the busy subset of slots: `slots` lists the
    /// busy slot ids (strictly increasing), `tokens[i]` pairs with
    /// `slots[i]`; advances only those slots' state rows in place and
    /// returns logits (slots.len(), vocab), row i for `slots[i]`.
    /// Bit-identical per slot to [`Session::decode`] at any occupancy.
    pub fn decode_slots(
        &self,
        state: &mut [HostValue],
        slots: &[usize],
        tokens: &[i32],
    ) -> Result<Tensor> {
        self.inner.decode_slots(state, slots, tokens)
    }

    /// True when the backend implements the chunked prefill path.
    pub fn supports_prefill(&self) -> bool {
        self.inner.supports_prefill()
    }

    /// Chunked prompt prefill for one slot: runs `tokens` through the
    /// parallel forward path seeded from (and advancing, in place) that
    /// slot's state rows; returns the last-position logits (1, vocab).
    /// Bit-identical to feeding the tokens one per step through
    /// [`Session::decode`], for any chunking.
    pub fn prefill(&self, state: &mut [HostValue], slot: usize, tokens: &[i32]) -> Result<Tensor> {
        self.inner.prefill(state, slot, tokens)
    }

    /// True when the backend implements per-slot state export/import (the
    /// serving session state cache requires it).
    pub fn supports_state_io(&self) -> bool {
        self.inner.supports_state_io()
    }

    /// Export one serving slot's recurrent state rows (exact f32 copy,
    /// one row per decode-state tensor).
    pub fn export_slot_state(&self, state: &[HostValue], slot: usize) -> Result<Vec<Vec<f32>>> {
        self.inner.export_slot_state(state, slot)
    }

    /// Restore rows captured by [`Session::export_slot_state`] into
    /// `slot` (any slot — state rows are slot-position independent),
    /// leaving all other slots untouched.
    pub fn import_slot_state(
        &self,
        state: &mut [HostValue],
        slot: usize,
        rows: &[Vec<f32>],
    ) -> Result<()> {
        self.inner.import_slot_state(state, slot, rows)
    }
}
