//! Training loops: LM / MAD / classifier over a [`Session`].
//!
//! The trainer owns the schedule, the data prefetcher, metrics history and
//! checkpointing; the math lives entirely inside the AOT step executable.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::checkpoint;
use crate::coordinator::config::{RunConfig, Task};
use crate::coordinator::schedule::Schedule;
use crate::coordinator::session::Session;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::loader::{Prefetcher, TokenStream};
use crate::data::mad::{MadGen, MadTask};
use crate::data::mnist::{Corruption, Smnist};
use crate::data::tokenizer::Bpe;
use crate::runtime::{Backend, HostValue};
use crate::util::json::Json;
use crate::util::logging::Meter;
use crate::util::rng::Rng;

/// One recorded point of the training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub step: u64,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f64,
}

/// Full training record returned to the caller (and dumped as JSON).
#[derive(Clone, Debug, Default)]
pub struct History {
    pub curve: Vec<CurvePoint>,
    pub evals: Vec<(u64, f32)>, // (step, eval metric: LM ppl / clf acc)
    pub tokens_per_step: usize,
    pub wall_secs: f64,
}

impl History {
    pub fn final_loss(&self) -> f32 {
        self.curve.last().map(|p| p.loss).unwrap_or(f32::NAN)
    }

    /// Mean loss over the last `n` points (smoother than final_loss).
    pub fn tail_loss(&self, n: usize) -> f32 {
        if self.curve.is_empty() {
            return f32::NAN;
        }
        let k = n.min(self.curve.len());
        let s: f32 = self.curve[self.curve.len() - k..].iter().map(|p| p.loss).sum();
        s / k as f32
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "curve",
                Json::Arr(
                    self.curve
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("step", Json::Num(p.step as f64)),
                                ("loss", Json::Num(p.loss as f64)),
                                ("grad_norm", Json::Num(p.grad_norm as f64)),
                                ("lr", Json::Num(p.lr)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "evals",
                Json::Arr(
                    self.evals
                        .iter()
                        .map(|&(s, m)| Json::arr_f64(&[s as f64, m as f64]))
                        .collect(),
                ),
            ),
            ("tokens_per_step", Json::Num(self.tokens_per_step as f64)),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }
}

/// A batch for the two data slots of the step graph.
pub type DataBatch = (HostValue, HostValue);

/// Train an LM (or MAD) session from a token/target batch source.
pub fn train_lm<F>(
    session: &mut Session,
    schedule: Schedule,
    steps: u64,
    mut next_batch: F,
    mut on_step: impl FnMut(&CurvePoint),
) -> Result<History>
where
    F: FnMut() -> DataBatch,
{
    let t0 = std::time::Instant::now();
    let mut hist = History {
        tokens_per_step: session.batch * session.seq,
        ..Default::default()
    };
    let mut meter = Meter::new(Some(steps));
    for _ in 0..steps {
        let (tokens, targets) = next_batch();
        let lr = schedule.lr(session.steps_done() + 1);
        let metrics = session.step([tokens, targets], lr as f32)?;
        let point = CurvePoint {
            step: session.steps_done(),
            loss: metrics.loss,
            grad_norm: metrics.grad_norm,
            lr,
        };
        hist.curve.push(point);
        meter.add(1);
        if point.step % 25 == 0 || point.step == steps {
            log::info!(
                "[{}] {} | loss {:.4} | gnorm {:.3} | lr {:.2e}",
                session.family(),
                meter.line("step"),
                point.loss,
                point.grad_norm,
                point.lr
            );
        }
        on_step(&point);
    }
    hist.wall_secs = t0.elapsed().as_secs_f64();
    Ok(hist)
}

/// Build the LM data pipeline for a config: corpus -> BPE -> token stream
/// -> prefetching batcher. Returns (prefetcher, tokenizer).
pub fn lm_data(
    cfg: &RunConfig,
    batch: usize,
    seq: usize,
) -> Result<(Prefetcher<(HostValue, HostValue)>, Bpe)> {
    let vocab = vocab_for_preset(&cfg.preset);
    let mut corpus = Corpus::new(cfg.seed, CorpusConfig::default());
    let sample = corpus.text(cfg.corpus_bytes.min(300_000));
    let bpe = if vocab > 256 { Bpe::train(&sample, vocab) } else { Bpe::bytes_only() };
    let text = if cfg.corpus_bytes > sample.len() {
        let mut t = sample;
        t.push_str(&corpus.text(cfg.corpus_bytes - t.len()));
        t
    } else {
        sample
    };
    let ids: Vec<i32> = bpe.encode_cached(&text).iter().map(|&x| x as i32).collect();
    log::info!(
        "corpus: {} bytes -> {} tokens (vocab {})",
        text.len(),
        ids.len(),
        bpe.vocab_size()
    );
    let mut stream = TokenStream::new(ids);
    let pf = Prefetcher::spawn(4, move || {
        let (t, y) = stream.lm_batch(batch, seq);
        (
            HostValue::i32(&[batch, seq], t),
            HostValue::i32(&[batch, seq], y),
        )
    });
    Ok((pf, bpe))
}

/// Vocab sizes matching `python/compile/model.py` PRESETS.
pub fn vocab_for_preset(preset: &str) -> usize {
    match preset {
        "tiny" => 256,
        "mini" => 1024,
        "small" => 2048,
        "medium" => 4096,
        "100m" => 8192,
        "mad" => 64,
        _ => 256,
    }
}

/// Build a MAD data prefetcher for one task.
pub fn mad_data(
    task: MadTask,
    batch: usize,
    seq: usize,
    seed: u64,
) -> Prefetcher<(HostValue, HostValue)> {
    let mut g = MadGen::new(task, seq, seed);
    Prefetcher::spawn(4, move || {
        let (t, y) = g.batch(batch);
        (
            HostValue::i32(&[batch, seq], t),
            HostValue::i32(&[batch, seq], y),
        )
    })
}

/// Build a classifier (sMNIST) prefetcher with a train-time corruption.
pub fn clf_data(
    batch: usize,
    seed: u64,
    corruption: Corruption,
) -> Prefetcher<(HostValue, HostValue)> {
    let mut gen = Smnist::new(seed);
    let mut rng = Rng::new(seed ^ 0xC0_4415);
    Prefetcher::spawn(4, move || {
        let (mut px, ls) = gen.batch(batch);
        for row in px.chunks_mut(crate::data::mnist::SEQ) {
            corruption.apply(row, &mut rng);
        }
        (
            HostValue::F32(crate::tensor::Tensor::from_vec(
                &[batch, crate::data::mnist::SEQ],
                px,
            )),
            HostValue::i32(&[batch], ls),
        )
    })
}

/// End-to-end run driver used by the launcher binary: builds the session and
/// pipeline for `cfg`, trains, evaluates, writes history + checkpoints.
pub fn run(backend: &dyn Backend, cfg: &RunConfig) -> Result<History> {
    let family = cfg.family();
    let mut session = Session::init(backend, &family, cfg.seed as u32)?;
    log::info!(
        "session {family}: {} param tensors, {:.2}M elements, batch {} x seq {}",
        session.n_params_tensors(),
        session.param_elems() as f64 / 1e6,
        session.batch,
        session.seq
    );
    let schedule = Schedule::paper_default(cfg.peak_lr, cfg.steps);
    let (batch, seq) = (session.batch, session.seq);

    enum Source {
        Pf(Prefetcher<(HostValue, HostValue)>),
    }
    let source = match cfg.task {
        Task::Lm => Source::Pf(lm_data(cfg, batch, seq)?.0),
        Task::Mad => Source::Pf(mad_data(MadTask::InContextRecall, batch, seq, cfg.seed)),
        Task::Classifier => Source::Pf(clf_data(batch, cfg.seed, Corruption::None)),
    };
    let Source::Pf(pf) = source;

    let ckpt_dir: PathBuf = cfg.out_dir.join(&family);
    let ckpt_every = cfg.ckpt_every;
    let mut hist = train_lm(
        &mut session,
        schedule,
        cfg.steps,
        || pf.next(),
        |_| {},
    )?;

    if ckpt_every > 0 || cfg.steps > 0 {
        let tensors = session.export_state()?;
        checkpoint::save(&ckpt_dir.join("final.ckpt"), session.steps_done(), &tensors)?;
        log::info!("checkpoint: {}", ckpt_dir.join("final.ckpt").display());
    }

    // Final eval: LM perplexity on held-out stream / clf accuracy.
    if let Task::Lm = cfg.task {
        let eval_cfg = RunConfig { seed: cfg.seed + 1, ..cfg.clone() };
        let (eval_pf, _) = lm_data(&eval_cfg, batch, seq)?;
        let mut loss_sum = 0f64;
        let mut count = 0f64;
        for _ in 0..cfg.eval_batches {
            let (t, y) = eval_pf.next();
            let outs = session.eval([t, y])?;
            loss_sum += outs[0] as f64;
            count += outs[1] as f64;
        }
        let ppl = (loss_sum / count.max(1.0)).exp();
        log::info!("eval: ppl {ppl:.2} over {count} tokens");
        hist.evals.push((session.steps_done(), ppl as f32));
    }

    std::fs::create_dir_all(&ckpt_dir)?;
    crate::util::json::write_file(&ckpt_dir.join("history.json"), &hist.to_json())?;
    Ok(hist)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_presets_match_python() {
        assert_eq!(vocab_for_preset("tiny"), 256);
        assert_eq!(vocab_for_preset("small"), 2048);
        assert_eq!(vocab_for_preset("100m"), 8192);
        assert_eq!(vocab_for_preset("mad"), 64);
    }

    #[test]
    fn history_tail_loss() {
        let mut h = History::default();
        for i in 0..10 {
            h.curve.push(CurvePoint { step: i, loss: i as f32, grad_norm: 0.0, lr: 0.0 });
        }
        assert!((h.tail_loss(2) - 8.5).abs() < 1e-6);
        assert_eq!(h.final_loss(), 9.0);
    }
}
