//! Layer-3 coordinator: the part of the system that owns the run.
//!
//! * [`config`]      — typed experiment/run configuration (JSON + CLI).
//! * [`schedule`]    — LR schedules (cosine + warmup, paper Appendix A).
//! * [`session`]     — a model bound to an execution backend (pure-Rust CPU
//!   or PJRT via the `xla` feature) through `runtime::Backend`.
//! * [`trainer`]     — training loops (LM, classifier) with metrics,
//!   checkpointing and prefetched data.
//! * [`evaluator`]   — perplexity + downstream-probe + MAD accuracy evals.
//! * [`server`]      — slot-based continuously-batched decode service on the
//!   O(1)-state recurrent path (the serving win linear attention buys).
//! * [`checkpoint`]  — binary param/opt-state snapshots.
//! * [`experiments`] — the registry mapping paper tables/figures to runs.

#![forbid(unsafe_code)]

pub mod checkpoint;
pub mod config;
pub mod evaluator;
pub mod experiments;
pub mod schedule;
pub mod server;
pub mod session;
pub mod trainer;
