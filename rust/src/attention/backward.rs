//! Reverse-mode gradients through the sequential generalized delta rule.
//!
//! The CPU training backend backpropagates through the recurrence
//!
//! ```text
//! u_t = v_t - S_{t-1}^T k_t
//! S_t = S_{t-1} + alpha_t k_t u_t^T
//! o_t = S_t^T q_t
//! ```
//!
//! by recomputing the forward state trajectory (S_0..S_L) for one head and
//! then running the adjoint recurrence backwards with the running state
//! cotangent G = dL/dS_t:
//!
//! ```text
//! dq_t      = S_t do_t
//! G        += q_t do_t^T                       (o_t contribution)
//! dalpha_t  = k_t^T G u_t
//! du_t      = alpha_t G^T k_t
//! dk_t      = alpha_t G u_t - S_{t-1} du_t
//! dv_t      = du_t
//! G        -= k_t du_t^T                       (u_t's S_{t-1} dependence)
//! ```
//!
//! Memory is O(L * Dk * Dv) transient per head — the caller loops over
//! (batch, head) pairs so the peak is one head's trajectory, not the whole
//! batch (the checkpointing trade the classifier's L=784 sequences need).
//! The core is [`delta_bptt_into`]: raw slices in, gradients written in
//! place, the trajectory buffers drawn from a caller-owned [`Scratch`]
//! arena, and every inner loop a SIMD-dispatched `dot`/`axpy`.

use crate::tensor::{axpy, dot, Scratch, Tensor};

/// Gradients of the alpha-form sequential delta rule.
///
/// q, k: (L, Dk); v: (L, Dv); alpha: len L; dout: (L, Dv) = dL/do.
/// Returns (dq (L,Dk), dk (L,Dk), dv (L,Dv), dalpha (len L)).
pub fn delta_bptt(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    alpha: &[f32],
    dout: &Tensor,
) -> (Tensor, Tensor, Tensor, Vec<f32>) {
    let l = q.shape()[0];
    let dk = q.shape()[1];
    let dv = v.shape()[1];
    assert_eq!(k.shape(), &[l, dk]);
    assert_eq!(v.shape(), &[l, dv]);
    assert_eq!(dout.shape(), &[l, dv]);
    assert_eq!(alpha.len(), l);

    let mut dq = vec![0.0f32; l * dk];
    let mut dkk = vec![0.0f32; l * dk];
    let mut dvv = vec![0.0f32; l * dv];
    let mut dalpha = vec![0.0f32; l];
    let mut scratch = Scratch::new();
    delta_bptt_into(
        q.data(),
        k.data(),
        v.data(),
        alpha,
        dout.data(),
        dk,
        dv,
        &mut dq,
        &mut dkk,
        &mut dvv,
        &mut dalpha,
        &mut scratch,
    );
    (
        Tensor::from_vec(&[l, dk], dq),
        Tensor::from_vec(&[l, dk], dkk),
        Tensor::from_vec(&[l, dv], dvv),
        dalpha,
    )
}

/// Allocation-free core of [`delta_bptt`] on raw row-major slices. The
/// gradient outputs are overwritten (`dq`/`dkk`: (L, Dk); `dvv`: (L, Dv);
/// `dalpha`: len L); the recomputed state trajectory, u-sequence and
/// adjoint carriers come from `scratch` and go back before returning.
pub fn delta_bptt_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    alpha: &[f32],
    dout: &[f32],
    dk: usize,
    dv: usize,
    dq: &mut [f32],
    dkk: &mut [f32],
    dvv: &mut [f32],
    dalpha: &mut [f32],
    scratch: &mut Scratch,
) {
    let l = alpha.len();
    debug_assert_eq!(q.len(), l * dk);
    debug_assert_eq!(k.len(), l * dk);
    debug_assert_eq!(v.len(), l * dv);
    debug_assert_eq!(dout.len(), l * dv);
    debug_assert_eq!(dq.len(), l * dk);
    debug_assert_eq!(dkk.len(), l * dk);
    debug_assert_eq!(dvv.len(), l * dv);
    debug_assert_eq!(dalpha.len(), l);
    let sd = dk * dv;

    // Forward recompute: states[t*sd..] = S_t (S_0 = 0 from the zeroed
    // take), us[t*dv..] = u_t = v_t - S_{t-1}^T k_t.
    let mut states = scratch.take((l + 1) * sd);
    let mut us = scratch.take(l * dv);
    for t in 0..l {
        let kt = &k[t * dk..(t + 1) * dk];
        let (done, rest) = states.split_at_mut((t + 1) * sd);
        let s_prev = &done[t * sd..];
        let s_new = &mut rest[..sd];
        s_new.copy_from_slice(s_prev);
        let u = &mut us[t * dv..(t + 1) * dv];
        u.copy_from_slice(&v[t * dv..(t + 1) * dv]);
        for (i, &ki) in kt.iter().enumerate() {
            if ki != 0.0 {
                axpy(-ki, &s_prev[i * dv..(i + 1) * dv], u);
            }
        }
        let a = alpha[t];
        for (i, &ki) in kt.iter().enumerate() {
            let aki = a * ki;
            if aki != 0.0 {
                axpy(aki, u, &mut s_new[i * dv..(i + 1) * dv]);
            }
        }
    }

    // Backward sweep with the running cotangent G = dL/dS_t.
    let mut g = scratch.take(sd);
    let mut gk = scratch.take(dv);
    for t in (0..l).rev() {
        let qt = &q[t * dk..(t + 1) * dk];
        let kt = &k[t * dk..(t + 1) * dk];
        let dot_r = &dout[t * dv..(t + 1) * dv];
        let s_t = &states[(t + 1) * sd..(t + 2) * sd];
        let s_prev = &states[t * sd..(t + 1) * sd];
        let u = &us[t * dv..(t + 1) * dv];
        let a = alpha[t];

        // dq_t = S_t do_t ;  G += q_t do_t^T
        let dqr = &mut dq[t * dk..(t + 1) * dk];
        for i in 0..dk {
            dqr[i] = dot(&s_t[i * dv..(i + 1) * dv], dot_r);
            let qi = qt[i];
            if qi != 0.0 {
                axpy(qi, dot_r, &mut g[i * dv..(i + 1) * dv]);
            }
        }

        // gk = G^T k_t ;  dalpha_t = gk . u_t ;  du_t = alpha_t gk
        gk.iter_mut().for_each(|x| *x = 0.0);
        for (i, &ki) in kt.iter().enumerate() {
            if ki != 0.0 {
                axpy(ki, &g[i * dv..(i + 1) * dv], &mut gk);
            }
        }
        dalpha[t] = dot(&gk, u);

        // dk_t = alpha_t (G u_t - S_{t-1} du_t/alpha_t) ; dv_t = alpha_t gk
        let dkr = &mut dkk[t * dk..(t + 1) * dk];
        for i in 0..dk {
            let gu = dot(&g[i * dv..(i + 1) * dv], u);
            let sdu = dot(&s_prev[i * dv..(i + 1) * dv], &gk);
            dkr[i] = a * gu - a * sdu;
        }
        let dvr = &mut dvv[t * dv..(t + 1) * dv];
        for (dvj, &gkj) in dvr.iter_mut().zip(gk.iter()) {
            *dvj = a * gkj;
        }

        // G -= k_t du_t^T
        for (i, &ki) in kt.iter().enumerate() {
            let c = a * ki;
            if c != 0.0 {
                axpy(-c, &gk, &mut g[i * dv..(i + 1) * dv]);
            }
        }
    }

    scratch.put(states);
    scratch.put(us);
    scratch.put(g);
    scratch.put(gk);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::sequential::sequential_delta_alpha;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize], sigma: f32) -> Tensor {
        Tensor::from_vec(shape, rng.normal_vec(shape.iter().product(), 0.0, sigma))
    }

    /// Scalar loss: sum(out * w) for a fixed random weight tensor, so
    /// dL/dout = w exactly and finite differences are cheap.
    fn loss(q: &Tensor, k: &Tensor, v: &Tensor, alpha: &[f32], w: &Tensor) -> f64 {
        let (out, _) = sequential_delta_alpha(q, k, v, alpha);
        out.data()
            .iter()
            .zip(w.data().iter())
            .map(|(&o, &ww)| o as f64 * ww as f64)
            .sum()
    }

    fn perturbed(t: &Tensor, idx: usize, h: f32) -> Tensor {
        let mut d = t.data().to_vec();
        d[idx] += h;
        Tensor::from_vec(t.shape(), d)
    }

    #[test]
    fn bptt_matches_finite_differences() {
        let mut rng = Rng::new(0xB7);
        let (l, dk, dv) = (7, 4, 3);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        // Gate-mapped alphas keep the recurrence contractive, so the f32
        // forward stays O(1) and finite differences stay clean.
        let alpha: Vec<f32> = (0..l)
            .map(|t| {
                let lam: f32 = k.row(t).iter().map(|x| x * x).sum();
                crate::attention::gates::alpha_efla(0.1 + 0.8 * rng.f32(), lam)
            })
            .collect();
        let w = rand_t(&mut rng, &[l, dv], 1.0);

        let (dq, dk_, dv_, dalpha) = delta_bptt(&q, &k, &v, &alpha, &w);

        let h = 1e-3f32;
        let check = |analytic: f32, fd: f64, what: &str| {
            let tol = 1e-2 * (1.0 + fd.abs());
            assert!(
                (analytic as f64 - fd).abs() < tol,
                "{what}: analytic {analytic} vs fd {fd}"
            );
        };
        for idx in 0..l * dk {
            let fd = (loss(&perturbed(&q, idx, h), &k, &v, &alpha, &w)
                - loss(&perturbed(&q, idx, -h), &k, &v, &alpha, &w))
                / (2.0 * h as f64);
            check(dq.data()[idx], fd, "dq");
            let fd = (loss(&q, &perturbed(&k, idx, h), &v, &alpha, &w)
                - loss(&q, &perturbed(&k, idx, -h), &v, &alpha, &w))
                / (2.0 * h as f64);
            check(dk_.data()[idx], fd, "dk");
        }
        for idx in 0..l * dv {
            let fd = (loss(&q, &k, &perturbed(&v, idx, h), &alpha, &w)
                - loss(&q, &k, &perturbed(&v, idx, -h), &alpha, &w))
                / (2.0 * h as f64);
            check(dv_.data()[idx], fd, "dv");
        }
        for t in 0..l {
            let mut ap = alpha.clone();
            ap[t] += h;
            let mut am = alpha.clone();
            am[t] -= h;
            let fd = (loss(&q, &k, &v, &ap, &w) - loss(&q, &k, &v, &am, &w)) / (2.0 * h as f64);
            check(dalpha[t], fd, "dalpha");
        }
    }

    #[test]
    fn zero_alpha_passes_no_gradient_to_kv() {
        // With alpha = 0 the state never updates: dk = dv = 0, dq = 0
        // (S stays zero), and dalpha reflects the would-be first write.
        let mut rng = Rng::new(3);
        let (l, d) = (5, 3);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], 1.0);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let dout = rand_t(&mut rng, &[l, d], 1.0);
        let alpha = vec![0.0f32; l];
        let (dq, dk_, dv_, _) = delta_bptt(&q, &k, &v, &alpha, &dout);
        assert!(dq.norm() < 1e-7);
        assert!(dk_.norm() < 1e-7);
        assert!(dv_.norm() < 1e-7);
    }

    #[test]
    fn into_form_with_reused_scratch_matches_wrapper() {
        let mut rng = Rng::new(0xC4);
        let (l, dk, dv) = (9, 5, 4);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        let dout = rand_t(&mut rng, &[l, dv], 1.0);
        let alpha: Vec<f32> = (0..l).map(|_| 0.2 + 0.1 * rng.f32()).collect();
        let (dq_ref, dk_ref, dv_ref, da_ref) = delta_bptt(&q, &k, &v, &alpha, &dout);

        let mut scratch = Scratch::new();
        for _ in 0..2 {
            let mut dq = vec![1.0f32; l * dk]; // dirty outputs must be overwritten
            let mut dkk = vec![1.0f32; l * dk];
            let mut dvv = vec![1.0f32; l * dv];
            let mut dalpha = vec![1.0f32; l];
            delta_bptt_into(
                q.data(),
                k.data(),
                v.data(),
                &alpha,
                dout.data(),
                dk,
                dv,
                &mut dq,
                &mut dkk,
                &mut dvv,
                &mut dalpha,
                &mut scratch,
            );
            assert_eq!(dq.as_slice(), dq_ref.data());
            assert_eq!(dkk.as_slice(), dk_ref.data());
            assert_eq!(dvv.as_slice(), dv_ref.data());
            assert_eq!(dalpha, da_ref);
        }
    }
}
