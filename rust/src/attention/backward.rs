//! Reverse-mode gradients through the sequential generalized delta rule.
//!
//! The CPU training backend backpropagates through the recurrence
//!
//! ```text
//! u_t = v_t - S_{t-1}^T k_t
//! S_t = S_{t-1} + alpha_t k_t u_t^T
//! o_t = S_t^T q_t
//! ```
//!
//! by recomputing the forward state trajectory (S_0..S_L) for one head and
//! then running the adjoint recurrence backwards with the running state
//! cotangent G = dL/dS_t:
//!
//! ```text
//! dq_t      = S_t do_t
//! G        += q_t do_t^T                       (o_t contribution)
//! dalpha_t  = k_t^T G u_t
//! du_t      = alpha_t G^T k_t
//! dk_t      = alpha_t G u_t - S_{t-1} du_t
//! dv_t      = du_t
//! G        -= k_t du_t^T                       (u_t's S_{t-1} dependence)
//! ```
//!
//! Memory is O(L * Dk * Dv) transient per head — the caller loops over
//! (batch, head) pairs so the peak is one head's trajectory, not the whole
//! batch (the checkpointing trade the classifier's L=784 sequences need).

use crate::tensor::Tensor;

/// Gradients of the alpha-form sequential delta rule.
///
/// q, k: (L, Dk); v: (L, Dv); alpha: len L; dout: (L, Dv) = dL/do.
/// Returns (dq (L,Dk), dk (L,Dk), dv (L,Dv), dalpha (len L)).
pub fn delta_bptt(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    alpha: &[f32],
    dout: &Tensor,
) -> (Tensor, Tensor, Tensor, Vec<f32>) {
    let l = q.shape()[0];
    let dk = q.shape()[1];
    let dv = v.shape()[1];
    assert_eq!(k.shape(), &[l, dk]);
    assert_eq!(v.shape(), &[l, dv]);
    assert_eq!(dout.shape(), &[l, dv]);
    assert_eq!(alpha.len(), l);

    // Forward recompute: states[t] = S_t (flat dk*dv), u[t] = v_t - S_{t-1}^T k_t.
    let mut states: Vec<Vec<f32>> = Vec::with_capacity(l + 1);
    states.push(vec![0.0f32; dk * dv]);
    let mut us: Vec<Vec<f32>> = Vec::with_capacity(l);
    for t in 0..l {
        let kt = k.row(t);
        let vt = v.row(t);
        let s_prev = &states[t];
        let mut u = vt.to_vec();
        for (i, &ki) in kt.iter().enumerate() {
            if ki == 0.0 {
                continue;
            }
            let srow = &s_prev[i * dv..(i + 1) * dv];
            for (uj, &sj) in u.iter_mut().zip(srow.iter()) {
                *uj -= ki * sj;
            }
        }
        let mut s_new = s_prev.clone();
        let a = alpha[t];
        for (i, &ki) in kt.iter().enumerate() {
            let aki = a * ki;
            if aki == 0.0 {
                continue;
            }
            let srow = &mut s_new[i * dv..(i + 1) * dv];
            for (sj, &uj) in srow.iter_mut().zip(u.iter()) {
                *sj += aki * uj;
            }
        }
        states.push(s_new);
        us.push(u);
    }

    // Backward sweep.
    let mut dq = vec![0.0f32; l * dk];
    let mut dkk = vec![0.0f32; l * dk];
    let mut dvv = vec![0.0f32; l * dv];
    let mut dalpha = vec![0.0f32; l];
    let mut g = vec![0.0f32; dk * dv]; // dL/dS carried backwards
    let mut gk = vec![0.0f32; dv]; // scratch: G^T k
    for t in (0..l).rev() {
        let qt = q.row(t);
        let kt = k.row(t);
        let dot = dout.row(t);
        let s_t = &states[t + 1];
        let s_prev = &states[t];
        let u = &us[t];
        let a = alpha[t];

        // dq_t = S_t do_t ;  G += q_t do_t^T
        {
            let dqr = &mut dq[t * dk..(t + 1) * dk];
            for i in 0..dk {
                let srow = &s_t[i * dv..(i + 1) * dv];
                let mut acc = 0.0f32;
                for (sj, dj) in srow.iter().zip(dot.iter()) {
                    acc += sj * dj;
                }
                dqr[i] = acc;
                let qi = qt[i];
                if qi != 0.0 {
                    let grow = &mut g[i * dv..(i + 1) * dv];
                    for (gj, dj) in grow.iter_mut().zip(dot.iter()) {
                        *gj += qi * dj;
                    }
                }
            }
        }

        // gk = G^T k_t ;  dalpha_t = gk . u_t ;  du_t = alpha_t gk
        gk.iter_mut().for_each(|x| *x = 0.0);
        for (i, &ki) in kt.iter().enumerate() {
            if ki == 0.0 {
                continue;
            }
            let grow = &g[i * dv..(i + 1) * dv];
            for (gkj, &gj) in gk.iter_mut().zip(grow.iter()) {
                *gkj += ki * gj;
            }
        }
        let mut da = 0.0f32;
        for (gkj, uj) in gk.iter().zip(u.iter()) {
            da += gkj * uj;
        }
        dalpha[t] = da;

        // dk_t = alpha_t G u_t - S_{t-1} du_t   (du_t = alpha_t gk)
        // dv_t = du_t ;  G -= k_t du_t^T
        {
            let dkr = &mut dkk[t * dk..(t + 1) * dk];
            for i in 0..dk {
                let grow = &g[i * dv..(i + 1) * dv];
                let sprow = &s_prev[i * dv..(i + 1) * dv];
                let mut gu = 0.0f32;
                let mut sdu = 0.0f32;
                for j in 0..dv {
                    gu += grow[j] * u[j];
                    sdu += sprow[j] * gk[j];
                }
                dkr[i] = a * gu - a * sdu;
            }
            let dvr = &mut dvv[t * dv..(t + 1) * dv];
            for (dvj, &gkj) in dvr.iter_mut().zip(gk.iter()) {
                *dvj = a * gkj;
            }
            for (i, &ki) in kt.iter().enumerate() {
                let c = a * ki;
                if c == 0.0 {
                    continue;
                }
                let grow = &mut g[i * dv..(i + 1) * dv];
                for (gj, &gkj) in grow.iter_mut().zip(gk.iter()) {
                    *gj -= c * gkj;
                }
            }
        }
    }

    (
        Tensor::from_vec(&[l, dk], dq),
        Tensor::from_vec(&[l, dk], dkk),
        Tensor::from_vec(&[l, dv], dvv),
        dalpha,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::sequential::sequential_delta_alpha;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize], sigma: f32) -> Tensor {
        Tensor::from_vec(shape, rng.normal_vec(shape.iter().product(), 0.0, sigma))
    }

    /// Scalar loss: sum(out * w) for a fixed random weight tensor, so
    /// dL/dout = w exactly and finite differences are cheap.
    fn loss(q: &Tensor, k: &Tensor, v: &Tensor, alpha: &[f32], w: &Tensor) -> f64 {
        let (out, _) = sequential_delta_alpha(q, k, v, alpha);
        out.data()
            .iter()
            .zip(w.data().iter())
            .map(|(&o, &ww)| o as f64 * ww as f64)
            .sum()
    }

    fn perturbed(t: &Tensor, idx: usize, h: f32) -> Tensor {
        let mut d = t.data().to_vec();
        d[idx] += h;
        Tensor::from_vec(t.shape(), d)
    }

    #[test]
    fn bptt_matches_finite_differences() {
        let mut rng = Rng::new(0xB7);
        let (l, dk, dv) = (7, 4, 3);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        // Gate-mapped alphas keep the recurrence contractive, so the f32
        // forward stays O(1) and finite differences stay clean.
        let alpha: Vec<f32> = (0..l)
            .map(|t| {
                let lam: f32 = k.row(t).iter().map(|x| x * x).sum();
                crate::attention::gates::alpha_efla(0.1 + 0.8 * rng.f32(), lam)
            })
            .collect();
        let w = rand_t(&mut rng, &[l, dv], 1.0);

        let (dq, dk_, dv_, dalpha) = delta_bptt(&q, &k, &v, &alpha, &w);

        let h = 1e-3f32;
        let check = |analytic: f32, fd: f64, what: &str| {
            let tol = 1e-2 * (1.0 + fd.abs());
            assert!(
                (analytic as f64 - fd).abs() < tol,
                "{what}: analytic {analytic} vs fd {fd}"
            );
        };
        for idx in 0..l * dk {
            let fd = (loss(&perturbed(&q, idx, h), &k, &v, &alpha, &w)
                - loss(&perturbed(&q, idx, -h), &k, &v, &alpha, &w))
                / (2.0 * h as f64);
            check(dq.data()[idx], fd, "dq");
            let fd = (loss(&q, &perturbed(&k, idx, h), &v, &alpha, &w)
                - loss(&q, &perturbed(&k, idx, -h), &v, &alpha, &w))
                / (2.0 * h as f64);
            check(dk_.data()[idx], fd, "dk");
        }
        for idx in 0..l * dv {
            let fd = (loss(&q, &k, &perturbed(&v, idx, h), &alpha, &w)
                - loss(&q, &k, &perturbed(&v, idx, -h), &alpha, &w))
                / (2.0 * h as f64);
            check(dv_.data()[idx], fd, "dv");
        }
        for t in 0..l {
            let mut ap = alpha.clone();
            ap[t] += h;
            let mut am = alpha.clone();
            am[t] -= h;
            let fd = (loss(&q, &k, &v, &ap, &w) - loss(&q, &k, &v, &am, &w)) / (2.0 * h as f64);
            check(dalpha[t], fd, "dalpha");
        }
    }

    #[test]
    fn zero_alpha_passes_no_gradient_to_kv() {
        // With alpha = 0 the state never updates: dk = dv = 0, dq = 0
        // (S stays zero), and dalpha reflects the would-be first write.
        let mut rng = Rng::new(3);
        let (l, d) = (5, 3);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], 1.0);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let dout = rand_t(&mut rng, &[l, d], 1.0);
        let alpha = vec![0.0f32; l];
        let (dq, dk_, dv_, _) = delta_bptt(&q, &k, &v, &alpha, &dout);
        assert!(dq.norm() < 1e-7);
        assert!(dk_.norm() < 1e-7);
        assert!(dv_.norm() < 1e-7);
    }
}
