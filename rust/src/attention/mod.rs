//! Pure-Rust mirror of the L1 attention kernels.
//!
//! Three roles:
//!  1. *property-test anchor*: proptest invariants (chunkwise == sequential,
//!     transition eigenvalues in (0,1], delta-rule limit, order convergence)
//!     run against this implementation, and golden vectors emitted by
//!     `python/compile/aot.py` pin it to the Pallas kernel bit-for-bit-ish;
//!  2. *error-analysis substrate*: the integrator sweep behind the paper's
//!     §3/§6 claims (bench `kernel_throughput`) runs here, where we control
//!     every flop;
//!  3. *CPU serving fallback*: the server can decode through
//!     [`sequential::DeltaState`] when no PJRT executable is loaded.

pub mod chunkwise;
pub mod gates;
pub mod sequential;

pub use chunkwise::chunkwise_delta;
pub use gates::{alpha_efla, alpha_euler, alpha_rk, gate_series, Gate};
pub use sequential::{sequential_delta, DeltaState};
