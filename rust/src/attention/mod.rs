//! Pure-Rust mirror of the L1 attention kernels.
//!
//! Three roles:
//!  1. *property-test anchor*: proptest invariants (chunkwise == sequential,
//!     transition eigenvalues in (0,1], delta-rule limit, order convergence)
//!     run against this implementation, and golden vectors emitted by
//!     `python/compile/aot.py` pin it to the Pallas kernel bit-for-bit-ish;
//!  2. *error-analysis substrate*: the integrator sweep behind the paper's
//!     §3/§6 claims (bench `kernel_throughput`) runs here, where we control
//!     every flop;
//!  3. *CPU execution backend substrate*: the pure-Rust backend
//!     (`runtime::cpu`) trains and serves through [`chunkwise_delta_alpha`],
//!     [`sequential::DeltaState`] and the BPTT adjoint in [`backward`].

#![forbid(unsafe_code)]

pub mod backward;
pub mod chunkwise;
pub mod gates;
pub mod sequential;

pub use backward::{delta_bptt, delta_bptt_into};
pub use chunkwise::{
    chunkwise_delta, chunkwise_delta_alpha, chunkwise_delta_alpha_into,
    chunkwise_delta_alpha_seeded,
};
pub use gates::{alpha_efla, alpha_efla_grad, alpha_euler, alpha_rk, gate_series, Gate};
pub use sequential::{
    delta_step_alpha, sequential_delta, sequential_delta_alpha, sequential_delta_alpha_into,
    DeltaState,
};
