//! Chunkwise-parallel WY/UT form — Rust mirror of the Pallas kernel.
//!
//! Direct transcription of `python/compile/kernels/chunkwise.py` (paper
//! Eqs. 21-32): per chunk of size C,
//!
//! ```text
//! A    = strict_tril(diag(alpha) K K^T)
//! T    = (I + A)^{-1} diag(alpha)          — forward substitution here
//! W    = T K ;  U = T V
//! O    = Q S + tril(Q K^T) (U - W S)
//! S'   = S + K^T (U - W S)
//! ```
//!
//! The unit-lower-triangular inverse is computed by forward substitution
//! (O(C^2) dot products) instead of the kernel's MXU-friendly nilpotent
//! doubling — on a scalar CPU the substitution is cheaper. Equality of the
//! two is exactly what the golden-vector test pins.
//!
//! All hot loops operate on flat row slices (`copy_from_slice` + fused
//! `axpy` / SIMD-dispatched matmuls) — see `benches/kernel_throughput.rs`
//! for the measured win over the earlier per-element `get`/`set` form. The
//! core is [`chunkwise_delta_alpha_into`]: raw slices in, output and state
//! written in place, every per-chunk temporary drawn from a caller-owned
//! [`Scratch`] arena so the chunk loop allocates nothing in steady state.

use crate::tensor::axpy;
use crate::tensor::{matmul_into, matmul_nt_into, matmul_tn_into, Scratch, Tensor};

use super::gates::{Gate, EPS_LAMBDA};

/// Chunkwise generalized delta rule, single head.
///
/// q, k: (L, Dk); v: (L, Dv); beta: len L; returns (out (L, Dv), S (Dk, Dv)).
/// `l` need not divide `chunk`; the tail chunk is handled exactly.
pub fn chunkwise_delta(
    gate: Gate,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    beta: &[f32],
    chunk: usize,
) -> (Tensor, Tensor) {
    let l = q.shape()[0];
    assert_eq!(beta.len(), l);

    // Resolve the scalar gate per token, then run the alpha form.
    let alpha: Vec<f32> = (0..l)
        .map(|t| {
            let lam: f32 = k.row(t).iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
            gate.alpha(beta[t], lam)
        })
        .collect();
    chunkwise_delta_alpha(q, k, v, &alpha, chunk)
}

/// [`chunkwise_delta`] with per-token alpha supplied directly — the entry
/// point the CPU backend's model layer uses (it owns the gate composition:
/// beta projections, adaptive decay, DeltaNet's normalized keys). Starts
/// from S = 0; see [`chunkwise_delta_alpha_seeded`] for an explicit
/// initial state.
pub fn chunkwise_delta_alpha(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    alpha: &[f32],
    chunk: usize,
) -> (Tensor, Tensor) {
    let dk = q.shape()[1];
    let dv = v.shape()[1];
    chunkwise_delta_alpha_seeded(q, k, v, alpha, chunk, &Tensor::zeros(&[dk, dv]))
}

/// [`chunkwise_delta_alpha`] seeded from an explicit initial state `s0`
/// (Dk, Dv) instead of zeros — the prefill form: a serving slot's
/// recurrent state streams through successive prompt segments, each run
/// through the parallel chunkwise kernel from wherever the last segment
/// left off. Returns (out (L, Dv), final state (Dk, Dv)).
pub fn chunkwise_delta_alpha_seeded(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    alpha: &[f32],
    chunk: usize,
    s0: &Tensor,
) -> (Tensor, Tensor) {
    let l = q.shape()[0];
    let dk = q.shape()[1];
    let dv = v.shape()[1];
    assert_eq!(k.shape(), &[l, dk]);
    assert_eq!(v.shape(), &[l, dv]);
    assert_eq!(alpha.len(), l);
    assert_eq!(s0.shape(), &[dk, dv]);

    let mut s = s0.data().to_vec();
    let mut out = vec![0.0f32; l * dv];
    let mut scratch = Scratch::new();
    chunkwise_delta_alpha_into(
        q.data(),
        k.data(),
        v.data(),
        alpha,
        dk,
        dv,
        chunk,
        &mut out,
        &mut s,
        &mut scratch,
    );
    (Tensor::from_vec(&[l, dv], out), Tensor::from_vec(&[dk, dv], s))
}

/// Allocation-free core of [`chunkwise_delta_alpha`] on raw row-major
/// slices. `out` (L, Dv) must be zeroed; `s` (Dk, Dv) is the running state
/// — zeros for a fresh sequence, or a seeded state mid-stream — updated in
/// place, so callers can stream chunked segments through one state (the
/// serving prefill path enters here with a slot's live state). Per-chunk
/// temporaries (`kk`, `w`, `u`, `ws`, `qk`) come from `scratch` and go
/// back each chunk: steady state allocates nothing.
///
/// Bit-reproducibility note: the per-token rounding depends on `chunk`
/// (the WY/UT form re-associates the intra-chunk sums), but for a *fixed*
/// `chunk` the kernel's arithmetic per token is independent of how the
/// sequence is split across calls as long as splits land on chunk
/// boundaries — and with `chunk == 1` it is independent of any split.
/// The serving paths exploit the latter (see `runtime/cpu/layers/mixer.rs`).
pub fn chunkwise_delta_alpha_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    alpha: &[f32],
    dk: usize,
    dv: usize,
    chunk: usize,
    out: &mut [f32],
    s: &mut [f32],
    scratch: &mut Scratch,
) {
    assert!(chunk >= 1);
    let l = alpha.len();
    debug_assert_eq!(q.len(), l * dk);
    debug_assert_eq!(k.len(), l * dk);
    debug_assert_eq!(v.len(), l * dv);
    debug_assert_eq!(out.len(), l * dv);
    debug_assert_eq!(s.len(), dk * dv);

    let mut c0 = 0;
    while c0 < l {
        let c = chunk.min(l - c0);
        // Chunk row slices straight out of the row-major buffers.
        let qc = &q[c0 * dk..(c0 + c) * dk];
        let kc = &k[c0 * dk..(c0 + c) * dk];
        let vc = &v[c0 * dv..(c0 + c) * dv];
        let ac = &alpha[c0..c0 + c];

        // kk = K K^T (C, C); only the strict lower triangle is consumed.
        let mut kk = scratch.take(c * c);
        matmul_nt_into(kc, kc, &mut kk, c, dk, c);

        // Solve (I + A) X = diag(a) [K | V] by forward substitution, rows
        // in order: X[r] = a_r*rhs[r] - sum_{i<r} A[r,i] X[i].
        let mut w = scratch.take(c * dk);
        let mut u = scratch.take(c * dv);
        for r in 0..c {
            let ar = ac[r];
            let (w_done, w_rest) = w.split_at_mut(r * dk);
            let wr = &mut w_rest[..dk];
            wr.copy_from_slice(&kc[r * dk..(r + 1) * dk]);
            for x in wr.iter_mut() {
                *x *= ar;
            }
            let (u_done, u_rest) = u.split_at_mut(r * dv);
            let ur = &mut u_rest[..dv];
            ur.copy_from_slice(&vc[r * dv..(r + 1) * dv]);
            for x in ur.iter_mut() {
                *x *= ar;
            }
            let kkr = &kk[r * c..r * c + r];
            for (i, &kki) in kkr.iter().enumerate() {
                let aij = ar * kki; // diag(a) row-scales KK^T
                if aij == 0.0 {
                    continue;
                }
                axpy(-aij, &w_done[i * dk..(i + 1) * dk], wr);
                axpy(-aij, &u_done[i * dv..(i + 1) * dv], ur);
            }
        }

        // delta = U - W S  (C, Dv), computed in place in u.
        let mut ws = scratch.take(c * dv);
        matmul_into(&w, s, &mut ws, c, dk, dv);
        let mut delta = u;
        for (d, w_) in delta.iter_mut().zip(ws.iter()) {
            *d -= w_;
        }

        // O = Q S + tril(Q K^T) delta, written straight into the output rows.
        let mut qk = scratch.take(c * c);
        matmul_nt_into(qc, kc, &mut qk, c, dk, c);
        let oc = &mut out[c0 * dv..(c0 + c) * dv];
        matmul_into(qc, s, oc, c, dk, dv);
        for r in 0..c {
            let orow = &mut oc[r * dv..(r + 1) * dv];
            for (i, &g) in qk[r * c..r * c + r + 1].iter().enumerate() {
                if g == 0.0 {
                    continue;
                }
                axpy(g, &delta[i * dv..(i + 1) * dv], orow);
            }
        }

        // S' = S + K^T delta (fused rank-C update)
        matmul_tn_into(kc, &delta, s, c, dk, dv);

        scratch.put(kk);
        scratch.put(w);
        scratch.put(delta);
        scratch.put(ws);
        scratch.put(qk);

        c0 += c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::sequential::{sequential_delta, sequential_delta_alpha};
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize], sigma: f32) -> Tensor {
        Tensor::from_vec(shape, rng.normal_vec(shape.iter().product(), 0.0, sigma))
    }

    fn check_matches_sequential(gate: Gate, l: usize, d: usize, chunk: usize, seed: u64) {
        // Key scale keeps beta*lambda inside every gate's stability region:
        // for unstable settings trajectories diverge and float noise makes
        // exact comparison meaningless (that instability is itself covered
        // by sequential::tests::euler_diverges_efla_saturates_on_high_energy).
        let sigma = if gate == Gate::Efla { 0.8 } else { 0.3 };
        let mut rng = Rng::new(seed);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], sigma);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (o1, s1) = sequential_delta(gate, &q, &k, &v, &beta);
        let (o2, s2) = chunkwise_delta(gate, &q, &k, &v, &beta, chunk);
        let od = o1.max_abs_diff(&o2);
        let sd = s1.max_abs_diff(&s2);
        assert!(od < 2e-4, "out diff {od} (gate {gate:?} l={l} c={chunk})");
        assert!(sd < 2e-4, "state diff {sd}");
    }

    #[test]
    fn matches_sequential_efla() {
        check_matches_sequential(Gate::Efla, 48, 8, 16, 10);
    }

    #[test]
    fn matches_sequential_euler() {
        check_matches_sequential(Gate::Euler, 48, 8, 16, 11);
    }

    #[test]
    fn matches_sequential_rk2() {
        check_matches_sequential(Gate::Rk(2), 48, 8, 16, 12);
    }

    #[test]
    fn ragged_tail_chunk() {
        check_matches_sequential(Gate::Efla, 50, 8, 16, 13); // 50 = 3*16 + 2
        check_matches_sequential(Gate::Efla, 7, 4, 16, 14); // single short chunk
    }

    /// Per-token alpha through the exact gate: keeps alpha * ||k||^2 inside
    /// the contraction region so float noise between the two forms cannot be
    /// amplified by a divergent trajectory.
    fn stable_alpha(rng: &mut Rng, k: &Tensor) -> Vec<f32> {
        (0..k.shape()[0])
            .map(|t| {
                let lam: f32 = k.row(t).iter().map(|x| x * x).sum();
                crate::attention::gates::alpha_efla(rng.f32(), lam)
            })
            .collect()
    }

    #[test]
    fn alpha_form_matches_sequential_alpha_form() {
        let mut rng = Rng::new(21);
        let (l, dk, dv) = (40, 8, 6);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        let alpha = stable_alpha(&mut rng, &k);
        let (o1, s1) = sequential_delta_alpha(&q, &k, &v, &alpha);
        let (o2, s2) = chunkwise_delta_alpha(&q, &k, &v, &alpha, 16);
        assert!(o1.max_abs_diff(&o2) < 2e-4);
        assert!(s1.max_abs_diff(&s2) < 2e-4);
    }

    #[test]
    fn chunk_size_invariance() {
        let mut rng = Rng::new(15);
        let (l, d) = (40, 6);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], 0.7);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (o1, s1) = chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, 1);
        for c in [2, 5, 8, 40, 64] {
            let (o2, s2) = chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, c);
            assert!(o1.max_abs_diff(&o2) < 2e-4, "chunk {c}");
            assert!(s1.max_abs_diff(&s2) < 2e-4, "chunk {c}");
        }
    }

    #[test]
    fn into_form_with_reused_scratch_matches_wrapper() {
        // A dirty, reused arena must not leak state between calls, and the
        // in-place state lets a split sequence stream through two calls.
        let mut rng = Rng::new(33);
        let (l, dk, dv) = (24, 6, 10);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        let alpha = stable_alpha(&mut rng, &k);
        let (o_ref, s_ref) = chunkwise_delta_alpha(&q, &k, &v, &alpha, 8);

        let mut scratch = crate::tensor::Scratch::new();
        for _ in 0..2 {
            let mut out = vec![0.0f32; l * dv];
            let mut s = vec![0.0f32; dk * dv];
            chunkwise_delta_alpha_into(
                q.data(),
                k.data(),
                v.data(),
                &alpha,
                dk,
                dv,
                8,
                &mut out,
                &mut s,
                &mut scratch,
            );
            assert_eq!(out.as_slice(), o_ref.data());
            assert_eq!(s.as_slice(), s_ref.data());
        }

        // Stream the same sequence as two segments through one state. The
        // split sits on a chunk boundary so the chunk partition (and hence
        // the float rounding) is identical to the one-shot run.
        let half = 16;
        let mut out = vec![0.0f32; l * dv];
        let mut s = vec![0.0f32; dk * dv];
        let (o1, o2) = out.split_at_mut(half * dv);
        chunkwise_delta_alpha_into(
            &q.data()[..half * dk],
            &k.data()[..half * dk],
            &v.data()[..half * dv],
            &alpha[..half],
            dk,
            dv,
            8,
            o1,
            &mut s,
            &mut scratch,
        );
        chunkwise_delta_alpha_into(
            &q.data()[half * dk..],
            &k.data()[half * dk..],
            &v.data()[half * dv..],
            &alpha[half..],
            dk,
            dv,
            8,
            o2,
            &mut s,
            &mut scratch,
        );
        assert_eq!(out.as_slice(), o_ref.data());
        assert_eq!(s.as_slice(), s_ref.data());
    }

    #[test]
    fn seeded_form_matches_split_run() {
        // Splitting a sequence on a chunk boundary and seeding the second
        // call with the first call's final state must reproduce the
        // one-shot run exactly (same chunk partition => same rounding).
        let mut rng = Rng::new(44);
        let (l, dk, dv, chunk) = (32, 6, 10, 8);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        let alpha = stable_alpha(&mut rng, &k);
        let (o_ref, s_ref) = chunkwise_delta_alpha(&q, &k, &v, &alpha, chunk);

        let half = 16;
        let slice = |t: &Tensor, a: usize, b: usize, w: usize| {
            Tensor::from_vec(&[b - a, w], t.data()[a * w..b * w].to_vec())
        };
        let (o1, s1) = chunkwise_delta_alpha(
            &slice(&q, 0, half, dk),
            &slice(&k, 0, half, dk),
            &slice(&v, 0, half, dv),
            &alpha[..half],
            chunk,
        );
        let (o2, s2) = chunkwise_delta_alpha_seeded(
            &slice(&q, half, l, dk),
            &slice(&k, half, l, dk),
            &slice(&v, half, l, dv),
            &alpha[half..],
            chunk,
            &s1,
        );
        assert_eq!(&o_ref.data()[..half * dv], o1.data());
        assert_eq!(&o_ref.data()[half * dv..], o2.data());
        assert_eq!(s_ref.data(), s2.data());
    }

    #[test]
    fn seeded_chunk1_is_split_invariant() {
        // With chunk == 1 the kernel's per-token arithmetic is independent
        // of ANY split of the sequence across seeded calls — the property
        // the serving prefill path relies on for bit-exact equivalence
        // with token-at-a-time decoding.
        let mut rng = Rng::new(45);
        let (l, dk, dv) = (20, 5, 7);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        let alpha = stable_alpha(&mut rng, &k);
        let (o_ref, s_ref) = chunkwise_delta_alpha(&q, &k, &v, &alpha, 1);

        for split in [1usize, 3, 9, 19] {
            let mut s = Tensor::zeros(&[dk, dv]);
            let mut out = Vec::new();
            let mut pos = 0;
            while pos < l {
                let end = (pos + split).min(l);
                let seg = |t: &Tensor, w: usize| {
                    Tensor::from_vec(&[end - pos, w], t.data()[pos * w..end * w].to_vec())
                };
                let (o, s2) = chunkwise_delta_alpha_seeded(
                    &seg(&q, dk),
                    &seg(&k, dk),
                    &seg(&v, dv),
                    &alpha[pos..end],
                    1,
                    &s,
                );
                out.extend_from_slice(o.data());
                s = s2;
                pos = end;
            }
            assert_eq!(out.as_slice(), o_ref.data(), "split {split}");
            assert_eq!(s.data(), s_ref.data(), "split {split}");
        }
    }

    #[test]
    fn rectangular_dk_dv() {
        // Dk != Dv exercises every stride in the flat-slice loops.
        let mut rng = Rng::new(16);
        let (l, dk, dv) = (33, 5, 9);
        let q = rand_t(&mut rng, &[l, dk], 1.0);
        let k = rand_t(&mut rng, &[l, dk], 0.7);
        let v = rand_t(&mut rng, &[l, dv], 1.0);
        let alpha = stable_alpha(&mut rng, &k);
        let (o1, s1) = sequential_delta_alpha(&q, &k, &v, &alpha);
        let (o2, s2) = chunkwise_delta_alpha(&q, &k, &v, &alpha, 8);
        assert!(o1.max_abs_diff(&o2) < 5e-4);
        assert!(s1.max_abs_diff(&s2) < 5e-4);
    }
}
