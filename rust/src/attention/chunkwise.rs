//! Chunkwise-parallel WY/UT form — Rust mirror of the Pallas kernel.
//!
//! Direct transcription of `python/compile/kernels/chunkwise.py` (paper
//! Eqs. 21-32): per chunk of size C,
//!
//! ```text
//! A    = strict_tril(diag(alpha) K K^T)
//! T    = (I + A)^{-1} diag(alpha)          — forward substitution here
//! W    = T K ;  U = T V
//! O    = Q S + tril(Q K^T) (U - W S)
//! S'   = S + K^T (U - W S)
//! ```
//!
//! The unit-lower-triangular inverse is computed by forward substitution
//! (O(C^2) dot products) instead of the kernel's MXU-friendly nilpotent
//! doubling — on a scalar CPU the substitution is cheaper. Equality of the
//! two is exactly what the golden-vector test pins.

use crate::tensor::{matmul, matmul_nt, Tensor};

use super::gates::{Gate, EPS_LAMBDA};

/// Chunkwise generalized delta rule, single head.
///
/// q, k: (L, Dk); v: (L, Dv); beta: len L; returns (out (L, Dv), S (Dk, Dv)).
/// `l` need not divide `chunk`; the tail chunk is handled exactly.
pub fn chunkwise_delta(
    gate: Gate,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    beta: &[f32],
    chunk: usize,
) -> (Tensor, Tensor) {
    assert!(chunk >= 1);
    let l = q.shape()[0];
    let dk = q.shape()[1];
    let dv = v.shape()[1];
    assert_eq!(k.shape(), &[l, dk]);
    assert_eq!(v.shape(), &[l, dv]);
    assert_eq!(beta.len(), l);

    // Precompute per-token alpha.
    let alpha: Vec<f32> = (0..l)
        .map(|t| {
            let lam: f32 = k.row(t).iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
            gate.alpha(beta[t], lam)
        })
        .collect();

    let mut s = Tensor::zeros(&[dk, dv]);
    let mut out = vec![0.0f32; l * dv];

    let mut c0 = 0;
    while c0 < l {
        let c = chunk.min(l - c0);
        // Chunk views.
        let qc = slice_rows(q, c0, c);
        let kc = slice_rows(k, c0, c);
        let vc = slice_rows(v, c0, c);
        let ac = &alpha[c0..c0 + c];

        // A = strict_tril(diag(a) K K^T)
        let kk = matmul_nt(&kc, &kc); // (C, C)

        // Solve (I + A) X = diag(a) [K | V] by forward substitution, rows
        // in order: X[r] = a_r*rhs[r] - sum_{i<r} A[r,i] X[i].
        let mut w = Tensor::zeros(&[c, dk]);
        let mut u = Tensor::zeros(&[c, dv]);
        for r in 0..c {
            let ar = ac[r];
            // start with a_r * k_r / a_r * v_r
            for j in 0..dk {
                w.set(&[r, j], ar * kc.get(&[r, j]));
            }
            for j in 0..dv {
                u.set(&[r, j], ar * vc.get(&[r, j]));
            }
            for i in 0..r {
                let aij = ar * kk.get(&[r, i]); // diag(a) row-scales KK^T
                if aij == 0.0 {
                    continue;
                }
                for j in 0..dk {
                    let val = w.get(&[r, j]) - aij * w.get(&[i, j]);
                    w.set(&[r, j], val);
                }
                for j in 0..dv {
                    let val = u.get(&[r, j]) - aij * u.get(&[i, j]);
                    u.set(&[r, j], val);
                }
            }
        }

        // delta = U - W S  (C, Dv)
        let ws = matmul(&w, &s);
        let mut delta = u.clone();
        for (d, w_) in delta.data_mut().iter_mut().zip(ws.data().iter()) {
            *d -= w_;
        }

        // O = Q S + tril(Q K^T) delta
        let qs = matmul(&qc, &s); // (C, Dv)
        let qk = matmul_nt(&qc, &kc); // (C, C)
        for r in 0..c {
            let orow = &mut out[(c0 + r) * dv..(c0 + r + 1) * dv];
            for j in 0..dv {
                orow[j] = qs.get(&[r, j]);
            }
            for i in 0..=r {
                let g = qk.get(&[r, i]);
                if g == 0.0 {
                    continue;
                }
                for j in 0..dv {
                    orow[j] += g * delta.get(&[i, j]);
                }
            }
        }

        // S' = S + K^T delta
        for i in 0..c {
            for a_ in 0..dk {
                let kia = kc.get(&[i, a_]);
                if kia == 0.0 {
                    continue;
                }
                for j in 0..dv {
                    let val = s.get(&[a_, j]) + kia * delta.get(&[i, j]);
                    s.set(&[a_, j], val);
                }
            }
        }

        c0 += c;
    }

    (Tensor::from_vec(&[l, dv], out), s)
}

fn slice_rows(t: &Tensor, start: usize, n: usize) -> Tensor {
    let cols = t.shape()[1];
    Tensor::from_vec(&[n, cols], t.data()[start * cols..(start + n) * cols].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::sequential::sequential_delta;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize], sigma: f32) -> Tensor {
        Tensor::from_vec(shape, rng.normal_vec(shape.iter().product(), 0.0, sigma))
    }

    fn check_matches_sequential(gate: Gate, l: usize, d: usize, chunk: usize, seed: u64) {
        // Key scale keeps beta*lambda inside every gate's stability region:
        // for unstable settings trajectories diverge and float noise makes
        // exact comparison meaningless (that instability is itself covered
        // by sequential::tests::euler_diverges_efla_saturates_on_high_energy).
        let sigma = if gate == Gate::Efla { 0.8 } else { 0.3 };
        let mut rng = Rng::new(seed);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], sigma);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (o1, s1) = sequential_delta(gate, &q, &k, &v, &beta);
        let (o2, s2) = chunkwise_delta(gate, &q, &k, &v, &beta, chunk);
        let od = o1.max_abs_diff(&o2);
        let sd = s1.max_abs_diff(&s2);
        assert!(od < 2e-4, "out diff {od} (gate {gate:?} l={l} c={chunk})");
        assert!(sd < 2e-4, "state diff {sd}");
    }

    #[test]
    fn matches_sequential_efla() {
        check_matches_sequential(Gate::Efla, 48, 8, 16, 10);
    }

    #[test]
    fn matches_sequential_euler() {
        check_matches_sequential(Gate::Euler, 48, 8, 16, 11);
    }

    #[test]
    fn matches_sequential_rk2() {
        check_matches_sequential(Gate::Rk(2), 48, 8, 16, 12);
    }

    #[test]
    fn ragged_tail_chunk() {
        check_matches_sequential(Gate::Efla, 50, 8, 16, 13); // 50 = 3*16 + 2
        check_matches_sequential(Gate::Efla, 7, 4, 16, 14); // single short chunk
    }

    #[test]
    fn chunk_size_invariance() {
        let mut rng = Rng::new(15);
        let (l, d) = (40, 6);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], 0.7);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let beta: Vec<f32> = (0..l).map(|_| rng.f32()).collect();
        let (o1, s1) = chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, 1);
        for c in [2, 5, 8, 40, 64] {
            let (o2, s2) = chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, c);
            assert!(o1.max_abs_diff(&o2) < 2e-4, "chunk {c}");
            assert!(s1.max_abs_diff(&s2) < 2e-4, "chunk {c}");
        }
    }
}
