//! Integrator gates (Rust mirror of `python/compile/kernels/gates.py`).
//!
//! Every integrator of the delta-rule ODE collapses to the generalized
//! update `S' = (I - alpha k k^T) S + alpha k v^T` with a scalar gate:
//!
//!   Euler / DeltaNet : alpha = beta
//!   RK-N             : alpha = -g_N(beta*lambda) / lambda,
//!                      g_N(x) = sum_{m=1..N} (-x)^m / m!
//!   EFLA (exact)     : alpha = (1 - e^{-beta*lambda}) / lambda
//!
//! lambda = ||k||^2, clipped at EPS_LAMBDA (paper Appendix A); the EFLA
//! numerator uses `exp_m1` to keep precision at small beta*lambda.

/// Paper Appendix A epsilon for the lambda clip.
pub const EPS_LAMBDA: f32 = 1e-12;

/// Which member of the integrator family to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gate {
    /// Explicit Euler (DeltaNet): alpha = beta.
    Euler,
    /// Order-N Runge-Kutta truncation.
    Rk(u32),
    /// Exact solution (EFLA).
    Efla,
}

impl Gate {
    /// Gate value for one token.
    pub fn alpha(self, beta: f32, lambda: f32) -> f32 {
        match self {
            Gate::Euler => alpha_euler(beta),
            Gate::Rk(n) => alpha_rk(beta, lambda, n),
            Gate::Efla => alpha_efla(beta, lambda),
        }
    }

    /// Human-readable name (bench tables).
    pub fn name(self) -> String {
        match self {
            Gate::Euler => "euler(deltanet)".to_string(),
            Gate::Rk(n) => format!("rk{n}"),
            Gate::Efla => "efla(exact)".to_string(),
        }
    }
}

/// g_N(x) = sum_{m=1..N} (-x)^m / m!, Horner evaluation (order >= 1).
pub fn gate_series(x: f64, order: u32) -> f64 {
    assert!(order >= 1);
    let mut acc = 0.0f64;
    for m in (1..=order).rev() {
        acc = (-x) / m as f64 * (1.0 + acc);
    }
    acc
}

/// Euler gate: alpha = beta (lambda-independent — DeltaNet).
pub fn alpha_euler(beta: f32) -> f32 {
    beta
}

/// Order-N RK gate.
pub fn alpha_rk(beta: f32, lambda: f32, order: u32) -> f32 {
    let lam = lambda.max(EPS_LAMBDA) as f64;
    let x = beta as f64 * lam;
    (-gate_series(x, order) / lam) as f32
}

/// Exact EFLA gate with expm1 precision (paper Eq. 20 + Appendix A).
pub fn alpha_efla(beta: f32, lambda: f32) -> f32 {
    let lam = lambda.max(EPS_LAMBDA) as f64;
    let x = beta as f64 * lam;
    (-(-x).exp_m1() / lam) as f32
}

/// Value + partial derivatives of the EFLA gate:
/// `(alpha, d alpha / d beta, d alpha / d lambda)`.
///
/// Needed by the CPU backend's backward pass. Computed in f64; the
/// `d alpha / d lambda` formula `(beta e^{-x} - alpha) / lambda` cancels
/// catastrophically as `x = beta*lambda -> 0`, so a series expansion
/// (`-beta^2/2 + beta^2 x/3 + O(x^2)`) takes over below x = 1e-4.
pub fn alpha_efla_grad(beta: f32, lambda: f32) -> (f32, f32, f32) {
    let lam = lambda.max(EPS_LAMBDA) as f64;
    let b = beta as f64;
    let x = b * lam;
    let e = (-x).exp();
    let alpha = -(-x).exp_m1() / lam;
    let da_db = e;
    let da_dl = if x < 1e-4 {
        b * b * (-0.5 + x / 3.0)
    } else {
        (b * e - alpha) / lam
    };
    (alpha as f32, da_db as f32, da_dl as f32)
}

/// Transition eigenvalue along k: 1 - alpha*lambda. For EFLA this equals
/// e^{-beta*lambda} exactly (paper §6: spectral gate / memory dominance).
pub fn transition_eigenvalue(gate: Gate, beta: f32, lambda: f32) -> f32 {
    1.0 - gate.alpha(beta, lambda) * lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rk1_is_euler() {
        for beta in [0.0f32, 0.3, 0.9, 1.0] {
            for lam in [1e-9f32, 0.5, 4.0, 100.0] {
                let a = alpha_rk(beta, lam, 1);
                assert!((a - beta).abs() < 1e-6, "beta={beta} lam={lam} a={a}");
            }
        }
    }

    #[test]
    fn rk2_matches_closed_form() {
        // alpha_2 = beta (1 - beta*lambda/2)   (paper Eq. 11)
        for (beta, lam) in [(0.5f32, 0.8f32), (0.9, 2.0), (0.1, 10.0)] {
            let expect = beta * (1.0 - beta * lam / 2.0);
            assert!((alpha_rk(beta, lam, 2) - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn efla_is_rk_limit() {
        let (beta, lam) = (0.7f32, 3.0f32);
        let exact = alpha_efla(beta, lam);
        let mut last_err = f32::INFINITY;
        for n in [1u32, 2, 4, 8, 16] {
            let err = (alpha_rk(beta, lam, n) - exact).abs();
            assert!(err <= last_err + 1e-7, "order {n}: {err} > {last_err}");
            last_err = err;
        }
        assert!(last_err < 1e-6);
    }

    #[test]
    fn efla_delta_rule_limit_small_lambda() {
        // lambda -> 0  =>  alpha -> beta (paper §6 asymptotic connection)
        let beta = 0.83f32;
        for lam in [1e-10f32, 1e-8, 1e-6] {
            let a = alpha_efla(beta, lam);
            assert!((a - beta).abs() < 1e-4, "lam={lam} a={a}");
        }
    }

    #[test]
    fn efla_eigenvalue_in_unit_interval() {
        // 1 - alpha*lambda = e^{-beta*lambda} in (0, 1]
        for beta in [0.0f32, 0.2, 1.0, 5.0] {
            for lam in [1e-6f32, 0.5, 8.0, 1000.0] {
                let ev = transition_eigenvalue(Gate::Efla, beta, lam);
                // exact arithmetic gives ev = e^{-beta*lam} in (0, 1]; in f32
                // the 1 - alpha*lam form can round to exactly 0 at extreme
                // stiffness, hence >= 0 here.
                assert!(ev >= 0.0 && ev <= 1.0 + 1e-6, "beta={beta} lam={lam} ev={ev}");
                let expect = (-(beta as f64) * lam as f64).exp() as f32;
                assert!((ev - expect).abs() < 2e-5);
            }
        }
    }

    #[test]
    fn euler_eigenvalue_escapes_unit_interval() {
        // the instability EFLA fixes: |1 - beta*lambda| > 1 for beta*lambda > 2
        let ev = transition_eigenvalue(Gate::Euler, 1.0, 3.0);
        assert!(ev < -1.0);
    }

    #[test]
    fn efla_grad_matches_finite_differences() {
        let fd = |beta: f64, lam: f64| {
            let h = 1e-6;
            let f = |b: f64, l: f64| -(-b * l).exp_m1() / l;
            (
                (f(beta + h, lam) - f(beta - h, lam)) / (2.0 * h),
                (f(beta, lam + h) - f(beta, lam - h)) / (2.0 * h),
            )
        };
        for (beta, lam) in [(0.3f32, 0.5f32), (0.9, 2.0), (0.1, 8.0), (0.7, 1e-3)] {
            let (a, dab, dal) = alpha_efla_grad(beta, lam);
            assert!((a - alpha_efla(beta, lam)).abs() < 1e-6);
            let (fdb, fdl) = fd(beta as f64, lam as f64);
            assert!((dab as f64 - fdb).abs() < 1e-4, "beta={beta} lam={lam}");
            assert!((dal as f64 - fdl).abs() < 1e-4 * (1.0 + fdl.abs()), "beta={beta} lam={lam}");
        }
    }

    #[test]
    fn efla_grad_series_branch_is_smooth() {
        // values just above and below the series switchover must agree
        let beta = 0.8f32;
        let (_, _, lo) = alpha_efla_grad(beta, 0.9e-4 / 0.8);
        let (_, _, hi) = alpha_efla_grad(beta, 1.1e-4 / 0.8);
        assert!((lo - hi).abs() < 1e-4, "{lo} vs {hi}");
    }

    #[test]
    fn gate_series_is_expm1_limit() {
        for x in [0.0f64, 0.1, 1.0, 4.0] {
            let g = gate_series(x, 30);
            let expect = (-x).exp_m1();
            assert!((g - expect).abs() < 1e-12, "x={x}");
        }
    }
}
