//! Sequential (token-by-token) generalized delta rule — single head.
//!
//! The O(L * Dk * Dv) recurrence of paper Eq. 20:
//!
//! ```text
//! S_t = S_{t-1} + alpha_t k_t (v_t - S_{t-1}^T k_t)^T
//! o_t = S_t^T q_t
//! ```
//!
//! [`DeltaState`] is the allocation-free streaming form used by the CPU
//! serving fallback and the error-analysis bench; [`sequential_delta`] is
//! the batch convenience wrapper the tests use.

use crate::tensor::{axpy, Scratch, Tensor};

use super::gates::{Gate, EPS_LAMBDA};

/// Streaming per-head delta-rule state (Dk x Dv, f32, row-major).
#[derive(Clone, Debug)]
pub struct DeltaState {
    dk: usize,
    dv: usize,
    /// S stored row-major: s[i*dv + j] = S[i][j]
    s: Vec<f32>,
    /// scratch: S^T k (length dv)
    stk: Vec<f32>,
}

impl DeltaState {
    pub fn new(dk: usize, dv: usize) -> Self {
        DeltaState { dk, dv, s: vec![0.0; dk * dv], stk: vec![0.0; dv] }
    }

    pub fn dims(&self) -> (usize, usize) {
        (self.dk, self.dv)
    }

    pub fn state(&self) -> &[f32] {
        &self.s
    }

    pub fn state_mut(&mut self) -> &mut [f32] {
        &mut self.s
    }

    pub fn reset(&mut self) {
        self.s.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Advance one token and write o = S'^T q into `out` (len dv).
    /// Allocation-free.
    pub fn step(
        &mut self,
        gate: Gate,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        beta: f32,
        out: &mut [f32],
    ) {
        let lambda: f32 = k.iter().map(|x| x * x).sum::<f32>().max(EPS_LAMBDA);
        let alpha = gate.alpha(beta, lambda);
        self.step_alpha(q, k, v, alpha, out);
    }

    /// [`step`](Self::step) with the scalar gate already resolved to alpha —
    /// the form the model layer uses (it owns beta/lambda/gate composition).
    pub fn step_alpha(&mut self, q: &[f32], k: &[f32], v: &[f32], alpha: f32, out: &mut [f32]) {
        delta_step_alpha(&mut self.s, q, k, v, alpha, out, &mut self.stk, self.dk, self.dv);
    }

    /// Frobenius norm of the state (used by the stability experiments).
    pub fn norm(&self) -> f32 {
        self.s.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

/// One generalized delta-rule token update on a raw row-major state slice
/// `s` (Dk x Dv): `u = v - S^T k; S += alpha k u^T; out = S'^T q`.
///
/// Shared by [`DeltaState`] and the CPU backend's decode path so the two
/// never drift numerically. `stk` is caller-provided scratch of length
/// `dv` (keeps the token hot loop allocation-free).
#[allow(clippy::too_many_arguments)]
pub fn delta_step_alpha(
    s: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    alpha: f32,
    out: &mut [f32],
    stk: &mut [f32],
    dk: usize,
    dv: usize,
) {
    debug_assert_eq!(s.len(), dk * dv);
    debug_assert_eq!(q.len(), dk);
    debug_assert_eq!(k.len(), dk);
    debug_assert_eq!(v.len(), dv);
    debug_assert_eq!(out.len(), dv);
    debug_assert_eq!(stk.len(), dv);

    // stk = S^T k (row-level zero-skips stay: they gate whole vector ops,
    // the SIMD-dispatched axpy inside is branch-free).
    stk.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..dk {
        let ki = k[i];
        if ki == 0.0 {
            continue;
        }
        axpy(ki, &s[i * dv..(i + 1) * dv], stk);
    }
    // stk := u = v - S^T k, then S += alpha * k u^T as row axpys.
    for (uj, &vj) in stk.iter_mut().zip(v.iter()) {
        *uj = vj - *uj;
    }
    for i in 0..dk {
        let aki = alpha * k[i];
        if aki == 0.0 {
            continue;
        }
        axpy(aki, stk, &mut s[i * dv..(i + 1) * dv]);
    }
    // o = S'^T q
    out.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..dk {
        let qi = q[i];
        if qi == 0.0 {
            continue;
        }
        axpy(qi, &s[i * dv..(i + 1) * dv], out);
    }
}

/// Batch single-head run. q,k: (L, Dk); v: (L, Dv); beta: len L.
/// Returns (out (L, Dv), final state (Dk, Dv)).
pub fn sequential_delta(
    gate: Gate,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    beta: &[f32],
) -> (Tensor, Tensor) {
    assert_eq!(q.ndim(), 2);
    let l = q.shape()[0];
    let dk = q.shape()[1];
    let dv = v.shape()[1];
    assert_eq!(k.shape(), &[l, dk]);
    assert_eq!(v.shape(), &[l, dv]);
    assert_eq!(beta.len(), l);

    let mut st = DeltaState::new(dk, dv);
    let mut out = vec![0.0f32; l * dv];
    for t in 0..l {
        let (qr, kr, vr) = (q.row(t), k.row(t), v.row(t));
        st.step(gate, qr, kr, vr, beta[t], &mut out[t * dv..(t + 1) * dv]);
    }
    (
        Tensor::from_vec(&[l, dv], out),
        Tensor::from_vec(&[dk, dv], st.state().to_vec()),
    )
}

/// [`sequential_delta`] with per-token alpha supplied directly (the model
/// layer resolves gate/beta/lambda itself).
pub fn sequential_delta_alpha(
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
    alpha: &[f32],
) -> (Tensor, Tensor) {
    assert_eq!(q.ndim(), 2);
    let l = q.shape()[0];
    let dk = q.shape()[1];
    let dv = v.shape()[1];
    assert_eq!(k.shape(), &[l, dk]);
    assert_eq!(v.shape(), &[l, dv]);
    assert_eq!(alpha.len(), l);

    let mut out = vec![0.0f32; l * dv];
    let mut s = vec![0.0f32; dk * dv];
    let mut scratch = Scratch::new();
    sequential_delta_alpha_into(
        q.data(),
        k.data(),
        v.data(),
        alpha,
        dk,
        dv,
        &mut out,
        &mut s,
        &mut scratch,
    );
    (Tensor::from_vec(&[l, dv], out), Tensor::from_vec(&[dk, dv], s))
}

/// Allocation-free core of [`sequential_delta_alpha`] on raw row-major
/// slices: `out` (L, Dv) is overwritten token by token, `s` (Dk, Dv) is
/// the running state — zeros for a fresh sequence — advanced in place.
/// The per-token scratch vector comes from `scratch`.
pub fn sequential_delta_alpha_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    alpha: &[f32],
    dk: usize,
    dv: usize,
    out: &mut [f32],
    s: &mut [f32],
    scratch: &mut Scratch,
) {
    let l = alpha.len();
    debug_assert_eq!(q.len(), l * dk);
    debug_assert_eq!(k.len(), l * dk);
    debug_assert_eq!(v.len(), l * dv);
    debug_assert_eq!(out.len(), l * dv);
    debug_assert_eq!(s.len(), dk * dv);
    let mut stk = scratch.take(dv);
    for t in 0..l {
        delta_step_alpha(
            s,
            &q[t * dk..(t + 1) * dk],
            &k[t * dk..(t + 1) * dk],
            &v[t * dv..(t + 1) * dv],
            alpha[t],
            &mut out[t * dv..(t + 1) * dv],
            &mut stk,
            dk,
            dv,
        );
    }
    scratch.put(stk);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_t(rng: &mut Rng, shape: &[usize], sigma: f32) -> Tensor {
        Tensor::from_vec(shape, rng.normal_vec(shape.iter().product(), 0.0, sigma))
    }

    #[test]
    fn first_token_matches_closed_form() {
        // S_1 = alpha k v^T, o_1 = S_1^T q
        let mut rng = Rng::new(1);
        let (dk, dv) = (6, 5);
        let q = rand_t(&mut rng, &[1, dk], 1.0);
        let k = rand_t(&mut rng, &[1, dk], 1.0);
        let v = rand_t(&mut rng, &[1, dv], 1.0);
        let beta = [0.7f32];
        let (out, s) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
        let lam: f32 = k.data().iter().map(|x| x * x).sum();
        let alpha = super::super::gates::alpha_efla(0.7, lam);
        for i in 0..dk {
            for j in 0..dv {
                let expect = alpha * k.get(&[0, i]) * v.get(&[0, j]);
                assert!((s.get(&[i, j]) - expect).abs() < 1e-5);
            }
        }
        for j in 0..dv {
            let expect: f32 = (0..dk).map(|i| s.get(&[i, j]) * q.get(&[0, i])).sum();
            assert!((out.get(&[0, j]) - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn repeated_key_is_idempotent_memory_write() {
        // Writing (k, v) twice with EFLA must still map k -> approx v
        // direction: the second write corrects toward v, never overshoots.
        let dk = 4;
        let k: Vec<f32> = vec![1.0, 0.5, -0.3, 0.2];
        let v: Vec<f32> = vec![0.9, -0.4, 0.1, 0.3];
        let mut st = DeltaState::new(dk, dk);
        let mut out = vec![0.0; dk];
        for _ in 0..50 {
            st.step(Gate::Efla, &k, &k, &v, 1.0, &mut out);
        }
        // After many writes, S^T k ~= v * (k.k) scaled readout via q=k:
        // o = S^T k should approach v (reconstruction objective fixed point).
        for j in 0..dk {
            assert!((out[j] - v[j]).abs() < 1e-3, "j={j} out={} v={}", out[j], v[j]);
        }
    }

    #[test]
    fn euler_diverges_efla_saturates_on_high_energy() {
        // The paper's stability claim at the recurrence level: scale keys up
        // and Euler's state norm explodes while EFLA's stays bounded.
        let mut rng = Rng::new(2);
        let (l, d) = (64, 8);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], 4.0); // lambda ~ d*16 >> 2
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let beta = vec![0.9f32; l];
        let (_, s_euler) = sequential_delta(Gate::Euler, &q, &k, &v, &beta);
        let (_, s_efla) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
        let en = s_euler.norm();
        assert!(en.is_nan() || en > 1e6, "euler norm {en}");
        assert!(s_efla.norm() < 1e3, "efla norm {}", s_efla.norm());
    }

    #[test]
    fn zero_beta_is_identity() {
        let mut rng = Rng::new(3);
        let (l, d) = (10, 4);
        let q = rand_t(&mut rng, &[l, d], 1.0);
        let k = rand_t(&mut rng, &[l, d], 1.0);
        let v = rand_t(&mut rng, &[l, d], 1.0);
        let beta = vec![0.0f32; l];
        let (out, s) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
        assert!(s.norm() < 1e-7);
        assert!(out.norm() < 1e-7);
    }
}
