//! # EFLA — Error-Free Linear Attention
//!
//! Rust coordinator (Layer 3) of the three-layer reproduction of
//! *"Error-Free Linear Attention is a Free Lunch: Exact Solution from
//! Continuous-Time Dynamics"* (Lei, Zhang, Poria, 2025).
//!
//! Layers:
//! * **L1** `python/compile/kernels/` — chunkwise generalized delta-rule
//!   Pallas kernel; the integrator family (DeltaNet/RK-N/EFLA) differs only
//!   in a scalar gate.
//! * **L2** `python/compile/` — JAX transformer LM + sMNIST classifier with
//!   fused AdamW train steps, AOT-lowered to HLO text once (only needed for
//!   the optional PJRT backend).
//! * **L3** this crate — execution backends, data pipeline,
//!   training/eval/serving coordinators, experiment harness. Python never
//!   runs at runtime.
//!
//! ## Workspace layout
//!
//! The Cargo workspace lives at the repository root; this package is
//! `rust/` with the library (`efla`), the `efla` launcher binary
//! (`rust/src/main.rs`), the `efla-lint` static-analysis binary
//! (`rust/src/bin/efla-lint.rs`), the examples under `../examples/`, and the
//! per-table/figure benches under `../benches/` (all wired as explicit
//! `[[example]]`/`[[bench]]` targets in `rust/Cargo.toml`).
//!
//! ## Execution backends
//!
//! Everything above [`runtime`] is written against the
//! [`runtime::Backend`] / [`runtime::ModelSession`] traits:
//!
//! * **CPU backend** ([`runtime::CpuBackend`]) — always available, pure
//!   Rust: a composable layer stack (`runtime/cpu/layers/` with paired
//!   fwd/bwd tapes over the primitives in `runtime/cpu/ops.rs`), AdamW,
//!   eval statistics and the O(1)-state in-place decode, all on top of
//!   [`tensor`] + [`attention`]. The per-(batch, head) kernel work and
//!   large matmuls fan out over a `std::thread::scope` executor
//!   (`--threads` / `EFLA_NUM_THREADS`, bit-identical numerics at any
//!   count). Needs no artifacts: families like `lm_tiny_efla` are built
//!   from their names using the same preset table
//!   `python/compile/model.py` uses.
//! * **PJRT backend** (`runtime::pjrt`, feature `xla`, off by default) —
//!   executes the AOT HLO-text artifacts through a vendored `xla` crate.
//!   With the feature disabled the PJRT code is compiled out entirely;
//!   enabling it requires adding the vendored crate as a path dependency.
//!
//! [`runtime::open_backend`] picks PJRT when the feature is on and
//! artifacts are present, else the CPU backend.
//!
//! ## Verify
//!
//! The tier-1 check is, from the repository root:
//!
//! ```text
//! cargo build --release && cargo test -q
//! ```
//!
//! which uses default features (CPU backend only — no `xla` crate, no
//! artifacts required). An end-to-end run:
//!
//! ```text
//! cargo run --release -- train --task lm --preset tiny --mixer efla --steps 20
//! ```
//!
//! Entry points: the `efla` launcher binary, the examples in `examples/`,
//! and the per-table/figure benches in `benches/`.

// Numeric kernel code: index loops over flat row-major buffers are the
// idiom here (clearer next to the math, and often borrow-friendlier than
// iterator chains).
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
// Unsafe hygiene: inside the few `unsafe fn`s (SIMD kernels in
// `tensor::gemm`) every unsafe operation must sit in its own scoped
// `unsafe {}` block with a SAFETY note; `efla-lint` (see [`lint`]) checks
// the comments and confines `unsafe` to the allowlisted modules.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod attention;
pub mod coordinator;
pub mod data;
pub mod lint;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod util;
