//! # EFLA — Error-Free Linear Attention
//!
//! Rust coordinator (Layer 3) of the three-layer reproduction of
//! *"Error-Free Linear Attention is a Free Lunch: Exact Solution from
//! Continuous-Time Dynamics"* (Lei, Zhang, Poria, 2025).
//!
//! Layers:
//! * **L1** `python/compile/kernels/` — chunkwise generalized delta-rule
//!   Pallas kernel; the integrator family (DeltaNet/RK-N/EFLA) differs only
//!   in a scalar gate.
//! * **L2** `python/compile/` — JAX transformer LM + sMNIST classifier with
//!   fused AdamW train steps, AOT-lowered to HLO text once.
//! * **L3** this crate — PJRT runtime, data pipeline, training/eval/serving
//!   coordinators, experiment harness. Python never runs at runtime.
//!
//! Entry points: the `efla` launcher binary (`rust/src/main.rs`), the
//! examples in `examples/`, and the per-table/figure benches in `benches/`.

pub mod attention;
pub mod coordinator;
pub mod data;
pub mod runtime;
pub mod tensor;
pub mod util;
