//! `efla route`: a replica-sharded front end over N serving engines.
//!
//! The paper's O(1)-state property makes failover cheap: a replica holds
//! no KV cache, so losing one loses at most the requests it was actively
//! generating — the router's job is to make even those invisible where
//! possible. This module schedules `POST /v1/generate` across replicas
//! (in-process [`super::Frontend`]s on their own threads, or remote
//! engines reached through [`super::http`]) with:
//!
//! * **session-affine scheduling with state handoff** — a request
//!   carrying a `session_id` is routed to its rendezvous-hash *home*
//!   replica ([`rendezvous_pick`], FNV-1a over `session/addr` — the
//!   same hash the state cache spills under), so multi-turn TTFT stays
//!   flat under sharding; when the home is ejected the router falls
//!   back to least-loaded and first tries to **migrate** the parked
//!   O(d²) state from wherever the session last landed
//!   (`GET`/`PUT /v1/state/{session}`), with cold prefill as the
//!   always-correct last resort;
//! * **least-loaded scheduling** — among session-less requests (or on
//!   fallback) the routable replica with the fewest router-side
//!   in-flight requests wins;
//! * **health checking** — a prober polls every replica's `/healthz` on
//!   an interval (and caches its `/stats` for aggregation); passive
//!   request outcomes feed the same circuit breaker;
//! * **a circuit breaker per replica** — `Healthy → Suspect → Ejected →
//!   HalfOpen` ([`Breaker`]): consecutive failures suspect then eject,
//!   a cooldown later one probe request may pass through, its outcome
//!   closes or re-opens the circuit;
//! * **retry with jittered exponential backoff** — connect failures,
//!   read timeouts, 429s and 5xx failover to a *different* replica
//!   (each replica is tried at most once per request, so a retry can
//!   never bounce off its own duplicate id); a request whose stream
//!   already emitted a token to the client is NEVER retried — the
//!   stream is terminated with an error line instead;
//! * **end-to-end deadlines** — the client's `timeout_ms` bounds the
//!   whole retry budget; the body is forwarded verbatim, so the replica
//!   engine also abandons its slot at the same deadline;
//! * **graceful degradation** — when every replica is saturated or
//!   ejected the router sheds with `503` + `Retry-After` instead of
//!   queueing unboundedly, and `/stats` + `/healthz` keep answering
//!   throughout (per-replica breakdown included).
//!
//! The router holds no model state of its own: it is std-only plumbing
//! over the existing HTTP substrate, and greedy outputs proxied through
//! it are bit-identical to hitting a replica directly.

#![forbid(unsafe_code)]

use std::collections::{BTreeSet, HashMap};
use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::Scope;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::http::{self, ChunkedWriter, ClientOpts, ParseError, Request};
use super::state_cache::fnv1a;
use super::{respond_error, respond_json, ErrorCode, SIGNALLED, STATS_SCHEMA_VERSION};

/// Soft cap on concurrently served router connections.
const MAX_CONNECTIONS: usize = 512;

/// Router knobs. Defaults are tuned for LAN-local replicas.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Health probe period per replica, in ms.
    pub health_interval_ms: u64,
    /// Read/connect timeout of one health probe, in ms — a stalled
    /// replica must fail the probe fast.
    pub health_timeout_ms: u64,
    /// Connect timeout of a proxied request, in ms.
    pub connect_timeout_ms: u64,
    /// Read timeout of a proxied request, in ms (per read; a healthy
    /// token stream resets it chunk by chunk).
    pub read_timeout_ms: u64,
    /// Deadline applied to requests without their own `timeout_ms`.
    /// 0 = none.
    pub default_timeout_ms: u64,
    /// Max replicas tried per request (connect failure / 429 / 5xx each
    /// consume one attempt). Clamped to the replica count.
    pub max_attempts: usize,
    /// Backoff before retry k is `min(cap, base << k)` ms, jittered to
    /// [1/2, 1) of itself.
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
    /// Consecutive failures before a replica turns Suspect / Ejected.
    pub suspect_after: u32,
    pub eject_after: u32,
    /// Ejection cooldown before a half-open probe is allowed, in ms.
    pub cooldown_ms: u64,
    /// Seed of the backoff-jitter RNG.
    pub seed: u64,
    /// Route sessions to their rendezvous-hash home replica
    /// (`--affinity on|off`).
    pub affinity: bool,
    /// Migrate parked session state on failover (`--migrate on|off`).
    pub migrate: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            health_interval_ms: 200,
            health_timeout_ms: 500,
            connect_timeout_ms: 1_000,
            read_timeout_ms: 120_000,
            default_timeout_ms: 0,
            max_attempts: 3,
            backoff_base_ms: 25,
            backoff_cap_ms: 1_000,
            suspect_after: 1,
            eject_after: 3,
            cooldown_ms: 1_000,
            seed: 0,
            affinity: true,
            migrate: true,
        }
    }
}

/// Circuit-breaker states of one replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CircuitState {
    /// Routable; no recent failures.
    Healthy,
    /// Routable, but accumulating consecutive failures.
    Suspect,
    /// Not routable; waiting out the cooldown.
    Ejected,
    /// Cooldown expired: exactly one probe request may pass through.
    HalfOpen,
}

impl CircuitState {
    pub fn as_str(&self) -> &'static str {
        match self {
            CircuitState::Healthy => "healthy",
            CircuitState::Suspect => "suspect",
            CircuitState::Ejected => "ejected",
            CircuitState::HalfOpen => "half_open",
        }
    }
}

/// Per-replica circuit breaker. Pure and time-explicit (every transition
/// takes `now`), so the state machine is unit-testable without sleeping.
#[derive(Clone, Debug)]
pub struct Breaker {
    suspect_after: u32,
    eject_after: u32,
    cooldown: Duration,
    state: CircuitState,
    /// Consecutive failures since the last success.
    failures: u32,
    ejected_at: Option<Instant>,
    /// A half-open probe is in flight; further traffic stays blocked
    /// until its outcome lands.
    probing: bool,
}

impl Breaker {
    pub fn new(suspect_after: u32, eject_after: u32, cooldown: Duration) -> Breaker {
        Breaker {
            suspect_after: suspect_after.max(1),
            eject_after: eject_after.max(1),
            cooldown,
            state: CircuitState::Healthy,
            failures: 0,
            ejected_at: None,
            probing: false,
        }
    }

    pub fn state(&self) -> CircuitState {
        self.state
    }

    /// Routable without a probe? (Healthy or Suspect.)
    pub fn routable(&self) -> bool {
        matches!(self.state, CircuitState::Healthy | CircuitState::Suspect)
    }

    /// A request or health probe against the replica succeeded: close
    /// the circuit.
    pub fn on_success(&mut self) {
        self.state = CircuitState::Healthy;
        self.failures = 0;
        self.ejected_at = None;
        self.probing = false;
    }

    /// A request or health probe failed. Returns true when this failure
    /// newly ejected the replica (for the ejection counter).
    pub fn on_failure(&mut self, now: Instant) -> bool {
        self.failures = self.failures.saturating_add(1);
        self.probing = false;
        match self.state {
            CircuitState::HalfOpen => {
                // The probe failed: straight back to Ejected, cooldown
                // restarts from now.
                self.state = CircuitState::Ejected;
                self.ejected_at = Some(now);
                true
            }
            CircuitState::Healthy | CircuitState::Suspect => {
                if self.failures >= self.eject_after {
                    self.state = CircuitState::Ejected;
                    self.ejected_at = Some(now);
                    true
                } else {
                    if self.failures >= self.suspect_after {
                        self.state = CircuitState::Suspect;
                    }
                    false
                }
            }
            CircuitState::Ejected => false,
        }
    }

    /// May one probe request pass through right now? Transitions
    /// Ejected → HalfOpen once the cooldown expired and claims the
    /// single probe slot.
    pub fn try_probe(&mut self, now: Instant) -> bool {
        match self.state {
            CircuitState::Ejected => {
                let expired = match self.ejected_at {
                    Some(t) => now.duration_since(t) >= self.cooldown,
                    None => true,
                };
                if expired {
                    self.state = CircuitState::HalfOpen;
                    self.probing = true;
                    true
                } else {
                    false
                }
            }
            CircuitState::HalfOpen => {
                if self.probing {
                    false
                } else {
                    self.probing = true;
                    true
                }
            }
            // Routable states need no probe slot.
            CircuitState::Healthy | CircuitState::Suspect => true,
        }
    }
}

/// Rendezvous (highest-random-weight) score of `session` on the replica
/// at `addr`: FNV-1a over `session/addr` — the same hash
/// ([`fnv1a`]) the state cache derives spill filenames from, so
/// session → replica affinity is one naming convention end to end.
pub fn rendezvous_score(session: &str, addr: &str) -> u64 {
    let mut bytes = Vec::with_capacity(session.len() + 1 + addr.len());
    bytes.extend_from_slice(session.as_bytes());
    bytes.push(b'/');
    bytes.extend_from_slice(addr.as_bytes());
    fnv1a(&bytes)
}

/// The session's *home* replica: argmax of [`rendezvous_score`] over
/// `addrs`. Strictly-greater comparison means the lowest index wins
/// ties, so the pick is deterministic. Computed over the FULL replica
/// set (not just the healthy one): removing or re-adding one replica
/// only remaps the sessions homed on it, never the rest — the property
/// that makes affinity survive fleet-size changes.
pub fn rendezvous_pick(session: &str, addrs: &[impl AsRef<str>]) -> Option<usize> {
    let mut best: Option<(usize, u64)> = None;
    for (i, addr) in addrs.iter().enumerate() {
        let score = rendezvous_score(session, addr.as_ref());
        let better = match best {
            None => true,
            Some((_, s)) => score > s,
        };
        if better {
            best = Some((i, score));
        }
    }
    best.map(|(i, _)| i)
}

/// Jittered exponential backoff before retry `attempt` (0-based):
/// uniform in [d/2, d) where d = min(cap, base << attempt).
pub fn backoff_ms(cfg: &RouterConfig, attempt: usize, rng: &mut Rng) -> u64 {
    let base = cfg.backoff_base_ms.max(1);
    let mult = 1u64 << attempt.min(16);
    let d = base.saturating_mul(mult).min(cfg.backoff_cap_ms.max(base));
    let half = (d / 2).max(1);
    half + rng.below(half)
}

/// One upstream replica as the router sees it.
struct Replica {
    addr: String,
    breaker: Mutex<Breaker>,
    /// Router-side in-flight requests (the least-loaded signal).
    in_flight: AtomicUsize,
    probes_ok: AtomicU64,
    probes_failed: AtomicU64,
    /// Last successfully fetched upstream `/stats` body, for the
    /// aggregated view — served even while the replica is ejected.
    last_stats: Mutex<Option<Json>>,
}

impl Replica {
    fn new(addr: String, cfg: &RouterConfig) -> Replica {
        let cooldown = Duration::from_millis(cfg.cooldown_ms);
        Replica {
            addr,
            breaker: Mutex::new(Breaker::new(cfg.suspect_after, cfg.eject_after, cooldown)),
            in_flight: AtomicUsize::new(0),
            probes_ok: AtomicU64::new(0),
            probes_failed: AtomicU64::new(0),
            last_stats: Mutex::new(None),
        }
    }

    fn breaker(&self) -> std::sync::MutexGuard<'_, Breaker> {
        self.breaker.lock().expect("breaker lock")
    }
}

/// Router-level counters surfaced by `GET /stats`.
#[derive(Default)]
struct RouterStats {
    /// Generate requests received.
    requests: AtomicU64,
    /// Generate requests fully answered from a replica (200 or a relayed
    /// client error).
    proxied_ok: AtomicU64,
    /// Failover attempts beyond each request's first try.
    retries: AtomicU64,
    /// Requests shed with 503 (+ Retry-After).
    shed: AtomicU64,
    /// 502s: every eligible replica failed hard.
    failed: AtomicU64,
    /// 504s: retry budget outlived the request deadline.
    timeouts: AtomicU64,
    /// Breaker transitions into Ejected.
    ejections: AtomicU64,
    /// Upstream attempt failures (connect/read/5xx), pre-retry.
    upstream_errors: AtomicU64,
    /// Streams that broke after the first forwarded token (terminated
    /// with an error line, never retried).
    streams_broken: AtomicU64,
    /// Sessioned requests whose first pick was their rendezvous home.
    affinity_hits: AtomicU64,
    /// Sessioned requests whose home was unroutable at first pick —
    /// routed least-loaded instead.
    affinity_fallbacks: AtomicU64,
    /// State migrations that moved a parked session (export + import ok).
    migrations_ok: AtomicU64,
    /// State migrations that failed (either leg) — the target replica
    /// cold-prefilled instead.
    migrations_failed: AtomicU64,
}

/// Shared state of the accept loop, workers and prober.
struct RouterCtx {
    cfg: RouterConfig,
    replicas: Vec<Replica>,
    stats: RouterStats,
    shutdown: Arc<AtomicBool>,
    conns: AtomicUsize,
    rng: Mutex<Rng>,
    /// Where each session last *landed* (index of the replica that fully
    /// answered its latest turn) — the migration source on failover,
    /// which may differ from the rendezvous home after a prior fallback.
    /// Grows with distinct session ids; entries are a usize each, so
    /// even millions of sessions stay cheap.
    sessions: Mutex<HashMap<String, usize>>,
}

impl RouterCtx {
    /// Pick the next replica for a request, excluding already-tried
    /// ones: least-in-flight among routable replicas first, then a
    /// half-open probe slot on a cooled-down ejected replica.
    fn pick(&self, tried: &BTreeSet<usize>, now: Instant) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if tried.contains(&i) || !r.breaker().routable() {
                continue;
            }
            let load = r.in_flight.load(Ordering::SeqCst);
            let better = match best {
                None => true,
                Some((_, best_load)) => load < best_load,
            };
            if better {
                best = Some((i, load));
            }
        }
        if let Some((i, _)) = best {
            return Some(i);
        }
        for (i, r) in self.replicas.iter().enumerate() {
            if !tried.contains(&i) && r.breaker().try_probe(now) {
                return Some(i);
            }
        }
        None
    }

    fn note_success(&self, idx: usize) {
        self.replicas[idx].breaker().on_success();
    }

    fn note_failure(&self, idx: usize, now: Instant) {
        if self.replicas[idx].breaker().on_failure(now) {
            self.stats.ejections.fetch_add(1, Ordering::SeqCst);
            log::warn!("replica {} ejected", self.replicas[idx].addr);
        }
    }

    /// Replicas currently routable (Healthy/Suspect).
    fn available(&self) -> usize {
        self.replicas.iter().filter(|r| r.breaker().routable()).count()
    }
}

/// A bound-but-not-yet-serving router (two-phase like
/// [`super::Frontend`]: callers learn the OS-assigned port and grab the
/// shutdown flag before the blocking serve loop starts).
pub struct Router {
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    backends: Vec<String>,
    cfg: RouterConfig,
}

impl Router {
    /// Bind `listen` in front of `backends` (replica addresses).
    pub fn bind(listen: &str, backends: Vec<String>, cfg: RouterConfig) -> Result<Router> {
        anyhow::ensure!(!backends.is_empty(), "router needs at least one backend");
        let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        Ok(Router { listener, shutdown: Arc::new(AtomicBool::new(false)), backends, cfg })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Serve until shutdown (blocking): accept loop on the calling
    /// thread, one worker per connection plus the health prober as
    /// scoped threads — all joined on return.
    pub fn run(self) -> Result<()> {
        let addr = self.local_addr()?;
        let cfg = self.cfg;
        let ctx = RouterCtx {
            cfg,
            replicas: self.backends.iter().map(|b| Replica::new(b.clone(), &cfg)).collect(),
            stats: RouterStats::default(),
            shutdown: self.shutdown.clone(),
            conns: AtomicUsize::new(0),
            rng: Mutex::new(Rng::new(cfg.seed)),
            sessions: Mutex::new(HashMap::new()),
        };
        // Machine-readable readiness line (scripts/route_chaos.py keys
        // on it; logs go to stderr).
        println!("ROUTE listening on {addr}");
        std::io::stdout().flush().ok();
        log::info!(
            "routing http://{addr} across {} replica(s): {}",
            ctx.replicas.len(),
            self.backends.join(", ")
        );
        let listener = self.listener;
        std::thread::scope(|s| {
            let ctx = &ctx;
            s.spawn(move || prober_loop(ctx));
            accept_loop(s, &listener, ctx);
        });
        log::info!(
            "router served {} request(s): {} ok, {} shed, {} failed, {} retries, {} ejections",
            ctx.stats.requests.load(Ordering::SeqCst),
            ctx.stats.proxied_ok.load(Ordering::SeqCst),
            ctx.stats.shed.load(Ordering::SeqCst),
            ctx.stats.failed.load(Ordering::SeqCst),
            ctx.stats.retries.load(Ordering::SeqCst),
            ctx.stats.ejections.load(Ordering::SeqCst),
        );
        Ok(())
    }
}

/// Poll every replica's `/healthz` (feeding the breaker) and cache its
/// `/stats` for the aggregated view.
fn prober_loop(ctx: &RouterCtx) {
    let opts = ClientOpts {
        connect_timeout: Duration::from_millis(ctx.cfg.health_timeout_ms.max(1)),
        read_timeout: Duration::from_millis(ctx.cfg.health_timeout_ms.max(1)),
    };
    while !ctx.shutdown.load(Ordering::SeqCst) {
        let now = Instant::now();
        for (i, r) in ctx.replicas.iter().enumerate() {
            let healthy = match http::request_with(&r.addr, "GET", "/healthz", b"", opts) {
                Ok(resp) => resp.status == 200,
                Err(_) => false,
            };
            if healthy {
                r.probes_ok.fetch_add(1, Ordering::SeqCst);
                ctx.note_success(i);
                if let Ok(resp) = http::request_with(&r.addr, "GET", "/stats", b"", opts) {
                    if resp.status == 200 {
                        if let Ok(j) = json::parse(&resp.text()) {
                            *r.last_stats.lock().expect("last_stats lock") = Some(j);
                        }
                    }
                }
            } else {
                r.probes_failed.fetch_add(1, Ordering::SeqCst);
                ctx.note_failure(i, now);
            }
        }
        // Sleep in small steps so shutdown is observed promptly.
        let mut left = ctx.cfg.health_interval_ms.max(10);
        while left > 0 && !ctx.shutdown.load(Ordering::SeqCst) {
            let step = left.min(20);
            std::thread::sleep(Duration::from_millis(step));
            left -= step;
        }
    }
}

fn accept_loop<'scope, 'env>(
    scope: &'scope Scope<'scope, 'env>,
    listener: &'scope TcpListener,
    ctx: &'scope RouterCtx,
) {
    loop {
        if SIGNALLED.load(Ordering::SeqCst) {
            ctx.shutdown.store(true, Ordering::SeqCst);
        }
        if ctx.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if ctx.conns.load(Ordering::SeqCst) >= MAX_CONNECTIONS {
                    let mut stream = stream;
                    let _ = http::write_response(
                        &mut stream,
                        503,
                        "application/json",
                        b"{\"error\":{\"code\":\"too_many_connections\",\
                          \"message\":\"too many connections\"}}",
                        false,
                    );
                    continue;
                }
                ctx.conns.fetch_add(1, Ordering::SeqCst);
                scope.spawn(move || {
                    if let Err(e) = serve_conn(stream, ctx) {
                        log::debug!("router connection ended: {e:#}");
                    }
                    ctx.conns.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log::warn!("router accept error: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn serve_conn(stream: TcpStream, ctx: &RouterCtx) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let req = match http::read_request(&mut reader, http::DEFAULT_MAX_BODY) {
            Ok(req) => req,
            Err(ParseError::Closed) => return Ok(()),
            Err(ParseError::IdleTimeout) => {
                if ctx.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(ParseError::Io(_)) => return Ok(()),
            Err(e @ ParseError::BodyTooLarge { .. }) => {
                respond_error(&mut writer, ErrorCode::BodyTooLarge, &e.to_string(), false)?;
                return Ok(());
            }
            Err(e) => {
                respond_error(&mut writer, ErrorCode::BadRequest, &e.to_string(), false)?;
                return Ok(());
            }
        };
        let keep = req.keep_alive() && !ctx.shutdown.load(Ordering::SeqCst);
        route(&mut writer, &req, keep, ctx)?;
        if !keep {
            return Ok(());
        }
    }
}

fn route(w: &mut TcpStream, req: &Request, keep: bool, ctx: &RouterCtx) -> Result<()> {
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => healthz(w, keep, ctx),
        ("GET", "/stats") => respond_json(w, 200, &stats_json(ctx), keep),
        ("POST", "/v1/generate") => proxy_generate(w, req, keep, ctx),
        ("GET" | "HEAD", "/v1/generate") => {
            respond_error(w, ErrorCode::MethodNotAllowed, "use POST", keep)
        }
        (m, p) => respond_error(w, ErrorCode::NotFound, &format!("no route {m} {p}"), keep),
    }
}

fn healthz(w: &mut TcpStream, keep: bool, ctx: &RouterCtx) -> Result<()> {
    let draining = ctx.shutdown.load(Ordering::SeqCst);
    let (status, ok, state) = if draining { (503, false, "draining") } else { (200, true, "ok") };
    let mut fields = vec![
        ("ok", Json::Bool(ok)),
        ("status", Json::Str(state.to_string())),
        ("replicas", Json::Num(ctx.replicas.len() as f64)),
        ("available", Json::Num(ctx.available() as f64)),
    ];
    if draining {
        fields.push(("error", ErrorCode::Draining.body("router is draining")));
    }
    respond_json(w, status, &Json::obj(fields), keep)
}

fn stats_json(ctx: &RouterCtx) -> Json {
    let mut per_replica = Vec::new();
    let mut agg_completed = 0.0;
    let mut agg_tokens = 0.0;
    let mut agg_tok_s = 0.0;
    for r in &ctx.replicas {
        let state = r.breaker().state();
        let cached = r.last_stats.lock().expect("last_stats lock").clone();
        if let Some(js) = &cached {
            agg_completed += js.get("completed").as_f64().unwrap_or(0.0);
            agg_tokens += js.get("tokens_processed").as_f64().unwrap_or(0.0);
            agg_tok_s += js.get("tokens_per_sec").as_f64().unwrap_or(0.0);
        }
        per_replica.push(Json::obj(vec![
            ("addr", Json::Str(r.addr.clone())),
            ("state", Json::Str(state.as_str().to_string())),
            ("in_flight", Json::Num(r.in_flight.load(Ordering::SeqCst) as f64)),
            ("probes_ok", Json::Num(r.probes_ok.load(Ordering::SeqCst) as f64)),
            ("probes_failed", Json::Num(r.probes_failed.load(Ordering::SeqCst) as f64)),
            ("stats", cached.unwrap_or(Json::Null)),
        ]));
    }
    let s = &ctx.stats;
    Json::obj(vec![
        ("schema_version", Json::Num(STATS_SCHEMA_VERSION as f64)),
        ("replicas", Json::Arr(per_replica)),
        ("available", Json::Num(ctx.available() as f64)),
        ("requests", Json::Num(s.requests.load(Ordering::SeqCst) as f64)),
        ("proxied_ok", Json::Num(s.proxied_ok.load(Ordering::SeqCst) as f64)),
        ("retries", Json::Num(s.retries.load(Ordering::SeqCst) as f64)),
        ("shed", Json::Num(s.shed.load(Ordering::SeqCst) as f64)),
        ("failed", Json::Num(s.failed.load(Ordering::SeqCst) as f64)),
        ("timeouts", Json::Num(s.timeouts.load(Ordering::SeqCst) as f64)),
        ("ejections", Json::Num(s.ejections.load(Ordering::SeqCst) as f64)),
        ("upstream_errors", Json::Num(s.upstream_errors.load(Ordering::SeqCst) as f64)),
        ("streams_broken", Json::Num(s.streams_broken.load(Ordering::SeqCst) as f64)),
        (
            "routing",
            Json::obj(vec![
                ("affinity", Json::Bool(ctx.cfg.affinity)),
                ("migrate", Json::Bool(ctx.cfg.migrate)),
                ("affinity_hits", Json::Num(s.affinity_hits.load(Ordering::SeqCst) as f64)),
                (
                    "affinity_fallbacks",
                    Json::Num(s.affinity_fallbacks.load(Ordering::SeqCst) as f64),
                ),
                ("migrations_ok", Json::Num(s.migrations_ok.load(Ordering::SeqCst) as f64)),
                (
                    "migrations_failed",
                    Json::Num(s.migrations_failed.load(Ordering::SeqCst) as f64),
                ),
            ]),
        ),
        (
            "aggregate",
            Json::obj(vec![
                ("completed", Json::Num(agg_completed)),
                ("tokens_processed", Json::Num(agg_tokens)),
                ("tokens_per_sec", Json::Num(agg_tok_s)),
            ]),
        ),
    ])
}

/// Outcome of one upstream attempt.
enum Attempt {
    /// The response was fully relayed to the client; the request is done.
    Done,
    /// Retryable upstream status (429 / 5xx); nothing was written to
    /// the client.
    Retryable(u16),
    /// Transport failure (connect / read / parse) with nothing written
    /// to the client.
    Failed(String),
    /// The stream broke after at least one forwarded token; the client
    /// response was terminated with an error line. Terminal: never retry.
    Broken,
}

fn shed(w: &mut TcpStream, ctx: &RouterCtx, keep: bool, why: &str) -> Result<()> {
    ctx.stats.shed.fetch_add(1, Ordering::SeqCst);
    let body = ErrorCode::ReplicasSaturated.envelope(why).to_string();
    http::write_response_with(
        w,
        503,
        "application/json",
        &[("retry-after", "1")],
        body.as_bytes(),
        keep,
    )?;
    Ok(())
}

/// The request's session key, normalized exactly like the engine does
/// (integer keys become their decimal string). Malformed values yield
/// `None` here — the replica relays the authoritative 400.
fn session_of(j: &Json) -> Option<String> {
    match j.get("session_id") {
        Json::Null => None,
        v => {
            let sid = v
                .as_str()
                .map(str::to_string)
                .or_else(|| v.as_usize().map(|n| n.to_string()));
            sid.filter(|s| !s.is_empty())
        }
    }
}

fn proxy_generate(w: &mut TcpStream, req: &Request, keep: bool, ctx: &RouterCtx) -> Result<()> {
    let arrived = Instant::now();
    ctx.stats.requests.fetch_add(1, Ordering::SeqCst);
    let body = match std::str::from_utf8(&req.body) {
        Ok(b) => b,
        Err(_) => return respond_error(w, ErrorCode::BadRequest, "body must be UTF-8 JSON", keep),
    };
    let j = match json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return respond_error(w, ErrorCode::BadRequest, &format!("invalid JSON body: {e}"), keep)
        }
    };
    let stream = j.get("stream").as_bool().unwrap_or(false);
    let timeout_ms = match j.get("timeout_ms") {
        Json::Null => {
            if ctx.cfg.default_timeout_ms > 0 {
                Some(ctx.cfg.default_timeout_ms)
            } else {
                None
            }
        }
        v => match v.as_usize() {
            Some(ms) if ms > 0 => Some(ms as u64),
            _ => {
                let msg = "timeout_ms must be a positive integer";
                return respond_error(w, ErrorCode::BadRequest, msg, keep);
            }
        },
    };
    let deadline = timeout_ms.map(|ms| arrived + Duration::from_millis(ms));
    // Home replica of a sessioned request: rendezvous over the FULL
    // replica set, so the home is stable regardless of current health.
    let session = session_of(&j);
    let home = match &session {
        Some(sid) if ctx.cfg.affinity => {
            let addrs: Vec<&str> = ctx.replicas.iter().map(|r| r.addr.as_str()).collect();
            rendezvous_pick(sid, &addrs)
        }
        _ => None,
    };

    let mut tried: BTreeSet<usize> = BTreeSet::new();
    let max_attempts = ctx.cfg.max_attempts.clamp(1, ctx.replicas.len());
    let mut attempts = 0usize;
    let mut saw_hard_failure = false;
    let mut last_error = String::new();
    loop {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            ctx.stats.timeouts.fetch_add(1, Ordering::SeqCst);
            let msg = "deadline exceeded before a replica answered";
            return respond_error(w, ErrorCode::DeadlineExceeded, msg, keep);
        }
        if attempts >= max_attempts {
            break;
        }
        let now = Instant::now();
        // First pick of a sessioned request prefers the rendezvous home;
        // an unroutable home falls back to least-loaded (counted once —
        // retries after a failed first attempt are plain failover).
        let picked = if attempts == 0 {
            match home {
                Some(h) if ctx.replicas[h].breaker().routable() => {
                    ctx.stats.affinity_hits.fetch_add(1, Ordering::SeqCst);
                    Some(h)
                }
                Some(_) => {
                    let p = ctx.pick(&tried, now);
                    if p.is_some() {
                        ctx.stats.affinity_fallbacks.fetch_add(1, Ordering::SeqCst);
                    }
                    p
                }
                None => ctx.pick(&tried, now),
            }
        } else {
            ctx.pick(&tried, now)
        };
        let Some(idx) = picked else { break };
        tried.insert(idx);
        // State handoff: when the first target differs from wherever the
        // session last landed (failed-over home, or healing back to it),
        // move the parked state there before forwarding. At most one
        // attempt per request; failure just means a cold prefill.
        if attempts == 0 && ctx.cfg.migrate {
            if let Some(sid) = &session {
                let last = ctx.sessions.lock().expect("sessions lock").get(sid).copied();
                if let Some(from) = last.filter(|&from| from != idx) {
                    match migrate_state(ctx, sid, from, idx) {
                        Ok(()) => {
                            ctx.stats.migrations_ok.fetch_add(1, Ordering::SeqCst);
                            log::info!(
                                "session {sid}: state migrated {} -> {}",
                                ctx.replicas[from].addr,
                                ctx.replicas[idx].addr
                            );
                        }
                        Err(e) => {
                            ctx.stats.migrations_failed.fetch_add(1, Ordering::SeqCst);
                            log::warn!("session {sid}: migration failed ({e}), cold prefill");
                        }
                    }
                }
            }
        }
        if attempts > 0 {
            ctx.stats.retries.fetch_add(1, Ordering::SeqCst);
            let ms = {
                let mut rng = ctx.rng.lock().expect("rng lock");
                backoff_ms(&ctx.cfg, attempts - 1, &mut rng)
            };
            let mut wait = Duration::from_millis(ms);
            if let Some(d) = deadline {
                wait = wait.min(d.saturating_duration_since(Instant::now()));
            }
            std::thread::sleep(wait);
        }
        attempts += 1;
        let replica = &ctx.replicas[idx];
        replica.in_flight.fetch_add(1, Ordering::SeqCst);
        let outcome = forward(w, &replica.addr, &req.body, stream, deadline, keep, ctx);
        replica.in_flight.fetch_sub(1, Ordering::SeqCst);
        match outcome? {
            Attempt::Done => {
                ctx.note_success(idx);
                ctx.stats.proxied_ok.fetch_add(1, Ordering::SeqCst);
                if let Some(sid) = &session {
                    ctx.sessions.lock().expect("sessions lock").insert(sid.clone(), idx);
                }
                return Ok(());
            }
            Attempt::Retryable(status) => {
                if status == 429 {
                    // A full admission queue means the replica is alive —
                    // don't trip its breaker, just go elsewhere.
                    ctx.note_success(idx);
                } else {
                    saw_hard_failure = true;
                    ctx.stats.upstream_errors.fetch_add(1, Ordering::SeqCst);
                    ctx.note_failure(idx, Instant::now());
                }
                last_error = format!("replica {} answered {status}", replica.addr);
            }
            Attempt::Failed(e) => {
                saw_hard_failure = true;
                ctx.stats.upstream_errors.fetch_add(1, Ordering::SeqCst);
                ctx.note_failure(idx, Instant::now());
                last_error = format!("replica {}: {e}", replica.addr);
            }
            Attempt::Broken => {
                ctx.stats.streams_broken.fetch_add(1, Ordering::SeqCst);
                ctx.note_failure(idx, Instant::now());
                // Tokens already reached the client: terminal by design.
                return Ok(());
            }
        }
    }
    if saw_hard_failure {
        ctx.stats.failed.fetch_add(1, Ordering::SeqCst);
        let msg = format!("all replicas failed ({last_error})");
        respond_error(w, ErrorCode::AllReplicasFailed, &msg, keep)
    } else {
        // Everything routable was saturated (429s) or no replica was
        // routable at all: shed politely.
        shed(w, ctx, keep, "all replicas saturated or ejected, retry later")
    }
}

/// Move session `session`'s parked state from replica `from` to `to`:
/// a consuming `GET /v1/state/{session}` export, then a `PUT` import of
/// the same bytes. Either leg failing is non-fatal for the request —
/// the destination cold-prefills the transcript instead, which is
/// always correct (and the strict-prefix check on the import side makes
/// a stale snapshot harmless). Exporting is safe even while the session
/// has a turn in flight on `from`: a seated turn has already consumed
/// its cache entry, so GET finds nothing and the migration just fails.
fn migrate_state(
    ctx: &RouterCtx,
    session: &str,
    from: usize,
    to: usize,
) -> std::result::Result<(), String> {
    let opts = ClientOpts {
        connect_timeout: Duration::from_millis(ctx.cfg.connect_timeout_ms.max(1)),
        read_timeout: Duration::from_millis(ctx.cfg.read_timeout_ms.max(1)),
    };
    let path = format!("/v1/state/{session}");
    let from_addr = &ctx.replicas[from].addr;
    let to_addr = &ctx.replicas[to].addr;
    let exported = match http::request_with(from_addr, "GET", &path, b"", opts) {
        Err(e) => return Err(format!("export from {from_addr}: {e}")),
        Ok(resp) if resp.status != 200 => {
            return Err(format!("export from {from_addr}: status {}", resp.status))
        }
        Ok(resp) => resp.body,
    };
    match http::request_with(to_addr, "PUT", &path, &exported, opts) {
        Err(e) => Err(format!("import into {to_addr}: {e}")),
        Ok(resp) if resp.status != 200 => {
            Err(format!("import into {to_addr}: status {}", resp.status))
        }
        Ok(_) => Ok(()),
    }
}

/// Run one upstream attempt and relay the outcome. Never writes a byte
/// to the client before the upstream outcome is known (non-streaming) or
/// the first token chunk arrived (streaming) — everything before that
/// point stays retryable.
fn forward(
    w: &mut TcpStream,
    addr: &str,
    body: &[u8],
    stream: bool,
    deadline: Option<Instant>,
    keep: bool,
    ctx: &RouterCtx,
) -> Result<Attempt> {
    let mut read_timeout = Duration::from_millis(ctx.cfg.read_timeout_ms.max(1));
    if let Some(d) = deadline {
        let left = d.saturating_duration_since(Instant::now());
        // The engine answers a timed-out request itself (finish_reason
        // "timeout"); pad the socket bound so that answer can arrive
        // before the router's own 504 path cuts the connection.
        read_timeout = read_timeout.min(left + Duration::from_millis(250)).max(MIN_READ_TIMEOUT);
    }
    let opts = ClientOpts {
        connect_timeout: Duration::from_millis(ctx.cfg.connect_timeout_ms.max(1)),
        read_timeout,
    };
    if !stream {
        return match http::request_with(addr, "POST", "/v1/generate", body, opts) {
            Err(e) => Ok(Attempt::Failed(e.to_string())),
            Ok(resp) => match resp.status {
                429 => Ok(Attempt::Retryable(429)),
                s if s >= 500 => Ok(Attempt::Retryable(s)),
                s => {
                    // 200 or a client error (400/404/409/413): relay
                    // verbatim — retrying a client error elsewhere
                    // cannot change the answer.
                    http::write_response(w, s, "application/json", &resp.body, keep)?;
                    Ok(Attempt::Done)
                }
            },
        };
    }
    let mut sr = match http::request_streaming(addr, "POST", "/v1/generate", body, opts) {
        Ok(sr) => sr,
        Err(e) => return Ok(Attempt::Failed(e.to_string())),
    };
    if sr.status != 200 {
        // Error statuses arrive with fixed-length bodies; drain and
        // relay or retry with the non-streaming rules.
        let mut full = Vec::new();
        loop {
            match sr.next_chunk() {
                Ok(Some(chunk)) => full.extend_from_slice(&chunk),
                Ok(None) => break,
                Err(e) => return Ok(Attempt::Failed(e.to_string())),
            }
        }
        return match sr.status {
            429 => Ok(Attempt::Retryable(429)),
            s if s >= 500 => Ok(Attempt::Retryable(s)),
            s => {
                http::write_response(w, s, "application/json", &full, keep)?;
                Ok(Attempt::Done)
            }
        };
    }
    // Hold the client's response head until the first upstream token
    // chunk is in hand: a failure before it stays retryable, a failure
    // after it is terminal.
    let first = match sr.next_chunk() {
        Ok(Some(chunk)) => chunk,
        Ok(None) => return Ok(Attempt::Failed("empty upstream stream".into())),
        Err(e) => return Ok(Attempt::Failed(e.to_string())),
    };
    let mut cw = ChunkedWriter::start(w, 200, "application/json", keep)?;
    cw.chunk(&first)?;
    loop {
        match sr.next_chunk() {
            Ok(Some(chunk)) => cw.chunk(&chunk)?,
            Ok(None) => {
                cw.finish()?;
                return Ok(Attempt::Done);
            }
            Err(e) => {
                // Mid-stream upstream failure with tokens already on the
                // wire: terminate the client stream cleanly (error line +
                // proper chunked framing), never retry.
                let err = Json::obj(vec![
                    ("error", Json::Str(format!("upstream stream broke: {e}"))),
                    ("done", Json::Bool(true)),
                ]);
                cw.chunk(format!("{}\n", err.to_string()).as_bytes())?;
                cw.finish()?;
                return Ok(Attempt::Broken);
            }
        }
    }
}

/// Floor of the per-attempt socket read timeout.
const MIN_READ_TIMEOUT: Duration = Duration::from_millis(50);

#[cfg(test)]
mod tests {
    use super::*;

    fn breaker() -> Breaker {
        Breaker::new(1, 3, Duration::from_millis(100))
    }

    #[test]
    fn breaker_walks_healthy_suspect_ejected() {
        let t0 = Instant::now();
        let mut b = breaker();
        assert_eq!(b.state(), CircuitState::Healthy);
        assert!(b.routable());
        assert!(!b.on_failure(t0), "first failure suspects, not ejects");
        assert_eq!(b.state(), CircuitState::Suspect);
        assert!(b.routable(), "suspect replicas still take traffic");
        assert!(!b.on_failure(t0));
        assert!(b.on_failure(t0), "third consecutive failure ejects");
        assert_eq!(b.state(), CircuitState::Ejected);
        assert!(!b.routable());
    }

    #[test]
    fn breaker_success_closes_from_any_state() {
        let t0 = Instant::now();
        let mut b = breaker();
        b.on_failure(t0);
        b.on_failure(t0);
        b.on_success();
        assert_eq!(b.state(), CircuitState::Healthy);
        // The failure streak is reset too: two more failures only
        // suspect again.
        b.on_failure(t0);
        assert!(!b.on_failure(t0));
        assert_eq!(b.state(), CircuitState::Suspect);
    }

    #[test]
    fn breaker_half_open_admits_exactly_one_probe() {
        let t0 = Instant::now();
        let mut b = breaker();
        for _ in 0..3 {
            b.on_failure(t0);
        }
        assert_eq!(b.state(), CircuitState::Ejected);
        // Cooldown not expired: no probe.
        assert!(!b.try_probe(t0 + Duration::from_millis(50)));
        assert_eq!(b.state(), CircuitState::Ejected);
        // Cooldown expired: exactly one probe passes.
        let t1 = t0 + Duration::from_millis(150);
        assert!(b.try_probe(t1));
        assert_eq!(b.state(), CircuitState::HalfOpen);
        assert!(!b.try_probe(t1), "second concurrent probe is blocked");
        // Probe success closes the circuit.
        b.on_success();
        assert_eq!(b.state(), CircuitState::Healthy);
    }

    #[test]
    fn breaker_failed_probe_reejects_with_fresh_cooldown() {
        let t0 = Instant::now();
        let mut b = breaker();
        for _ in 0..3 {
            b.on_failure(t0);
        }
        let t1 = t0 + Duration::from_millis(150);
        assert!(b.try_probe(t1));
        assert!(b.on_failure(t1), "a failed probe is a fresh ejection");
        assert_eq!(b.state(), CircuitState::Ejected);
        // The cooldown restarts at t1, so t1+50ms is still closed...
        assert!(!b.try_probe(t1 + Duration::from_millis(50)));
        // ...and t1+150ms admits the next probe.
        assert!(b.try_probe(t1 + Duration::from_millis(150)));
    }

    #[test]
    fn backoff_grows_exponentially_and_respects_the_cap() {
        let cfg = RouterConfig {
            backoff_base_ms: 16,
            backoff_cap_ms: 200,
            ..RouterConfig::default()
        };
        let mut rng = Rng::new(7);
        for attempt in 0..10 {
            let d = (16u64 << attempt).min(200);
            let ms = backoff_ms(&cfg, attempt, &mut rng);
            assert!(
                ms >= (d / 2).max(1) && ms < d.max(2),
                "attempt {attempt}: backoff {ms}ms outside [{}, {})",
                (d / 2).max(1),
                d
            );
        }
    }

    #[test]
    fn backoff_is_deterministic_per_seed() {
        let cfg = RouterConfig::default();
        let run = |seed: u64| -> Vec<u64> {
            let mut rng = Rng::new(seed);
            (0..8).map(|a| backoff_ms(&cfg, a, &mut rng)).collect()
        };
        assert_eq!(run(3), run(3));
    }

    #[test]
    fn router_rejects_an_empty_backend_list() {
        assert!(Router::bind("127.0.0.1:0", Vec::new(), RouterConfig::default()).is_err());
    }

    #[test]
    fn rendezvous_is_deterministic_and_lowest_index_wins_ties() {
        let addrs = ["127.0.0.1:9001", "127.0.0.1:9002", "127.0.0.1:9003"];
        for sid in ["alice", "bob", "42", "a-much-longer-session-key"] {
            let a = rendezvous_pick(sid, &addrs).unwrap();
            let b = rendezvous_pick(sid, &addrs).unwrap();
            assert_eq!(a, b, "same session + fleet must pick the same home");
        }
        // A duplicated address scores identically; strict-greater argmax
        // keeps the first occurrence.
        let dup = ["127.0.0.1:9001", "127.0.0.1:9001"];
        assert_eq!(rendezvous_pick("alice", &dup), Some(0));
        let none: [&str; 0] = [];
        assert_eq!(rendezvous_pick("alice", &none), None);
    }

    #[test]
    fn rendezvous_only_remaps_sessions_homed_on_a_removed_replica() {
        // The HRW property the tentpole leans on: dropping one replica
        // moves ONLY the sessions homed on it — everyone else keeps
        // their home (no global remap, unlike `hash % n`).
        let full = ["127.0.0.1:9001", "127.0.0.1:9002", "127.0.0.1:9003"];
        let without_last = &full[..2];
        let mut orphans = [0usize; 2];
        for i in 0..200 {
            let sid = format!("session-{i}");
            let before = rendezvous_pick(&sid, &full).unwrap();
            let after = rendezvous_pick(&sid, without_last).unwrap();
            if before < 2 {
                assert_eq!(after, before, "{sid}: survivor-homed session moved");
            } else {
                orphans[after] += 1;
            }
        }
        // Orphaned sessions spread over BOTH survivors (they re-run the
        // same argmax, minus one candidate), and re-adding the replica
        // restores every original home — `before` is a pure function of
        // (session, fleet), which the survivor loop already pinned.
        assert!(orphans[0] > 0 && orphans[1] > 0, "orphans all piled up: {orphans:?}");
    }

    #[test]
    fn rendezvous_spreads_sessions_across_three_replicas() {
        let addrs = ["127.0.0.1:9001", "127.0.0.1:9002", "127.0.0.1:9003"];
        let mut counts = [0usize; 3];
        let n = 3000;
        for i in 0..n {
            counts[rendezvous_pick(&format!("session-{i}"), &addrs).unwrap()] += 1;
        }
        // Uniform would be 1000 each; allow a generous ±30% band, which
        // a healthy 64-bit hash passes with enormous margin.
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (n / 3) * 7 / 10 <= c && c <= (n / 3) * 13 / 10,
                "replica {i} got {c} of {n} sessions: {counts:?}"
            );
        }
    }
}
