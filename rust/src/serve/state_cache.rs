//! Bounded LRU cache of parked per-session recurrent state.
//!
//! EFLA's analog of prefix caching. A transformer would need a KV cache
//! that grows with the conversation; the error-free linear-attention
//! recurrence compresses a whole transcript into a fixed O(1) state (conv
//! warm-start windows + S per layer, a few KB per slot), so parking a
//! finished turn's state and restoring it for the follow-up turn is
//! nearly free — and, because the exact-solution recurrence is a pure
//! function of the token sequence fed through it, **bit-exact**: a
//! restored state replays to exactly the logits a full-transcript prefill
//! would produce, at any thread count, matmul tier, and slot occupancy.
//!
//! Mechanics:
//! * entries are keyed by the client's `session_id` and hold the exact
//!   token transcript the state has absorbed plus the raw f32 state rows
//!   captured by `ModelSession::export_slot_state`;
//! * the memory tier is bounded by [`StateCache::new`]'s `max_bytes`
//!   (`efla serve --state-cache-bytes`); crossing the bound evicts the
//!   least-recently-used entry;
//! * with a spill directory (`--state-cache-dir`) evicted entries are
//!   written to disk through the [`crate::coordinator::checkpoint`]
//!   serialization (magic + JSON header + LE f32 payload) and restored
//!   transparently on the next lookup; without one they are dropped and
//!   the session falls back to a cold full prefill;
//! * a lookup only hits when the cached transcript is a **strict prefix**
//!   of the new turn's prompt — the engine then restores the rows into a
//!   free slot (any slot: states are slot-position independent) and
//!   prefills only the suffix. [`StateCache::take`] removes the entry, so
//!   a hit hands exclusive ownership of the state to the slot; the
//!   extended state is re-inserted when the turn finishes.
//!
//! This module is pure bookkeeping: no model math, no matmuls. The
//! engine-side scheduling (per-session serialization, restore-before-
//! prefill, snapshot-on-finish) lives in [`crate::coordinator::server`].

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::coordinator::checkpoint;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};

/// Shared handle to one engine's state cache. The engine thread owns the
/// scheduling (restore/snapshot); the HTTP front end's
/// `/v1/state/{session}` transfer endpoints take the same handle to
/// export/import *parked* entries, so a router can migrate a session to
/// another replica without touching live slots.
pub type SharedStateCache = Arc<Mutex<StateCache>>;

/// One parked session: the tokens its state has absorbed + the raw rows.
#[derive(Clone, Debug, PartialEq)]
pub struct CachedState {
    /// Exact token sequence fed through the recurrence (consumed prompt +
    /// generated tokens that were fed back; the final sampled token of a
    /// turn never was, so the follow-up prompt supplies it).
    pub transcript: Vec<i32>,
    /// One raw f32 row per decode-state tensor, in `decode_state` order.
    pub rows: Vec<Vec<f32>>,
}

impl CachedState {
    /// Resident bytes of this entry (payload only; bookkeeping excluded).
    fn bytes(&self) -> usize {
        let row_elems: usize = self.rows.iter().map(|r| r.len()).sum();
        4 * (row_elems + self.transcript.len())
    }

    /// Serialize to the wire form of the `/v1/state/{session}` transfer
    /// endpoints: the checkpoint layout (magic + u32 header length +
    /// JSON header + LE f32 payload) written into a byte buffer instead
    /// of a file. Tensor 0 is the transcript (token ids are exact in
    /// f32 up to 2^24), tensors 1.. the raw state rows; the header
    /// `step` carries the transcript length — byte-compatible with the
    /// spill files, so both sides validate the same way.
    pub fn to_wire(&self) -> Vec<u8> {
        let mut shapes = Vec::with_capacity(1 + self.rows.len());
        shapes.push(Json::obj(vec![("shape", Json::arr_usize(&[self.transcript.len()]))]));
        for row in &self.rows {
            shapes.push(Json::obj(vec![("shape", Json::arr_usize(&[row.len()]))]));
        }
        let header = Json::obj(vec![
            ("step", Json::Num(self.transcript.len() as f64)),
            ("tensors", Json::Arr(shapes)),
        ])
        .to_string();
        let mut out = Vec::with_capacity(8 + header.len() + self.bytes());
        out.extend_from_slice(&checkpoint::MAGIC.to_le_bytes());
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for &t in &self.transcript {
            out.extend_from_slice(&(t as f32).to_le_bytes());
        }
        for row in &self.rows {
            for &x in row {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        out
    }

    /// Parse the wire form back. Rejects a bad magic, a malformed
    /// header, a transcript/step mismatch, and trailing or missing
    /// payload bytes — an importing replica never trusts the router.
    /// (A *stale* but well-formed state is caught later by the
    /// strict-prefix check at lookup time, exactly like a spill file.)
    pub fn from_wire(bytes: &[u8]) -> anyhow::Result<CachedState> {
        use anyhow::bail;
        if bytes.len() < 8 {
            bail!("state payload too short ({} bytes)", bytes.len());
        }
        let magic = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
        if magic != checkpoint::MAGIC {
            bail!("state payload has a bad magic");
        }
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let Some(hbuf) = bytes.get(8..8 + hlen) else {
            bail!("state payload header truncated");
        };
        let header = json::parse(std::str::from_utf8(hbuf)?)
            .map_err(|e| anyhow::anyhow!("state payload header: {e}"))?;
        let step = header.usize_field("step")?;
        let specs = header
            .get("tensors")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("state payload header missing tensors"))?;
        if specs.is_empty() {
            bail!("state payload has no tensors");
        }
        let mut cursor = 8 + hlen;
        let mut flats: Vec<Vec<f32>> = Vec::with_capacity(specs.len());
        for spec in specs {
            let shape = spec.get("shape").usize_array()?;
            let n: usize = shape.iter().product();
            let Some(raw) = bytes.get(cursor..cursor + n * 4) else {
                bail!("state payload tensor data truncated");
            };
            cursor += n * 4;
            flats.push(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
        }
        if cursor != bytes.len() {
            bail!("state payload has {} trailing bytes", bytes.len() - cursor);
        }
        let rows = flats.split_off(1);
        let toks = flats.pop().expect("specs checked non-empty");
        if toks.len() != step {
            bail!("state payload transcript length {} != step {step}", toks.len());
        }
        Ok(CachedState {
            transcript: toks.iter().map(|&x| x as i32).collect(),
            rows,
        })
    }
}

struct Entry {
    state: CachedState,
    /// Monotonic LRU clock value of the last insert/lookup touch.
    last_used: u64,
    bytes: usize,
}

/// Counter snapshot mirrored into `ServerStats` / `GET /stats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StateCacheStats {
    /// Successful restores (memory or disk).
    pub hits: u64,
    /// Lookups with a `session_id` that found no usable state (absent,
    /// evicted without spill, or transcript not a prefix of the prompt).
    pub misses: u64,
    /// Entries pushed out of the memory tier at the byte bound.
    pub evictions: u64,
    /// Evicted entries written to the disk spill tier.
    pub spills: u64,
    /// Hits served from the disk tier (also counted in `hits`).
    pub disk_hits: u64,
    /// Entries currently resident in memory.
    pub entries: usize,
    /// Bytes currently resident in memory.
    pub resident_bytes: usize,
}

/// The session state cache. `max_bytes == 0` disables everything: no
/// lookups, no snapshots, counters never move.
pub struct StateCache {
    max_bytes: usize,
    spill_dir: Option<PathBuf>,
    entries: HashMap<String, Entry>,
    /// Sessions whose state lives in a spill file on disk.
    spilled: HashMap<String, PathBuf>,
    tick: u64,
    mem_bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    spills: u64,
    disk_hits: u64,
}

impl StateCache {
    /// `max_bytes` bounds the memory tier (0 = disabled); a non-empty
    /// `spill_dir` arms the disk tier for evicted entries.
    pub fn new(max_bytes: usize, spill_dir: &str) -> StateCache {
        let spill_dir = if spill_dir.is_empty() || max_bytes == 0 {
            None
        } else {
            Some(PathBuf::from(spill_dir))
        };
        StateCache {
            max_bytes,
            spill_dir,
            entries: HashMap::new(),
            spilled: HashMap::new(),
            tick: 0,
            mem_bytes: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            spills: 0,
            disk_hits: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.max_bytes > 0
    }

    /// Current counters + occupancy.
    pub fn stats(&self) -> StateCacheStats {
        StateCacheStats {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            spills: self.spills,
            disk_hits: self.disk_hits,
            entries: self.entries.len(),
            resident_bytes: self.mem_bytes,
        }
    }

    /// Look up `session`'s parked state for a new turn whose full prompt
    /// is `prompt`. Hits only when the cached transcript is a strict
    /// prefix of `prompt` (equality would leave the turn nothing to
    /// prefill and no seeding logits). A hit removes the entry — the
    /// caller owns the state until it re-inserts the extended snapshot.
    pub fn take(&mut self, session: &str, prompt: &[i32]) -> Option<CachedState> {
        if !self.enabled() {
            return None;
        }
        if let Some(entry) = self.entries.get(session) {
            if is_strict_prefix(&entry.state.transcript, prompt) {
                let entry = self.entries.remove(session).expect("entry checked above");
                self.mem_bytes -= entry.bytes;
                self.hits += 1;
                return Some(entry.state);
            }
            // Present but stale (diverged or replayed conversation): the
            // state is unusable for this prompt. Leave it; a completed
            // turn overwrites it.
            self.misses += 1;
            return None;
        }
        if let Some(path) = self.spilled.get(session).cloned() {
            match load_spill(&path) {
                Ok(state) if is_strict_prefix(&state.transcript, prompt) => {
                    self.spilled.remove(session);
                    std::fs::remove_file(&path).ok();
                    self.hits += 1;
                    self.disk_hits += 1;
                    return Some(state);
                }
                Ok(_) => {}
                Err(e) => {
                    log::warn!("state cache: spill read {} failed: {e:#}", path.display());
                    self.spilled.remove(session);
                    std::fs::remove_file(&path).ok();
                }
            }
        }
        self.misses += 1;
        None
    }

    /// Remove and return `session`'s parked state regardless of any
    /// prompt — the export side of the `GET /v1/state/{session}`
    /// migration endpoint. Consuming (rather than copying) preserves
    /// the exclusive-ownership invariant of [`StateCache::take`]: after
    /// a migration exactly one replica holds the session. Deliberately
    /// counts neither a hit nor a miss — migration is a transport
    /// event, not a lookup — so the engine's hit/miss counters keep
    /// meaning "turns that resumed" vs "turns that prefilled cold".
    pub fn take_any(&mut self, session: &str) -> Option<CachedState> {
        if !self.enabled() {
            return None;
        }
        if let Some(entry) = self.entries.remove(session) {
            self.mem_bytes -= entry.bytes;
            return Some(entry.state);
        }
        if let Some(path) = self.spilled.remove(session) {
            let loaded = load_spill(&path);
            std::fs::remove_file(&path).ok();
            match loaded {
                Ok(state) => return Some(state),
                Err(e) => {
                    log::warn!("state cache: spill read {} failed: {e:#}", path.display());
                }
            }
        }
        None
    }

    /// Park a finished turn's state under `session`, evicting (and
    /// spilling, when a directory is armed) least-recently-used entries
    /// until the memory tier fits the bound again. Replacing a session's
    /// own previous entry is not an eviction.
    pub fn insert(&mut self, session: &str, state: CachedState) {
        if !self.enabled() || state.transcript.is_empty() {
            return;
        }
        if let Some(old) = self.entries.remove(session) {
            self.mem_bytes -= old.bytes;
        }
        if let Some(path) = self.spilled.remove(session) {
            std::fs::remove_file(&path).ok();
        }
        let bytes = state.bytes();
        if bytes > self.max_bytes {
            // Never fits in memory: straight to the disk tier (or gone).
            self.evictions += 1;
            self.spill(session, &state);
            return;
        }
        self.tick += 1;
        self.mem_bytes += bytes;
        self.entries.insert(session.to_string(), Entry { state, last_used: self.tick, bytes });
        while self.mem_bytes > self.max_bytes {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("over the bound implies a resident entry");
            let entry = self.entries.remove(&victim).expect("victim is resident");
            self.mem_bytes -= entry.bytes;
            self.evictions += 1;
            self.spill(&victim, &entry.state);
        }
    }

    /// Write an evicted entry to the disk tier, if one is armed.
    fn spill(&mut self, session: &str, state: &CachedState) {
        let Some(dir) = self.spill_dir.clone() else { return };
        let path = dir.join(format!("{:016x}.state", fnv1a(session.as_bytes())));
        match save_spill(&path, state) {
            Ok(()) => {
                self.spills += 1;
                self.spilled.insert(session.to_string(), path);
            }
            Err(e) => log::warn!("state cache: spill write {} failed: {e:#}", path.display()),
        }
    }
}

/// True when `prefix` is a strict prefix of `seq`.
fn is_strict_prefix(prefix: &[i32], seq: &[i32]) -> bool {
    prefix.len() < seq.len() && prefix == &seq[..prefix.len()]
}

/// FNV-1a 64-bit — stable spill filenames and the router's rendezvous
/// hash, without new dependencies. A spill-name collision merely
/// overwrites another session's spill file; the transcript prefix check
/// on load rejects the mismatch (cold prefill). The router
/// ([`crate::serve::router`]) reuses the same function over
/// `session/addr` pairs so session → replica affinity is one naming
/// convention end to end.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Spill file = checkpoint format: step carries the transcript length,
/// tensor 0 the transcript (token ids are exact in f32 up to 2^24, far
/// above any byte-level vocab), tensors 1.. the raw state rows.
fn save_spill(path: &std::path::Path, state: &CachedState) -> anyhow::Result<()> {
    let mut tensors = Vec::with_capacity(1 + state.rows.len());
    let toks: Vec<f32> = state.transcript.iter().map(|&t| t as f32).collect();
    tensors.push(Tensor::from_vec(&[toks.len()], toks));
    for row in &state.rows {
        tensors.push(Tensor::from_vec(&[row.len()], row.clone()));
    }
    checkpoint::save(path, state.transcript.len() as u64, &tensors)
}

fn load_spill(path: &std::path::Path) -> anyhow::Result<CachedState> {
    let (step, tensors) = checkpoint::load(path)?;
    let Some((toks, rows)) = tensors.split_first() else {
        anyhow::bail!("{}: spill file has no tensors", path.display());
    };
    if toks.len() != step as usize {
        anyhow::bail!("{}: transcript length {} != step {step}", path.display(), toks.len());
    }
    Ok(CachedState {
        transcript: toks.data().iter().map(|&x| x as i32).collect(),
        rows: rows.iter().map(|t| t.data().to_vec()).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(token: i32, elems: usize) -> CachedState {
        CachedState { transcript: vec![token; 4], rows: vec![vec![token as f32; elems]] }
    }

    fn spill_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("efla_sc_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn disabled_cache_never_counts() {
        let mut c = StateCache::new(0, "");
        assert!(!c.enabled());
        c.insert("a", entry(1, 8));
        assert_eq!(c.take("a", &[1, 1, 1, 1, 2]), None);
        assert_eq!(c.stats(), StateCacheStats::default());
    }

    #[test]
    fn strict_prefix_rules_out_equality_and_divergence() {
        let mut c = StateCache::new(1 << 20, "");
        c.insert("a", CachedState { transcript: vec![1, 2, 3], rows: vec![vec![0.5; 4]] });
        // Equal transcript: nothing left to prefill — miss.
        assert_eq!(c.take("a", &[1, 2, 3]), None);
        // Diverged transcript: miss, entry retained.
        assert_eq!(c.take("a", &[1, 9, 3, 4]), None);
        // Strict prefix: hit, and the hit removes the entry.
        assert!(c.take("a", &[1, 2, 3, 4]).is_some());
        assert_eq!(c.take("a", &[1, 2, 3, 4]), None);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 3, 0));
    }

    #[test]
    fn lru_evicts_the_oldest_entry_at_the_byte_bound() {
        // Each entry is 4*(64 + 4) = 272 bytes; bound fits two.
        let mut c = StateCache::new(600, "");
        c.insert("a", entry(1, 64));
        c.insert("b", entry(2, 64));
        assert_eq!(c.stats().entries, 2);
        // Re-inserting a session replaces in place: no eviction.
        c.insert("a", entry(1, 64));
        assert_eq!(c.stats().evictions, 0);
        // A third session crosses the bound; "b" is now least recent.
        c.insert("c", entry(3, 64));
        let s = c.stats();
        assert_eq!((s.entries, s.evictions), (2, 1));
        assert_eq!(c.take("b", &[2, 2, 2, 2, 9]), None, "b was evicted (no spill tier)");
        assert!(c.take("a", &[1, 1, 1, 1, 9]).is_some());
        assert!(c.take("c", &[3, 3, 3, 3, 9]).is_some());
        assert_eq!(c.stats().resident_bytes, 0);
    }

    #[test]
    fn spill_round_trip_restores_identical_payload() {
        let dir = spill_dir("roundtrip");
        let mut c = StateCache::new(300, dir.to_str().unwrap());
        let parked = CachedState {
            transcript: vec![7, 8, 9, 10],
            rows: vec![vec![1.5, -2.25, 1e-9], vec![0.0; 5]],
        };
        c.insert("a", parked.clone());
        // "b" evicts "a" to disk.
        c.insert("b", entry(2, 64));
        let s = c.stats();
        assert_eq!((s.evictions, s.spills, s.entries), (1, 1, 1));
        // Restored bits must be exactly what was parked.
        let back = c.take("a", &[7, 8, 9, 10, 11]).expect("disk hit");
        assert_eq!(back, parked);
        let s = c.stats();
        assert_eq!((s.hits, s.disk_hits), (1, 1));
        // The spill file was consumed by the hit.
        assert_eq!(c.take("a", &[7, 8, 9, 10, 11]), None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wire_round_trip_restores_identical_payload() {
        let parked = CachedState {
            transcript: vec![7, 8, 9, 10],
            rows: vec![vec![1.5, -2.25, 1e-9], vec![0.0; 5]],
        };
        let wire = parked.to_wire();
        let back = CachedState::from_wire(&wire).expect("wire round trip");
        assert_eq!(back, parked);
    }

    #[test]
    fn wire_parse_rejects_malformed_payloads() {
        let wire = entry(3, 8).to_wire();
        assert!(CachedState::from_wire(b"").is_err(), "empty");
        assert!(CachedState::from_wire(b"not a state payload").is_err(), "bad magic");
        assert!(CachedState::from_wire(&wire[..wire.len() - 1]).is_err(), "truncated");
        let mut extra = wire.clone();
        extra.push(0);
        assert!(CachedState::from_wire(&extra).is_err(), "trailing bytes");
    }

    #[test]
    fn take_any_consumes_without_a_prompt_or_counters() {
        let mut c = StateCache::new(1 << 20, "");
        c.insert("a", entry(1, 8));
        let got = c.take_any("a").expect("resident entry exported");
        assert_eq!(got, entry(1, 8));
        // Consumed: a second export finds nothing.
        assert_eq!(c.take_any("a"), None);
        // Transport events move no lookup counters.
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
    }

    #[test]
    fn take_any_drains_the_spill_tier_too() {
        let dir = spill_dir("take_any");
        let mut c = StateCache::new(300, dir.to_str().unwrap());
        c.insert("a", entry(7, 32));
        c.insert("b", entry(2, 64)); // evicts "a" to disk
        assert!(c.take_any("a").is_some(), "spilled entry exported");
        assert_eq!(c.take_any("a"), None, "spill file consumed");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn oversized_entry_spills_straight_to_disk() {
        let dir = spill_dir("oversize");
        let mut c = StateCache::new(16, dir.to_str().unwrap());
        c.insert("big", entry(5, 64));
        let s = c.stats();
        assert_eq!((s.entries, s.evictions, s.spills), (0, 1, 1));
        assert!(c.take("big", &[5, 5, 5, 5, 6]).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
