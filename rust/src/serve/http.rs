//! Minimal HTTP/1.1 substrate (std-only — no `hyper`/`tiny_http` in the
//! vendor set).
//!
//! Server side: [`read_request`] parses one request from a `BufRead`
//! (request line, headers, `Content-Length` body with a size cap) with
//! keep-alive support; [`write_response`] and [`ChunkedWriter`] emit
//! fixed-length and `Transfer-Encoding: chunked` responses (the token
//! stream of `POST /v1/generate` with `"stream": true`).
//!
//! Client side: [`read_response`] (understands both framings, de-chunks),
//! the [`request`] one-shot helper — used by the integration tests,
//! `examples/serve.rs` and anything else that wants to poke the front end
//! without an external HTTP client — and [`request_streaming`], which
//! hands back the response head plus a chunk-at-a-time body reader (the
//! router proxies token streams through it). Both connects and reads are
//! bounded by [`ClientOpts`] timeouts: health probes against a stalled
//! replica must fail fast, not hang the prober.
//!
//! Deliberately small: no TLS, no request pipelining, no chunked *request*
//! bodies (rejected as unsupported), header names lowercased at parse
//! time so lookups are case-insensitive per RFC 9110.

#![forbid(unsafe_code)]

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Cap on request-line + header bytes per request.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;

/// Default cap on request bodies (the generate endpoint's JSON).
pub const DEFAULT_MAX_BODY: usize = 1 << 20;

/// A parsed HTTP request.
#[derive(Clone, Debug)]
pub struct Request {
    pub method: String,
    /// Request target as sent (path + optional query).
    pub target: String,
    /// `HTTP/1.1` or `HTTP/1.0`.
    pub version: String,
    /// Header pairs; names lowercased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// Keep the connection open after responding? HTTP/1.1 defaults to
    /// yes unless `Connection: close`; HTTP/1.0 defaults to no unless
    /// `Connection: keep-alive`.
    pub fn keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("").to_ascii_lowercase();
        if self.version == "HTTP/1.0" {
            conn == "keep-alive"
        } else {
            conn != "close"
        }
    }

    /// The target's path component (query string stripped).
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or("")
    }
}

/// Why a request (or client-side response) could not be parsed.
#[derive(Debug)]
pub enum ParseError {
    /// Clean EOF before the first byte (keep-alive connection ended).
    Closed,
    /// Read timeout before the first byte of a new request — the worker
    /// checks the shutdown flag and retries the read.
    IdleTimeout,
    BadRequestLine(String),
    BadHeader(String),
    BadContentLength(String),
    /// Chunked (or other non-identity) request bodies are not accepted.
    UnsupportedTransferEncoding,
    HeadTooLarge { limit: usize },
    BodyTooLarge { len: usize, limit: usize },
    Io(io::Error),
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ParseError::Closed => write!(f, "connection closed"),
            ParseError::IdleTimeout => write!(f, "idle read timeout"),
            ParseError::BadRequestLine(l) => write!(f, "malformed request line: {l:?}"),
            ParseError::BadHeader(l) => write!(f, "malformed header: {l:?}"),
            ParseError::BadContentLength(v) => write!(f, "invalid content-length: {v:?}"),
            ParseError::UnsupportedTransferEncoding => {
                write!(f, "transfer-encoding request bodies are not supported")
            }
            ParseError::HeadTooLarge { limit } => {
                write!(f, "request head exceeds {limit} bytes")
            }
            ParseError::BodyTooLarge { len, limit } => {
                write!(f, "request body of {len} bytes exceeds limit of {limit}")
            }
            ParseError::Io(e) => write!(f, "i/o error: {e}"),
        }
    }
}

impl std::error::Error for ParseError {}

/// Read one CRLF- (or LF-) terminated line. `read_any` tracks whether any
/// byte of the current message was consumed, so an idle timeout on a
/// keep-alive connection is distinguishable from a timeout mid-request.
fn read_line(
    r: &mut impl BufRead,
    read_any: &mut bool,
    budget: &mut usize,
) -> Result<String, ParseError> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if line.is_empty() && !*read_any {
                    return Err(ParseError::Closed);
                }
                let e = io::Error::new(io::ErrorKind::UnexpectedEof, "eof mid-request");
                return Err(ParseError::Io(e));
            }
            Ok(_) => {
                *read_any = true;
                if *budget == 0 {
                    return Err(ParseError::HeadTooLarge { limit: MAX_HEAD_BYTES });
                }
                *budget -= 1;
                if byte[0] == b'\n' {
                    break;
                }
                line.push(byte[0]);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(e.kind()) => {
                if line.is_empty() && !*read_any {
                    return Err(ParseError::IdleTimeout);
                }
                return Err(ParseError::Io(e));
            }
            Err(e) => return Err(ParseError::Io(e)),
        }
    }
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    String::from_utf8(line).map_err(|_| ParseError::BadHeader("non-utf8 bytes".into()))
}

fn is_timeout(kind: io::ErrorKind) -> bool {
    matches!(kind, io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

fn find_header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
}

/// Parse one request. Bodies are read only when `Content-Length` is
/// present and within `max_body`; anything larger is rejected before a
/// byte of it is read.
pub fn read_request<R: BufRead>(r: &mut R, max_body: usize) -> Result<Request, ParseError> {
    let mut read_any = false;
    let mut budget = MAX_HEAD_BYTES;
    let line = read_line(r, &mut read_any, &mut budget)?;
    let parts: Vec<&str> = line.split_whitespace().collect();
    if parts.len() != 3 || !parts[2].starts_with("HTTP/") {
        return Err(ParseError::BadRequestLine(line));
    }
    let (method, target, version) =
        (parts[0].to_string(), parts[1].to_string(), parts[2].to_string());
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = read_line(r, &mut read_any, &mut budget)?;
        if line.is_empty() {
            break;
        }
        let (name, value) = match line.split_once(':') {
            Some((n, v)) if !n.is_empty() && !n.contains(' ') => (n, v),
            _ => return Err(ParseError::BadHeader(line)),
        };
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
    if let Some(te) = find_header(&headers, "transfer-encoding") {
        if !te.eq_ignore_ascii_case("identity") {
            return Err(ParseError::UnsupportedTransferEncoding);
        }
    }
    let body_len = match find_header(&headers, "content-length") {
        None => 0,
        Some(v) => {
            v.trim().parse::<usize>().map_err(|_| ParseError::BadContentLength(v.into()))?
        }
    };
    if body_len > max_body {
        return Err(ParseError::BodyTooLarge { len: body_len, limit: max_body });
    }
    let mut body = vec![0u8; body_len];
    r.read_exact(&mut body).map_err(ParseError::Io)?;
    Ok(Request { method, target, version, headers, body })
}

/// Canonical reason phrase for the statuses the front end emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write a fixed-length response.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    write_response_with(w, status, content_type, &[], body, keep_alive)
}

/// [`write_response`] with extra headers (e.g. `Retry-After` on a shed
/// 503/429). Header names must be lowercase; values must be CRLF-free.
pub fn write_response_with(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(
        w,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        conn
    )?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Incremental `Transfer-Encoding: chunked` response writer. Every
/// [`ChunkedWriter::chunk`] is flushed immediately — it is the streaming
/// transport of the generate endpoint, one token per chunk.
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    /// Write the response head and switch the body to chunked framing.
    pub fn start(
        w: &'a mut W,
        status: u16,
        content_type: &str,
        keep_alive: bool,
    ) -> io::Result<Self> {
        let conn = if keep_alive { "keep-alive" } else { "close" };
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ntransfer-encoding: chunked\r\n\
             connection: {}\r\n\r\n",
            status,
            reason(status),
            content_type,
            conn
        )?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Emit one chunk (empty input is skipped — a zero-length chunk would
    /// terminate the stream).
    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream (last-chunk + trailing CRLF).
    pub fn finish(self) -> io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// A client-side response (tests / examples / smoke drivers).
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    /// De-chunked body.
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        find_header(&self.headers, &name.to_ascii_lowercase())
    }

    /// Body as UTF-8 (lossy).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Parse a response, de-chunking `Transfer-Encoding: chunked` bodies.
pub fn read_response<R: BufRead>(r: &mut R) -> Result<Response, ParseError> {
    let mut read_any = false;
    let mut budget = MAX_HEAD_BYTES;
    let line = read_line(r, &mut read_any, &mut budget)?;
    let parts: Vec<&str> = line.split_whitespace().collect();
    if parts.len() < 2 || !parts[0].starts_with("HTTP/") {
        return Err(ParseError::BadRequestLine(line));
    }
    let status = parts[1].parse::<u16>().map_err(|_| ParseError::BadRequestLine(line.clone()))?;
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = read_line(r, &mut read_any, &mut budget)?;
        if line.is_empty() {
            break;
        }
        let (name, value) = match line.split_once(':') {
            Some((n, v)) if !n.is_empty() => (n, v),
            _ => return Err(ParseError::BadHeader(line)),
        };
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
    let chunked = find_header(&headers, "transfer-encoding")
        .map(|v| v.to_ascii_lowercase().contains("chunked"))
        .unwrap_or(false);
    let mut body = Vec::new();
    let mut cbudget = usize::MAX;
    if chunked {
        loop {
            let size_line = read_line(r, &mut read_any, &mut cbudget)?;
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| ParseError::BadContentLength(size_line))?;
            if size == 0 {
                // Trailing CRLF after the last-chunk.
                let _ = read_line(r, &mut read_any, &mut cbudget);
                break;
            }
            let mut chunk = vec![0u8; size];
            r.read_exact(&mut chunk).map_err(ParseError::Io)?;
            body.extend_from_slice(&chunk);
            let mut crlf = [0u8; 2];
            r.read_exact(&mut crlf).map_err(ParseError::Io)?;
        }
    } else if let Some(v) = find_header(&headers, "content-length") {
        let len =
            v.trim().parse::<usize>().map_err(|_| ParseError::BadContentLength(v.into()))?;
        body = vec![0u8; len];
        r.read_exact(&mut body).map_err(ParseError::Io)?;
    } else {
        r.read_to_end(&mut body).map_err(ParseError::Io)?;
    }
    Ok(Response { status, headers, body })
}

/// Client-side socket timeouts. The old client hardcoded a 120s read
/// timeout and let connects block indefinitely — a stalled replica would
/// wedge the router's health prober. Both bounds are now explicit.
#[derive(Clone, Copy, Debug)]
pub struct ClientOpts {
    pub connect_timeout: Duration,
    pub read_timeout: Duration,
}

impl Default for ClientOpts {
    fn default() -> Self {
        ClientOpts {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(120),
        }
    }
}

/// Connect with [`ClientOpts::connect_timeout`], trying each resolved
/// address in turn.
fn connect(addr: &str, opts: ClientOpts) -> io::Result<TcpStream> {
    let mut last_err =
        io::Error::new(io::ErrorKind::InvalidInput, format!("cannot resolve {addr}"));
    for resolved in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&resolved, opts.connect_timeout) {
            Ok(stream) => {
                stream.set_read_timeout(Some(opts.read_timeout))?;
                stream.set_write_timeout(Some(opts.read_timeout))?;
                stream.set_nodelay(true).ok();
                return Ok(stream);
            }
            Err(e) => last_err = e,
        }
    }
    Err(last_err)
}

fn send_request_head(
    stream: &mut TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
) -> io::Result<()> {
    write!(
        stream,
        "{} {} HTTP/1.1\r\nhost: {}\r\ncontent-type: application/json\r\n\
         content-length: {}\r\nconnection: close\r\n\r\n",
        method,
        path,
        addr,
        body.len()
    )?;
    stream.write_all(body)?;
    stream.flush()
}

/// One-shot client request against `addr` (e.g. `127.0.0.1:8080`) with
/// the default timeouts.
pub fn request(addr: &str, method: &str, path: &str, body: &[u8]) -> io::Result<Response> {
    request_with(addr, method, path, body, ClientOpts::default())
}

/// One-shot client request with explicit connect/read timeouts.
pub fn request_with(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    opts: ClientOpts,
) -> io::Result<Response> {
    let mut stream = connect(addr, opts)?;
    send_request_head(&mut stream, addr, method, path, body)?;
    let mut r = BufReader::new(stream);
    read_response(&mut r).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
}

/// A response whose body is consumed incrementally — the router's
/// streaming proxy reads one upstream chunk at a time and forwards it to
/// its own client without buffering the whole generation.
pub struct StreamingResponse<R: BufRead> {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    reader: R,
    chunked: bool,
    /// Bytes left in a `Content-Length` body (identity framing).
    remaining: usize,
    done: bool,
}

impl<R: BufRead> StreamingResponse<R> {
    pub fn header(&self, name: &str) -> Option<&str> {
        find_header(&self.headers, &name.to_ascii_lowercase())
    }

    /// The next body fragment: one chunk in chunked framing, a bounded
    /// read otherwise. `Ok(None)` = body complete.
    pub fn next_chunk(&mut self) -> Result<Option<Vec<u8>>, ParseError> {
        if self.done {
            return Ok(None);
        }
        if self.chunked {
            let mut read_any = true;
            let mut budget = usize::MAX;
            let size_line = read_line(&mut self.reader, &mut read_any, &mut budget)?;
            let size = usize::from_str_radix(size_line.trim(), 16)
                .map_err(|_| ParseError::BadContentLength(size_line))?;
            if size == 0 {
                let _ = read_line(&mut self.reader, &mut read_any, &mut budget);
                self.done = true;
                return Ok(None);
            }
            let mut chunk = vec![0u8; size];
            self.reader.read_exact(&mut chunk).map_err(ParseError::Io)?;
            let mut crlf = [0u8; 2];
            self.reader.read_exact(&mut crlf).map_err(ParseError::Io)?;
            Ok(Some(chunk))
        } else {
            let take = self.remaining.min(8 * 1024);
            if take == 0 {
                self.done = true;
                return Ok(None);
            }
            let mut buf = vec![0u8; take];
            self.reader.read_exact(&mut buf).map_err(ParseError::Io)?;
            self.remaining -= take;
            Ok(Some(buf))
        }
    }
}

/// Parse a response head and return the body as a [`StreamingResponse`].
/// Bodies without `Content-Length` or chunked framing are treated as
/// empty (the serving endpoints always frame their bodies).
pub fn read_response_streaming<R: BufRead>(
    mut reader: R,
) -> Result<StreamingResponse<R>, ParseError> {
    let mut read_any = false;
    let mut budget = MAX_HEAD_BYTES;
    let line = read_line(&mut reader, &mut read_any, &mut budget)?;
    let parts: Vec<&str> = line.split_whitespace().collect();
    if parts.len() < 2 || !parts[0].starts_with("HTTP/") {
        return Err(ParseError::BadRequestLine(line));
    }
    let status = parts[1].parse::<u16>().map_err(|_| ParseError::BadRequestLine(line.clone()))?;
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = read_line(&mut reader, &mut read_any, &mut budget)?;
        if line.is_empty() {
            break;
        }
        let (name, value) = match line.split_once(':') {
            Some((n, v)) if !n.is_empty() => (n, v),
            _ => return Err(ParseError::BadHeader(line)),
        };
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }
    let chunked = find_header(&headers, "transfer-encoding")
        .map(|v| v.to_ascii_lowercase().contains("chunked"))
        .unwrap_or(false);
    let remaining = if chunked {
        0
    } else {
        match find_header(&headers, "content-length") {
            Some(v) => {
                v.trim().parse::<usize>().map_err(|_| ParseError::BadContentLength(v.into()))?
            }
            None => 0,
        }
    };
    Ok(StreamingResponse { status, headers, reader, chunked, remaining, done: false })
}

/// Send a request and hand back the response head plus a chunk-at-a-time
/// body reader — the transport of the router's streaming proxy.
pub fn request_streaming(
    addr: &str,
    method: &str,
    path: &str,
    body: &[u8],
    opts: ClientOpts,
) -> io::Result<StreamingResponse<BufReader<TcpStream>>> {
    let mut stream = connect(addr, opts)?;
    send_request_head(&mut stream, addr, method, path, body)?;
    read_response_streaming(BufReader::new(stream))
        .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(s: &str) -> Result<Request, ParseError> {
        read_request(&mut Cursor::new(s.as_bytes().to_vec()), DEFAULT_MAX_BODY)
    }

    #[test]
    fn parses_get_with_headers() {
        let req = parse("GET /stats?v=1 HTTP/1.1\r\nHost: x\r\nX-Thing: a b\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/stats?v=1");
        assert_eq!(req.path(), "/stats");
        assert_eq!(req.version, "HTTP/1.1");
        // Header names are lowercased; lookup is case-insensitive.
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("X-THING"), Some("a b"));
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_body_by_content_length() {
        let req = parse("POST /v1/generate HTTP/1.1\r\ncontent-length: 4\r\n\r\nabcd").unwrap();
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn keep_alive_negotiation() {
        let close = parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!close.keep_alive());
        let old = parse("GET / HTTP/1.0\r\n\r\n").unwrap();
        assert!(!old.keep_alive(), "HTTP/1.0 defaults to close");
        let old_ka = parse("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(old_ka.keep_alive());
    }

    #[test]
    fn malformed_request_lines_rejected() {
        let bads = ["GARBAGE\r\n\r\n", "GET /\r\n\r\n", "GET / HTTP/1.1 X\r\n\r\n"];
        for bad in bads {
            match parse(bad) {
                Err(ParseError::BadRequestLine(_)) => {}
                other => panic!("{bad:?}: expected BadRequestLine, got {other:?}"),
            }
        }
        // The version token must be HTTP/x.
        match parse("GET / FTP/1\r\n\r\n") {
            Err(ParseError::BadRequestLine(_)) => {}
            other => panic!("expected BadRequestLine, got {other:?}"),
        }
    }

    #[test]
    fn malformed_headers_rejected() {
        match parse("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n") {
            Err(ParseError::BadHeader(_)) => {}
            other => panic!("expected BadHeader, got {other:?}"),
        }
        match parse("GET / HTTP/1.1\r\ncontent-length: two\r\n\r\n") {
            Err(ParseError::BadContentLength(_)) => {}
            other => panic!("expected BadContentLength, got {other:?}"),
        }
    }

    #[test]
    fn oversized_body_rejected_before_reading_it() {
        let head = "POST / HTTP/1.1\r\ncontent-length: 999999\r\n\r\n";
        match read_request(&mut Cursor::new(head.as_bytes().to_vec()), 1024) {
            Err(ParseError::BodyTooLarge { len: 999999, limit: 1024 }) => {}
            other => panic!("expected BodyTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn chunked_request_bodies_rejected() {
        match parse("POST / HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n") {
            Err(ParseError::UnsupportedTransferEncoding) => {}
            other => panic!("expected UnsupportedTransferEncoding, got {other:?}"),
        }
    }

    #[test]
    fn keep_alive_parses_back_to_back_requests() {
        let two = "GET /healthz HTTP/1.1\r\n\r\nPOST /x HTTP/1.1\r\ncontent-length: 2\r\n\r\nhi";
        let mut cur = Cursor::new(two.as_bytes().to_vec());
        let a = read_request(&mut cur, DEFAULT_MAX_BODY).unwrap();
        assert_eq!(a.path(), "/healthz");
        let b = read_request(&mut cur, DEFAULT_MAX_BODY).unwrap();
        assert_eq!(b.path(), "/x");
        assert_eq!(b.body, b"hi");
        // The connection then ends cleanly.
        match read_request(&mut cur, DEFAULT_MAX_BODY) {
            Err(ParseError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn fixed_response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, 429, "application/json", b"{\"error\":\"full\"}", true).unwrap();
        let resp = read_response(&mut Cursor::new(buf)).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("content-type"), Some("application/json"));
        assert_eq!(resp.text(), "{\"error\":\"full\"}");
    }

    #[test]
    fn chunked_response_roundtrip() {
        let mut buf = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut buf, 200, "application/json", false).unwrap();
            cw.chunk(b"{\"token\":1}\n").unwrap();
            cw.chunk(b"").unwrap(); // skipped, must not terminate the stream
            cw.chunk(b"{\"done\":true}\n").unwrap();
            cw.finish().unwrap();
        }
        let resp = read_response(&mut Cursor::new(buf)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.text(), "{\"token\":1}\n{\"done\":true}\n");
    }

    #[test]
    fn extra_headers_are_emitted_and_parsed_back() {
        let mut buf = Vec::new();
        let extra = [("retry-after", "1")];
        write_response_with(&mut buf, 503, "application/json", &extra, b"{}", false).unwrap();
        let resp = read_response(&mut Cursor::new(buf)).unwrap();
        assert_eq!(resp.status, 503);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.text(), "{}");
    }

    #[test]
    fn gateway_statuses_have_reasons() {
        assert_eq!(reason(502), "Bad Gateway");
        assert_eq!(reason(504), "Gateway Timeout");
    }

    #[test]
    fn streaming_reader_yields_chunks_one_at_a_time() {
        let mut buf = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut buf, 200, "application/json", false).unwrap();
            cw.chunk(b"{\"token\":1}\n").unwrap();
            cw.chunk(b"{\"done\":true}\n").unwrap();
            cw.finish().unwrap();
        }
        let mut sr = read_response_streaming(Cursor::new(buf)).unwrap();
        assert_eq!(sr.status, 200);
        assert_eq!(sr.header("transfer-encoding"), Some("chunked"));
        assert_eq!(sr.next_chunk().unwrap().as_deref(), Some(&b"{\"token\":1}\n"[..]));
        assert_eq!(sr.next_chunk().unwrap().as_deref(), Some(&b"{\"done\":true}\n"[..]));
        assert!(sr.next_chunk().unwrap().is_none());
        assert!(sr.next_chunk().unwrap().is_none(), "stays done after the last chunk");
    }

    #[test]
    fn streaming_reader_handles_fixed_length_bodies() {
        let mut buf = Vec::new();
        write_response(&mut buf, 429, "application/json", b"{\"error\":\"full\"}", false).unwrap();
        let mut sr = read_response_streaming(Cursor::new(buf)).unwrap();
        assert_eq!(sr.status, 429);
        let body = sr.next_chunk().unwrap().unwrap();
        assert_eq!(body, b"{\"error\":\"full\"}");
        assert!(sr.next_chunk().unwrap().is_none());
    }

    #[test]
    fn streaming_reader_surfaces_a_truncated_stream_as_an_error() {
        // A dangling chunked body (no terminating 0-chunk) must surface
        // as Io, not silently end — the proxy relays it as a mid-stream
        // upstream failure.
        let mut buf = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut buf, 200, "application/json", false).unwrap();
            cw.chunk(b"{\"token\":1}\n").unwrap();
            // no finish(): the upstream died mid-stream
        }
        let mut sr = read_response_streaming(Cursor::new(buf)).unwrap();
        assert_eq!(sr.next_chunk().unwrap().as_deref(), Some(&b"{\"token\":1}\n"[..]));
        assert!(sr.next_chunk().is_err(), "truncated stream must error");
    }
}
