//! HTTP serving front end: the network face of the O(1)-state engine.
//!
//! The paper's serving argument (and the ROADMAP north star) is that an
//! EFLA slot costs the same at token 1 and token 100,000 — no KV cache,
//! just fixed-size recurrent state. This module puts traffic on that
//! property: a std-only HTTP/1.1 server (no new dependencies —
//! `std::net::TcpListener` + scoped threads) in front of the
//! continuously batched engine of [`engine`].
//!
//! * [`http`]   — request parsing, fixed and chunked response writers,
//!   and a tiny client (tests/examples).
//! * [`engine`] — the continuous-batching loop: bounded admission queue,
//!   per-request event channels, graceful drain.
//! * this file  — [`Frontend`]: bind, accept loop, connection workers,
//!   routing, `/stats` JSON, and SIGINT/SIGTERM handling.
//!
//! ## Endpoints
//!
//! * `POST /v1/generate` — JSON body with `prompt` (string, byte-level
//!   tokens) or `tokens` (int array), optional `max_tokens`,
//!   `temperature`, `id`, `stream`. Non-streamed: one JSON object.
//!   Streamed: `Transfer-Encoding: chunked`, one JSON line per token,
//!   then a final line with `"done": true` and the full result.
//! * `GET /stats`   — engine/queue/latency counters as JSON.
//! * `GET /healthz` — readiness probe: 200 while admitting, **503** with
//!   `"status": "draining"` once shutdown began and `"saturated"` while
//!   the admission queue is full — the router stops routing to a replica
//!   that cannot admit work.
//! * `POST /fault`  — swap the fault-injection spec of a running front
//!   end (body: the [`fault::FaultSpec`] grammar; chaos harness only).
//! * `GET`/`PUT /v1/state/{session}` — export / import one *parked*
//!   state-cache entry as the checkpoint-layout wire form
//!   ([`state_cache::CachedState::to_wire`]). The router's failover
//!   migration path; GET consumes the entry (exclusive ownership moves
//!   with the bytes).
//!
//! ## Error envelope (v1)
//!
//! Every non-2xx JSON response — engine *and* router — uses one shape:
//! `{"error": {"code": "<stable_snake_case>", "message": "...",
//! "retry_after_ms": <int, optional>}}`. [`ErrorCode`] is the single
//! code → status mapping table both front ends share. `/stats` bodies
//! carry `"schema_version": 2`.
//!
//! Backpressure: the admission queue holds at most
//! [`ServerConfig::queue_depth`] waiting requests (decode slots are extra
//! capacity); beyond that `POST /v1/generate` answers **429** without
//! touching the engine. Shutdown (SIGTERM/SIGINT or the
//! [`Frontend::shutdown_flag`]): keep accepting (so probes observe the
//! draining status), answer new generates 503, drain accepted work
//! within [`ServerConfig::drain_timeout_secs`], then return.
//!
//! Threading: a [`crate::coordinator::session::Session`] is not `Sync`,
//! so [`Frontend::run`] keeps the engine on the calling thread and spawns
//! the accept loop plus one worker per connection as scoped threads —
//! when `run` returns, no thread of the front end is left behind.

pub mod engine;
pub mod fault;
pub mod http;
pub mod router;
pub mod state_cache;

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::Scope;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::server::{GenRequest, GenResult, ServerConfig, ServerStats, SubmitError};
use crate::coordinator::session::Session;
use crate::util::json::{self, Json};

use engine::{EngineShared, Event, Submission};
use fault::{FaultInjector, FaultSpec};
use http::{ChunkedWriter, ParseError, Request};
use state_cache::CachedState;

/// `/stats` schema version, bumped whenever a field is renamed or moved.
/// Present on engine and router stats bodies alike.
pub const STATS_SCHEMA_VERSION: u64 = 2;

/// Stable error codes of the unified v1 error envelope.
///
/// Every non-2xx JSON response from the engine front end *and* the
/// router renders as `{"error": {"code", "message", "retry_after_ms"?}}`
/// via [`ErrorCode::envelope`]; this enum is the single code →
/// HTTP-status mapping table both share, so the two front ends cannot
/// drift apart. Codes are stable API: clients switch on `code`, never
/// on `message`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Malformed request (bad JSON, bad field, bad path segment).
    BadRequest,
    /// Request body exceeded the configured cap.
    BodyTooLarge,
    /// No route for this method + path.
    NotFound,
    /// Route exists, method does not.
    MethodNotAllowed,
    /// Engine refusal: empty prompt.
    EmptyPrompt,
    /// Engine refusal: `max_tokens` of 0.
    ZeroMaxTokens,
    /// Engine refusal: a request with this id is already in flight.
    DuplicateId,
    /// Admission queue full — retry after backoff.
    QueueFull,
    /// Shutdown began; new work is refused while accepted work drains.
    ShuttingDown,
    /// The engine loop is gone (post-drain or crashed).
    EngineStopped,
    /// Accepted, then abandoned by the drain deadline.
    RequestDropped,
    /// Fault-injection layer produced this error (chaos runs only).
    InjectedFault,
    /// Connection cap reached; bounced before a worker was spawned.
    TooManyConnections,
    /// `GET /v1/state/{session}`: no parked entry for that session.
    SessionNotFound,
    /// State transfer endpoints with the cache disabled or not yet up.
    StateCacheDisabled,
    /// `PUT /v1/state/{session}`: body failed wire-form validation.
    InvalidStatePayload,
    /// `/healthz` during shutdown.
    Draining,
    /// `/healthz` while the admission queue is full.
    Saturated,
    /// Router: the client's `timeout_ms` budget expired.
    DeadlineExceeded,
    /// Router: every routable replica was tried and failed.
    AllReplicasFailed,
    /// Router: no routable replica (all ejected or saturated).
    ReplicasSaturated,
}

impl ErrorCode {
    /// The stable snake_case wire code.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::BodyTooLarge => "body_too_large",
            ErrorCode::NotFound => "not_found",
            ErrorCode::MethodNotAllowed => "method_not_allowed",
            ErrorCode::EmptyPrompt => "empty_prompt",
            ErrorCode::ZeroMaxTokens => "zero_max_tokens",
            ErrorCode::DuplicateId => "duplicate_id",
            ErrorCode::QueueFull => "queue_full",
            ErrorCode::ShuttingDown => "shutting_down",
            ErrorCode::EngineStopped => "engine_stopped",
            ErrorCode::RequestDropped => "request_dropped",
            ErrorCode::InjectedFault => "injected_fault",
            ErrorCode::TooManyConnections => "too_many_connections",
            ErrorCode::SessionNotFound => "session_not_found",
            ErrorCode::StateCacheDisabled => "state_cache_disabled",
            ErrorCode::InvalidStatePayload => "invalid_state_payload",
            ErrorCode::Draining => "draining",
            ErrorCode::Saturated => "saturated",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::AllReplicasFailed => "all_replicas_failed",
            ErrorCode::ReplicasSaturated => "replicas_saturated",
        }
    }

    /// The HTTP status this code always ships with.
    pub fn status(self) -> u16 {
        match self {
            ErrorCode::BadRequest
            | ErrorCode::EmptyPrompt
            | ErrorCode::ZeroMaxTokens
            | ErrorCode::InvalidStatePayload => 400,
            ErrorCode::NotFound
            | ErrorCode::SessionNotFound
            | ErrorCode::StateCacheDisabled => 404,
            ErrorCode::MethodNotAllowed => 405,
            ErrorCode::DuplicateId => 409,
            ErrorCode::BodyTooLarge => 413,
            ErrorCode::QueueFull => 429,
            ErrorCode::InjectedFault => 500,
            ErrorCode::AllReplicasFailed => 502,
            ErrorCode::ShuttingDown
            | ErrorCode::EngineStopped
            | ErrorCode::RequestDropped
            | ErrorCode::TooManyConnections
            | ErrorCode::Draining
            | ErrorCode::Saturated
            | ErrorCode::ReplicasSaturated => 503,
            ErrorCode::DeadlineExceeded => 504,
        }
    }

    /// Retry hint for transient saturation codes.
    pub fn retry_after_ms(self) -> Option<u64> {
        match self {
            ErrorCode::QueueFull | ErrorCode::ReplicasSaturated => Some(1000),
            _ => None,
        }
    }

    /// The inner `{"code", "message", "retry_after_ms"?}` object.
    pub fn body(self, msg: &str) -> Json {
        let mut fields = vec![
            ("code", Json::Str(self.as_str().to_string())),
            ("message", Json::Str(msg.to_string())),
        ];
        if let Some(ms) = self.retry_after_ms() {
            fields.push(("retry_after_ms", Json::Num(ms as f64)));
        }
        Json::obj(fields)
    }

    /// The full `{"error": {...}}` envelope object for this code.
    pub fn envelope(self, msg: &str) -> Json {
        Json::obj(vec![("error", self.body(msg))])
    }
}

/// Soft cap on concurrently served connections; beyond it new arrivals
/// get an immediate 503 instead of a worker thread.
const MAX_CONNECTIONS: usize = 512;

/// Server-side ceiling on `max_tokens` per request. Slots are only freed
/// when a generation reaches its budget, so an unbounded client value
/// could pin a slot (and survive the client's disconnect) indefinitely.
const MAX_TOKENS_LIMIT: usize = 4096;

/// Auto-assigned request ids start here; client-supplied ids must stay
/// below it, so the two ranges can never collide — a client that never
/// sets an id can never be bounced with a spurious duplicate-id 409.
/// 2^48 keeps every id exactly representable in the JSON f64 substrate.
const AUTO_ID_BASE: u64 = 1 << 48;

/// Latency samples retained per metric for the `/stats` percentiles.
const LATENCY_SAMPLES: usize = 4096;

/// Ceiling on a client `session_id` key. Keys are stored verbatim in the
/// state cache (and hashed into spill filenames), so an unbounded key
/// would let clients inflate the cache's bookkeeping for free.
const MAX_SESSION_ID_BYTES: usize = 128;

/// Process-wide flag set by SIGINT/SIGTERM once
/// [`install_signal_handlers`] ran. The accept loop propagates it into
/// the per-frontend shutdown flag.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

/// Install SIGINT + SIGTERM handlers that request a graceful drain.
///
/// std has no signal API and the vendor set has no `libc`/`ctrlc` crate,
/// so this binds `signal(2)` from the platform C library directly. The
/// handler is async-signal-safe: it only stores to an atomic.
#[cfg(unix)]
pub fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        SIGNALLED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: signal(2) is called with a handler of the matching C ABI
    // (cast through usize, the declared parameter type); the handler body
    // only stores to an atomic, which is async-signal-safe.
    unsafe {
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
    }
}

/// Non-unix builds: signals are not wired; use the shutdown flag.
#[cfg(not(unix))]
pub fn install_signal_handlers() {}

/// Shared context of all connection workers.
struct ConnCtx {
    engine_tx: mpsc::SyncSender<Submission>,
    shared: Arc<EngineShared>,
    shutdown: Arc<AtomicBool>,
    /// Set after the engine loop returned: the accept loop (which keeps
    /// serving probes through the drain) exits on it.
    engine_done: Arc<AtomicBool>,
    fault: Arc<FaultInjector>,
    next_id: AtomicU64,
    conns: AtomicUsize,
    slots: usize,
    queue_depth: usize,
}

/// A bound-but-not-yet-serving HTTP front end. Two-phase so callers
/// (tests, the smoke driver) can learn the OS-assigned port of
/// `127.0.0.1:0` and grab the shutdown flag before the blocking serve
/// loop starts.
pub struct Frontend {
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    fault: Arc<FaultInjector>,
}

impl Frontend {
    /// Bind `listen` (e.g. `127.0.0.1:8080`, or port `0` for an
    /// OS-assigned port).
    pub fn bind(listen: &str) -> Result<Frontend> {
        let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        listener.set_nonblocking(true).context("listener nonblocking")?;
        Ok(Frontend {
            listener,
            shutdown: Arc::new(AtomicBool::new(false)),
            fault: Arc::new(FaultInjector::disabled()),
        })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Flag that ends [`Frontend::run`] with a graceful drain. Signals
    /// set it too (via [`install_signal_handlers`]).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Arm the fault-injection layer (the `--fault` / `EFLA_FAULT` spec;
    /// also swappable at runtime through `POST /fault`).
    pub fn set_fault_spec(&self, spec: FaultSpec) {
        self.fault.set_spec(spec);
    }

    /// Handle to the fault layer (tests drive it directly).
    pub fn fault_injector(&self) -> Arc<FaultInjector> {
        self.fault.clone()
    }

    /// Serve until shutdown (blocking). The engine runs on the calling
    /// thread; accept loop and connection workers are scoped threads, so
    /// everything is joined when this returns.
    pub fn run(self, session: &Session, cfg: ServerConfig, seed: u64) -> Result<ServerStats> {
        let queue_depth = cfg.queue_depth.max(1);
        let slots = session.decode_batch()?;
        let addr = self.local_addr()?;
        let (tx, rx) = mpsc::sync_channel::<Submission>(queue_depth);
        let shared = Arc::new(EngineShared::with_fault(LATENCY_SAMPLES, self.fault.clone()));
        let engine_done = Arc::new(AtomicBool::new(false));
        let ctx = ConnCtx {
            engine_tx: tx,
            shared: shared.clone(),
            shutdown: self.shutdown.clone(),
            engine_done: engine_done.clone(),
            fault: self.fault.clone(),
            next_id: AtomicU64::new(1),
            conns: AtomicUsize::new(0),
            slots,
            queue_depth,
        };
        // Machine-readable readiness line on stdout: scripts/serve_smoke.py
        // and the integration tests key on it (logs go to stderr).
        println!("SERVE listening on {addr}");
        std::io::stdout().flush().ok();
        log::info!(
            "serving on http://{addr} ({} slots, queue depth {}, drain timeout {:.1}s)",
            slots,
            queue_depth,
            cfg.drain_timeout_secs
        );
        let listener = self.listener;
        let shutdown = self.shutdown;
        let stats = std::thread::scope(|s| {
            let ctx = &ctx;
            let listener = &listener;
            s.spawn(move || accept_loop(s, listener, ctx));
            let stats = engine::run_engine(session, cfg, seed, rx, &shared, &shutdown);
            // Unblock the accept loop and any keep-alive workers even when
            // the engine exits on an error. The accept loop serves probes
            // (healthz = draining) until the engine is done, then exits.
            shutdown.store(true, Ordering::SeqCst);
            engine_done.store(true, Ordering::SeqCst);
            stats
        })?;
        log::info!(
            "served {} requests ({} rejected) in {:.1}s",
            stats.completed,
            shared.rejected.load(Ordering::SeqCst),
            stats.wall_secs
        );
        Ok(stats)
    }
}

fn accept_loop<'scope, 'env>(
    scope: &'scope Scope<'scope, 'env>,
    listener: &'scope TcpListener,
    ctx: &'scope ConnCtx,
) {
    loop {
        if SIGNALLED.load(Ordering::SeqCst) {
            ctx.shutdown.store(true, Ordering::SeqCst);
        }
        // Keep accepting through the drain — probes must observe the
        // draining healthz status — and exit once the engine returned.
        if ctx.engine_done.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                if ctx.conns.load(Ordering::SeqCst) >= MAX_CONNECTIONS {
                    let mut stream = stream;
                    let _ = http::write_response(
                        &mut stream,
                        503,
                        "application/json",
                        b"{\"error\":{\"code\":\"too_many_connections\",\
                          \"message\":\"too many connections\"}}",
                        false,
                    );
                    continue;
                }
                ctx.conns.fetch_add(1, Ordering::SeqCst);
                scope.spawn(move || {
                    handle_conn(stream, ctx);
                    ctx.conns.fetch_sub(1, Ordering::SeqCst);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                log::warn!("accept error: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn handle_conn(stream: TcpStream, ctx: &ConnCtx) {
    if let Err(e) = serve_conn(stream, ctx) {
        log::debug!("connection ended: {e:#}");
    }
}

fn serve_conn(stream: TcpStream, ctx: &ConnCtx) -> Result<()> {
    // Fault layer: a refusing (or dead) replica drops the socket before
    // reading a byte — the client sees a reset/closed connection.
    if ctx.fault.refuse_connection() {
        return Ok(());
    }
    // Accepted sockets may inherit the listener's non-blocking mode on
    // some platforms; force blocking + timeouts.
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    loop {
        let req = match http::read_request(&mut reader, http::DEFAULT_MAX_BODY) {
            Ok(req) => req,
            Err(ParseError::Closed) => return Ok(()),
            Err(ParseError::IdleTimeout) => {
                if ctx.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                continue;
            }
            Err(ParseError::Io(_)) => return Ok(()),
            Err(e @ ParseError::BodyTooLarge { .. }) => {
                respond_error(&mut writer, ErrorCode::BodyTooLarge, &e.to_string(), false)?;
                return Ok(());
            }
            Err(e) => {
                respond_error(&mut writer, ErrorCode::BadRequest, &e.to_string(), false)?;
                return Ok(());
            }
        };
        // Fault layer: stall every parsed request (healthz included — a
        // stalled replica must look stalled to the health prober).
        ctx.fault.stall();
        let keep = req.keep_alive() && !ctx.shutdown.load(Ordering::SeqCst);
        route(&mut writer, &req, keep, ctx)?;
        if !keep {
            return Ok(());
        }
    }
}

/// The `/healthz` body: `ok` plus a `status` of `"ok"`, `"draining"`
/// (shutdown began) or `"saturated"` (admission queue full). The latter
/// two answer 503 so a router health check stops routing here; their
/// bodies also carry the v1 error envelope (same `code` as `status`)
/// alongside the probe fields.
fn healthz(w: &mut TcpStream, keep: bool, ctx: &ConnCtx) -> Result<()> {
    let not_ok = if ctx.shutdown.load(Ordering::SeqCst) {
        Some(ErrorCode::Draining)
    } else if ctx.shared.queue_depth() >= ctx.queue_depth {
        Some(ErrorCode::Saturated)
    } else {
        None
    };
    let (status, ok, state) = match not_ok {
        Some(code) => (code.status(), false, code.as_str()),
        None => (200, true, "ok"),
    };
    let mut fields = vec![
        ("ok", Json::Bool(ok)),
        ("status", Json::Str(state.to_string())),
        ("slots", Json::Num(ctx.slots as f64)),
    ];
    if let Some(code) = not_ok {
        fields.push(("error", code.body(&format!("replica is {state}"))));
    }
    respond_json(w, status, &Json::obj(fields), keep)
}

fn handle_set_fault(w: &mut TcpStream, req: &Request, keep: bool, ctx: &ConnCtx) -> Result<()> {
    let body = match std::str::from_utf8(&req.body) {
        Ok(b) => b,
        Err(_) => return respond_error(w, ErrorCode::BadRequest, "fault spec must be UTF-8", keep),
    };
    match FaultSpec::parse(body.trim()) {
        Ok(spec) => {
            log::warn!("fault spec set to {spec:?}");
            ctx.fault.set_spec(spec);
            respond_json(w, 200, &Json::obj(vec![("ok", Json::Bool(true))]), keep)
        }
        Err(msg) => respond_error(w, ErrorCode::BadRequest, &msg, keep),
    }
}

/// `GET`/`PUT /v1/state/{session}` — the router's migration transport.
///
/// GET exports one *parked* cache entry as the checkpoint-layout wire
/// form and **consumes** it (exclusive ownership moves with the bytes,
/// exactly like a seated turn's `take`); PUT validates the wire form and
/// parks it here. No shutdown gate: a draining replica must keep
/// exporting so its sessions can move before it exits.
fn handle_state_transfer(
    w: &mut TcpStream,
    req: &Request,
    keep: bool,
    ctx: &ConnCtx,
) -> Result<()> {
    let session = &req.path()["/v1/state/".len()..];
    if session.is_empty() {
        return respond_error(w, ErrorCode::BadRequest, "empty session id", keep);
    }
    if session.len() > MAX_SESSION_ID_BYTES {
        let msg = format!("session id must be at most {MAX_SESSION_ID_BYTES} bytes");
        return respond_error(w, ErrorCode::BadRequest, &msg, keep);
    }
    let Some(cache) = ctx.shared.state_cache() else {
        // The engine publishes its handle right after it starts; until
        // then (or with the cache sized 0) there is nothing to transfer.
        return respond_error(w, ErrorCode::StateCacheDisabled, "state cache not available", keep);
    };
    let mut guard = cache.lock().expect("state cache lock");
    if !guard.enabled() {
        drop(guard);
        let msg = "state cache disabled (--state-cache-bytes 0)";
        return respond_error(w, ErrorCode::StateCacheDisabled, msg, keep);
    }
    match req.method.as_str() {
        "GET" => match guard.take_any(session) {
            Some(state) => {
                let body = state.to_wire();
                drop(guard);
                http::write_response(w, 200, "application/octet-stream", &body, keep)?;
                Ok(())
            }
            None => {
                drop(guard);
                let msg = format!("no parked state for session {session}");
                respond_error(w, ErrorCode::SessionNotFound, &msg, keep)
            }
        },
        // route() only forwards GET | PUT here.
        _ => match CachedState::from_wire(&req.body) {
            Ok(state) => {
                guard.insert(session, state);
                drop(guard);
                respond_json(w, 200, &Json::obj(vec![("ok", Json::Bool(true))]), keep)
            }
            Err(e) => {
                drop(guard);
                respond_error(w, ErrorCode::InvalidStatePayload, &format!("{e:#}"), keep)
            }
        },
    }
}

fn route(w: &mut TcpStream, req: &Request, keep: bool, ctx: &ConnCtx) -> Result<()> {
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => healthz(w, keep, ctx),
        ("GET", "/stats") => respond_json(w, 200, &stats_json(ctx), keep),
        ("POST", "/v1/generate") => handle_generate(w, req, keep, ctx),
        ("POST", "/fault") => handle_set_fault(w, req, keep, ctx),
        ("GET" | "PUT", p) if p.starts_with("/v1/state/") => {
            handle_state_transfer(w, req, keep, ctx)
        }
        ("GET" | "HEAD", "/v1/generate") => {
            respond_error(w, ErrorCode::MethodNotAllowed, "use POST", keep)
        }
        (m, p) if p.starts_with("/v1/state/") => {
            respond_error(w, ErrorCode::MethodNotAllowed, &format!("no route {m} {p}"), keep)
        }
        (m, p) => respond_error(w, ErrorCode::NotFound, &format!("no route {m} {p}"), keep),
    }
}

fn respond_json(w: &mut TcpStream, status: u16, body: &Json, keep: bool) -> Result<()> {
    let text = body.to_string();
    http::write_response(w, status, "application/json", text.as_bytes(), keep)?;
    Ok(())
}

fn respond_error(w: &mut TcpStream, code: ErrorCode, msg: &str, keep: bool) -> Result<()> {
    respond_json(w, code.status(), &code.envelope(msg), keep)
}

fn respond_submit_error(w: &mut TcpStream, e: &SubmitError, keep: bool) -> Result<()> {
    let code = match e {
        SubmitError::DuplicateId { .. } => ErrorCode::DuplicateId,
        SubmitError::EmptyPrompt { .. } => ErrorCode::EmptyPrompt,
        SubmitError::ZeroMaxNew { .. } => ErrorCode::ZeroMaxTokens,
    };
    respond_error(w, code, &e.to_string(), keep)
}

/// Byte-level models: render a token as its printable ASCII char.
fn printable(t: i32) -> char {
    if (32..127).contains(&t) {
        (t as u8) as char
    } else {
        '?'
    }
}

fn result_json(res: &GenResult, done_marker: bool) -> Json {
    let text: String = res.tokens.iter().map(|&t| printable(t)).collect();
    let toks = Json::Arr(res.tokens.iter().map(|&t| Json::Num(t as f64)).collect());
    let mut fields = vec![
        ("id", Json::Num(res.id as f64)),
        ("tokens", toks),
        ("text", Json::Str(text)),
        ("steps", Json::Num(res.steps as f64)),
        ("finish_reason", Json::Str(res.finish_reason.as_str().to_string())),
        ("ttft_ms", Json::Num(res.ttft_secs * 1e3)),
        ("queue_ms", Json::Num(res.queue_wait_secs * 1e3)),
        ("e2e_ms", Json::Num(res.e2e_secs * 1e3)),
    ];
    if done_marker {
        fields.push(("done", Json::Bool(true)));
    }
    Json::obj(fields)
}

fn stats_json(ctx: &ConnCtx) -> Json {
    let s = ctx.shared.server_stats();
    let (qw, e2e) = ctx.shared.latency_summaries();
    Json::obj(vec![
        ("schema_version", Json::Num(STATS_SCHEMA_VERSION as f64)),
        ("slots", Json::Num(ctx.slots as f64)),
        ("threads", Json::Num(s.threads as f64)),
        ("queue_depth", Json::Num(ctx.shared.queue_depth() as f64)),
        ("accepted", Json::Num(ctx.shared.accepted.load(Ordering::SeqCst) as f64)),
        ("rejected", Json::Num(ctx.shared.rejected.load(Ordering::SeqCst) as f64)),
        ("admitted", Json::Num(s.admitted as f64)),
        ("completed", Json::Num(s.completed as f64)),
        ("timed_out", Json::Num(s.timed_out as f64)),
        ("draining", Json::Bool(ctx.shutdown.load(Ordering::SeqCst))),
        ("engine_steps", Json::Num(s.engine_steps as f64)),
        ("prefill_tokens", Json::Num(s.prefill_tokens as f64)),
        ("decode_tokens", Json::Num(s.decode_tokens as f64)),
        ("tokens_processed", Json::Num(s.tokens_processed as f64)),
        ("tokens_per_sec", Json::Num(s.tokens_per_sec())),
        ("utilization", Json::Num(s.utilization())),
        ("mean_ttft_ms", Json::Num(s.mean_ttft_secs() * 1e3)),
        ("mean_queue_wait_ms", Json::Num(s.mean_queue_wait_secs() * 1e3)),
        ("mean_e2e_ms", Json::Num(s.mean_e2e_secs() * 1e3)),
        ("p50_queue_wait_ms", Json::Num(qw.p50_secs * 1e3)),
        ("p95_queue_wait_ms", Json::Num(qw.p95_secs * 1e3)),
        ("p50_e2e_ms", Json::Num(e2e.p50_secs * 1e3)),
        ("p95_e2e_ms", Json::Num(e2e.p95_secs * 1e3)),
        (
            "state_cache",
            Json::obj(vec![
                ("hits", Json::Num(s.cache_hits as f64)),
                ("misses", Json::Num(s.cache_misses as f64)),
                ("evictions", Json::Num(s.cache_evictions as f64)),
                ("spills", Json::Num(s.cache_spills as f64)),
                ("disk_hits", Json::Num(s.cache_disk_hits as f64)),
                ("entries", Json::Num(s.cache_entries as f64)),
                ("bytes", Json::Num(s.cache_bytes as f64)),
            ]),
        ),
    ])
}

/// A parsed `POST /v1/generate` body.
struct ParsedGenerate {
    req: GenRequest,
    stream: bool,
    /// Client deadline budget (`timeout_ms`), turned into an absolute
    /// [`GenRequest::deadline`] against the arrival timestamp.
    timeout_ms: Option<u64>,
}

/// Parse the generate body into a request; `Err(msg)` maps to a 400.
fn parse_generate(j: &Json, ctx: &ConnCtx) -> std::result::Result<ParsedGenerate, String> {
    let prompt: Vec<i32> = if let Some(s) = j.get("prompt").as_str() {
        s.bytes().map(|b| b as i32).collect()
    } else if let Some(arr) = j.get("tokens").as_arr() {
        let mut toks = Vec::with_capacity(arr.len());
        for v in arr {
            match v.as_i64() {
                Some(x) => toks.push(x as i32),
                None => return Err("tokens must be an array of integers".into()),
            }
        }
        toks
    } else {
        return Err("body needs 'prompt' (string) or 'tokens' (int array)".into());
    };
    if prompt.is_empty() {
        return Err("empty prompt".into());
    }
    let max_new = match j.get("max_tokens") {
        Json::Null => 32,
        v => v.as_usize().ok_or("max_tokens must be a non-negative integer")?,
    };
    if max_new == 0 {
        return Err("max_tokens must be at least 1".into());
    }
    if max_new > MAX_TOKENS_LIMIT {
        return Err(format!("max_tokens must be at most {MAX_TOKENS_LIMIT}"));
    }
    let temperature = j.get("temperature").as_f64().unwrap_or(0.0) as f32;
    let stream = j.get("stream").as_bool().unwrap_or(false);
    let timeout_ms = match j.get("timeout_ms") {
        Json::Null => None,
        v => {
            let ms = v.as_usize().ok_or("timeout_ms must be a non-negative integer")? as u64;
            if ms == 0 {
                return Err("timeout_ms must be at least 1".into());
            }
            Some(ms)
        }
    };
    let id = match j.get("id") {
        Json::Null => AUTO_ID_BASE + ctx.next_id.fetch_add(1, Ordering::SeqCst),
        v => {
            let id = v.as_usize().ok_or("id must be a non-negative integer")? as u64;
            if id >= AUTO_ID_BASE {
                return Err(format!("id must be below {AUTO_ID_BASE} (reserved range)"));
            }
            id
        }
    };
    let session_id = match j.get("session_id") {
        Json::Null => None,
        v => {
            let sid = if let Some(s) = v.as_str() {
                s.to_string()
            } else if let Some(n) = v.as_usize() {
                // Integer keys are accepted and normalized to their
                // decimal string — "42" and 42 name the same session.
                n.to_string()
            } else {
                return Err("session_id must be a string or non-negative integer".into());
            };
            if sid.is_empty() {
                return Err("session_id must not be empty".into());
            }
            if sid.len() > MAX_SESSION_ID_BYTES {
                return Err(format!("session_id must be at most {MAX_SESSION_ID_BYTES} bytes"));
            }
            Some(sid)
        }
    };
    let req = GenRequest { id, prompt, max_new, temperature, deadline: None, session_id };
    Ok(ParsedGenerate { req, stream, timeout_ms })
}

fn handle_generate(w: &mut TcpStream, req: &Request, keep: bool, ctx: &ConnCtx) -> Result<()> {
    let submitted = Instant::now();
    let body = match std::str::from_utf8(&req.body) {
        Ok(b) => b,
        Err(_) => return respond_error(w, ErrorCode::BadRequest, "body must be UTF-8 JSON", keep),
    };
    let j = match json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return respond_error(w, ErrorCode::BadRequest, &format!("invalid JSON body: {e}"), keep)
        }
    };
    let parsed = match parse_generate(&j, ctx) {
        Ok(parsed) => parsed,
        Err(msg) => return respond_error(w, ErrorCode::BadRequest, &msg, keep),
    };
    let ParsedGenerate { mut req, stream, timeout_ms } = parsed;
    if let Some(ms) = timeout_ms {
        req.deadline = Some(submitted + Duration::from_millis(ms));
    }
    // Fault layer: count the request toward die_after; maybe inject a 500.
    if ctx.fault.on_generate() {
        return respond_error(w, ErrorCode::InjectedFault, "injected fault", keep);
    }
    if ctx.shutdown.load(Ordering::SeqCst) {
        return respond_error(w, ErrorCode::ShuttingDown, "shutting down", false);
    }
    let (ev_tx, ev_rx) = mpsc::channel::<Event>();
    let sub = Submission { req, submitted, stream, events: ev_tx };
    match ctx.engine_tx.try_send(sub) {
        Ok(()) => ctx.shared.note_accepted(),
        Err(mpsc::TrySendError::Full(_)) => {
            ctx.shared.note_rejected();
            let code = ErrorCode::QueueFull;
            return respond_error(w, code, "admission queue full, retry later", keep);
        }
        Err(mpsc::TrySendError::Disconnected(_)) => {
            return respond_error(w, ErrorCode::EngineStopped, "engine stopped", false);
        }
    }
    if stream {
        stream_response(w, &ev_rx, keep, ctx)
    } else {
        // Ignore Token events (none are sent for stream=false submissions)
        // and answer with the terminal event.
        loop {
            match ev_rx.recv() {
                Ok(Event::Token(_)) => continue,
                Ok(Event::Done(res)) => {
                    return respond_json(w, 200, &result_json(&res, false), keep)
                }
                Ok(Event::Rejected(e)) => return respond_submit_error(w, &e, keep),
                Err(_) => {
                    let code = ErrorCode::RequestDropped;
                    return respond_error(w, code, "request dropped during shutdown", false);
                }
            }
        }
    }
}

/// Streamed generate: hold the status line until the first event so a
/// rejection still gets its real status code, then emit one JSON line
/// per token and a final `"done": true` line.
fn stream_response(
    w: &mut TcpStream,
    ev_rx: &mpsc::Receiver<Event>,
    keep: bool,
    ctx: &ConnCtx,
) -> Result<()> {
    let cut_after = ctx.fault.cut_stream_after();
    let mut sent_chunks = 0u64;
    let first = match ev_rx.recv() {
        Ok(ev) => ev,
        Err(_) => {
            let code = ErrorCode::RequestDropped;
            return respond_error(w, code, "request dropped during shutdown", false);
        }
    };
    match first {
        Event::Rejected(e) => respond_submit_error(w, &e, keep),
        ev => {
            let mut cw = ChunkedWriter::start(w, 200, "application/json", keep)?;
            let mut ev = ev;
            loop {
                match ev {
                    Event::Token(t) => {
                        let piece = Json::obj(vec![
                            ("token", Json::Num(t as f64)),
                            ("text", Json::Str(printable(t).to_string())),
                        ]);
                        cw.chunk(format!("{}\n", piece.to_string()).as_bytes())?;
                        sent_chunks += 1;
                        if cut_after > 0 && sent_chunks >= cut_after {
                            // Fault layer: abandon the chunked body with
                            // no terminating 0-chunk and drop the
                            // connection — the client sees a truncated
                            // stream (tokens already on the wire, so a
                            // router must NOT retry this request).
                            anyhow::bail!("fault: stream cut after {cut_after} chunk(s)");
                        }
                    }
                    Event::Done(res) => {
                        let fin = result_json(&res, true);
                        cw.chunk(format!("{}\n", fin.to_string()).as_bytes())?;
                        cw.finish()?;
                        return Ok(());
                    }
                    Event::Rejected(e) => {
                        // Mid-stream rejection cannot happen (submit is
                        // checked before the first token), but terminate
                        // the stream defensively.
                        let err = Json::obj(vec![
                            ("error", Json::Str(e.to_string())),
                            ("done", Json::Bool(true)),
                        ]);
                        cw.chunk(format!("{}\n", err.to_string()).as_bytes())?;
                        cw.finish()?;
                        return Ok(());
                    }
                }
                ev = match ev_rx.recv() {
                    Ok(next) => next,
                    Err(_) => {
                        let err = Json::obj(vec![
                            ("error", Json::Str("request abandoned during shutdown".into())),
                            ("done", Json::Bool(true)),
                        ]);
                        cw.chunk(format!("{}\n", err.to_string()).as_bytes())?;
                        cw.finish()?;
                        return Ok(());
                    }
                };
            }
        }
    }
}
