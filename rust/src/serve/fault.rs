//! Deterministic fault injection for the serving stack.
//!
//! The chaos harness (`scripts/route_chaos.py`), the router unit tests
//! and the CI `route-chaos` job all need *reproducible* failures: a
//! replica that stalls, errors, refuses connections, cuts a token stream
//! mid-flight, or dies after K requests — on demand and seeded, never
//! from real flakiness. [`FaultSpec`] is the parsed `--fault` /
//! `EFLA_FAULT` grammar; [`FaultInjector`] is the shared runtime object
//! threaded into the HTTP worker path (connection refusal, per-request
//! stall, injected 500s, stream cuts) and the engine loop (per-step
//! stall, so deadline abandonment is testable against a slow engine).
//!
//! The spec is runtime-swappable through `POST /fault` on a serving
//! front end, because the chaos script must stall a replica that is
//! already running — relaunching it would reset the very state (slots,
//! queue, stats) the experiment is about.
//!
//! Grammar: comma-separated `key=value` pairs and bare flags, e.g.
//! `stall_ms=250,error_rate=0.5,refuse,die_after=20,seed=7`. Keys:
//!
//! * `stall_ms=N`          — sleep N ms in the worker before handling
//!   any parsed request (health probes included — a stalled replica
//!   must look stalled to the prober);
//! * `engine_stall_ms=N`   — sleep N ms per engine loop iteration (a
//!   slow engine: deadlines expire, queues back up);
//! * `error_rate=P`        — answer `/v1/generate` with an injected 500
//!   with probability P (seeded RNG, deterministic sequence);
//! * `refuse`              — drop every accepted connection immediately;
//! * `die_after=K`         — after K generate requests the replica
//!   plays dead: every subsequent connection is dropped;
//! * `cut_stream_after=K`  — abort a streamed response after K token
//!   chunks without the terminating 0-chunk (the client sees a
//!   truncated chunked body — the no-retry-after-first-token case);
//! * `seed=S`              — RNG seed for `error_rate` (default 0).

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::Rng;

/// A parsed fault spec. `Default` is the no-op spec (inject nothing).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Worker-side stall before handling each request, in ms.
    pub stall_ms: u64,
    /// Engine-side stall per loop iteration, in ms.
    pub engine_stall_ms: u64,
    /// Probability of answering a generate with an injected 500.
    pub error_rate: f64,
    /// Drop every connection at accept.
    pub refuse: bool,
    /// Play dead (drop all connections) after this many generate
    /// requests. 0 = never.
    pub die_after: u64,
    /// Abort a streamed response after this many token chunks. 0 = never.
    pub cut_stream_after: u64,
    /// Seed of the `error_rate` RNG.
    pub seed: u64,
}

impl FaultSpec {
    /// Parse the `--fault` grammar; `Err` carries a message for a 400 or
    /// CLI error. The empty string parses to the no-op spec.
    pub fn parse(spec: &str) -> Result<FaultSpec, String> {
        let mut out = FaultSpec::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, value) = match part.split_once('=') {
                Some((k, v)) => (k.trim(), Some(v.trim())),
                None => (part, None),
            };
            let parse_u64 = |v: Option<&str>| -> Result<u64, String> {
                v.ok_or_else(|| format!("fault key '{key}' needs =<int>"))?
                    .parse::<u64>()
                    .map_err(|_| format!("fault key '{key}' needs an integer value"))
            };
            match key {
                "stall_ms" => out.stall_ms = parse_u64(value)?,
                "engine_stall_ms" => out.engine_stall_ms = parse_u64(value)?,
                "die_after" => out.die_after = parse_u64(value)?,
                "cut_stream_after" => out.cut_stream_after = parse_u64(value)?,
                "seed" => out.seed = parse_u64(value)?,
                "refuse" => out.refuse = true,
                "error_rate" => {
                    let v = value.ok_or("fault key 'error_rate' needs =<float>")?;
                    let p =
                        v.parse::<f64>().map_err(|_| "error_rate needs a float".to_string())?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("error_rate {p} outside [0, 1]"));
                    }
                    out.error_rate = p;
                }
                _ => return Err(format!("unknown fault key '{key}'")),
            }
        }
        Ok(out)
    }

    /// True when the spec injects nothing.
    pub fn is_noop(&self) -> bool {
        *self == FaultSpec::default()
    }

    /// Parse a per-replica fault spec for `efla route --fault` over `n`
    /// replicas. Semicolon-separated entries; an `idx:spec` entry targets
    /// one replica, a bare spec applies to every replica. Later entries
    /// override earlier ones per replica, so
    /// `"stall_ms=10;0:die_after=5"` stalls all replicas and additionally
    /// re-specs replica 0 to die after 5 requests.
    pub fn parse_scoped(spec: &str, n: usize) -> Result<Vec<FaultSpec>, String> {
        let mut out = vec![FaultSpec::default(); n];
        for entry in spec.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            match entry.split_once(':') {
                Some((idx, rest)) => {
                    let i = idx
                        .trim()
                        .parse::<usize>()
                        .map_err(|_| format!("fault scope '{idx}' is not a replica index"))?;
                    if i >= n {
                        return Err(format!("fault scope {i} out of range (have {n} replicas)"));
                    }
                    out[i] = FaultSpec::parse(rest)?;
                }
                None => {
                    let parsed = FaultSpec::parse(entry)?;
                    for slot in &mut out {
                        *slot = parsed.clone();
                    }
                }
            }
        }
        Ok(out)
    }
}

/// Shared, runtime-swappable fault state of one serving front end.
pub struct FaultInjector {
    spec: Mutex<FaultSpec>,
    rng: Mutex<Rng>,
    /// Generate requests seen so far (drives `die_after`).
    generates: AtomicU64,
    /// Latched by `die_after`; a dead replica drops every connection.
    dead: AtomicBool,
}

impl FaultInjector {
    pub fn new(spec: FaultSpec) -> FaultInjector {
        let rng = Rng::new(spec.seed);
        FaultInjector {
            spec: Mutex::new(spec),
            rng: Mutex::new(rng),
            generates: AtomicU64::new(0),
            dead: AtomicBool::new(false),
        }
    }

    /// The no-op injector every front end starts with.
    pub fn disabled() -> FaultInjector {
        FaultInjector::new(FaultSpec::default())
    }

    /// Swap the active spec (the `POST /fault` path). Resets the RNG to
    /// the new seed and revives a dead replica, so one process can run
    /// several chaos phases back to back.
    pub fn set_spec(&self, spec: FaultSpec) {
        *self.rng.lock().expect("fault rng lock") = Rng::new(spec.seed);
        self.generates.store(0, Ordering::SeqCst);
        self.dead.store(false, Ordering::SeqCst);
        *self.spec.lock().expect("fault spec lock") = spec;
    }

    /// Snapshot of the active spec.
    pub fn spec(&self) -> FaultSpec {
        self.spec.lock().expect("fault spec lock").clone()
    }

    /// Did `die_after` already trigger?
    pub fn dead(&self) -> bool {
        self.dead.load(Ordering::SeqCst)
    }

    /// Should this freshly accepted connection be dropped on the floor?
    pub fn refuse_connection(&self) -> bool {
        self.dead() || self.spec.lock().expect("fault spec lock").refuse
    }

    /// Worker-side stall before handling a parsed request.
    pub fn stall(&self) {
        let ms = self.spec.lock().expect("fault spec lock").stall_ms;
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    /// Engine-side stall, once per engine loop iteration.
    pub fn stall_engine(&self) {
        let ms = self.spec.lock().expect("fault spec lock").engine_stall_ms;
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    /// Count one generate request; latch `dead` when `die_after` is
    /// reached. Returns true when this request should answer an
    /// injected 500 (`error_rate`).
    pub fn on_generate(&self) -> bool {
        let spec = self.spec.lock().expect("fault spec lock").clone();
        let n = self.generates.fetch_add(1, Ordering::SeqCst) + 1;
        if spec.die_after > 0 && n >= spec.die_after {
            self.dead.store(true, Ordering::SeqCst);
        }
        spec.error_rate > 0.0
            && self.rng.lock().expect("fault rng lock").bernoulli(spec.error_rate)
    }

    /// Abort a streamed response after this many token chunks (0 = never).
    pub fn cut_stream_after(&self) -> u64 {
        self.spec.lock().expect("fault spec lock").cut_stream_after
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let spec =
            FaultSpec::parse("stall_ms=250, error_rate=0.5, refuse, die_after=20, seed=7").unwrap();
        assert_eq!(spec.stall_ms, 250);
        assert!((spec.error_rate - 0.5).abs() < 1e-12);
        assert!(spec.refuse);
        assert_eq!(spec.die_after, 20);
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.cut_stream_after, 0);
        assert!(!spec.is_noop());
    }

    #[test]
    fn empty_spec_is_noop() {
        assert!(FaultSpec::parse("").unwrap().is_noop());
        assert!(FaultSpec::parse("  ").unwrap().is_noop());
    }

    #[test]
    fn scoped_specs_target_single_replicas() {
        let specs = FaultSpec::parse_scoped("stall_ms=10;0:die_after=5", 3).unwrap();
        assert_eq!(specs[0], FaultSpec::parse("die_after=5").unwrap());
        assert_eq!(specs[1], FaultSpec::parse("stall_ms=10").unwrap());
        assert_eq!(specs[2], FaultSpec::parse("stall_ms=10").unwrap());
        assert!(FaultSpec::parse_scoped("7:refuse", 3).is_err(), "scope out of range");
        assert!(FaultSpec::parse_scoped("x:refuse", 3).is_err(), "scope not an index");
        let noop = FaultSpec::parse_scoped("", 2).unwrap();
        assert!(noop.iter().all(FaultSpec::is_noop));
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(FaultSpec::parse("explode=1").is_err());
        assert!(FaultSpec::parse("stall_ms").is_err());
        assert!(FaultSpec::parse("stall_ms=abc").is_err());
        assert!(FaultSpec::parse("error_rate=1.5").is_err());
        assert!(FaultSpec::parse("error_rate=-0.1").is_err());
    }

    #[test]
    fn die_after_latches_dead_and_set_spec_revives() {
        let inj = FaultInjector::new(FaultSpec::parse("die_after=3").unwrap());
        assert!(!inj.dead());
        inj.on_generate();
        inj.on_generate();
        assert!(!inj.dead(), "dies only at the K-th request");
        inj.on_generate();
        assert!(inj.dead());
        assert!(inj.refuse_connection(), "a dead replica refuses connections");
        inj.set_spec(FaultSpec::default());
        assert!(!inj.dead(), "set_spec revives the replica");
        assert!(!inj.refuse_connection());
    }

    #[test]
    fn error_rate_is_seeded_and_deterministic() {
        let run = || -> Vec<bool> {
            let inj = FaultInjector::new(FaultSpec::parse("error_rate=0.5,seed=42").unwrap());
            (0..32).map(|_| inj.on_generate()).collect()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed, same injected-error sequence");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "p=0.5 mixes both outcomes");
    }

    #[test]
    fn noop_injector_injects_nothing() {
        let inj = FaultInjector::disabled();
        assert!(!inj.refuse_connection());
        assert!(!inj.on_generate());
        assert_eq!(inj.cut_stream_after(), 0);
        assert!(!inj.dead());
    }
}
