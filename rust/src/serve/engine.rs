//! The continuous-batching engine loop behind the HTTP front end.
//!
//! PR 4's [`Server`] already refills freed slots from its internal queue,
//! but it only exposed batch semantics: callers submitted everything up
//! front and `run_to_completion` drained the world. This module turns it
//! into a *service*: [`run_engine`] owns the `Server` on the calling
//! thread (a [`Session`] is not `Sync`, so the engine runs wherever the
//! session lives) and consumes [`Submission`]s from a **bounded**
//! `sync_channel` — the admission queue. Connection workers `try_send`
//! into it; a full channel is the 429 backpressure signal. Requests join
//! slots the moment one frees **mid-flight**, finished generations leave
//! immediately through their per-request event channel, and the prefill
//! token budget stays shared with the PR 4 scheduler — decode-phase slots
//! are never starved behind a new arrival's long prompt. The decode
//! phase itself runs slot-batched: every busy slot advances through one
//! packed GEMM per projection (`Session::decode_slots`), with per-slot
//! bits pinned independent of occupancy, so tokens streamed under any
//! concurrent load match a solo run of the same request exactly.
//!
//! Event flow per accepted request:
//! * [`Event::Token`] for every generated token (streaming responses
//!   flush each as one HTTP chunk) — only when the submission asked;
//! * exactly one terminal event: [`Event::Done`] with the full
//!   [`GenResult`], or [`Event::Rejected`] with the typed
//!   [`SubmitError`] (duplicate id, empty prompt, zero budget).
//!
//! Shutdown: when the flag flips, the engine keeps stepping until every
//! accepted request has finished (in-flight slots *and* channel-queued
//! submissions), bounded by [`ServerConfig::drain_timeout_secs`]; workers
//! whose request is abandoned by the deadline observe a dropped event
//! channel and answer 503.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::server::{
    GenRequest, GenResult, Server, ServerConfig, ServerStats, SubmitError,
};
use crate::coordinator::session::Session;
use crate::serve::fault::FaultInjector;
use crate::serve::state_cache::SharedStateCache;

/// What a request's event channel can carry.
#[derive(Clone, Debug)]
pub enum Event {
    /// One freshly generated token.
    Token(i32),
    /// The request finished (terminal).
    Done(GenResult),
    /// The engine refused the request (terminal).
    Rejected(SubmitError),
}

/// One request travelling from a connection worker to the engine.
pub struct Submission {
    pub req: GenRequest,
    /// Arrival at the socket — queue-wait and TTFT include channel time.
    pub submitted: Instant,
    /// Forward per-token [`Event::Token`]s in addition to the terminal
    /// event (the `"stream": true` path).
    pub stream: bool,
    pub events: mpsc::Sender<Event>,
}

/// p50/p95 summary over the retained latency samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencySummary {
    pub count: usize,
    pub p50_secs: f64,
    pub p95_secs: f64,
}

/// Bounded sample ring (newest-wins once full) for latency percentiles.
struct Ring {
    buf: Vec<f64>,
    next: usize,
    cap: usize,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { buf: Vec::new(), next: 0, cap: cap.max(1) }
    }

    fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.next] = x;
            self.next = (self.next + 1) % self.cap;
        }
    }

    fn summary(&self) -> LatencySummary {
        if self.buf.is_empty() {
            return LatencySummary::default();
        }
        let mut xs = self.buf.clone();
        xs.sort_by(f64::total_cmp);
        let pick = |p: f64| xs[((p * (xs.len() - 1) as f64).round() as usize).min(xs.len() - 1)];
        LatencySummary { count: xs.len(), p50_secs: pick(0.5), p95_secs: pick(0.95) }
    }
}

/// Counters and latency samples shared between the engine thread, the
/// connection workers and `GET /stats`.
pub struct EngineShared {
    /// Submissions currently sitting in the admission channel. Signed:
    /// the worker-side increment and engine-side decrement race benignly.
    queued: AtomicI64,
    /// Requests accepted into the admission queue so far.
    pub accepted: AtomicU64,
    /// Requests bounced with 429 (admission queue full).
    pub rejected: AtomicU64,
    server_stats: Mutex<ServerStats>,
    queue_wait: Mutex<Ring>,
    e2e: Mutex<Ring>,
    /// Fault layer hook of the engine loop (`engine_stall_ms`).
    fault: Arc<FaultInjector>,
    /// Shared handle to the engine's session state cache, published by
    /// [`run_engine`] once the [`Server`] exists. The `/v1/state/{session}`
    /// transfer endpoints use it to export/import *parked* entries; `None`
    /// until the engine starts (handlers answer 404 in that window).
    state_cache: Mutex<Option<SharedStateCache>>,
}

impl EngineShared {
    /// `sample_cap` bounds the per-metric latency rings.
    pub fn new(sample_cap: usize) -> EngineShared {
        Self::with_fault(sample_cap, Arc::new(FaultInjector::disabled()))
    }

    /// [`EngineShared::new`] with the front end's fault injector, so the
    /// engine loop shares the runtime-swappable spec with the workers.
    pub fn with_fault(sample_cap: usize, fault: Arc<FaultInjector>) -> EngineShared {
        EngineShared {
            queued: AtomicI64::new(0),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            server_stats: Mutex::new(ServerStats::default()),
            queue_wait: Mutex::new(Ring::new(sample_cap)),
            e2e: Mutex::new(Ring::new(sample_cap)),
            fault,
            state_cache: Mutex::new(None),
        }
    }

    /// Publish the engine's state-cache handle for the transfer endpoints.
    pub fn set_state_cache(&self, cache: SharedStateCache) {
        *self.state_cache.lock().expect("state_cache lock") = Some(cache);
    }

    /// The state-cache handle, once [`run_engine`] has published it.
    pub fn state_cache(&self) -> Option<SharedStateCache> {
        self.state_cache.lock().expect("state_cache lock").clone()
    }

    /// Record a successful `try_send` into the admission channel.
    pub fn note_accepted(&self) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.accepted.fetch_add(1, Ordering::SeqCst);
    }

    /// Record a 429 bounce.
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::SeqCst);
    }

    fn note_popped(&self) {
        self.queued.fetch_sub(1, Ordering::SeqCst);
    }

    /// Submissions waiting in the admission channel right now.
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::SeqCst).max(0) as usize
    }

    /// Latest engine-side [`ServerStats`] snapshot.
    pub fn server_stats(&self) -> ServerStats {
        *self.server_stats.lock().expect("server_stats lock")
    }

    fn set_server_stats(&self, s: ServerStats) {
        *self.server_stats.lock().expect("server_stats lock") = s;
    }

    fn record_result(&self, r: &GenResult) {
        self.queue_wait.lock().expect("queue_wait lock").push(r.queue_wait_secs);
        self.e2e.lock().expect("e2e lock").push(r.e2e_secs);
    }

    /// (queue-wait, end-to-end) percentile summaries.
    pub fn latency_summaries(&self) -> (LatencySummary, LatencySummary) {
        let qw = self.queue_wait.lock().expect("queue_wait lock").summary();
        let e2e = self.e2e.lock().expect("e2e lock").summary();
        (qw, e2e)
    }
}

type Sinks = HashMap<u64, (mpsc::Sender<Event>, bool)>;

fn seat(server: &mut Server<'_>, sinks: &mut Sinks, sub: Submission) {
    let Submission { req, submitted, stream, events } = sub;
    let id = req.id;
    match server.submit_at(req, submitted) {
        Ok(()) => {
            sinks.insert(id, (events, stream));
        }
        Err(e) => {
            let _ = events.send(Event::Rejected(e));
        }
    }
}

/// Run the continuous-batching engine until shutdown (blocking; the
/// engine owns the `Server` for the whole run). Returns the final stats.
pub fn run_engine(
    session: &Session,
    cfg: ServerConfig,
    seed: u64,
    rx: Receiver<Submission>,
    shared: &EngineShared,
    shutdown: &AtomicBool,
) -> Result<ServerStats> {
    let mut server = Server::with_config(session, seed, cfg.clone())?;
    server.enable_events();
    shared.set_state_cache(server.state_cache());
    shared.set_server_stats(server.stats);
    let mut sinks: Sinks = HashMap::new();
    let t0 = Instant::now();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        // Fault layer: a stalled engine (per-iteration sleep) makes
        // deadline abandonment and queue backup observable in tests.
        shared.fault.stall_engine();
        // Admit only what the next step can seat: the bounded channel is
        // the real queue, so the 429 signal reflects slots + queue_depth.
        while server.queue_len() < server.free_slots() {
            match rx.try_recv() {
                Ok(sub) => {
                    shared.note_popped();
                    seat(&mut server, &mut sinks, sub);
                }
                Err(_) => break,
            }
        }
        if !server.has_work() {
            if shutdown.load(Ordering::SeqCst) {
                // Final sweep: seat anything that slipped into the channel
                // before the flag flipped, then leave.
                match rx.try_recv() {
                    Ok(sub) => {
                        shared.note_popped();
                        seat(&mut server, &mut sinks, sub);
                        continue;
                    }
                    Err(_) => break,
                }
            }
            // Idle: park on the channel instead of spinning.
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(sub) => {
                    shared.note_popped();
                    seat(&mut server, &mut sinks, sub);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            continue;
        }
        server.engine_step()?;
        for ev in server.take_events() {
            if let Some((tx, stream)) = sinks.get(&ev.id) {
                if *stream {
                    let _ = tx.send(Event::Token(ev.token));
                }
            }
        }
        for res in server.take_results() {
            shared.record_result(&res);
            if let Some((tx, _)) = sinks.remove(&res.id) {
                let _ = tx.send(Event::Done(res));
            }
        }
        server.stats.wall_secs = t0.elapsed().as_secs_f64();
        shared.set_server_stats(server.stats);
        if shutdown.load(Ordering::SeqCst) {
            let deadline = *drain_deadline.get_or_insert_with(|| {
                Instant::now() + Duration::from_secs_f64(cfg.drain_timeout_secs.max(0.0))
            });
            if Instant::now() >= deadline {
                log::warn!(
                    "drain timeout after {:.1}s: abandoning {} in-flight request(s)",
                    cfg.drain_timeout_secs,
                    sinks.len()
                );
                break;
            }
        }
    }
    server.stats.wall_secs = t0.elapsed().as_secs_f64();
    shared.set_server_stats(server.stats);
    // Dropping `sinks` (and `rx`) disconnects any abandoned workers —
    // they observe the closed channel and answer 503.
    Ok(server.stats)
}
