//! `efla-lint`: repo-native static analysis for the EFLA invariants.
//!
//! The serving stack's correctness story rests on conventions that `cargo
//! check` cannot see: every `unsafe` site carries a `SAFETY:` contract,
//! unsafe stays confined to three audited modules, float orderings are
//! NaN-total, the decode hot path never touches the allocator, and the
//! serving path only calls slot-class-pinned matmul wrappers. This module
//! turns those conventions into machine-checked rules over the source tree
//! (`rust/src` + `rust/tests`), shipped as the `efla-lint` bin target and
//! exercised by `tests/lint_tool.rs` in the normal test suite.
//!
//! Rules:
//!
//! * `EFL001 safety-comment` — each line containing the `unsafe` keyword
//!   must carry or be immediately preceded by a `SAFETY:` comment (the
//!   `# Safety` doc-section convention on unsafe fns also counts).
//! * `EFL002 unsafe-allowlist` — `unsafe` may appear only in the
//!   [`UNSAFE_ALLOWLIST`] modules. No escape hatch.
//! * `EFL003 forbid-header` — every other module must be covered by a
//!   `#![forbid(unsafe_code)]` header, its own or an ancestor `mod.rs`'s.
//!   (A `mod.rs` that declares an allowlisted child is exempt: forbid
//!   propagates down and can never be re-allowed. EFL002 still covers it.)
//! * `EFL004 float-ord` — `partial_cmp` is banned: NaN turns it into a
//!   panic or a logic bug. Use `total_cmp`.
//! * `EFL005 no-alloc` — functions tagged as allocation-free must not
//!   contain `Vec::new`, `vec!`, `.to_vec()`, `.clone()` or `Box::new`.
//! * `EFL006 serving-pin` — `serve/` and `coordinator/server.rs` may only
//!   call matmul entry points declared in [`SERVING_MATMUL_ALLOWLIST`]
//!   (the slot-batched `*_acc_serving_batched` wrappers): any other
//!   `matmul*` identifier is flagged, so new unpinned entry points are
//!   caught without updating a ban list.
//!
//! Directive comments (parsed from comment text only, so rule tokens in
//! prose or string literals never collide with code):
//!
//! * a comment whose text starts with `lint: no-alloc` tags the next `fn`
//!   item — its whole body becomes an EFL005 region;
//! * a comment whose text starts with `lint: allow(rule-name)` waives
//!   `float-ord`, `no-alloc` or `serving-pin` for its own line (trailing
//!   comment) or for the next code line (standalone comment line).
//!
//! The scanner strips comments and string/char literals first (tracking
//! raw strings, nested block comments, and lifetimes vs char literals), so
//! fixtures embedded as string literals and rule names in docs are inert.

#![forbid(unsafe_code)]

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Modules permitted to contain `unsafe` (each audited and SAFETY-noted).
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "rust/src/tensor/gemm.rs",
    "rust/src/serve/mod.rs",
    "rust/src/runtime/pjrt.rs",
];

/// Directories under the repo root that the linter walks.
pub const LINT_ROOTS: &[&str] = &["rust/src", "rust/tests"];

/// Subdirectory holding deliberately-violating fixtures (skipped by walks).
pub const FIXTURE_DIR: &str = "lint_fixtures";

/// Allocation tokens banned inside no-alloc regions.
const NO_ALLOC_TOKENS: &[&str] = &["Vec::new", "vec!", ".to_vec(", ".clone(", "Box::new"];

/// The only matmul entry points the serving path may call: the
/// slot-batched wrappers whose kernel class is keyed on the engine's slot
/// capacity, so row bits never depend on occupancy or batch shape. Every
/// other identifier starting with `matmul` is flagged by EFL006.
pub const SERVING_MATMUL_ALLOWLIST: &[&str] =
    &["matmul_acc_serving_batched", "matmul_nt_acc_serving_batched"];

/// How far below its tag comment a `fn` item may start.
const TAG_SCAN_LINES: usize = 32;

/// How far above an `unsafe` line a SAFETY comment may sit, across blank,
/// attribute, and comment-only lines.
const SAFETY_SCAN_LINES: usize = 40;

/// The enforced rule set. Ids are stable and used by fixtures and CI logs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    SafetyComment,
    UnsafeAllowlist,
    ForbidHeader,
    FloatOrd,
    NoAlloc,
    ServingPin,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::SafetyComment => "EFL001",
            Rule::UnsafeAllowlist => "EFL002",
            Rule::ForbidHeader => "EFL003",
            Rule::FloatOrd => "EFL004",
            Rule::NoAlloc => "EFL005",
            Rule::ServingPin => "EFL006",
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Rule::SafetyComment => "safety-comment",
            Rule::UnsafeAllowlist => "unsafe-allowlist",
            Rule::ForbidHeader => "forbid-header",
            Rule::FloatOrd => "float-ord",
            Rule::NoAlloc => "no-alloc",
            Rule::ServingPin => "serving-pin",
        }
    }

    /// Rules that accept an `allow(...)` escape hatch. The unsafe-hygiene
    /// rules are deliberately absent: they cannot be waived.
    fn from_name(name: &str) -> Option<Rule> {
        match name {
            "float-ord" => Some(Rule::FloatOrd),
            "no-alloc" => Some(Rule::NoAlloc),
            "serving-pin" => Some(Rule::ServingPin),
            _ => None,
        }
    }
}

/// One finding: repo-relative path, 1-based line, rule, human message.
#[derive(Clone, Debug)]
pub struct Violation {
    pub path: String,
    pub line: usize,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{} {}] {}",
            self.path,
            self.line,
            self.rule.id(),
            self.rule.name(),
            self.msg
        )
    }
}

/// One source line split into executable code and comment text. String and
/// char literal contents are blanked out of `code`.
#[derive(Clone, Debug, Default)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

#[derive(Clone, Copy)]
enum Ctx {
    Code,
    Block(u32),
    Str,
    RawStr(u32),
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn ends_with_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(is_ident_char)
}

/// Split `src` into per-line code/comment channels.
pub fn strip_source(src: &str) -> Vec<Line> {
    let cs: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut ctx = Ctx::Code;
    let mut line_comment = false;
    let mut i = 0usize;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line_comment = false;
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        if line_comment {
            cur.comment.push(c);
            i += 1;
            continue;
        }
        match ctx {
            Ctx::Code => {
                if c == '/' && cs.get(i + 1) == Some(&'/') {
                    line_comment = true;
                    i += 2;
                    // Fold the doc markers of `///` and `//!` into the opener.
                    if matches!(cs.get(i), Some(&'/') | Some(&'!')) {
                        i += 1;
                    }
                } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                    ctx = Ctx::Block(1);
                    cur.code.push(' ');
                    i += 2;
                } else if c == '"' {
                    ctx = Ctx::Str;
                    cur.code.push('"');
                    i += 1;
                } else if (c == 'r' || c == 'b') && !ends_with_ident(&cur.code) {
                    // Possible raw / byte string literal prefix.
                    let mut j = i + 1;
                    let mut raw = c == 'r';
                    if c == 'b' && cs.get(j) == Some(&'r') {
                        raw = true;
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    if raw {
                        while cs.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                    }
                    if cs.get(j) == Some(&'"') {
                        ctx = if raw { Ctx::RawStr(hashes) } else { Ctx::Str };
                        cur.code.push('"');
                        i = j + 1;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime.
                    if cs.get(i + 1) == Some(&'\\') {
                        cur.code.push(' ');
                        i += 2;
                        while i < cs.len() && cs[i] != '\'' && cs[i] != '\n' {
                            i += 1;
                        }
                        if cs.get(i) == Some(&'\'') {
                            i += 1;
                        }
                    } else if cs.get(i + 2) == Some(&'\'') && cs.get(i + 1) != Some(&'\'') {
                        cur.code.push(' ');
                        i += 3;
                    } else {
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            Ctx::Block(depth) => {
                if c == '/' && cs.get(i + 1) == Some(&'*') {
                    ctx = Ctx::Block(depth + 1);
                    i += 2;
                } else if c == '*' && cs.get(i + 1) == Some(&'/') {
                    ctx = if depth == 1 { Ctx::Code } else { Ctx::Block(depth - 1) };
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            Ctx::Str => {
                if c == '\\' {
                    if cs.get(i + 1) == Some(&'\n') {
                        lines.push(std::mem::take(&mut cur));
                    }
                    i += 2;
                } else if c == '"' {
                    ctx = Ctx::Code;
                    cur.code.push('"');
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Ctx::RawStr(hashes) => {
                if c == '"' && (0..hashes as usize).all(|h| cs.get(i + 1 + h) == Some(&'#')) {
                    ctx = Ctx::Code;
                    cur.code.push('"');
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Find `needle` in `code` at identifier boundaries: wherever the needle's
/// own edge is an identifier character, the adjacent source character must
/// not be one. Returns the byte offset of the first hit.
pub fn find_token(code: &str, needle: &str) -> Option<usize> {
    let head_ident = needle.chars().next().is_some_and(is_ident_char);
    let tail_ident = needle.chars().next_back().is_some_and(is_ident_char);
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(needle) {
        let at = start + pos;
        let ok_before = !head_ident
            || code[..at].chars().next_back().is_none_or(|c| !is_ident_char(c));
        let ok_after = !tail_ident
            || code[at + needle.len()..].chars().next().is_none_or(|c| !is_ident_char(c));
        if ok_before && ok_after {
            return Some(at);
        }
        start = at + needle.len();
    }
    None
}

/// Find the next full identifier beginning with `matmul` in `code` at or
/// after byte offset `from`. Returns `(end, ident)` where `end` is the
/// offset just past the identifier (resume the scan there). Occurrences
/// embedded in a longer identifier (`my_matmul_helper`) don't count —
/// only identifiers that *start* with `matmul`.
fn next_matmul_ident(code: &str, from: usize) -> Option<(usize, &str)> {
    let mut at = from;
    while let Some(pos) = code[at..].find("matmul") {
        let start = at + pos;
        if code[..start].chars().next_back().is_some_and(is_ident_char) {
            at = start + "matmul".len();
            continue;
        }
        let tail =
            code[start..].char_indices().find(|&(_, c)| !is_ident_char(c)).map(|(i, _)| start + i);
        let end = tail.unwrap_or(code.len());
        return Some((end, &code[start..end]));
    }
    None
}

#[derive(Clone, Debug, Default)]
struct Marks {
    safety: bool,
    tag_no_alloc: bool,
    allows: Vec<Rule>,
}

fn parse_marks(comment: &str) -> Marks {
    let mut m = Marks::default();
    let text = comment.trim();
    if text.contains("SAFETY:") || text.contains("# Safety") {
        m.safety = true;
    }
    if let Some(rest) = text.strip_prefix("lint:") {
        let rest = rest.trim_start();
        if let Some(args) = rest.strip_prefix("allow(") {
            if let Some(end) = args.find(')') {
                for name in args[..end].split(',') {
                    if let Some(rule) = Rule::from_name(name.trim()) {
                        m.allows.push(rule);
                    }
                }
            }
        } else if rest.starts_with("no-alloc") {
            m.tag_no_alloc = true;
        }
    }
    m
}

/// Resolve every `lint: no-alloc` tag to the (start, end) line span of the
/// next `fn` item's body, found by brace tracking over stripped code.
fn no_alloc_regions(lines: &[Line], marks: &[Marks]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let tags = marks.iter().enumerate().filter(|(_, m)| m.tag_no_alloc).map(|(i, _)| i);
    for tag in tags {
        let horizon = lines.len().min(tag + TAG_SCAN_LINES);
        let Some(f0) = (tag..horizon).find(|&j| find_token(&lines[j].code, "fn").is_some())
        else {
            continue;
        };
        let mut depth = 0i32;
        let mut opened = false;
        let mut end = f0;
        'body: for (j, line) in lines.iter().enumerate().skip(f0) {
            for c in line.code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        opened = true;
                    }
                    '}' => {
                        depth -= 1;
                        if opened && depth == 0 {
                            end = j;
                            break 'body;
                        }
                    }
                    _ => {}
                }
            }
            end = j;
        }
        regions.push((f0, end));
    }
    regions
}

/// True when line `i` (containing `unsafe`) has a SAFETY comment on the
/// line itself or above it, across blank / attribute / comment-only lines.
fn has_safety_comment(lines: &[Line], marks: &[Marks], i: usize) -> bool {
    if marks[i].safety {
        return true;
    }
    for j in (i.saturating_sub(SAFETY_SCAN_LINES)..i).rev() {
        let code = lines[j].code.trim();
        if !(code.is_empty() || code.starts_with('#')) {
            return false;
        }
        if marks[j].safety {
            return true;
        }
    }
    false
}

fn scan_lines(path: &str, lines: &[Line]) -> Vec<Violation> {
    let marks: Vec<Marks> = lines.iter().map(|l| parse_marks(&l.comment)).collect();

    // Standalone allow-comments apply to the next code line; trailing
    // allow-comments to their own line.
    let mut allowed: Vec<Vec<Rule>> = vec![Vec::new(); lines.len()];
    let mut pending: Vec<Rule> = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.code.trim().is_empty() {
            pending.extend(marks[i].allows.iter().copied());
        } else {
            allowed[i] = std::mem::take(&mut pending);
            allowed[i].extend(marks[i].allows.iter().copied());
        }
    }

    let allowlisted = UNSAFE_ALLOWLIST.contains(&path);
    let serving = path.starts_with("rust/src/serve/") || path == "rust/src/coordinator/server.rs";
    let regions = no_alloc_regions(lines, &marks);

    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        let allow = |rule: Rule| allowed[i].contains(&rule);
        let mut push = |rule: Rule, msg: String| {
            out.push(Violation { path: path.to_string(), line: i + 1, rule, msg });
        };
        if find_token(code, "unsafe").is_some() {
            if !allowlisted {
                push(Rule::UnsafeAllowlist, unsafe_allowlist_msg());
            }
            if !has_safety_comment(lines, &marks, i) {
                let msg = "`unsafe` without an immediately preceding SAFETY comment";
                push(Rule::SafetyComment, msg.to_string());
            }
        }
        if find_token(code, "partial_cmp").is_some() && !allow(Rule::FloatOrd) {
            let msg = "NaN-unsafe float ordering: use `total_cmp`";
            push(Rule::FloatOrd, msg.to_string());
        }
        if regions.iter().any(|&(a, b)| (a..=b).contains(&i)) && !allow(Rule::NoAlloc) {
            for tok in NO_ALLOC_TOKENS {
                if find_token(code, tok).is_some() {
                    push(Rule::NoAlloc, format!("allocation `{tok}` inside a no-alloc region"));
                }
            }
        }
        if serving && !allow(Rule::ServingPin) {
            let mut at = 0usize;
            while let Some((next, ident)) = next_matmul_ident(code, at) {
                if !SERVING_MATMUL_ALLOWLIST.contains(&ident) {
                    push(Rule::ServingPin, serving_pin_msg(ident));
                }
                at = next;
            }
        }
    }
    out
}

fn unsafe_allowlist_msg() -> String {
    format!("`unsafe` outside the allowlisted modules [{}]", UNSAFE_ALLOWLIST.join(", "))
}

fn serving_pin_msg(tok: &str) -> String {
    format!(
        "unpinned `{tok}` on the serving path: use the slot-batched `*_acc_serving_batched` \
         wrappers"
    )
}

/// Scan a single file for the per-file rules (all but `forbid-header`).
/// `path` must be repo-relative with `/` separators — it selects the
/// unsafe-allowlist and serving-path behavior.
pub fn scan_source(path: &str, src: &str) -> Vec<Violation> {
    scan_lines(path, &strip_source(src))
}

fn has_forbid(lines: &[Line]) -> bool {
    lines.iter().any(|l| l.code.contains("forbid(unsafe_code)"))
}

fn needs_forbid_header(path: &str) -> bool {
    if !path.ends_with(".rs") || path == "rust/src/lib.rs" || UNSAFE_ALLOWLIST.contains(&path) {
        return false;
    }
    // A mod.rs that declares an allowlisted child cannot carry the header
    // itself: forbid propagates down the module tree and, unlike deny, can
    // never be re-allowed. Those parents stay guarded by EFL002 instead.
    !UNSAFE_ALLOWLIST.iter().any(|u| match u.rsplit_once('/') {
        Some((dir, _)) => path == format!("{dir}/mod.rs"),
        None => false,
    })
}

/// Ancestor `mod.rs` files whose `#![forbid(unsafe_code)]` covers `path`
/// (lint attributes propagate down the module tree).
fn covering_mods(path: &str) -> Vec<String> {
    let mut out = Vec::new();
    if let Some(rest) = path.strip_prefix("rust/src/") {
        let mut parts: Vec<&str> = rest.split('/').collect();
        parts.pop();
        while !parts.is_empty() {
            out.push(format!("rust/src/{}/mod.rs", parts.join("/")));
            parts.pop();
        }
    }
    out
}

/// Lint a whole tree of `(path, source)` pairs, adding the tree-level
/// `forbid-header` rule on top of the per-file scan.
pub fn lint_sources(files: &[(String, String)]) -> Vec<Violation> {
    let stripped: Vec<(&str, Vec<Line>)> =
        files.iter().map(|(p, s)| (p.as_str(), strip_source(s))).collect();
    let forbid: BTreeSet<&str> =
        stripped.iter().filter(|(_, l)| has_forbid(l)).map(|(p, _)| *p).collect();
    let mut out = Vec::new();
    for (path, lines) in &stripped {
        out.extend(scan_lines(path, lines));
        if needs_forbid_header(path)
            && !forbid.contains(path)
            && !covering_mods(path).iter().any(|m| forbid.contains(m.as_str()))
        {
            let msg = "module not covered by `#![forbid(unsafe_code)]` (own header or an \
                       ancestor `mod.rs`)";
            out.push(Violation {
                path: (*path).to_string(),
                line: 1,
                rule: Rule::ForbidHeader,
                msg: msg.to_string(),
            });
        }
    }
    out.sort_by(|a, b| (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule)));
    out
}

/// Repository root, resolved from the crate manifest dir at compile time.
pub fn repo_root() -> PathBuf {
    match Path::new(env!("CARGO_MANIFEST_DIR")).parent() {
        Some(p) => p.to_path_buf(),
        None => PathBuf::from("."),
    }
}

/// Collect `(repo-relative path, source)` for every `.rs` file under the
/// lint roots, sorted by path. Fixture directories are skipped.
pub fn collect_tree(root: &Path) -> io::Result<Vec<(String, String)>> {
    let mut paths = Vec::new();
    for sub in LINT_ROOTS {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, &mut paths)?;
        }
    }
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let rel = p.strip_prefix(root).unwrap_or(&p).to_string_lossy().replace('\\', "/");
        files.push((rel, fs::read_to_string(&p)?));
    }
    files.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(files)
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            if path.file_name().is_some_and(|n| n == FIXTURE_DIR) {
                continue;
            }
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        strip_source(src).into_iter().map(|l| l.code).collect()
    }

    fn rules_of(vs: &[Violation]) -> Vec<Rule> {
        vs.iter().map(|v| v.rule).collect()
    }

    const GEMM: &str = "rust/src/tensor/gemm.rs";

    #[test]
    fn strips_line_and_block_comments() {
        let lines = strip_source("let a = 1; // trailing\n/* one\n two */ let b = 2;\n");
        assert_eq!(lines[0].code, "let a = 1; ");
        assert_eq!(lines[0].comment, " trailing");
        assert_eq!(lines[1].comment, " one");
        assert_eq!(lines[2].comment, " two ");
        assert!(lines[2].code.contains("let b = 2;"));
        assert!(!lines[1].code.contains("one"));
    }

    #[test]
    fn strips_nested_block_comments() {
        let lines = strip_source("/* a /* b */ still comment */ code();\n");
        assert!(lines[0].code.contains("code();"));
        assert!(!lines[0].code.contains("still"));
    }

    #[test]
    fn strips_strings_and_raw_strings() {
        let plain = codes("let s = \"contains partial_cmp and more\"; f();\n");
        assert!(!plain[0].contains("partial_cmp"));
        assert!(plain[0].contains("f();"));
        let raw = codes("let s = r#\"quoted \"inner\" text\"#; g();\n");
        assert!(!raw[0].contains("inner"));
        assert!(raw[0].contains("g();"));
        let multi = codes("let s = \"line one\nline two\"; h();\n");
        assert!(multi[1].contains("h();"));
        assert!(!multi[0].contains("one"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let lt = codes("fn f<'a>(x: &'a str) {}\n");
        assert!(lt[0].contains("<'a>"));
        let quote_char = codes("let c = '\"'; i();\n");
        assert!(!quote_char[0].contains('"'));
        assert!(quote_char[0].contains("i();"));
        let escaped = codes("let c = '\\''; j();\n");
        assert!(escaped[0].contains("j();"));
    }

    #[test]
    fn find_token_respects_boundaries() {
        assert!(find_token("matmul_acc_serving(x)", "matmul_acc").is_none());
        assert!(find_token("ops::matmul_acc(x)", "matmul_acc").is_some());
        assert!(find_token("forbid(unsafe_code)", "unsafe").is_none());
        assert!(find_token("deny(unsafe_op_in_unsafe_fn)", "unsafe").is_none());
        assert!(find_token("x.to_vec()", ".to_vec(").is_some());
        assert!(find_token("my_vec!(1)", "vec!").is_none());
        assert!(find_token("let v = vec![0; 4];", "vec!").is_some());
    }

    #[test]
    fn safety_rule_fires_without_comment_and_clears_with_one() {
        let ident = "fn f(p: *const f32) -> f32 {\n    unsafe_block_here(p)\n}\n";
        assert!(scan_source(GEMM, ident).is_empty());
        let bad = "fn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules_of(&scan_source(GEMM, bad)), vec![Rule::SafetyComment]);
        let good =
            "fn f(p: *const f32) -> f32 {\n    // SAFETY: p is valid\n    unsafe { *p }\n}\n";
        assert!(scan_source(GEMM, good).is_empty());
        let doc = "/// # Safety\n/// caller checks cpu features\npub fn g() {}\n";
        assert!(scan_source(GEMM, doc).is_empty());
    }

    #[test]
    fn safety_comment_reaches_across_attributes() {
        let src = "// SAFETY: features checked by caller\n#[inline]\nfn f() { unsafe { g() } }\n";
        assert!(scan_source(GEMM, src).is_empty());
        let blocked = "// SAFETY: stale\nlet x = 1;\nfn f() { unsafe { g() } }\n";
        assert_eq!(rules_of(&scan_source(GEMM, blocked)), vec![Rule::SafetyComment]);
    }

    #[test]
    fn allowlist_rule_fires_outside_allowed_modules() {
        let src = "fn f(p: *const f32) -> f32 {\n    // SAFETY: p is valid\n    unsafe { *p }\n}\n";
        let vs = scan_source("rust/src/util/math.rs", src);
        assert_eq!(rules_of(&vs), vec![Rule::UnsafeAllowlist]);
        assert_eq!(vs[0].line, 3);
    }

    #[test]
    fn float_ord_rule_and_escape_hatch() {
        let bad = "fn f(xs: &mut [f64]) {\n    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        let vs = scan_source("rust/src/util/math.rs", bad);
        assert_eq!(rules_of(&vs), vec![Rule::FloatOrd]);
        assert_eq!(vs[0].line, 2);
        let ok = "fn f(xs: &mut [f64]) {\n    // lint: allow(float-ord) -- NaN filtered above\n    \
                  xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        assert!(scan_source("rust/src/util/math.rs", ok).is_empty());
    }

    #[test]
    fn no_alloc_region_tracks_fn_body_and_escape() {
        let src = "// lint: no-alloc\nfn hot(out: &mut [f32]) {\n    let v = vec![0.0; 4];\n    \
                   out[0] = v[0];\n}\nfn cold() -> Vec<f32> {\n    vec![1.0]\n}\n";
        let vs = scan_source("rust/src/runtime/cpu/ops.rs", src);
        assert_eq!(rules_of(&vs), vec![Rule::NoAlloc]);
        assert_eq!(vs[0].line, 3);
        let escaped = "// lint: no-alloc\nfn hot(out: &mut [f32]) {\n    \
                       let v = vec![0.0; 4]; // lint: allow(no-alloc) -- startup only\n    \
                       out[0] = v[0];\n}\n";
        assert!(scan_source("rust/src/runtime/cpu/ops.rs", escaped).is_empty());
    }

    #[test]
    fn serving_pin_rule_only_on_serving_paths() {
        let src = "fn step(a: &[f32], b: &[f32], c: &mut [f32]) {\n    \
                   ops::matmul_into(a, b, c, 1, 2, 3);\n}\n";
        assert_eq!(rules_of(&scan_source("rust/src/serve/engine.rs", src)), vec![Rule::ServingPin]);
        assert_eq!(
            rules_of(&scan_source("rust/src/coordinator/server.rs", src)),
            vec![Rule::ServingPin]
        );
        assert!(scan_source("rust/src/runtime/cpu/ops.rs", src).is_empty());
        let pinned = "fn step(e: &Exec, a: &[f32], b: &[f32], c: &mut [f32]) {\n    \
                      ops::matmul_acc_serving_batched(e, a, b, c, 1, 2, 3, 4);\n}\n";
        assert!(scan_source("rust/src/serve/engine.rs", pinned).is_empty());
    }

    #[test]
    fn serving_pin_allowlist_is_exact_not_prefix_based() {
        // The retired single-row wrapper name is a *prefix* of the batched
        // one; the allowlist must match whole identifiers, so the old name
        // fires even though a hardcoded ban list would have missed new
        // variants.
        let old = "fn step(e: &Exec, a: &[f32], b: &[f32], c: &mut [f32]) {\n    \
                   ops::matmul_acc_serving(e, a, b, c, 2, 3);\n}\n";
        let vs = scan_source("rust/src/serve/engine.rs", old);
        assert_eq!(rules_of(&vs), vec![Rule::ServingPin]);
        assert!(vs[0].msg.contains("matmul_acc_serving"), "{}", vs[0].msg);
        // Any novel matmul identifier is unpinned by default.
        let novel = "fn step() {\n    ops::matmul_fancy_new_entry(1);\n}\n";
        assert_eq!(
            rules_of(&scan_source("rust/src/serve/engine.rs", novel)),
            vec![Rule::ServingPin]
        );
        // ...but identifiers merely *containing* matmul are not matmul
        // entry points.
        let contains = "fn step() {\n    let n = 3;\n    drive_my_matmul_helper(n);\n}\n";
        assert!(scan_source("rust/src/serve/engine.rs", contains).is_empty());
    }

    #[test]
    fn next_matmul_ident_finds_whole_identifiers() {
        let code = "ops::matmul_nt_acc_serving_batched(x); matmul(y); my_matmul_helper(z);";
        let (end, ident) = next_matmul_ident(code, 0).unwrap();
        assert_eq!(ident, "matmul_nt_acc_serving_batched");
        let (end2, ident2) = next_matmul_ident(code, end).unwrap();
        assert_eq!(ident2, "matmul");
        assert!(next_matmul_ident(code, end2).is_none());
    }

    #[test]
    fn forbid_header_rule_covers_by_ancestor_mod() {
        let bare = vec![("rust/src/data/foo.rs".to_string(), "pub fn x() {}\n".to_string())];
        assert_eq!(rules_of(&lint_sources(&bare)), vec![Rule::ForbidHeader]);
        let covered = vec![
            ("rust/src/data/foo.rs".to_string(), "pub fn x() {}\n".to_string()),
            (
                "rust/src/data/mod.rs".to_string(),
                "#![forbid(unsafe_code)]\npub mod foo;\n".to_string(),
            ),
        ];
        assert!(lint_sources(&covered).is_empty());
        let own = vec![(
            "rust/tests/smoke.rs".to_string(),
            "#![forbid(unsafe_code)]\n#[test]\nfn t() {}\n".to_string(),
        )];
        assert!(lint_sources(&own).is_empty());
    }

    #[test]
    fn directive_prose_in_docs_is_inert() {
        let src = "//! Use a comment starting with `lint: no-alloc` to tag a fn.\n\
                   fn f() -> Vec<f32> {\n    vec![0.0]\n}\n";
        assert!(scan_source("rust/src/util/math.rs", src).is_empty());
    }
}
