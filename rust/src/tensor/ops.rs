//! Tensor operations: matmul wrappers, transposes, elementwise, reductions,
//! softmax.
//!
//! The raw matmul family (`matmul_into` / `matmul_nt_into` /
//! `matmul_tn_into` / `dot` / `axpy`) lives in [`super::gemm`] behind a
//! runtime SIMD dispatcher (AVX2+FMA microkernel → portable scalar); this
//! module keeps the [`Tensor`]-level conveniences built on top of it. See
//! `benches/kernel_throughput.rs` for measured numbers.

#![forbid(unsafe_code)]

use super::gemm::{matmul_into, matmul_nt_into};
use super::Tensor;

/// C = A @ B for 2-D tensors (M,K) x (K,N) -> (M,N).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    matmul_into(a.data(), b.data(), &mut out, m, k, n);
    Tensor::from_vec(&[m, n], out)
}

/// Fresh `m x n` product `a @ b` on raw row-major slices, returned as an
/// owned buffer. The single fresh-matmul helper shared by the CPU model
/// layers and the attention kernels (callers that want accumulation use
/// [`matmul_into`] / [`super::matmul_nt_into`] / [`super::matmul_tn_into`]
/// directly).
pub fn matmul_vec(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, b, &mut out, m, k, n);
    out
}

/// A^T for 2-D tensors.
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = a.data()[i * n + j];
        }
    }
    Tensor::from_vec(&[n, m], out)
}

/// C = A @ B^T : (M,K) x (N,K) -> (M,N). Fast path for row-major operands.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    matmul_nt_into(a.data(), b.data(), &mut out, m, k, n);
    Tensor::from_vec(&[m, n], out)
}

/// Elementwise map.
pub fn map(a: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    Tensor::from_vec(a.shape(), a.data().iter().map(|&x| f(x)).collect())
}

/// Elementwise binary op.
pub fn zip(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    Tensor::from_vec(
        a.shape(),
        a.data().iter().zip(b.data().iter()).map(|(&x, &y)| f(x, y)).collect(),
    )
}

/// In-place scale.
pub fn scale(a: &mut Tensor, s: f32) {
    for x in a.data_mut() {
        *x *= s;
    }
}

/// Sum of all elements.
pub fn sum(a: &Tensor) -> f32 {
    a.data().iter().sum()
}

/// Mean of all elements.
pub fn mean(a: &Tensor) -> f32 {
    if a.is_empty() {
        0.0
    } else {
        sum(a) / a.len() as f32
    }
}

/// Row-wise softmax of a 2-D tensor (numerically stable).
pub fn softmax_rows(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let row = &a.data()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0;
        for j in 0..n {
            let e = (row[j] - mx).exp();
            out[i * n + j] = e;
            z += e;
        }
        for j in 0..n {
            out[i * n + j] /= z;
        }
    }
    Tensor::from_vec(&[m, n], out)
}

/// argmax over the last axis of a 2-D tensor.
///
/// Uses IEEE total ordering ([`f32::total_cmp`]) so rows containing NaN
/// never panic: a positive NaN compares greater than every number (its
/// index is returned), a negative NaN smaller — deterministic either way.
pub fn argmax_rows(a: &Tensor) -> Vec<usize> {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    (0..m)
        .map(|i| {
            let row = &a.data()[i * n..(i + 1) * n];
            row.iter()
                .enumerate()
                .max_by(|x, y| x.1.total_cmp(y.1))
                .map(|(j, _)| j)
                .unwrap_or(0)
        })
        .collect()
}

/// Outer product u v^T -> (len(u), len(v)).
pub fn outer(u: &[f32], v: &[f32]) -> Tensor {
    let mut out = Vec::with_capacity(u.len() * v.len());
    for &ui in u {
        for &vj in v {
            out.push(ui * vj);
        }
    }
    Tensor::from_vec(&[u.len(), v.len()], out)
}

#[cfg(test)]
mod tests {
    use super::super::matmul_tn_into;
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::from_vec(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_rect_matches_naive() {
        let (m, k, n) = (7, 13, 5);
        let a = Tensor::from_vec(&[m, k], (0..m * k).map(|i| (i % 11) as f32 - 5.0).collect());
        let b = Tensor::from_vec(&[k, n], (0..k * n).map(|i| (i % 7) as f32 * 0.5).collect());
        let c = matmul(&a, &b);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.get(&[i, kk]) * b.get(&[kk, j]);
                }
                assert!((c.get(&[i, j]) - s).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn matmul_vec_matches_matmul() {
        let a = Tensor::from_vec(&[3, 4], (0..12).map(|i| i as f32 * 0.2 - 1.0).collect());
        let b = Tensor::from_vec(&[4, 5], (0..20).map(|i| (i as f32).sin()).collect());
        let c1 = matmul(&a, &b);
        let c2 = matmul_vec(a.data(), b.data(), 3, 4, 5);
        assert_eq!(c1.data(), c2.as_slice());
    }

    #[test]
    fn matmul_nt_matches_matmul_transpose() {
        let a = Tensor::from_vec(&[3, 4], (0..12).map(|i| i as f32 * 0.1).collect());
        let b = Tensor::from_vec(&[5, 4], (0..20).map(|i| (i as f32).sin()).collect());
        let c1 = matmul_nt(&a, &b);
        let c2 = matmul(&a, &transpose(&b));
        assert!(c1.max_abs_diff(&c2) < 1e-5);
    }

    #[test]
    fn matmul_nt_into_matches_matmul_nt() {
        let a = Tensor::from_vec(&[3, 4], (0..12).map(|i| i as f32 * 0.3 - 1.0).collect());
        let b = Tensor::from_vec(&[5, 4], (0..20).map(|i| (i as f32).cos()).collect());
        let c1 = matmul_nt(&a, &b);
        let mut out = vec![0.0f32; 3 * 5];
        matmul_nt_into(a.data(), b.data(), &mut out, 3, 4, 5);
        assert!(c1.max_abs_diff(&Tensor::from_vec(&[3, 5], out)) < 1e-5);
    }

    #[test]
    fn matmul_tn_into_matches_transpose_matmul() {
        let a = Tensor::from_vec(&[6, 3], (0..18).map(|i| (i as f32).sin()).collect());
        let b = Tensor::from_vec(&[6, 4], (0..24).map(|i| i as f32 * 0.1 - 1.0).collect());
        let c1 = matmul(&transpose(&a), &b);
        let mut out = vec![0.0f32; 3 * 4];
        matmul_tn_into(a.data(), b.data(), &mut out, 6, 3, 4);
        assert!(c1.max_abs_diff(&Tensor::from_vec(&[3, 4], out)) < 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn softmax_rows_sums_to_one() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., -1000., 0., 1000.]);
        let s = softmax_rows(&a);
        for i in 0..2 {
            let rs: f32 = s.row(i).iter().sum();
            assert!((rs - 1.0).abs() < 1e-5);
        }
        assert!(s.get(&[1, 2]) > 0.999); // stable under extreme logits
    }

    #[test]
    fn argmax_and_outer() {
        let a = Tensor::from_vec(&[2, 3], vec![0., 5., 1., 9., 0., 0.]);
        assert_eq!(argmax_rows(&a), vec![1, 0]);
        let o = outer(&[1., 2.], &[3., 4.]);
        assert_eq!(o.data(), &[3., 4., 6., 8.]);
    }

    #[test]
    fn argmax_rows_with_nan_does_not_panic() {
        // Regression: the old partial_cmp().unwrap() panicked on NaN rows.
        let a = Tensor::from_vec(&[3, 3], vec![0., f32::NAN, 1., 2., 0., 1., f32::NAN, 3., 9.]);
        let idx = argmax_rows(&a);
        assert_eq!(idx.len(), 3);
        // Positive NaN is the total-order maximum (above +inf), so rows
        // containing one pick its index; a NaN-free row behaves classically.
        assert_eq!(idx[0], 1);
        assert_eq!(idx[1], 0);
        assert_eq!(idx[2], 0);
    }
}
