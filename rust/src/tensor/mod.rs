//! Host-side tensor substrate.
//!
//! A small dense f32 tensor (row-major, owned storage) used by the data
//! pipeline, the pure-Rust attention reference, metrics, and the
//! literal<->host bridge. Not a BLAS replacement — just the operations this
//! system needs, implemented carefully enough to be property-tested and
//! fast enough for the reference benches. The raw matmul/dot/axpy family
//! lives in [`gemm`] behind a runtime SIMD dispatcher (packed AVX-512F /
//! AVX2+FMA / NEON microkernels with a portable scalar fallback;
//! `EFLA_FORCE_SCALAR=1` pins the scalar tier, `EFLA_KERNEL=<tier>` pins
//! a specific one); [`Scratch`] is the reusable-buffer arena the hot
//! paths thread through to stay allocation-free.

pub mod gemm;
mod ops;
mod scratch;

pub use gemm::{active_kernel, axpy, dot, force_kernel, matmul_into, matmul_nt_into,
    matmul_tn_into, Kernel, ENV_FORCE_SCALAR, ENV_KERNEL};
pub use ops::*;
pub use scratch::Scratch;

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Build from data; length must match the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} incompatible with data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    /// Scalar tensor.
    pub fn scalar(x: f32) -> Self {
        Tensor { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// First element (for scalar outputs).
    pub fn item(&self) -> f32 {
        assert!(!self.data.is_empty(), "item() on empty tensor");
        self.data[0]
    }

    /// Reshape (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "reshape {:?} -> {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    /// Row-major linear index for a multi-index.
    pub fn index(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut lin = 0;
        for (i, (&x, &s)) in idx.iter().zip(self.shape.iter()).enumerate() {
            debug_assert!(x < s, "index {idx:?} out of bounds {:?} at dim {i}", self.shape);
            lin = lin * s + x;
        }
        lin
    }

    pub fn get(&self, idx: &[usize]) -> f32 {
        self.data[self.index(idx)]
    }

    pub fn set(&mut self, idx: &[usize], val: f32) {
        let i = self.index(idx);
        self.data[i] = val;
    }

    /// Immutable view of row `r` of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &self.data[r * cols..(r + 1) * cols]
    }

    /// L2 norm of all elements.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.get(&[0, 0]), 0.0);
        assert_eq!(t.get(&[0, 2]), 2.0);
        assert_eq!(t.get(&[1, 0]), 3.0);
        assert_eq!(t.get(&[1, 2]), 5.0);
        assert_eq!(t.row(1), &[3., 4., 5.]);
    }

    #[test]
    fn set_and_reshape() {
        let mut t = Tensor::zeros(&[2, 2]);
        t.set(&[1, 1], 7.0);
        let t = t.reshape(&[4]);
        assert_eq!(t.get(&[3]), 7.0);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0]);
    }

    #[test]
    fn norm_and_diff() {
        let a = Tensor::from_vec(&[2], vec![3.0, 4.0]);
        let b = Tensor::from_vec(&[2], vec![3.0, 2.0]);
        assert!((a.norm() - 5.0).abs() < 1e-6);
        assert!((a.max_abs_diff(&b) - 2.0).abs() < 1e-6);
    }
}
