//! Reusable f32 buffer pool for allocation-free hot loops.
//!
//! [`Scratch`] is a LIFO pool of `Vec<f32>` buffers: [`Scratch::take`]
//! hands out a zeroed buffer of the requested length (reusing a pooled
//! allocation when one exists), [`Scratch::put`] returns it. Once every
//! pooled buffer's capacity has grown to its steady-state maximum, a
//! take/put cycle performs **no heap allocation** — the chunkwise kernel,
//! the BPTT sweep and the per-token decode loops all run through one.
//!
//! Ownership rule: **one arena per executor worker, never shared.** The
//! CPU backend's `Executor` owns one `Scratch` per worker thread and
//! threads it through the `*_scratch` task closures; a buffer taken inside
//! a task must be put back (or returned as a result) before the task ends.
//! Because `take` transfers ownership of a plain `Vec<f32>`, holding
//! several live buffers at once needs no lifetime juggling, and a callee
//! can keep drawing from the same `&mut Scratch` while earlier buffers are
//! still out. Forgetting `put` is never unsound — it only costs the pool
//! a reusable allocation.

#![forbid(unsafe_code)]

/// LIFO pool of reusable zero-initialized f32 buffers.
#[derive(Debug, Default)]
pub struct Scratch {
    pool: Vec<Vec<f32>>,
}

impl Scratch {
    /// Empty pool (no allocation until the first `take`).
    pub const fn new() -> Scratch {
        Scratch { pool: Vec::new() }
    }

    /// Check out a zeroed buffer of exactly `len` elements. Reuses the
    /// most recently returned allocation when the pool is non-empty.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.pool.pop().unwrap_or_default();
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.pool.push(buf);
        }
    }

    /// Buffers currently parked in the pool (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_buffers_and_reuses_capacity() {
        let mut sc = Scratch::new();
        let mut a = sc.take(8);
        assert_eq!(a, vec![0.0; 8]);
        a.iter_mut().for_each(|x| *x = 7.0);
        let cap = a.capacity();
        let ptr = a.as_ptr();
        sc.put(a);
        assert_eq!(sc.pooled(), 1);

        // Same allocation comes back, re-zeroed, for a smaller request.
        let b = sc.take(4);
        assert_eq!(b, vec![0.0; 4]);
        assert_eq!(b.capacity(), cap);
        assert_eq!(b.as_ptr(), ptr);
        sc.put(b);
    }

    #[test]
    fn multiple_buffers_can_be_live_at_once() {
        let mut sc = Scratch::new();
        let a = sc.take(3);
        let b = sc.take(5);
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 5);
        sc.put(a);
        sc.put(b);
        assert_eq!(sc.pooled(), 2);
        let c = sc.take(5);
        assert_eq!(c, vec![0.0; 5]);
    }

    #[test]
    fn empty_put_is_dropped() {
        let mut sc = Scratch::new();
        sc.put(Vec::new());
        assert_eq!(sc.pooled(), 0);
    }
}
