//! Register-blocked GEMM microkernel family with runtime SIMD dispatch.
//!
//! Every matmul in the system — the chunkwise attention kernel, the BPTT
//! backward, and all CPU model layers — funnels through the five raw
//! primitives exported here:
//!
//! * [`matmul_into`]    — `C += A  B`    (A: m×k, B: k×n, C: m×n)
//! * [`matmul_nt_into`] — `C += A  Bᵀ`   (B stored n×k row-major)
//! * [`matmul_tn_into`] — `C += Aᵀ B`    (A stored m×k row-major, C: k×n)
//! * [`dot`] / [`axpy`] — the vector building blocks
//!
//! Dispatch tiers, resolved once per process and cached:
//!
//! 1. **AVX-512F** (x86-64 hosts where `is_x86_feature_detected!` confirms
//!    `avx512f` and `fma`): the [`avx512`] mirror of the packed kernel
//!    with two 16-lane zmm columns per row ([`avx512::MR`]×[`avx512::NR`]).
//! 2. **AVX2+FMA** (x86-64 hosts where detection confirms both): a packed,
//!    register-blocked [`avx2::MR`]×[`avx2::NR`] microkernel (6 broadcast
//!    rows × 2 ymm columns = 12 in-register accumulators) over BLIS-style
//!    `MC`/`KC`/`NC` cache blocking, with thread-local packing buffers so
//!    steady-state calls allocate nothing. Shapes too small to amortize
//!    packing use unpacked `dot`/`axpy` loops instead.
//! 3. **NEON** (aarch64; baseline, no runtime probe needed): the [`neon`]
//!    mirror with two 4-lane q-register columns per row.
//! 4. **Scalar** (everything else, or `EFLA_FORCE_SCALAR=1`): the portable
//!    cache-blocked loops in [`scalar`], written branch-free in the inner
//!    loop so LLVM can autovectorize with baseline features.
//!
//! `EFLA_FORCE_SCALAR=1` always wins; `EFLA_KERNEL=avx512|avx2|neon|scalar`
//! pins one tier when the host supports it (unknown or unsupported names
//! fall through to auto-detection). All tiers agree to float tolerance
//! (FMA contracts one rounding per multiply-add and the packed kernels
//! re-associate the k-sum), which is pinned by the parity tests here and
//! in `tests/simd_parity.rs`. Within a tier, results are bit-identical
//! regardless of thread count — dispatch never consults the executor.
//!
//! Serving callers additionally pin their row arithmetic through
//! [`serving_class`]/[`serving_nt_class`]: the kernel class is keyed on
//! the engine's **configured** slot capacity `(max_slots, k, n)`, never on
//! the busy-row count of one call, so a decode row's bits are independent
//! of which slots happen to be occupied.

use std::sync::atomic::{AtomicU8, Ordering};

/// Env override: set to any non-empty value other than `0` to force the
/// scalar tier (testing/CI; read once, on first dispatch). Always wins
/// over [`ENV_KERNEL`].
pub const ENV_FORCE_SCALAR: &str = "EFLA_FORCE_SCALAR";

/// Env override: pin one dispatch tier by name — `avx512`, `avx2`, `neon`,
/// or `scalar`. Unknown or host-unsupported names fall through to
/// auto-detection (read once, on first dispatch).
pub const ENV_KERNEL: &str = "EFLA_KERNEL";

/// Which kernel tier the dispatcher resolved to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// Packed AVX-512F microkernel path.
    Avx512,
    /// Packed AVX2+FMA microkernel path.
    Avx2Fma,
    /// Packed NEON microkernel path (aarch64 baseline).
    Neon,
    /// Portable blocked-loop fallback.
    Scalar,
}

const K_UNRESOLVED: u8 = 0;
const K_SCALAR: u8 = 1;
const K_AVX2: u8 = 2;
const K_AVX512: u8 = 3;
const K_NEON: u8 = 4;

static ACTIVE: AtomicU8 = AtomicU8::new(K_UNRESOLVED);

fn code_of(tier: Kernel) -> u8 {
    match tier {
        Kernel::Avx512 => K_AVX512,
        Kernel::Avx2Fma => K_AVX2,
        Kernel::Neon => K_NEON,
        Kernel::Scalar => K_SCALAR,
    }
}

fn kernel_of(code: u8) -> Kernel {
    match code {
        K_AVX512 => Kernel::Avx512,
        K_AVX2 => Kernel::Avx2Fma,
        K_NEON => Kernel::Neon,
        _ => Kernel::Scalar,
    }
}

/// Whether this host can actually execute the tier (runtime feature
/// detection on x86-64; NEON is baseline on aarch64).
fn host_supports(tier: Kernel) -> bool {
    match tier {
        Kernel::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx512 => {
            is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("fma")
        }
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2Fma => is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma"),
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => true,
        _ => false,
    }
}

fn detect() -> u8 {
    if std::env::var(ENV_FORCE_SCALAR).map_or(false, |v| !v.is_empty() && v != "0") {
        return K_SCALAR;
    }
    if let Ok(name) = std::env::var(ENV_KERNEL) {
        match name.as_str() {
            "scalar" => return K_SCALAR,
            "avx512" if host_supports(Kernel::Avx512) => return K_AVX512,
            "avx2" if host_supports(Kernel::Avx2Fma) => return K_AVX2,
            "neon" if host_supports(Kernel::Neon) => return K_NEON,
            // Unknown or unsupported names fall through to auto-detection.
            _ => {}
        }
    }
    if host_supports(Kernel::Avx512) {
        return K_AVX512;
    }
    if host_supports(Kernel::Avx2Fma) {
        return K_AVX2;
    }
    if host_supports(Kernel::Neon) {
        return K_NEON;
    }
    K_SCALAR
}

/// The kernel tier dispatched on this host (feature detection, the
/// [`ENV_FORCE_SCALAR`] kill switch, and the [`ENV_KERNEL`] override are
/// resolved on first use and cached).
pub fn active_kernel() -> Kernel {
    let code = ACTIVE.load(Ordering::Relaxed);
    if code == K_UNRESOLVED {
        let k = detect();
        ACTIVE.store(k, Ordering::Relaxed);
        kernel_of(k)
    } else {
        kernel_of(code)
    }
}

/// Test/bench hook: pin the dispatcher to one tier (`None` re-detects on
/// next use). Requesting a tier the host cannot execute silently resolves
/// to scalar — forcing an unsupported tier would be UB. Returns the tier
/// now active. Global state: callers that flip this concurrently with
/// bit-exactness assertions race themselves, so keep it to single-test
/// binaries and bench `main`s.
pub fn force_kernel(k: Option<Kernel>) -> Kernel {
    let v = match k {
        None => K_UNRESOLVED,
        Some(tier) if host_supports(tier) => code_of(tier),
        Some(_) => K_SCALAR,
    };
    ACTIVE.store(v, Ordering::Relaxed);
    active_kernel()
}

#[inline]
fn simd_active() -> bool {
    active_kernel() != Kernel::Scalar
}

/// Below this flop count (2·m·k·n / 2) the packed kernel's packing passes
/// and tile traffic dominate; small shapes go through the unpacked paths.
/// Shared by every SIMD tier so a [`MatmulClass`] means the same shape
/// split on every host.
const PACKED_MIN_FLOPS: usize = 1 << 14;

fn use_packed(m: usize, k: usize, n: usize) -> bool {
    m >= 4 && n >= 8 && k >= 8 && m * k * n >= PACKED_MIN_FLOPS
}

// ----------------------------------------------------------------------
// Dispatched entry points
// ----------------------------------------------------------------------

/// out[m,n] += a[m,k] @ b[k,n] (out must be zeroed for a fresh product).
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_into_class(matmul_class(m, k, n), a, b, out, m, k, n);
}

/// out[m,n] += a[m,k] @ b[n,k]^T (transposed rhs, both row-major).
pub fn matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_nt_into_class(matmul_nt_class(m, k, n), a, b, out, m, k, n);
}

/// out[k,n] += a[m,k]^T @ b[m,n] (transposed lhs — the weight-gradient
/// shape dW = Xᵀ dY).
pub fn matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    matmul_tn_into_class(matmul_tn_class(m, k, n), a, b, out, m, k, n);
}

/// Kernel class resolved once per **full** matmul shape. Row-splitting
/// callers (the executor wrappers) must run every row chunk through the
/// class of the full shape: within a class, each output row's summation
/// order is independent of how many rows share the call, so results stay
/// bit-identical at any thread count — whereas re-dispatching per chunk
/// would flip classes when the split crosses the packing cutoffs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatmulClass {
    /// Packed microkernel path of the active SIMD tier.
    Packed,
    /// Unpacked dot/axpy path of the active SIMD tier.
    Small,
    /// Portable scalar path.
    Scalar,
}

/// The class [`matmul_into`] uses for this shape.
pub fn matmul_class(m: usize, k: usize, n: usize) -> MatmulClass {
    if simd_active() {
        if use_packed(m, k, n) {
            return MatmulClass::Packed;
        }
        if n >= 8 {
            return MatmulClass::Small;
        }
    }
    MatmulClass::Scalar
}

/// Kernel class for the slot-batched serving matmuls (`out += a @ b`):
/// keyed on the engine's **configured** slot capacity, never the busy-row
/// count of one call. Every serving-path projection — batched decode,
/// single-slot decode, chunked prefill, SwiGLU, and the LM head — resolves
/// its class through this key, so a slot's row bits depend only on
/// `(max_slots, k, n)` and stay identical across occupancy, arrival
/// order, and thread count. `max(1)` keeps the key meaningful for configs
/// without a decode graph.
pub fn serving_class(max_slots: usize, k: usize, n: usize) -> MatmulClass {
    matmul_class(max_slots.max(1), k, n)
}

/// [`serving_class`] for the transposed-rhs (`a @ bᵀ`) serving matmuls.
pub fn serving_nt_class(max_slots: usize, k: usize, n: usize) -> MatmulClass {
    matmul_nt_class(max_slots.max(1), k, n)
}

/// [`matmul_into`] pinned to a pre-resolved class (see [`matmul_class`]).
/// Every class is correct for any shape; the pin only fixes rounding.
pub fn matmul_into_class(
    class: MatmulClass,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    match (active_kernel(), class) {
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx512, MatmulClass::Packed) => {
            // SAFETY: Avx512 resolves only after runtime detection of
            // avx512f+fma; lengths asserted above.
            unsafe { avx512::matmul_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx512, MatmulClass::Small) => {
            // SAFETY: Avx512 resolves only after runtime detection of
            // avx512f+fma; lengths asserted above.
            unsafe { avx512::matmul_small(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx2Fma, MatmulClass::Packed) => {
            // SAFETY: Avx2Fma resolves only after runtime detection of
            // avx2+fma; lengths asserted above.
            unsafe { avx2::matmul_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx2Fma, MatmulClass::Small) => {
            // SAFETY: Avx2Fma resolves only after runtime detection of
            // avx2+fma; lengths asserted above.
            unsafe { avx2::matmul_small(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "aarch64")]
        (Kernel::Neon, MatmulClass::Packed) => {
            // SAFETY: NEON is baseline on aarch64; lengths asserted above.
            unsafe { neon::matmul_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "aarch64")]
        (Kernel::Neon, MatmulClass::Small) => {
            // SAFETY: NEON is baseline on aarch64; lengths asserted above.
            unsafe { neon::matmul_small(a, b, out, m, k, n) }
        }
        _ => scalar::matmul_into(a, b, out, m, k, n),
    }
}

/// The class [`matmul_nt_into`] uses for this shape.
pub fn matmul_nt_class(m: usize, k: usize, n: usize) -> MatmulClass {
    if simd_active() {
        if use_packed(m, k, n) {
            return MatmulClass::Packed;
        }
        if k >= 8 {
            return MatmulClass::Small;
        }
    }
    MatmulClass::Scalar
}

/// [`matmul_nt_into`] pinned to a pre-resolved class (see
/// [`matmul_nt_class`]).
pub fn matmul_nt_into_class(
    class: MatmulClass,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    match (active_kernel(), class) {
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx512, MatmulClass::Packed) => {
            // SAFETY: Avx512 resolves only after runtime detection of
            // avx512f+fma; lengths asserted above.
            unsafe { avx512::matmul_nt_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx512, MatmulClass::Small) => {
            // SAFETY: Avx512 resolves only after runtime detection of
            // avx512f+fma; lengths asserted above.
            unsafe { avx512::matmul_nt_small(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx2Fma, MatmulClass::Packed) => {
            // SAFETY: Avx2Fma resolves only after runtime detection of
            // avx2+fma; lengths asserted above.
            unsafe { avx2::matmul_nt_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx2Fma, MatmulClass::Small) => {
            // SAFETY: Avx2Fma resolves only after runtime detection of
            // avx2+fma; lengths asserted above.
            unsafe { avx2::matmul_nt_small(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "aarch64")]
        (Kernel::Neon, MatmulClass::Packed) => {
            // SAFETY: NEON is baseline on aarch64; lengths asserted above.
            unsafe { neon::matmul_nt_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "aarch64")]
        (Kernel::Neon, MatmulClass::Small) => {
            // SAFETY: NEON is baseline on aarch64; lengths asserted above.
            unsafe { neon::matmul_nt_small(a, b, out, m, k, n) }
        }
        _ => scalar::matmul_nt_into(a, b, out, m, k, n),
    }
}

/// The class [`matmul_tn_into`] uses for this shape. Packed dims: the
/// product is (k × m)·(m × n), so m is the depth.
pub fn matmul_tn_class(m: usize, k: usize, n: usize) -> MatmulClass {
    if simd_active() {
        if use_packed(k, m, n) {
            return MatmulClass::Packed;
        }
        if n >= 8 {
            return MatmulClass::Small;
        }
    }
    MatmulClass::Scalar
}

/// [`matmul_tn_into`] pinned to a pre-resolved class (see
/// [`matmul_tn_class`]).
pub fn matmul_tn_into_class(
    class: MatmulClass,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    match (active_kernel(), class) {
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx512, MatmulClass::Packed) => {
            // SAFETY: Avx512 resolves only after runtime detection of
            // avx512f+fma; lengths asserted above.
            unsafe { avx512::matmul_tn_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx512, MatmulClass::Small) => {
            // SAFETY: Avx512 resolves only after runtime detection of
            // avx512f+fma; lengths asserted above.
            unsafe { avx512::matmul_tn_small(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx2Fma, MatmulClass::Packed) => {
            // SAFETY: Avx2Fma resolves only after runtime detection of
            // avx2+fma; lengths asserted above.
            unsafe { avx2::matmul_tn_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "x86_64")]
        (Kernel::Avx2Fma, MatmulClass::Small) => {
            // SAFETY: Avx2Fma resolves only after runtime detection of
            // avx2+fma; lengths asserted above.
            unsafe { avx2::matmul_tn_small(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "aarch64")]
        (Kernel::Neon, MatmulClass::Packed) => {
            // SAFETY: NEON is baseline on aarch64; lengths asserted above.
            unsafe { neon::matmul_tn_packed(a, b, out, m, k, n) }
        }
        #[cfg(target_arch = "aarch64")]
        (Kernel::Neon, MatmulClass::Small) => {
            // SAFETY: NEON is baseline on aarch64; lengths asserted above.
            unsafe { neon::matmul_tn_small(a, b, out, m, k, n) }
        }
        _ => scalar::matmul_tn_into(a, b, out, m, k, n),
    }
}

/// Dot product.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    if a.len() >= 8 {
        match active_kernel() {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => {
                // SAFETY: Avx512 resolves only after runtime detection of
                // avx512f+fma; equal lengths asserted above.
                return unsafe { avx512::dot(a, b) };
            }
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2Fma => {
                // SAFETY: Avx2Fma resolves only after runtime detection of
                // avx2+fma; equal lengths asserted above.
                return unsafe { avx2::dot(a, b) };
            }
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => {
                // SAFETY: NEON is baseline on aarch64; equal lengths
                // asserted above.
                return unsafe { neon::dot(a, b) };
            }
            _ => {}
        }
    }
    scalar::dot(a, b)
}

/// y += alpha * x
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    if x.len() >= 8 {
        match active_kernel() {
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx512 => {
                // SAFETY: Avx512 resolves only after runtime detection of
                // avx512f+fma; equal lengths asserted above.
                unsafe { avx512::axpy(alpha, x, y) };
                return;
            }
            #[cfg(target_arch = "x86_64")]
            Kernel::Avx2Fma => {
                // SAFETY: Avx2Fma resolves only after runtime detection of
                // avx2+fma; equal lengths asserted above.
                unsafe { avx2::axpy(alpha, x, y) };
                return;
            }
            #[cfg(target_arch = "aarch64")]
            Kernel::Neon => {
                // SAFETY: NEON is baseline on aarch64; equal lengths
                // asserted above.
                unsafe { neon::axpy(alpha, x, y) };
                return;
            }
            _ => {}
        }
    }
    scalar::axpy(alpha, x, y);
}

// ----------------------------------------------------------------------
// Scalar tier
// ----------------------------------------------------------------------

/// Portable reference kernels: cache-blocked loops with branch-free inner
/// bodies (no zero-skip — the branch defeats autovectorization and makes
/// throughput depend on input sparsity). These are also the parity anchor
/// the SIMD tier is tested against.
pub mod scalar {
    /// out[m,n] += a[m,k] @ b[k,n].
    pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(out.len(), m * n);
        const BK: usize = 64;
        for k0 in (0..k).step_by(BK) {
            let kend = (k0 + BK).min(k);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                for kk in k0..kend {
                    let av = arow[kk];
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
        }
    }

    /// out[m,n] += a[m,k] @ b[n,k]^T.
    pub fn matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(out.len(), m * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += dot(arow, &b[j * k..(j + 1) * k]);
            }
        }
    }

    /// out[k,n] += a[m,k]^T @ b[m,n]: rank-1 row updates so the inner loop
    /// is a fused axpy over contiguous slices.
    pub fn matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        debug_assert_eq!(out.len(), k * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                axpy(av, brow, &mut out[kk * n..(kk + 1) * n]);
            }
        }
    }

    /// Dot product with 4-way unrolling.
    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = [0.0f32; 4];
        let chunks = a.len() / 4;
        for c in 0..chunks {
            let i = c * 4;
            acc[0] += a[i] * b[i];
            acc[1] += a[i + 1] * b[i + 1];
            acc[2] += a[i + 2] * b[i + 2];
            acc[3] += a[i + 3] * b[i + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for i in chunks * 4..a.len() {
            s += a[i] * b[i];
        }
        s
    }

    /// y += alpha * x
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        for (yi, xi) in y.iter_mut().zip(x.iter()) {
            *yi += alpha * xi;
        }
    }
}

// ----------------------------------------------------------------------
// AVX2+FMA tier
// ----------------------------------------------------------------------

/// AVX2+FMA kernels. Every public function is `unsafe`: the caller must
/// have confirmed `avx2` and `fma` via runtime detection (the dispatchers
/// above do; tests must guard explicitly). Inside them, each unsafe
/// operation sits in its own scoped `unsafe {}` block with a SAFETY note
/// (`#![deny(unsafe_op_in_unsafe_fn)]` at the crate root enforces the
/// scoping; `efla-lint` checks the notes).
#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    use std::arch::x86_64::*;
    use std::cell::RefCell;

    /// Microkernel rows (broadcast lanes of A).
    pub const MR: usize = 6;
    /// Microkernel columns (two 8-lane ymm vectors of B).
    pub const NR: usize = 16;
    // Cache blocking in f32 counts: the packed B block (KC×NC = 256 KiB)
    // targets L2, each packed A block (MC×KC = 96 KiB) streams through L1
    // in MR-row strips.
    const MC: usize = 96; // multiple of MR
    const KC: usize = 256;
    const NC: usize = 256; // multiple of NR

    thread_local! {
        /// Per-thread packing buffers (A panel, B panel): steady-state
        /// packed GEMM calls allocate nothing.
        static PACK: RefCell<(Vec<f32>, Vec<f32>)> =
            const { RefCell::new((Vec::new(), Vec::new())) };
    }

    /// Horizontal sum of 8 lanes. Safe `#[target_feature]` fn: it uses
    /// only value-based intrinsics, and its callers (the kernels below)
    /// enable the same features, so calling it there needs no `unsafe`.
    #[target_feature(enable = "avx2", enable = "fma")]
    fn hsum8(v: __m256) -> f32 {
        let lo = _mm256_castps256_ps128(v);
        let hi = _mm256_extractf128_ps(v, 1);
        let s = _mm_add_ps(lo, hi);
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Dot product, two 8-lane FMA accumulators.
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected); `a.len() == b.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 16 <= n {
            // SAFETY: i + 16 <= n == a.len() == b.len(), so both 8-lane
            // loads at i and i + 8 stay in bounds.
            let (a0, b0, a1, b1) = unsafe {
                (
                    _mm256_loadu_ps(ap.add(i)),
                    _mm256_loadu_ps(bp.add(i)),
                    _mm256_loadu_ps(ap.add(i + 8)),
                    _mm256_loadu_ps(bp.add(i + 8)),
                )
            };
            acc0 = _mm256_fmadd_ps(a0, b0, acc0);
            acc1 = _mm256_fmadd_ps(a1, b1, acc1);
            i += 16;
        }
        if i + 8 <= n {
            // SAFETY: i + 8 <= n, so one 8-lane load per operand fits.
            let (a0, b0) = unsafe { (_mm256_loadu_ps(ap.add(i)), _mm256_loadu_ps(bp.add(i))) };
            acc0 = _mm256_fmadd_ps(a0, b0, acc0);
            i += 8;
        }
        let mut s = hsum8(_mm256_add_ps(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// y += alpha * x, 8 lanes per FMA.
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected); `x.len() == y.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = _mm256_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n == x.len() == y.len(), so the 8-lane
            // load/store pair at offset i stays in bounds.
            unsafe {
                let xv = _mm256_loadu_ps(xp.add(i));
                let yv = _mm256_loadu_ps(yp.add(i));
                _mm256_storeu_ps(yp.add(i), _mm256_fmadd_ps(av, xv, yv));
            }
            i += 8;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    // ---------------- unpacked small-shape paths ----------------

    /// ikj loop with vector axpy rows (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected) and the `matmul_into` length
    /// contract.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                // SAFETY: axpy needs avx2+fma, guaranteed by this fn's own
                // contract; the slice bounds are equal-length rows.
                unsafe { axpy(av, &b[kk * n..(kk + 1) * n], orow) };
            }
        }
    }

    /// Row-dot loop (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected) and the `matmul_nt_into`
    /// length contract.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_nt_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                // SAFETY: dot needs avx2+fma, guaranteed by this fn's own
                // contract; both row slices have length k.
                orow[j] += unsafe { dot(arow, &b[j * k..(j + 1) * k]) };
            }
        }
    }

    /// Rank-1 axpy loop (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected) and the `matmul_tn_into`
    /// length contract.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn matmul_tn_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                // SAFETY: axpy needs avx2+fma, guaranteed by this fn's own
                // contract; the slice bounds are equal-length rows.
                unsafe { axpy(av, brow, &mut out[kk * n..(kk + 1) * n]) };
            }
        }
    }

    // ---------------- packed microkernel path ----------------

    /// MR×NR register tile: `kc` rank-1 updates from the packed panels.
    /// `apack` is column-major MR-wide (`apack[p*MR + r]`), `bpack`
    /// row-major NR-wide (`bpack[p*NR + c]`). 12 ymm accumulators + 2
    /// B loads + 1 broadcast = 15 of the 16 ymm registers.
    ///
    /// # Safety
    /// Requires avx2+fma; `apack.len() >= kc*MR`, `bpack.len() >= kc*NR`.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn microkernel(kc: usize, apack: &[f32], bpack: &[f32], tile: &mut [f32; MR * NR]) {
        debug_assert!(apack.len() >= kc * MR);
        debug_assert!(bpack.len() >= kc * NR);
        let mut ap = apack.as_ptr();
        let mut bp = bpack.as_ptr();
        let mut acc = [_mm256_setzero_ps(); 2 * MR];
        for _ in 0..kc {
            // SAFETY: the length asserts above give apack >= kc*MR and
            // bpack >= kc*NR floats; ap/bp advance MR/NR per iteration
            // for kc iterations, so every load and broadcast deref below
            // stays inside the packed panels.
            unsafe {
                let b0 = _mm256_loadu_ps(bp);
                let b1 = _mm256_loadu_ps(bp.add(8));
                let a0 = _mm256_set1_ps(*ap);
                acc[0] = _mm256_fmadd_ps(a0, b0, acc[0]);
                acc[1] = _mm256_fmadd_ps(a0, b1, acc[1]);
                let a1 = _mm256_set1_ps(*ap.add(1));
                acc[2] = _mm256_fmadd_ps(a1, b0, acc[2]);
                acc[3] = _mm256_fmadd_ps(a1, b1, acc[3]);
                let a2 = _mm256_set1_ps(*ap.add(2));
                acc[4] = _mm256_fmadd_ps(a2, b0, acc[4]);
                acc[5] = _mm256_fmadd_ps(a2, b1, acc[5]);
                let a3 = _mm256_set1_ps(*ap.add(3));
                acc[6] = _mm256_fmadd_ps(a3, b0, acc[6]);
                acc[7] = _mm256_fmadd_ps(a3, b1, acc[7]);
                let a4 = _mm256_set1_ps(*ap.add(4));
                acc[8] = _mm256_fmadd_ps(a4, b0, acc[8]);
                acc[9] = _mm256_fmadd_ps(a4, b1, acc[9]);
                let a5 = _mm256_set1_ps(*ap.add(5));
                acc[10] = _mm256_fmadd_ps(a5, b0, acc[10]);
                acc[11] = _mm256_fmadd_ps(a5, b1, acc[11]);
                ap = ap.add(MR);
                bp = bp.add(NR);
            }
        }
        let tp = tile.as_mut_ptr();
        for r in 0..MR {
            // SAFETY: tile holds MR*NR floats and r < MR, so both 8-lane
            // stores (at r*NR and r*NR + 8, with NR == 16) fit.
            unsafe {
                _mm256_storeu_ps(tp.add(r * NR), acc[2 * r]);
                _mm256_storeu_ps(tp.add(r * NR + 8), acc[2 * r + 1]);
            }
        }
    }

    /// Pack an `mr`×`kc` strip of op(A) into a column-major MR-wide panel,
    /// zero-padded to MR rows. `at(r, p)` indexes op(A) in absolute
    /// operand coordinates.
    fn pack_a(dst: &mut [f32], mr: usize, kc: usize, at: impl Fn(usize, usize) -> f32) {
        for p in 0..kc {
            let drow = &mut dst[p * MR..(p + 1) * MR];
            for (r, d) in drow.iter_mut().take(mr).enumerate() {
                *d = at(r, p);
            }
            drow[mr..].fill(0.0);
        }
    }

    /// Pack a `kc`×`nr` strip of op(B) into a row-major NR-wide panel,
    /// zero-padded to NR columns. `bt(p, c)` indexes op(B) absolutely.
    fn pack_b(dst: &mut [f32], nr: usize, kc: usize, bt: impl Fn(usize, usize) -> f32) {
        for p in 0..kc {
            let drow = &mut dst[p * NR..(p + 1) * NR];
            for (c, d) in drow.iter_mut().take(nr).enumerate() {
                *d = bt(p, c);
            }
            drow[nr..].fill(0.0);
        }
    }

    /// Packed driver: out(m×n) += opA(m×k) · opB(k×n), with `at(i, p)` /
    /// `bt(p, j)` indexing the logical operands. Plain (non-annotated)
    /// generic fn — only the concrete [`microkernel`] carries
    /// `#[target_feature]`; packing and the tile scatter-add are scalar.
    ///
    /// # Safety
    /// Requires avx2+fma (for the microkernel calls); `out.len() == m*n`;
    /// `at`/`bt` must be in-bounds for the full logical index ranges.
    unsafe fn gemm_packed(
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        apack: &mut Vec<f32>,
        bpack: &mut Vec<f32>,
        at: impl Fn(usize, usize) -> f32 + Copy,
        bt: impl Fn(usize, usize) -> f32 + Copy,
    ) {
        debug_assert_eq!(out.len(), m * n);
        apack.resize(MC * KC, 0.0);
        bpack.resize(KC * NC, 0.0);
        let mut tile = [0.0f32; MR * NR];
        let mut p0 = 0usize;
        while p0 < k {
            let kc = KC.min(k - p0);
            let mut j0 = 0usize;
            while j0 < n {
                let nc = NC.min(n - j0);
                let npan = nc.div_ceil(NR);
                for jp in 0..npan {
                    let j = j0 + jp * NR;
                    let nr = NR.min(n - j);
                    pack_b(&mut bpack[jp * kc * NR..(jp + 1) * kc * NR], nr, kc, |p, c| {
                        bt(p0 + p, j + c)
                    });
                }
                let mut i0 = 0usize;
                while i0 < m {
                    let mc = MC.min(m - i0);
                    let mpan = mc.div_ceil(MR);
                    for ip in 0..mpan {
                        let i = i0 + ip * MR;
                        let mr = MR.min(m - i);
                        pack_a(&mut apack[ip * kc * MR..(ip + 1) * kc * MR], mr, kc, |r, p| {
                            at(i + r, p0 + p)
                        });
                    }
                    for jp in 0..npan {
                        let j = j0 + jp * NR;
                        let nr = NR.min(n - j);
                        let bpan = &bpack[jp * kc * NR..(jp + 1) * kc * NR];
                        for ip in 0..mpan {
                            let i = i0 + ip * MR;
                            let mr = MR.min(m - i);
                            // SAFETY: avx2+fma holds per this fn's own
                            // contract; both panel slices hold exactly
                            // kc*MR / kc*NR floats.
                            unsafe {
                                microkernel(
                                    kc,
                                    &apack[ip * kc * MR..(ip + 1) * kc * MR],
                                    bpan,
                                    &mut tile,
                                );
                            }
                            for r in 0..mr {
                                let orow = &mut out[(i + r) * n + j..(i + r) * n + j + nr];
                                for (o, &t) in orow.iter_mut().zip(tile[r * NR..].iter()) {
                                    *o += t;
                                }
                            }
                        }
                    }
                    i0 += MC;
                }
                j0 += NC;
            }
            p0 += KC;
        }
    }

    /// Packed `out += a @ b`.
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected) and the `matmul_into` length
    /// contract.
    pub unsafe fn matmul_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: caller guarantees avx2+fma; closures index within the
            // asserted operand lengths.
            unsafe {
                gemm_packed(out, m, k, n, apack, bpack, |i, p| a[i * k + p], |p, j| b[p * n + j]);
            }
        });
    }

    /// Packed `out += a @ b^T` (b stored n×k row-major).
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected) and the `matmul_nt_into`
    /// length contract.
    pub unsafe fn matmul_nt_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: caller guarantees avx2+fma; closures index within the
            // asserted operand lengths.
            unsafe {
                gemm_packed(out, m, k, n, apack, bpack, |i, p| a[i * k + p], |p, j| b[j * k + p]);
            }
        });
    }

    /// Packed `out += a^T @ b` (a stored m×k row-major, out k×n): the
    /// logical product is (k×m)·(m×n), so the packed depth is m.
    ///
    /// # Safety
    /// Requires avx2+fma (runtime-detected) and the `matmul_tn_into`
    /// length contract.
    pub unsafe fn matmul_tn_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: caller guarantees avx2+fma; closures index within the
            // asserted operand lengths.
            unsafe {
                gemm_packed(out, k, m, n, apack, bpack, |i, p| a[p * k + i], |p, j| b[p * n + j]);
            }
        });
    }
}

// ----------------------------------------------------------------------
// AVX-512F tier
// ----------------------------------------------------------------------

/// AVX-512F kernels: the [`avx2`] structure widened to two 16-lane zmm
/// columns per microkernel row (12 accumulators + 2 B loads + 1 broadcast
/// = 15 of the 32 zmm registers). Every public function is `unsafe`: the
/// caller must have confirmed `avx512f` and `fma` via runtime detection
/// (the dispatchers above do; tests must guard explicitly).
#[cfg(target_arch = "x86_64")]
pub mod avx512 {
    use std::arch::x86_64::*;
    use std::cell::RefCell;

    /// Microkernel rows (broadcast lanes of A).
    pub const MR: usize = 6;
    /// Microkernel columns (two 16-lane zmm vectors of B).
    pub const NR: usize = 32;
    // Cache blocking in f32 counts, matching the avx2 tier: the packed B
    // block (KC×NC = 256 KiB) targets L2, each packed A block (MC×KC =
    // 96 KiB) streams through L1 in MR-row strips.
    const MC: usize = 96; // multiple of MR
    const KC: usize = 256;
    const NC: usize = 256; // multiple of NR

    thread_local! {
        /// Per-thread packing buffers (A panel, B panel): steady-state
        /// packed GEMM calls allocate nothing.
        static PACK: RefCell<(Vec<f32>, Vec<f32>)> =
            const { RefCell::new((Vec::new(), Vec::new())) };
    }

    /// Dot product, two 16-lane FMA accumulators.
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected); `a.len() == b.len()`.
    #[target_feature(enable = "avx512f", enable = "fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm512_setzero_ps();
        let mut acc1 = _mm512_setzero_ps();
        let mut i = 0usize;
        while i + 32 <= n {
            // SAFETY: i + 32 <= n == a.len() == b.len(), so both 16-lane
            // loads at i and i + 16 stay in bounds.
            let (a0, b0, a1, b1) = unsafe {
                (
                    _mm512_loadu_ps(ap.add(i)),
                    _mm512_loadu_ps(bp.add(i)),
                    _mm512_loadu_ps(ap.add(i + 16)),
                    _mm512_loadu_ps(bp.add(i + 16)),
                )
            };
            acc0 = _mm512_fmadd_ps(a0, b0, acc0);
            acc1 = _mm512_fmadd_ps(a1, b1, acc1);
            i += 32;
        }
        if i + 16 <= n {
            // SAFETY: i + 16 <= n, so one 16-lane load per operand fits.
            let (a0, b0) = unsafe { (_mm512_loadu_ps(ap.add(i)), _mm512_loadu_ps(bp.add(i))) };
            acc0 = _mm512_fmadd_ps(a0, b0, acc0);
            i += 16;
        }
        let mut s = _mm512_reduce_add_ps(_mm512_add_ps(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// y += alpha * x, 16 lanes per FMA.
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected); `x.len() == y.len()`.
    #[target_feature(enable = "avx512f", enable = "fma")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = _mm512_set1_ps(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            // SAFETY: i + 16 <= n == x.len() == y.len(), so the 16-lane
            // load/store pair at offset i stays in bounds.
            unsafe {
                let xv = _mm512_loadu_ps(xp.add(i));
                let yv = _mm512_loadu_ps(yp.add(i));
                _mm512_storeu_ps(yp.add(i), _mm512_fmadd_ps(av, xv, yv));
            }
            i += 16;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    // ---------------- unpacked small-shape paths ----------------

    /// ikj loop with vector axpy rows (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected) and the `matmul_into`
    /// length contract.
    #[target_feature(enable = "avx512f", enable = "fma")]
    pub unsafe fn matmul_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                // SAFETY: axpy needs avx512f+fma, guaranteed by this fn's
                // own contract; the slice bounds are equal-length rows.
                unsafe { axpy(av, &b[kk * n..(kk + 1) * n], orow) };
            }
        }
    }

    /// Row-dot loop (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected) and the `matmul_nt_into`
    /// length contract.
    #[target_feature(enable = "avx512f", enable = "fma")]
    pub unsafe fn matmul_nt_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                // SAFETY: dot needs avx512f+fma, guaranteed by this fn's
                // own contract; both row slices have length k.
                orow[j] += unsafe { dot(arow, &b[j * k..(j + 1) * k]) };
            }
        }
    }

    /// Rank-1 axpy loop (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected) and the `matmul_tn_into`
    /// length contract.
    #[target_feature(enable = "avx512f", enable = "fma")]
    pub unsafe fn matmul_tn_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                // SAFETY: axpy needs avx512f+fma, guaranteed by this fn's
                // own contract; the slice bounds are equal-length rows.
                unsafe { axpy(av, brow, &mut out[kk * n..(kk + 1) * n]) };
            }
        }
    }

    // ---------------- packed microkernel path ----------------

    /// MR×NR register tile: `kc` rank-1 updates from the packed panels.
    /// `apack` is column-major MR-wide (`apack[p*MR + r]`), `bpack`
    /// row-major NR-wide (`bpack[p*NR + c]`). 12 zmm accumulators + 2
    /// B loads + 1 broadcast = 15 of the 32 zmm registers.
    ///
    /// # Safety
    /// Requires avx512f+fma; `apack.len() >= kc*MR`,
    /// `bpack.len() >= kc*NR`.
    #[target_feature(enable = "avx512f", enable = "fma")]
    unsafe fn microkernel(kc: usize, apack: &[f32], bpack: &[f32], tile: &mut [f32; MR * NR]) {
        debug_assert!(apack.len() >= kc * MR);
        debug_assert!(bpack.len() >= kc * NR);
        let mut ap = apack.as_ptr();
        let mut bp = bpack.as_ptr();
        let mut acc = [_mm512_setzero_ps(); 2 * MR];
        for _ in 0..kc {
            // SAFETY: the length asserts above give apack >= kc*MR and
            // bpack >= kc*NR floats; ap/bp advance MR/NR per iteration
            // for kc iterations, so every load and broadcast deref below
            // stays inside the packed panels.
            unsafe {
                let b0 = _mm512_loadu_ps(bp);
                let b1 = _mm512_loadu_ps(bp.add(16));
                let a0 = _mm512_set1_ps(*ap);
                acc[0] = _mm512_fmadd_ps(a0, b0, acc[0]);
                acc[1] = _mm512_fmadd_ps(a0, b1, acc[1]);
                let a1 = _mm512_set1_ps(*ap.add(1));
                acc[2] = _mm512_fmadd_ps(a1, b0, acc[2]);
                acc[3] = _mm512_fmadd_ps(a1, b1, acc[3]);
                let a2 = _mm512_set1_ps(*ap.add(2));
                acc[4] = _mm512_fmadd_ps(a2, b0, acc[4]);
                acc[5] = _mm512_fmadd_ps(a2, b1, acc[5]);
                let a3 = _mm512_set1_ps(*ap.add(3));
                acc[6] = _mm512_fmadd_ps(a3, b0, acc[6]);
                acc[7] = _mm512_fmadd_ps(a3, b1, acc[7]);
                let a4 = _mm512_set1_ps(*ap.add(4));
                acc[8] = _mm512_fmadd_ps(a4, b0, acc[8]);
                acc[9] = _mm512_fmadd_ps(a4, b1, acc[9]);
                let a5 = _mm512_set1_ps(*ap.add(5));
                acc[10] = _mm512_fmadd_ps(a5, b0, acc[10]);
                acc[11] = _mm512_fmadd_ps(a5, b1, acc[11]);
                ap = ap.add(MR);
                bp = bp.add(NR);
            }
        }
        let tp = tile.as_mut_ptr();
        for r in 0..MR {
            // SAFETY: tile holds MR*NR floats and r < MR, so both 16-lane
            // stores (at r*NR and r*NR + 16, with NR == 32) fit.
            unsafe {
                _mm512_storeu_ps(tp.add(r * NR), acc[2 * r]);
                _mm512_storeu_ps(tp.add(r * NR + 16), acc[2 * r + 1]);
            }
        }
    }

    /// Pack an `mr`×`kc` strip of op(A) into a column-major MR-wide panel,
    /// zero-padded to MR rows. `at(r, p)` indexes op(A) in absolute
    /// operand coordinates.
    fn pack_a(dst: &mut [f32], mr: usize, kc: usize, at: impl Fn(usize, usize) -> f32) {
        for p in 0..kc {
            let drow = &mut dst[p * MR..(p + 1) * MR];
            for (r, d) in drow.iter_mut().take(mr).enumerate() {
                *d = at(r, p);
            }
            drow[mr..].fill(0.0);
        }
    }

    /// Pack a `kc`×`nr` strip of op(B) into a row-major NR-wide panel,
    /// zero-padded to NR columns. `bt(p, c)` indexes op(B) absolutely.
    fn pack_b(dst: &mut [f32], nr: usize, kc: usize, bt: impl Fn(usize, usize) -> f32) {
        for p in 0..kc {
            let drow = &mut dst[p * NR..(p + 1) * NR];
            for (c, d) in drow.iter_mut().take(nr).enumerate() {
                *d = bt(p, c);
            }
            drow[nr..].fill(0.0);
        }
    }

    /// Packed driver: out(m×n) += opA(m×k) · opB(k×n), with `at(i, p)` /
    /// `bt(p, j)` indexing the logical operands. Plain (non-annotated)
    /// generic fn — only the concrete [`microkernel`] carries
    /// `#[target_feature]`; packing and the tile scatter-add are scalar.
    ///
    /// # Safety
    /// Requires avx512f+fma (for the microkernel calls);
    /// `out.len() == m*n`; `at`/`bt` must be in-bounds for the full
    /// logical index ranges.
    unsafe fn gemm_packed(
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        apack: &mut Vec<f32>,
        bpack: &mut Vec<f32>,
        at: impl Fn(usize, usize) -> f32 + Copy,
        bt: impl Fn(usize, usize) -> f32 + Copy,
    ) {
        debug_assert_eq!(out.len(), m * n);
        apack.resize(MC * KC, 0.0);
        bpack.resize(KC * NC, 0.0);
        let mut tile = [0.0f32; MR * NR];
        let mut p0 = 0usize;
        while p0 < k {
            let kc = KC.min(k - p0);
            let mut j0 = 0usize;
            while j0 < n {
                let nc = NC.min(n - j0);
                let npan = nc.div_ceil(NR);
                for jp in 0..npan {
                    let j = j0 + jp * NR;
                    let nr = NR.min(n - j);
                    pack_b(&mut bpack[jp * kc * NR..(jp + 1) * kc * NR], nr, kc, |p, c| {
                        bt(p0 + p, j + c)
                    });
                }
                let mut i0 = 0usize;
                while i0 < m {
                    let mc = MC.min(m - i0);
                    let mpan = mc.div_ceil(MR);
                    for ip in 0..mpan {
                        let i = i0 + ip * MR;
                        let mr = MR.min(m - i);
                        pack_a(&mut apack[ip * kc * MR..(ip + 1) * kc * MR], mr, kc, |r, p| {
                            at(i + r, p0 + p)
                        });
                    }
                    for jp in 0..npan {
                        let j = j0 + jp * NR;
                        let nr = NR.min(n - j);
                        let bpan = &bpack[jp * kc * NR..(jp + 1) * kc * NR];
                        for ip in 0..mpan {
                            let i = i0 + ip * MR;
                            let mr = MR.min(m - i);
                            // SAFETY: avx512f+fma holds per this fn's own
                            // contract; both panel slices hold exactly
                            // kc*MR / kc*NR floats.
                            unsafe {
                                microkernel(
                                    kc,
                                    &apack[ip * kc * MR..(ip + 1) * kc * MR],
                                    bpan,
                                    &mut tile,
                                );
                            }
                            for r in 0..mr {
                                let orow = &mut out[(i + r) * n + j..(i + r) * n + j + nr];
                                for (o, &t) in orow.iter_mut().zip(tile[r * NR..].iter()) {
                                    *o += t;
                                }
                            }
                        }
                    }
                    i0 += MC;
                }
                j0 += NC;
            }
            p0 += KC;
        }
    }

    /// Packed `out += a @ b`.
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected) and the `matmul_into`
    /// length contract.
    pub unsafe fn matmul_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: caller guarantees avx512f+fma; closures index within
            // the asserted operand lengths.
            unsafe {
                gemm_packed(out, m, k, n, apack, bpack, |i, p| a[i * k + p], |p, j| b[p * n + j]);
            }
        });
    }

    /// Packed `out += a @ b^T` (b stored n×k row-major).
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected) and the `matmul_nt_into`
    /// length contract.
    pub unsafe fn matmul_nt_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: caller guarantees avx512f+fma; closures index within
            // the asserted operand lengths.
            unsafe {
                gemm_packed(out, m, k, n, apack, bpack, |i, p| a[i * k + p], |p, j| b[j * k + p]);
            }
        });
    }

    /// Packed `out += a^T @ b` (a stored m×k row-major, out k×n): the
    /// logical product is (k×m)·(m×n), so the packed depth is m.
    ///
    /// # Safety
    /// Requires avx512f+fma (runtime-detected) and the `matmul_tn_into`
    /// length contract.
    pub unsafe fn matmul_tn_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: caller guarantees avx512f+fma; closures index within
            // the asserted operand lengths.
            unsafe {
                gemm_packed(out, k, m, n, apack, bpack, |i, p| a[p * k + i], |p, j| b[p * n + j]);
            }
        });
    }
}

// ----------------------------------------------------------------------
// NEON tier (aarch64)
// ----------------------------------------------------------------------

/// NEON kernels: the [`avx2`] structure narrowed to two 4-lane q-register
/// columns per microkernel row. NEON is baseline on aarch64, so no runtime
/// probe is needed, but the functions stay `unsafe` for symmetry with the
/// other tiers: the raw-pointer loads/stores inside carry the same length
/// contracts.
#[cfg(target_arch = "aarch64")]
pub mod neon {
    use std::arch::aarch64::*;
    use std::cell::RefCell;

    /// Microkernel rows (broadcast lanes of A).
    pub const MR: usize = 6;
    /// Microkernel columns (two 4-lane q-register vectors of B).
    pub const NR: usize = 8;
    // Cache blocking in f32 counts, matching the avx2 tier: the packed B
    // block (KC×NC = 256 KiB) targets L2, each packed A block (MC×KC =
    // 96 KiB) streams through L1 in MR-row strips.
    const MC: usize = 96; // multiple of MR
    const KC: usize = 256;
    const NC: usize = 256; // multiple of NR

    thread_local! {
        /// Per-thread packing buffers (A panel, B panel): steady-state
        /// packed GEMM calls allocate nothing.
        static PACK: RefCell<(Vec<f32>, Vec<f32>)> =
            const { RefCell::new((Vec::new(), Vec::new())) };
    }

    /// Dot product, two 4-lane FMA accumulators.
    ///
    /// # Safety
    /// Requires `a.len() == b.len()` (the raw-pointer loads trust it).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n == a.len() == b.len(), so both 4-lane
            // loads at i and i + 4 stay in bounds.
            let (a0, b0, a1, b1) = unsafe {
                (
                    vld1q_f32(ap.add(i)),
                    vld1q_f32(bp.add(i)),
                    vld1q_f32(ap.add(i + 4)),
                    vld1q_f32(bp.add(i + 4)),
                )
            };
            acc0 = vfmaq_f32(acc0, a0, b0);
            acc1 = vfmaq_f32(acc1, a1, b1);
            i += 8;
        }
        if i + 4 <= n {
            // SAFETY: i + 4 <= n, so one 4-lane load per operand fits.
            let (a0, b0) = unsafe { (vld1q_f32(ap.add(i)), vld1q_f32(bp.add(i))) };
            acc0 = vfmaq_f32(acc0, a0, b0);
            i += 4;
        }
        let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    /// y += alpha * x, 4 lanes per FMA.
    ///
    /// # Safety
    /// Requires `x.len() == y.len()` (the raw-pointer loads trust it).
    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), y.len());
        let n = x.len();
        let av = vdupq_n_f32(alpha);
        let xp = x.as_ptr();
        let yp = y.as_mut_ptr();
        let mut i = 0usize;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n == x.len() == y.len(), so the 4-lane
            // load/store pair at offset i stays in bounds.
            unsafe {
                let xv = vld1q_f32(xp.add(i));
                let yv = vld1q_f32(yp.add(i));
                vst1q_f32(yp.add(i), vfmaq_f32(yv, av, xv));
            }
            i += 4;
        }
        while i < n {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    // ---------------- unpacked small-shape paths ----------------

    /// ikj loop with vector axpy rows (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires the `matmul_into` length contract.
    #[target_feature(enable = "neon")]
    pub unsafe fn matmul_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                // SAFETY: the slice bounds are equal-length rows, which is
                // all axpy's contract needs.
                unsafe { axpy(av, &b[kk * n..(kk + 1) * n], orow) };
            }
        }
    }

    /// Row-dot loop (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires the `matmul_nt_into` length contract.
    #[target_feature(enable = "neon")]
    pub unsafe fn matmul_nt_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                // SAFETY: both row slices have length k, which is all
                // dot's contract needs.
                orow[j] += unsafe { dot(arow, &b[j * k..(j + 1) * k]) };
            }
        }
    }

    /// Rank-1 axpy loop (shapes below the packing cutoff).
    ///
    /// # Safety
    /// Requires the `matmul_tn_into` length contract.
    #[target_feature(enable = "neon")]
    pub unsafe fn matmul_tn_small(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (kk, &av) in arow.iter().enumerate() {
                // SAFETY: the slice bounds are equal-length rows, which is
                // all axpy's contract needs.
                unsafe { axpy(av, brow, &mut out[kk * n..(kk + 1) * n]) };
            }
        }
    }

    // ---------------- packed microkernel path ----------------

    /// MR×NR register tile: `kc` rank-1 updates from the packed panels.
    /// `apack` is column-major MR-wide (`apack[p*MR + r]`), `bpack`
    /// row-major NR-wide (`bpack[p*NR + c]`). 12 q-register accumulators
    /// + 2 B loads + 1 broadcast = 15 of the 32 q registers.
    ///
    /// # Safety
    /// Requires `apack.len() >= kc*MR`, `bpack.len() >= kc*NR`.
    #[target_feature(enable = "neon")]
    unsafe fn microkernel(kc: usize, apack: &[f32], bpack: &[f32], tile: &mut [f32; MR * NR]) {
        debug_assert!(apack.len() >= kc * MR);
        debug_assert!(bpack.len() >= kc * NR);
        let mut ap = apack.as_ptr();
        let mut bp = bpack.as_ptr();
        let mut acc = [vdupq_n_f32(0.0); 2 * MR];
        for _ in 0..kc {
            // SAFETY: the length asserts above give apack >= kc*MR and
            // bpack >= kc*NR floats; ap/bp advance MR/NR per iteration
            // for kc iterations, so every load and broadcast deref below
            // stays inside the packed panels.
            unsafe {
                let b0 = vld1q_f32(bp);
                let b1 = vld1q_f32(bp.add(4));
                let a0 = vdupq_n_f32(*ap);
                acc[0] = vfmaq_f32(acc[0], a0, b0);
                acc[1] = vfmaq_f32(acc[1], a0, b1);
                let a1 = vdupq_n_f32(*ap.add(1));
                acc[2] = vfmaq_f32(acc[2], a1, b0);
                acc[3] = vfmaq_f32(acc[3], a1, b1);
                let a2 = vdupq_n_f32(*ap.add(2));
                acc[4] = vfmaq_f32(acc[4], a2, b0);
                acc[5] = vfmaq_f32(acc[5], a2, b1);
                let a3 = vdupq_n_f32(*ap.add(3));
                acc[6] = vfmaq_f32(acc[6], a3, b0);
                acc[7] = vfmaq_f32(acc[7], a3, b1);
                let a4 = vdupq_n_f32(*ap.add(4));
                acc[8] = vfmaq_f32(acc[8], a4, b0);
                acc[9] = vfmaq_f32(acc[9], a4, b1);
                let a5 = vdupq_n_f32(*ap.add(5));
                acc[10] = vfmaq_f32(acc[10], a5, b0);
                acc[11] = vfmaq_f32(acc[11], a5, b1);
                ap = ap.add(MR);
                bp = bp.add(NR);
            }
        }
        let tp = tile.as_mut_ptr();
        for r in 0..MR {
            // SAFETY: tile holds MR*NR floats and r < MR, so both 4-lane
            // stores (at r*NR and r*NR + 4, with NR == 8) fit.
            unsafe {
                vst1q_f32(tp.add(r * NR), acc[2 * r]);
                vst1q_f32(tp.add(r * NR + 4), acc[2 * r + 1]);
            }
        }
    }

    /// Pack an `mr`×`kc` strip of op(A) into a column-major MR-wide panel,
    /// zero-padded to MR rows. `at(r, p)` indexes op(A) in absolute
    /// operand coordinates.
    fn pack_a(dst: &mut [f32], mr: usize, kc: usize, at: impl Fn(usize, usize) -> f32) {
        for p in 0..kc {
            let drow = &mut dst[p * MR..(p + 1) * MR];
            for (r, d) in drow.iter_mut().take(mr).enumerate() {
                *d = at(r, p);
            }
            drow[mr..].fill(0.0);
        }
    }

    /// Pack a `kc`×`nr` strip of op(B) into a row-major NR-wide panel,
    /// zero-padded to NR columns. `bt(p, c)` indexes op(B) absolutely.
    fn pack_b(dst: &mut [f32], nr: usize, kc: usize, bt: impl Fn(usize, usize) -> f32) {
        for p in 0..kc {
            let drow = &mut dst[p * NR..(p + 1) * NR];
            for (c, d) in drow.iter_mut().take(nr).enumerate() {
                *d = bt(p, c);
            }
            drow[nr..].fill(0.0);
        }
    }

    /// Packed driver: out(m×n) += opA(m×k) · opB(k×n), with `at(i, p)` /
    /// `bt(p, j)` indexing the logical operands. Plain (non-annotated)
    /// generic fn — only the concrete [`microkernel`] carries
    /// `#[target_feature]`; packing and the tile scatter-add are scalar.
    ///
    /// # Safety
    /// `out.len() == m*n`; `at`/`bt` must be in-bounds for the full
    /// logical index ranges (the microkernel calls trust the panels).
    unsafe fn gemm_packed(
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        apack: &mut Vec<f32>,
        bpack: &mut Vec<f32>,
        at: impl Fn(usize, usize) -> f32 + Copy,
        bt: impl Fn(usize, usize) -> f32 + Copy,
    ) {
        debug_assert_eq!(out.len(), m * n);
        apack.resize(MC * KC, 0.0);
        bpack.resize(KC * NC, 0.0);
        let mut tile = [0.0f32; MR * NR];
        let mut p0 = 0usize;
        while p0 < k {
            let kc = KC.min(k - p0);
            let mut j0 = 0usize;
            while j0 < n {
                let nc = NC.min(n - j0);
                let npan = nc.div_ceil(NR);
                for jp in 0..npan {
                    let j = j0 + jp * NR;
                    let nr = NR.min(n - j);
                    pack_b(&mut bpack[jp * kc * NR..(jp + 1) * kc * NR], nr, kc, |p, c| {
                        bt(p0 + p, j + c)
                    });
                }
                let mut i0 = 0usize;
                while i0 < m {
                    let mc = MC.min(m - i0);
                    let mpan = mc.div_ceil(MR);
                    for ip in 0..mpan {
                        let i = i0 + ip * MR;
                        let mr = MR.min(m - i);
                        pack_a(&mut apack[ip * kc * MR..(ip + 1) * kc * MR], mr, kc, |r, p| {
                            at(i + r, p0 + p)
                        });
                    }
                    for jp in 0..npan {
                        let j = j0 + jp * NR;
                        let nr = NR.min(n - j);
                        let bpan = &bpack[jp * kc * NR..(jp + 1) * kc * NR];
                        for ip in 0..mpan {
                            let i = i0 + ip * MR;
                            let mr = MR.min(m - i);
                            // SAFETY: both panel slices hold exactly
                            // kc*MR / kc*NR floats, satisfying the
                            // microkernel's contract.
                            unsafe {
                                microkernel(
                                    kc,
                                    &apack[ip * kc * MR..(ip + 1) * kc * MR],
                                    bpan,
                                    &mut tile,
                                );
                            }
                            for r in 0..mr {
                                let orow = &mut out[(i + r) * n + j..(i + r) * n + j + nr];
                                for (o, &t) in orow.iter_mut().zip(tile[r * NR..].iter()) {
                                    *o += t;
                                }
                            }
                        }
                    }
                    i0 += MC;
                }
                j0 += NC;
            }
            p0 += KC;
        }
    }

    /// Packed `out += a @ b`.
    ///
    /// # Safety
    /// Requires the `matmul_into` length contract.
    pub unsafe fn matmul_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: closures index within the asserted operand lengths.
            unsafe {
                gemm_packed(out, m, k, n, apack, bpack, |i, p| a[i * k + p], |p, j| b[p * n + j]);
            }
        });
    }

    /// Packed `out += a @ b^T` (b stored n×k row-major).
    ///
    /// # Safety
    /// Requires the `matmul_nt_into` length contract.
    pub unsafe fn matmul_nt_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: closures index within the asserted operand lengths.
            unsafe {
                gemm_packed(out, m, k, n, apack, bpack, |i, p| a[i * k + p], |p, j| b[j * k + p]);
            }
        });
    }

    /// Packed `out += a^T @ b` (a stored m×k row-major, out k×n): the
    /// logical product is (k×m)·(m×n), so the packed depth is m.
    ///
    /// # Safety
    /// Requires the `matmul_tn_into` length contract.
    pub unsafe fn matmul_tn_packed(
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        PACK.with(|cell| {
            let (apack, bpack) = &mut *cell.borrow_mut();
            // SAFETY: closures index within the asserted operand lengths.
            unsafe {
                gemm_packed(out, k, m, n, apack, bpack, |i, p| a[p * k + i], |p, j| b[p * n + j]);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b.iter()).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    /// Rectangular sizes chosen to hit full tiles, remainder rows/cols
    /// (m % 6, n % 16), sub-cutoff small shapes, and >KC depths.
    const SIZES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 7, 5),
        (3, 5, 7),
        (6, 16, 16),
        (7, 17, 33),
        (12, 64, 48),
        (13, 300, 31),
        (64, 64, 64),
        (61, 67, 129),
        (128, 32, 256),
    ];

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        // Small sigma keeps the reassociation error of deep k-sums well
        // under the 1e-5 parity tolerance.
        rng.normal_vec(n, 0.0, 0.05)
    }

    #[test]
    fn dispatched_matmul_matches_scalar_all_shapes() {
        let mut rng = Rng::new(101);
        for &(m, k, n) in SIZES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_into(&a, &b, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            matmul_into(&a, &b, &mut c, m, k, n);
            assert!(
                max_abs_diff(&c_ref, &c) <= 1e-5,
                "nn {m}x{k}x{n}: diff {}",
                max_abs_diff(&c_ref, &c)
            );
        }
    }

    #[test]
    fn dispatched_matmul_nt_matches_scalar_all_shapes() {
        let mut rng = Rng::new(102);
        for &(m, k, n) in SIZES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, n * k);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_nt_into(&a, &b, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            matmul_nt_into(&a, &b, &mut c, m, k, n);
            assert!(
                max_abs_diff(&c_ref, &c) <= 1e-5,
                "nt {m}x{k}x{n}: diff {}",
                max_abs_diff(&c_ref, &c)
            );
        }
    }

    #[test]
    fn dispatched_matmul_tn_matches_scalar_all_shapes() {
        let mut rng = Rng::new(103);
        for &(m, k, n) in SIZES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, m * n);
            let mut c_ref = vec![0.0f32; k * n];
            scalar::matmul_tn_into(&a, &b, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; k * n];
            matmul_tn_into(&a, &b, &mut c, m, k, n);
            assert!(
                max_abs_diff(&c_ref, &c) <= 1e-5,
                "tn {m}x{k}x{n}: diff {}",
                max_abs_diff(&c_ref, &c)
            );
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn packed_avx2_matches_scalar_even_below_cutoff() {
        // Feature-detection guard (not active_kernel): the tier under test
        // stays covered on hosts where dispatch resolves to AVX-512.
        if !(is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")) {
            return; // no AVX2 on this host: nothing to pin
        }
        let mut rng = Rng::new(104);
        for &(m, k, n) in SIZES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_into(&a, &b, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            // SAFETY: the feature guard above confirmed avx2+fma.
            unsafe { avx2::matmul_packed(&a, &b, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "packed nn {m}x{k}x{n}");

            let bt = rand_vec(&mut rng, n * k);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_nt_into(&a, &bt, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            // SAFETY: the feature guard above confirmed avx2+fma.
            unsafe { avx2::matmul_nt_packed(&a, &bt, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "packed nt {m}x{k}x{n}");

            let bb = rand_vec(&mut rng, m * n);
            let mut c_ref = vec![0.0f32; k * n];
            scalar::matmul_tn_into(&a, &bb, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; k * n];
            // SAFETY: the feature guard above confirmed avx2+fma.
            unsafe { avx2::matmul_tn_packed(&a, &bb, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "packed tn {m}x{k}x{n}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn packed_avx512_matches_scalar_even_below_cutoff() {
        if !(is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("fma")) {
            return; // no AVX-512F on this host: nothing to pin
        }
        let mut rng = Rng::new(109);
        for &(m, k, n) in SIZES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_into(&a, &b, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            // SAFETY: the feature guard above confirmed avx512f+fma.
            unsafe { avx512::matmul_packed(&a, &b, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "avx512 nn {m}x{k}x{n}");

            let bt = rand_vec(&mut rng, n * k);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_nt_into(&a, &bt, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            // SAFETY: the feature guard above confirmed avx512f+fma.
            unsafe { avx512::matmul_nt_packed(&a, &bt, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "avx512 nt {m}x{k}x{n}");

            let bb = rand_vec(&mut rng, m * n);
            let mut c_ref = vec![0.0f32; k * n];
            scalar::matmul_tn_into(&a, &bb, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; k * n];
            // SAFETY: the feature guard above confirmed avx512f+fma.
            unsafe { avx512::matmul_tn_packed(&a, &bb, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "avx512 tn {m}x{k}x{n}");
        }
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn packed_neon_matches_scalar_even_below_cutoff() {
        let mut rng = Rng::new(110);
        for &(m, k, n) in SIZES {
            let a = rand_vec(&mut rng, m * k);
            let b = rand_vec(&mut rng, k * n);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_into(&a, &b, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            // SAFETY: NEON is baseline on aarch64; operand lengths match
            // the matmul_into contract by construction.
            unsafe { neon::matmul_packed(&a, &b, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "neon nn {m}x{k}x{n}");

            let bt = rand_vec(&mut rng, n * k);
            let mut c_ref = vec![0.0f32; m * n];
            scalar::matmul_nt_into(&a, &bt, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; m * n];
            // SAFETY: NEON is baseline on aarch64; operand lengths match
            // the matmul_nt_into contract by construction.
            unsafe { neon::matmul_nt_packed(&a, &bt, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "neon nt {m}x{k}x{n}");

            let bb = rand_vec(&mut rng, m * n);
            let mut c_ref = vec![0.0f32; k * n];
            scalar::matmul_tn_into(&a, &bb, &mut c_ref, m, k, n);
            let mut c = vec![0.0f32; k * n];
            // SAFETY: NEON is baseline on aarch64; operand lengths match
            // the matmul_tn_into contract by construction.
            unsafe { neon::matmul_tn_packed(&a, &bb, &mut c, m, k, n) };
            assert!(max_abs_diff(&c_ref, &c) <= 1e-5, "neon tn {m}x{k}x{n}");
        }
    }

    #[test]
    fn accumulate_semantics_preserved() {
        // All entry points are +=: a pre-filled out must keep its base.
        let mut rng = Rng::new(105);
        let (m, k, n) = (9, 11, 19);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let base: Vec<f32> = (0..m * n).map(|i| i as f32 * 0.01).collect();
        let mut c_ref = base.clone();
        scalar::matmul_into(&a, &b, &mut c_ref, m, k, n);
        let mut c = base;
        matmul_into(&a, &b, &mut c, m, k, n);
        assert!(max_abs_diff(&c_ref, &c) <= 1e-5);
    }

    #[test]
    fn dot_and_axpy_match_scalar_with_remainders() {
        let mut rng = Rng::new(106);
        for len in [1usize, 3, 7, 8, 9, 15, 16, 17, 31, 40, 127, 256] {
            let a = rand_vec(&mut rng, len);
            let b = rand_vec(&mut rng, len);
            let d_ref = scalar::dot(&a, &b);
            let d = dot(&a, &b);
            assert!((d_ref - d).abs() <= 1e-5, "dot len {len}: {d_ref} vs {d}");

            let mut y_ref = b.clone();
            scalar::axpy(0.37, &a, &mut y_ref);
            let mut y = b.clone();
            axpy(0.37, &a, &mut y);
            assert!(max_abs_diff(&y_ref, &y) <= 1e-5, "axpy len {len}");
        }
    }

    #[test]
    fn dot_unroll_tail() {
        let a: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..7).map(|i| (i * 2) as f32).collect();
        let expect: f32 = (0..7).map(|i| (i * i * 2) as f32).sum();
        assert!((dot(&a, &b) - expect).abs() < 1e-5);
        assert!((scalar::dot(&a, &b) - expect).abs() < 1e-5);
    }

    // NOTE: no force_kernel test here on purpose — flipping the global
    // dispatcher would race the bit-exact assertions of sibling lib tests
    // running on other harness threads. The force/round-trip behavior is
    // pinned by tests/force_scalar.rs and tests/grad_check_paths.rs,
    // which are single-test binaries.

    #[test]
    fn matmul_class_pins_chunks_to_the_full_shape_kernel() {
        // Row-split callers run chunks through the full-shape class; a
        // 2-row chunk under a Packed class must match the full packed run
        // row for row, bit for bit.
        let mut rng = Rng::new(107);
        let (m, k, n) = (64, 64, 64);
        let a = rand_vec(&mut rng, m * k);
        let b = rand_vec(&mut rng, k * n);
        let class = matmul_class(m, k, n);
        let mut full = vec![0.0f32; m * n];
        matmul_into_class(class, &a, &b, &mut full, m, k, n);
        let mut chunked = vec![0.0f32; m * n];
        for r0 in (0..m).step_by(2) {
            matmul_into_class(
                class,
                &a[r0 * k..(r0 + 2) * k],
                &b,
                &mut chunked[r0 * n..(r0 + 2) * n],
                2,
                k,
                n,
            );
        }
        assert_eq!(full, chunked, "row arithmetic must be chunk-invariant within a class");

        let bt = rand_vec(&mut rng, n * k);
        let class = matmul_nt_class(m, k, n);
        let mut full = vec![0.0f32; m * n];
        matmul_nt_into_class(class, &a, &bt, &mut full, m, k, n);
        let mut chunked = vec![0.0f32; m * n];
        for r0 in (0..m).step_by(2) {
            matmul_nt_into_class(
                class,
                &a[r0 * k..(r0 + 2) * k],
                &bt,
                &mut chunked[r0 * n..(r0 + 2) * n],
                2,
                k,
                n,
            );
        }
        assert_eq!(full, chunked);
    }

    #[test]
    fn serving_class_rows_are_occupancy_invariant() {
        // The serving key is the configured slot capacity: any busy subset
        // (1..=max_slots rows) must reproduce the full batch's rows bit
        // for bit under the same class, whatever tier is active.
        let mut rng = Rng::new(108);
        let (slots, k, n) = (4usize, 64usize, 256usize);
        let a = rand_vec(&mut rng, slots * k);
        let b = rand_vec(&mut rng, k * n);
        let class = serving_class(slots, k, n);
        assert_eq!(class, matmul_class(slots, k, n));
        assert_eq!(serving_class(0, k, n), matmul_class(1, k, n), "max(1) floor");
        let mut full = vec![0.0f32; slots * n];
        matmul_into_class(class, &a, &b, &mut full, slots, k, n);
        for busy in 1..=slots {
            let mut part = vec![0.0f32; busy * n];
            matmul_into_class(class, &a[..busy * k], &b, &mut part, busy, k, n);
            assert_eq!(
                part[..],
                full[..busy * n],
                "busy={busy} rows must match the full batch bitwise"
            );
        }

        let bt = rand_vec(&mut rng, n * k);
        let nt_class = serving_nt_class(slots, k, n);
        assert_eq!(nt_class, matmul_nt_class(slots, k, n));
        let mut full = vec![0.0f32; slots * n];
        matmul_nt_into_class(nt_class, &a, &bt, &mut full, slots, k, n);
        for busy in 1..=slots {
            let mut part = vec![0.0f32; busy * n];
            matmul_nt_into_class(nt_class, &a[..busy * k], &bt, &mut part, busy, k, n);
            assert_eq!(part[..], full[..busy * n], "nt busy={busy}");
        }
    }
}
