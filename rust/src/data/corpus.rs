//! Synthetic corpus generator — the SlimPajama stand-in (DESIGN.md §5).
//!
//! Documents are sequences of template sentences over a synthetic lexicon:
//!
//! * word frequencies are Zipfian (`s ~ 1.05`), like natural text;
//! * sentences follow a small Markov grammar (SVO templates with function
//!   words), giving local n-gram structure any LM can learn;
//! * each document introduces `facts` key-value pairs early ("the <attr> of
//!   <entity> is <value>") and *restates* them later — restatements are only
//!   predictable by a model that kept the association in memory, which is
//!   precisely the capability axis EFLA vs DeltaNet differ on (associative
//!   recall through the delta-rule state).
//!
//! The mix of unpredictable filler and predictable long-range restatements
//! means perplexity differences between token mixers reflect memory
//! fidelity, mirroring the role SlimPajama plays in the paper (§5.2).

use crate::util::rng::{Rng, ZipfSampler};

/// Corpus generation parameters.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    /// Lexicon sizes per category.
    pub n_entities: usize,
    pub n_attributes: usize,
    pub n_values: usize,
    pub n_filler: usize,
    /// Facts introduced (and later restated) per document.
    pub facts_per_doc: usize,
    /// Filler sentences between introduction block and restatement block.
    pub filler_sentences: usize,
    /// Zipf exponent for filler word frequencies.
    pub zipf_s: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            n_entities: 200,
            n_attributes: 40,
            n_values: 300,
            n_filler: 800,
            facts_per_doc: 4,
            filler_sentences: 12,
            zipf_s: 1.05,
        }
    }
}

/// Seeded document stream.
pub struct Corpus {
    cfg: CorpusConfig,
    rng: Rng,
    zipf: ZipfSampler,
    entities: Vec<String>,
    attributes: Vec<String>,
    values: Vec<String>,
    filler: Vec<String>,
}

/// Deterministic pseudo-word from an index ("lorem"-like, pronounceable).
fn make_word(idx: usize, prefix: char) -> String {
    const CONS: &[u8] = b"bcdfghklmnprstvz";
    const VOW: &[u8] = b"aeiou";
    let mut w = String::new();
    w.push(prefix);
    let mut x = idx + 7;
    for i in 0..3 {
        let c = CONS[(x + i * 13) % CONS.len()] as char;
        let v = VOW[(x / 3 + i * 5) % VOW.len()] as char;
        w.push(c);
        w.push(v);
        x /= 5;
        if x == 0 && i >= 1 {
            break;
        }
    }
    w
}

impl Corpus {
    pub fn new(seed: u64, cfg: CorpusConfig) -> Self {
        let rng = Rng::new(seed);
        let zipf = ZipfSampler::new(cfg.n_filler, cfg.zipf_s);
        let entities = (0..cfg.n_entities).map(|i| make_word(i, 'e')).collect();
        let attributes = (0..cfg.n_attributes).map(|i| make_word(i, 'a')).collect();
        let values = (0..cfg.n_values).map(|i| make_word(i, 'v')).collect();
        let filler = (0..cfg.n_filler).map(|i| make_word(i, 'w')).collect();
        Corpus { cfg, rng, zipf, entities, attributes, values, filler }
    }

    fn filler_sentence(&mut self) -> String {
        let n = self.rng.range(4, 9);
        let mut s = String::new();
        for i in 0..n {
            if i > 0 {
                s.push(' ');
            }
            let w = self.zipf.sample(&mut self.rng);
            s.push_str(&self.filler[w]);
        }
        s.push('.');
        s
    }

    /// Generate one document. Returns (text, facts) where facts are
    /// (entity, attribute, value) index triples — used by the probe builder.
    pub fn document(&mut self) -> (String, Vec<(usize, usize, usize)>) {
        let mut facts = Vec::with_capacity(self.cfg.facts_per_doc);
        for _ in 0..self.cfg.facts_per_doc {
            let e = self.rng.range(0, self.entities.len());
            let a = self.rng.range(0, self.attributes.len());
            let v = self.rng.range(0, self.values.len());
            facts.push((e, a, v));
        }

        let mut text = String::new();
        // Introduction block.
        for &(e, a, v) in &facts {
            text.push_str(&format!(
                "the {} of {} is {}. ",
                self.attributes[a], self.entities[e], self.values[v]
            ));
        }
        // Filler block.
        for _ in 0..self.cfg.filler_sentences {
            text.push_str(&self.filler_sentence());
            text.push(' ');
        }
        // Restatement block (long-range recall targets), shuffled order.
        let mut order: Vec<usize> = (0..facts.len()).collect();
        self.rng.shuffle(&mut order);
        for &i in &order {
            let (e, a, v) = facts[i];
            text.push_str(&format!(
                "recall the {} of {} is {}. ",
                self.attributes[a], self.entities[e], self.values[v]
            ));
        }
        text.push('\n');
        (text, facts)
    }

    /// Concatenate documents until at least `min_bytes` of text.
    pub fn text(&mut self, min_bytes: usize) -> String {
        let mut out = String::with_capacity(min_bytes + 1024);
        while out.len() < min_bytes {
            let (doc, _) = self.document();
            out.push_str(&doc);
        }
        out
    }

    /// Accessors used by the probe builder.
    pub fn entity(&self, i: usize) -> &str {
        &self.entities[i]
    }

    pub fn attribute(&self, i: usize) -> &str {
        &self.attributes[i]
    }

    pub fn value(&self, i: usize) -> &str {
        &self.values[i]
    }

    pub fn n_values(&self) -> usize {
        self.values.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Corpus::new(42, CorpusConfig::default());
        let mut b = Corpus::new(42, CorpusConfig::default());
        assert_eq!(a.document().0, b.document().0);
        assert_eq!(a.text(1000), b.text(1000));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Corpus::new(1, CorpusConfig::default());
        let mut b = Corpus::new(2, CorpusConfig::default());
        assert_ne!(a.document().0, b.document().0);
    }

    #[test]
    fn document_restates_facts() {
        let mut c = Corpus::new(7, CorpusConfig::default());
        let (text, facts) = c.document();
        assert_eq!(facts.len(), 4);
        for &(e, a, v) in &facts {
            let intro = format!("the {} of {} is {}.", c.attribute(a), c.entity(e), c.value(v));
            let recall = format!("recall {intro}");
            assert!(text.contains(&intro), "missing intro: {intro}");
            assert!(text.contains(&recall), "missing recall: {recall}");
        }
    }

    #[test]
    fn text_reaches_requested_size() {
        let mut c = Corpus::new(3, CorpusConfig::default());
        let t = c.text(10_000);
        assert!(t.len() >= 10_000);
        assert!(t.is_ascii());
    }

    #[test]
    fn words_are_wordlike() {
        for i in 0..50 {
            let w = make_word(i, 'x');
            assert!(w.len() >= 3 && w.len() <= 9, "{w}");
            assert!(w.chars().all(|c| c.is_ascii_lowercase()));
        }
    }
}
