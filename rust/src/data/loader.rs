//! Batch loaders with background prefetch.
//!
//! [`TokenStream`] turns corpus text into a ring of token ids and cuts
//! next-token-prediction batches; [`Prefetcher`] wraps any batch-producing
//! closure in a worker thread + bounded channel so data generation overlaps
//! the PJRT step (no tokio in the vendor set — std::thread + mpsc).

use std::sync::mpsc;
use std::thread;

/// Token ring buffer cutting (tokens, shifted targets) LM batches.
pub struct TokenStream {
    ids: Vec<i32>,
    cursor: usize,
}

impl TokenStream {
    pub fn new(ids: Vec<i32>) -> Self {
        assert!(!ids.is_empty(), "empty token stream");
        TokenStream { ids, cursor: 0 }
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// Next contiguous window of `n` tokens (wraps around).
    fn window(&mut self, n: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let take = (n - out.len()).min(self.ids.len() - self.cursor);
            out.extend_from_slice(&self.ids[self.cursor..self.cursor + take]);
            self.cursor = (self.cursor + take) % self.ids.len();
        }
        out
    }

    /// An LM batch: tokens (B*L) and next-token targets (B*L, last = -1).
    pub fn lm_batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut tgts = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let w = self.window(seq + 1);
            toks.extend_from_slice(&w[..seq]);
            for t in 0..seq {
                tgts.push(if t + 1 <= seq { w[t + 1] } else { -1 });
            }
        }
        (toks, tgts)
    }
}

/// A prefetched batch of any type.
pub struct Prefetcher<T: Send + 'static> {
    rx: mpsc::Receiver<T>,
    handle: Option<thread::JoinHandle<()>>,
}

impl<T: Send + 'static> Prefetcher<T> {
    /// Spawn a worker running `make` forever, keeping up to `depth` batches
    /// ready. The worker exits when the receiver is dropped.
    pub fn spawn<F>(depth: usize, mut make: F) -> Self
    where
        F: FnMut() -> T + Send + 'static,
    {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = thread::Builder::new()
            .name("efla-loader".into())
            .spawn(move || {
                loop {
                    let item = make();
                    if tx.send(item).is_err() {
                        break; // consumer gone
                    }
                }
            })
            .expect("spawn loader thread");
        Prefetcher { rx, handle: Some(handle) }
    }

    /// Blocking next batch.
    pub fn next(&self) -> T {
        self.rx.recv().expect("loader thread died")
    }
}

impl<T: Send + 'static> Drop for Prefetcher<T> {
    fn drop(&mut self) {
        // Drain channel so the worker unblocks on send, then join.
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, mpsc::sync_channel(1).1));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_stream_wraps() {
        let mut s = TokenStream::new(vec![1, 2, 3]);
        let w = s.window(7);
        assert_eq!(w, vec![1, 2, 3, 1, 2, 3, 1]);
    }

    #[test]
    fn lm_batch_targets_are_shifted() {
        let mut s = TokenStream::new((0..100).collect());
        let (toks, tgts) = s.lm_batch(2, 10);
        assert_eq!(toks.len(), 20);
        assert_eq!(tgts.len(), 20);
        for b in 0..2 {
            for t in 0..9 {
                assert_eq!(tgts[b * 10 + t], toks[b * 10 + t + 1]);
            }
        }
    }

    #[test]
    fn batches_advance() {
        let mut s = TokenStream::new((0..1000).collect());
        let (a, _) = s.lm_batch(1, 8);
        let (b, _) = s.lm_batch(1, 8);
        assert_ne!(a, b);
    }

    #[test]
    fn prefetcher_delivers_in_order() {
        let mut n = 0u32;
        let pf = Prefetcher::spawn(2, move || {
            n += 1;
            n
        });
        assert_eq!(pf.next(), 1);
        assert_eq!(pf.next(), 2);
        assert_eq!(pf.next(), 3);
    }

    #[test]
    fn prefetcher_shutdown_clean() {
        let pf = Prefetcher::spawn(1, || vec![0u8; 1024]);
        let _ = pf.next();
        drop(pf); // must not hang
    }
}
