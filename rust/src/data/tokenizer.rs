//! Byte-level BPE tokenizer — the Mistral-tokenizer stand-in (DESIGN.md §5).
//!
//! Base vocabulary is the 256 byte values; [`Bpe::train`] greedily merges
//! the most frequent adjacent pair until the requested vocab size. Encoding
//! applies merges in rank order (lowest-rank first), exactly like GPT-2/
//! SentencePiece-BPE. Vocab size must match the artifact's embedding table;
//! the trained table round-trips through JSON so a tokenizer trained once
//! is reusable across runs.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// A trained byte-level BPE tokenizer.
#[derive(Clone, Debug)]
pub struct Bpe {
    /// merge rank: (left, right) -> (rank, new_id); new_id = 256 + rank.
    merges: HashMap<(u32, u32), (u32, u32)>,
    /// id -> byte sequence (for decoding).
    vocab: Vec<Vec<u8>>,
}

impl Bpe {
    /// Byte-level identity tokenizer (vocab 256, no merges).
    pub fn bytes_only() -> Self {
        Bpe { merges: HashMap::new(), vocab: (0..256u32).map(|b| vec![b as u8]).collect() }
    }

    /// Train on `text` until `vocab_size` tokens exist (>= 256).
    ///
    /// Classic word-histogram BPE: the text is pre-tokenized into
    /// whitespace-inclusive chunks, distinct chunks are counted once, and
    /// merges operate on the (small) set of distinct chunks weighted by
    /// count — O(merges * distinct_words), independent of corpus length.
    pub fn train(text: &str, vocab_size: usize) -> Self {
        assert!(vocab_size >= 256, "vocab must include all bytes");
        let mut vocab: Vec<Vec<u8>> = (0..256u32).map(|b| vec![b as u8]).collect();
        let mut merges: HashMap<(u32, u32), (u32, u32)> = HashMap::new();

        // Distinct chunk histogram.
        let mut hist: HashMap<&str, u64> = HashMap::new();
        for chunk in split_inclusive_ws(text) {
            *hist.entry(chunk).or_insert(0) += 1;
        }
        let mut words: Vec<(Vec<u32>, u64)> = hist
            .into_iter()
            .map(|(w, c)| (w.bytes().map(|b| b as u32).collect(), c))
            .collect();
        // Deterministic order regardless of hash iteration.
        words.sort_unstable();

        while vocab.len() < vocab_size {
            let mut counts: HashMap<(u32, u32), u64> = HashMap::new();
            for (ids, c) in &words {
                for w in ids.windows(2) {
                    *counts.entry((w[0], w[1])).or_insert(0) += c;
                }
            }
            let best = counts
                .iter()
                .max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)))
                .map(|(&p, &c)| (p, c));
            let Some((pair, count)) = best else { break };
            if count < 2 {
                break; // nothing worth merging
            }
            let rank = merges.len() as u32;
            let new_id = vocab.len() as u32;
            let mut bytes = vocab[pair.0 as usize].clone();
            bytes.extend_from_slice(&vocab[pair.1 as usize]);
            vocab.push(bytes);
            merges.insert(pair, (rank, new_id));

            for (ids, _) in &mut words {
                if ids.len() < 2 {
                    continue;
                }
                let mut out = Vec::with_capacity(ids.len());
                let mut i = 0;
                while i < ids.len() {
                    if i + 1 < ids.len() && (ids[i], ids[i + 1]) == pair {
                        out.push(new_id);
                        i += 2;
                    } else {
                        out.push(ids[i]);
                        i += 1;
                    }
                }
                *ids = out;
            }
        }
        Bpe { merges, vocab }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Encode text to token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        if self.merges.is_empty() || ids.len() < 2 {
            return ids;
        }
        // Repeatedly apply the lowest-rank applicable merge (standard BPE).
        loop {
            let mut best: Option<(u32, usize)> = None; // (rank, position)
            for i in 0..ids.len().saturating_sub(1) {
                if let Some(&(rank, _)) = self.merges.get(&(ids[i], ids[i + 1])) {
                    if best.map_or(true, |(r, _)| rank < r) {
                        best = Some((rank, i));
                    }
                }
            }
            let Some((rank, _)) = best else { break };
            // Merge ALL occurrences of this pair in one sweep.
            let pair = self
                .merges
                .iter()
                .find(|(_, &(r, _))| r == rank)
                .map(|(&p, &(_, id))| (p, id))
                .expect("rank exists");
            let ((a, b), new_id) = pair;
            let mut out = Vec::with_capacity(ids.len());
            let mut i = 0;
            while i < ids.len() {
                if i + 1 < ids.len() && ids[i] == a && ids[i + 1] == b {
                    out.push(new_id);
                    i += 2;
                } else {
                    out.push(ids[i]);
                    i += 1;
                }
            }
            ids = out;
        }
        ids
    }

    /// Encode long text via a word cache: the text is split into
    /// whitespace-inclusive chunks (GPT-2-style pre-tokenization) and each
    /// distinct chunk is BPE-encoded once. Orders of magnitude faster than
    /// [`encode`] on natural text; merges never cross chunk boundaries,
    /// which is the standard BPE pre-tokenization contract.
    pub fn encode_cached(&self, text: &str) -> Vec<u32> {
        let mut cache: HashMap<&str, Vec<u32>> = HashMap::new();
        let mut out = Vec::with_capacity(text.len() / 2);
        for chunk in split_inclusive_ws(text) {
            let ids = cache.entry(chunk).or_insert_with(|| self.encode(chunk));
            out.extend_from_slice(ids);
        }
        out
    }

    /// Decode token ids back to text (lossy on invalid UTF-8).
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(b) = self.vocab.get(id as usize) {
                bytes.extend_from_slice(b);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Serialize to JSON (merge list in rank order).
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(u32, (u32, u32))> = self
            .merges
            .iter()
            .map(|(&(a, b), &(rank, _))| (rank, (a, b)))
            .collect();
        pairs.sort_unstable();
        Json::obj(vec![
            (
                "merges",
                Json::Arr(
                    pairs
                        .into_iter()
                        .map(|(_, (a, b))| Json::arr_usize(&[a as usize, b as usize]))
                        .collect(),
                ),
            ),
            ("vocab_size", Json::Num(self.vocab.len() as f64)),
        ])
    }

    /// Deserialize from JSON.
    pub fn from_json(j: &Json) -> Result<Self> {
        let merge_list = j.get("merges").as_arr().ok_or_else(|| anyhow!("missing merges"))?;
        let mut bpe = Bpe::bytes_only();
        for (rank, m) in merge_list.iter().enumerate() {
            let pair = m.usize_array()?;
            if pair.len() != 2 {
                return Err(anyhow!("bad merge entry"));
            }
            let (a, b) = (pair[0] as u32, pair[1] as u32);
            let new_id = bpe.vocab.len() as u32;
            let mut bytes = bpe
                .vocab
                .get(a as usize)
                .ok_or_else(|| anyhow!("merge refers to unknown id {a}"))?
                .clone();
            bytes.extend_from_slice(
                bpe.vocab.get(b as usize).ok_or_else(|| anyhow!("unknown id {b}"))?,
            );
            bpe.vocab.push(bytes);
            bpe.merges.insert((a, b), (rank as u32, new_id));
        }
        Ok(bpe)
    }
}

/// Split text into chunks, each a word plus its trailing whitespace.
fn split_inclusive_ws(text: &str) -> Vec<&str> {
    let bytes = text.as_bytes();
    let mut chunks = Vec::new();
    let mut start = 0;
    let mut i = 0;
    while i < bytes.len() {
        // advance through non-ws, then through ws; that's one chunk
        while i < bytes.len() && !bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        chunks.push(&text[start..i]);
        start = i;
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_ws_partitions() {
        let t = "ab  cd\ne";
        let chunks = split_inclusive_ws(t);
        assert_eq!(chunks.concat(), t);
        assert_eq!(chunks, vec!["ab  ", "cd\n", "e"]);
    }

    #[test]
    fn encode_cached_roundtrips_and_compresses() {
        let text = "the cat sat. the cat sat. the cat sat on the mat.";
        let t = Bpe::train(text, 300);
        let ids = t.encode_cached(text);
        assert_eq!(t.decode(&ids), text);
        assert!(ids.len() < text.len());
    }

    #[test]
    fn bytes_only_roundtrip() {
        let t = Bpe::bytes_only();
        let ids = t.encode("hello é world");
        assert_eq!(t.decode(&ids), "hello é world");
        assert_eq!(t.vocab_size(), 256);
    }

    #[test]
    fn train_grows_vocab_and_roundtrips() {
        let text = "the cat sat on the mat. the cat sat on the mat. banana banana banana.";
        let t = Bpe::train(text, 280);
        assert!(t.vocab_size() > 256);
        assert!(t.vocab_size() <= 280);
        let ids = t.encode(text);
        assert_eq!(t.decode(&ids), text);
        // Compression: merged tokens shorten the sequence.
        assert!(ids.len() < text.len());
    }

    #[test]
    fn roundtrips_unseen_text() {
        let t = Bpe::train("aaa bbb aaa bbb aaa", 262);
        let unseen = "xyzzy aaa qqq";
        assert_eq!(t.decode(&t.encode(unseen)), unseen);
    }

    #[test]
    fn json_roundtrip_preserves_encoding() {
        let text = "abc abc abc abd abd xyz";
        let t = Bpe::train(text, 270);
        let j = t.to_json();
        let t2 = Bpe::from_json(&j).unwrap();
        assert_eq!(t.encode(text), t2.encode(text));
        assert_eq!(t.vocab_size(), t2.vocab_size());
    }

    #[test]
    fn training_is_deterministic() {
        let text = "deterministic deterministic text text text";
        let a = Bpe::train(text, 265);
        let b = Bpe::train(text, 265);
        assert_eq!(a.encode(text), b.encode(text));
    }
}
