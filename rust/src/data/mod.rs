//! Data pipeline substrates (all synthetic, all seeded — DESIGN.md §5).
//!
//! * [`corpus`]    — SlimPajama stand-in: Zipf/Markov template text with
//!   embedded long-range key-value facts (what the LM experiments measure).
//! * [`tokenizer`] — byte-level BPE (Mistral-tokenizer stand-in).
//! * [`mnist`]     — procedural stroke-rendered sMNIST + the Fig-1/Fig-2
//!   corruption operators (dropout / intensity scaling / additive noise).
//! * [`mad`]       — the six MAD benchmark tasks (Table 2).
//! * [`probes`]    — synthetic downstream suites standing in for
//!   LAMBADA/BoolQ/... (Table 1 accuracy columns).
//! * [`loader`]    — background-threaded batch prefetcher.

#![forbid(unsafe_code)]

pub mod corpus;
pub mod loader;
pub mod mad;
pub mod mnist;
pub mod probes;
pub mod tokenizer;
