//! Procedural sequential MNIST — the Fig-1/Fig-2 substrate (DESIGN.md §5).
//!
//! Real MNIST is not available offline, and Fig. 1 measures *corruption
//! robustness of the sequence mixer*, not digit semantics.  We therefore
//! render 28x28 grayscale digits procedurally: each class 0-9 is drawn as a
//! polyline/ellipse skeleton in a seven-segment-like layout, rasterized with
//! a soft brush, then randomized per sample (affine jitter, stroke width,
//! intensity) so the task needs real classification, not template matching.
//! Images flatten row-major to length-784 pixel sequences in [0, 1].
//!
//! The three corruption operators from the paper (§5.1) are implemented
//! here and applied to the *pixel sequence*, exactly as the paper does:
//!
//! * [`corrupt_dropout`]   — Bernoulli(p) zeroing of tokens;
//! * [`corrupt_scale`]     — OOD intensity scaling by a factor;
//! * [`corrupt_noise`]     — additive Gaussian noise, sigma-parameterized.

use std::fmt;

use crate::util::rng::Rng;

pub const SIDE: usize = 28;
pub const SEQ: usize = SIDE * SIDE;

/// Typed error for a label outside 0..=9 — surfaced (instead of a panic)
/// so a corrupt data file cannot abort a training run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DigitOutOfRange(pub u8);

impl fmt::Display for DigitOutOfRange {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "digit {} out of range (expected 0..=9)", self.0)
    }
}

impl std::error::Error for DigitOutOfRange {}

/// One rendered example.
#[derive(Clone, Debug)]
pub struct Example {
    /// Length-784 pixel sequence in [0, 1] (pre-corruption).
    pub pixels: Vec<f32>,
    pub label: u8,
}

/// Digit skeletons on a [0,1]^2 canvas: list of polylines.
fn skeleton(digit: u8) -> Result<Vec<Vec<(f32, f32)>>, DigitOutOfRange> {
    // Key anchor points (x right, y down), seven-segment-ish with curves
    // approximated by extra vertices.
    let p = |x: f32, y: f32| (x, y);
    Ok(match digit {
        0 => vec![vec![
            p(0.5, 0.12), p(0.78, 0.3), p(0.78, 0.7), p(0.5, 0.88),
            p(0.22, 0.7), p(0.22, 0.3), p(0.5, 0.12),
        ]],
        1 => vec![vec![p(0.35, 0.25), p(0.55, 0.12), p(0.55, 0.88)],
                  vec![p(0.35, 0.88), p(0.75, 0.88)]],
        2 => vec![vec![
            p(0.25, 0.28), p(0.45, 0.12), p(0.7, 0.22), p(0.72, 0.42),
            p(0.3, 0.7), p(0.22, 0.88), p(0.78, 0.88),
        ]],
        3 => vec![vec![
            p(0.25, 0.18), p(0.6, 0.12), p(0.75, 0.3), p(0.52, 0.47),
            p(0.78, 0.66), p(0.6, 0.88), p(0.24, 0.82),
        ]],
        4 => vec![vec![p(0.62, 0.88), p(0.62, 0.12), p(0.2, 0.62), p(0.8, 0.62)]],
        5 => vec![vec![
            p(0.72, 0.12), p(0.28, 0.12), p(0.26, 0.45), p(0.6, 0.42),
            p(0.76, 0.62), p(0.6, 0.88), p(0.25, 0.82),
        ]],
        6 => vec![vec![
            p(0.68, 0.14), p(0.38, 0.3), p(0.25, 0.6), p(0.4, 0.88),
            p(0.7, 0.8), p(0.72, 0.55), p(0.3, 0.55),
        ]],
        7 => vec![vec![p(0.22, 0.12), p(0.78, 0.12), p(0.45, 0.88)]],
        8 => vec![vec![
            p(0.5, 0.12), p(0.72, 0.25), p(0.5, 0.46), p(0.28, 0.25), p(0.5, 0.12),
        ], vec![
            p(0.5, 0.46), p(0.76, 0.68), p(0.5, 0.88), p(0.24, 0.68), p(0.5, 0.46),
        ]],
        9 => vec![vec![
            p(0.7, 0.45), p(0.3, 0.45), p(0.28, 0.2), p(0.55, 0.12),
            p(0.72, 0.25), p(0.7, 0.45), p(0.62, 0.88),
        ]],
        other => return Err(DigitOutOfRange(other)),
    })
}

/// Procedural sMNIST generator.
pub struct Smnist {
    rng: Rng,
}

impl Smnist {
    pub fn new(seed: u64) -> Self {
        Smnist { rng: Rng::new(seed) }
    }

    /// Render one random example.
    pub fn sample(&mut self) -> Example {
        let label = self.rng.below(10) as u8;
        let pixels = self
            .render(label)
            .expect("labels drawn below 10 are always renderable");
        Example { pixels, label }
    }

    /// Render a specific digit with randomized style.
    ///
    /// Errors (instead of panicking) on a digit outside 0..=9, so callers
    /// feeding labels from external files can reject bad records cleanly.
    pub fn render(&mut self, digit: u8) -> Result<Vec<f32>, DigitOutOfRange> {
        let strokes = skeleton(digit)?;
        let rng = &mut self.rng;
        // Per-sample style jitter.
        let scale = 0.85 + 0.25 * rng.f32();
        let theta = (rng.f32() - 0.5) * 0.3; // +-0.15 rad rotation
        let (sin_t, cos_t) = (theta.sin(), theta.cos());
        let dx = (rng.f32() - 0.5) * 0.12;
        let dy = (rng.f32() - 0.5) * 0.12;
        let shear = (rng.f32() - 0.5) * 0.25;
        let brush = 0.95 + 0.75 * rng.f32(); // stroke radius in pixels
        let intensity = 0.85 + 0.15 * rng.f32();

        let mut img = vec![0.0f32; SEQ];
        for line in strokes {
            // Transform vertices.
            let pts: Vec<(f32, f32)> = line
                .iter()
                .map(|&(x, y)| {
                    let (cx, cy) = (x - 0.5, y - 0.5);
                    let xs = cx + shear * cy;
                    let xr = cos_t * xs - sin_t * cy;
                    let yr = sin_t * xs + cos_t * cy;
                    (
                        (0.5 + scale * xr + dx) * (SIDE as f32 - 1.0),
                        (0.5 + scale * yr + dy) * (SIDE as f32 - 1.0),
                    )
                })
                .collect();
            // Rasterize each segment with a soft circular brush.
            for seg in pts.windows(2) {
                let (x0, y0) = seg[0];
                let (x1, y1) = seg[1];
                let len = ((x1 - x0).powi(2) + (y1 - y0).powi(2)).sqrt().max(1e-3);
                let steps = (len * 3.0).ceil() as usize;
                for s in 0..=steps {
                    let t = s as f32 / steps as f32;
                    let (px, py) = (x0 + t * (x1 - x0), y0 + t * (y1 - y0));
                    let r = brush;
                    let (ilo, ihi) = (((py - r).floor().max(0.0)) as usize,
                                      ((py + r).ceil().min(SIDE as f32 - 1.0)) as usize);
                    let (jlo, jhi) = (((px - r).floor().max(0.0)) as usize,
                                      ((px + r).ceil().min(SIDE as f32 - 1.0)) as usize);
                    for i in ilo..=ihi {
                        for j in jlo..=jhi {
                            let d2 = (i as f32 - py).powi(2) + (j as f32 - px).powi(2);
                            let val = intensity * (-d2 / (0.5 * r * r)).exp();
                            let cell = &mut img[i * SIDE + j];
                            *cell = cell.max(val);
                        }
                    }
                }
            }
        }
        Ok(img)
    }

    /// A batch of (pixels, labels), flattened pixels row-major (B, 784).
    pub fn batch(&mut self, n: usize) -> (Vec<f32>, Vec<i32>) {
        let mut px = Vec::with_capacity(n * SEQ);
        let mut ls = Vec::with_capacity(n);
        for _ in 0..n {
            let ex = self.sample();
            px.extend_from_slice(&ex.pixels);
            ls.push(ex.label as i32);
        }
        (px, ls)
    }
}

// ---------------- corruption operators (paper §5.1) ----------------

/// Bernoulli pixel dropout with probability `p` (information loss).
pub fn corrupt_dropout(pixels: &mut [f32], p: f64, rng: &mut Rng) {
    if p <= 0.0 {
        return;
    }
    for x in pixels.iter_mut() {
        if rng.bernoulli(p) {
            *x = 0.0;
        }
    }
}

/// OOD intensity scaling: multiply the whole sequence by `factor`.
pub fn corrupt_scale(pixels: &mut [f32], factor: f32) {
    for x in pixels.iter_mut() {
        *x *= factor;
    }
}

/// Additive Gaussian noise with std `sigma`.
pub fn corrupt_noise(pixels: &mut [f32], sigma: f32, rng: &mut Rng) {
    if sigma <= 0.0 {
        return;
    }
    for x in pixels.iter_mut() {
        *x += rng.normal_f32(0.0, sigma);
    }
}

/// Which corruption a robustness sweep applies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Corruption {
    None,
    Dropout(f64),
    Scale(f32),
    Noise(f32),
}

impl Corruption {
    pub fn apply(self, pixels: &mut [f32], rng: &mut Rng) {
        match self {
            Corruption::None => {}
            Corruption::Dropout(p) => corrupt_dropout(pixels, p, rng),
            Corruption::Scale(f) => corrupt_scale(pixels, f),
            Corruption::Noise(s) => corrupt_noise(pixels, s, rng),
        }
    }

    pub fn label(self) -> String {
        match self {
            Corruption::None => "clean".to_string(),
            Corruption::Dropout(p) => format!("dropout p={p}"),
            Corruption::Scale(f) => format!("scale x{f}"),
            Corruption::Noise(s) => format!("noise sigma={s}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_rejects_out_of_range_digits() {
        let mut g = Smnist::new(9);
        for bad in [10u8, 11, 255] {
            assert_eq!(g.render(bad).unwrap_err(), DigitOutOfRange(bad));
        }
        let msg = format!("{}", DigitOutOfRange(12));
        assert!(msg.contains("12"), "{msg}");
    }

    #[test]
    fn renders_all_digits_nonempty() {
        let mut g = Smnist::new(1);
        for d in 0..10u8 {
            let img = g.render(d).unwrap();
            let on = img.iter().filter(|&&x| x > 0.2).count();
            assert!(on > 20, "digit {d} has only {on} lit pixels");
            assert!(on < SEQ / 2, "digit {d} fills {on} pixels — too dense");
            assert!(img.iter().all(|&x| (0.0..=1.0).contains(&x)));
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean images of different digits must differ substantially.
        let mut g = Smnist::new(2);
        let mean_img = |g: &mut Smnist, d: u8| {
            let mut acc = vec![0.0f32; SEQ];
            for _ in 0..20 {
                for (a, p) in acc.iter_mut().zip(g.render(d).unwrap()) {
                    *a += p / 20.0;
                }
            }
            acc
        };
        let m1 = mean_img(&mut g, 1);
        let m8 = mean_img(&mut g, 8);
        let dist: f32 = m1.iter().zip(&m8).map(|(a, b)| (a - b).powi(2)).sum();
        assert!(dist > 5.0, "digits 1 and 8 too similar: {dist}");
    }

    #[test]
    fn sample_is_deterministic_per_seed() {
        let mut a = Smnist::new(3);
        let mut b = Smnist::new(3);
        let (ea, eb) = (a.sample(), b.sample());
        assert_eq!(ea.label, eb.label);
        assert_eq!(ea.pixels, eb.pixels);
    }

    #[test]
    fn dropout_zeroes_expected_fraction() {
        let mut rng = Rng::new(4);
        let mut px = vec![1.0f32; 10_000];
        corrupt_dropout(&mut px, 0.4, &mut rng);
        let zeros = px.iter().filter(|&&x| x == 0.0).count();
        assert!((zeros as f64 / 10_000.0 - 0.4).abs() < 0.03);
    }

    #[test]
    fn scale_and_noise() {
        let mut px = vec![0.5f32; 100];
        corrupt_scale(&mut px, 8.0);
        assert!(px.iter().all(|&x| (x - 4.0).abs() < 1e-6));
        let mut rng = Rng::new(5);
        let before = px.clone();
        corrupt_noise(&mut px, 0.5, &mut rng);
        assert_ne!(px, before);
    }

    #[test]
    fn batch_shapes() {
        let mut g = Smnist::new(6);
        let (px, ls) = g.batch(8);
        assert_eq!(px.len(), 8 * SEQ);
        assert_eq!(ls.len(), 8);
        assert!(ls.iter().all(|&l| (0..10).contains(&l)));
    }
}
