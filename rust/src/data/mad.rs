//! MAD benchmark task generators (Table 2; Poli et al. 2024).
//!
//! Six synthetic token-manipulation tasks probing architectural
//! capabilities. Each generator emits `(tokens, targets)` pairs where
//! `targets[t] = -1` marks positions excluded from the loss (only answer
//! positions are scored), matching the masked-CE convention of the LM
//! artifacts.
//!
//! Vocabulary layout (vocab = 64 for the `mad` preset):
//!   0..=7    special tokens (PAD, SEP, QUERY, COPY, NOISE, BOS, EOS, MASK)
//!   8..=35   "key" alphabet
//!   36..=63  "value" alphabet

use crate::util::rng::Rng;

pub const PAD: i32 = 0;
pub const SEP: i32 = 1;
pub const QUERY: i32 = 2;
pub const COPY: i32 = 3;
pub const NOISE: i32 = 4;
pub const BOS: i32 = 5;
pub const EOS: i32 = 6;
pub const MASK: i32 = 7;
pub const KEY_BASE: i32 = 8;
pub const N_KEYS: i32 = 28;
pub const VAL_BASE: i32 = 36;
pub const N_VALS: i32 = 28;
pub const VOCAB: usize = 64;
pub const IGNORE: i32 = -1;

/// The six MAD tasks (paper Table 2 columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MadTask {
    /// Compress: recall tokens of a sequence after a compression marker.
    Compress,
    /// Fuzzy recall: recall value for a key *adjacent* to the queried one.
    FuzzyRecall,
    /// In-context recall: classic associative recall over k/v pairs.
    InContextRecall,
    /// Memorize: fixed global key->value map (learned in weights).
    Memorize,
    /// Noisy recall: associative recall with noise tokens interleaved.
    NoisyRecall,
    /// Selective copy: copy only non-noise tokens, in order.
    SelectiveCopy,
}

impl MadTask {
    pub fn all() -> [MadTask; 6] {
        [
            MadTask::Compress,
            MadTask::FuzzyRecall,
            MadTask::InContextRecall,
            MadTask::Memorize,
            MadTask::NoisyRecall,
            MadTask::SelectiveCopy,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            MadTask::Compress => "compress",
            MadTask::FuzzyRecall => "fuzzy_recall",
            MadTask::InContextRecall => "in_context_recall",
            MadTask::Memorize => "memorize",
            MadTask::NoisyRecall => "noisy_recall",
            MadTask::SelectiveCopy => "selective_copy",
        }
    }
}

fn key(rng: &mut Rng) -> i32 {
    KEY_BASE + rng.below(N_KEYS as u64) as i32
}

fn val(rng: &mut Rng) -> i32 {
    VAL_BASE + rng.below(N_VALS as u64) as i32
}

/// The fixed map used by `Memorize` (a function of the seed only).
pub fn memorize_map(seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed ^ 0x4D454D4F52495A45); // "MEMORIZE"
    (0..N_KEYS).map(|_| val(&mut rng)).collect()
}

/// One generated example.
pub struct MadExample {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

/// Generator producing fixed-length examples for one task.
pub struct MadGen {
    pub task: MadTask,
    pub seq: usize,
    rng: Rng,
    memo: Vec<i32>,
}

impl MadGen {
    pub fn new(task: MadTask, seq: usize, seed: u64) -> Self {
        let memo = memorize_map(seed);
        MadGen { task, seq, rng: Rng::new(seed), memo }
    }

    /// Generate one example of length exactly `self.seq`.
    pub fn example(&mut self) -> MadExample {
        let mut t = vec![PAD; self.seq];
        let mut y = vec![IGNORE; self.seq];
        match self.task {
            MadTask::InContextRecall => self.recall(&mut t, &mut y, 0.0, false),
            MadTask::NoisyRecall => self.recall(&mut t, &mut y, 0.3, false),
            MadTask::FuzzyRecall => self.recall(&mut t, &mut y, 0.0, true),
            MadTask::Memorize => self.memorize(&mut t, &mut y),
            MadTask::SelectiveCopy => self.selective_copy(&mut t, &mut y),
            MadTask::Compress => self.compress(&mut t, &mut y),
        }
        MadExample { tokens: t, targets: y }
    }

    /// Associative recall core: emit (k v) pairs (optionally interleaved
    /// with NOISE), then query a seen key; answer is the value at the next
    /// position. `fuzzy` queries key+1 (answer = value of the *nearest* key,
    /// here defined as the value bound to key), probing soft matching.
    fn recall(&mut self, t: &mut [i32], y: &mut [i32], noise_p: f64, fuzzy: bool) {
        let seq = self.seq;
        // Reserve 3 positions for [SEP QUERY-key answer].
        let budget = seq - 4;
        let mut pairs: Vec<(i32, i32)> = Vec::new();
        let mut pos = 0;
        t[pos] = BOS;
        pos += 1;
        while pos + 2 < budget {
            if noise_p > 0.0 && self.rng.bernoulli(noise_p) {
                t[pos] = NOISE;
                pos += 1;
                continue;
            }
            let (k, v) = (key(&mut self.rng), val(&mut self.rng));
            t[pos] = k;
            t[pos + 1] = v;
            pos += 2;
            pairs.push((k, v));
        }
        // Pick a queried pair (last binding wins for duplicate keys).
        let (qk, qv) = pairs[self.rng.range(0, pairs.len())];
        let qv = pairs.iter().rev().find(|&&(k, _)| k == qk).map(|&(_, v)| v).unwrap_or(qv);
        t[pos] = SEP;
        let asked = if fuzzy {
            // neighbouring key id (wraps inside the key alphabet)
            KEY_BASE + ((qk - KEY_BASE + 1) % N_KEYS)
        } else {
            qk
        };
        t[pos + 1] = QUERY;
        t[pos + 2] = asked;
        // Next-token convention: the target sits at the position whose
        // *input* is the asked key — the model must produce the bound value
        // before seeing it. The answer token itself is appended as teacher
        // forcing input only.
        y[pos + 2] = qv;
        if pos + 3 < seq {
            t[pos + 3] = qv;
        }
    }

    /// Fixed global map: input is [k] * n queries; output value per key is
    /// constant across the dataset (must be memorized in the weights).
    fn memorize(&mut self, t: &mut [i32], y: &mut [i32]) {
        let seq = self.seq;
        let mut pos = 0;
        t[pos] = BOS;
        pos += 1;
        while pos + 1 < seq {
            let kidx = self.rng.below(N_KEYS as u64) as usize;
            let k = KEY_BASE + kidx as i32;
            let v = self.memo[kidx];
            t[pos] = k;
            t[pos + 1] = v;
            y[pos] = v; // at the key position, predict the memorized value
            pos += 2;
        }
    }

    /// Copy the non-noise tokens after the COPY marker, in order.
    fn selective_copy(&mut self, t: &mut [i32], y: &mut [i32]) {
        let seq = self.seq;
        let n_content = (seq - 2) / 3; // content, noise, then copy region
        let mut content = Vec::with_capacity(n_content);
        let mut pos = 0;
        t[pos] = BOS;
        pos += 1;
        // content interleaved with noise
        while content.len() < n_content {
            if self.rng.bernoulli(0.4) {
                t[pos] = NOISE;
            } else {
                let v = val(&mut self.rng);
                t[pos] = v;
                content.push(v);
            }
            pos += 1;
        }
        t[pos] = COPY;
        for &c in &content {
            if pos + 1 >= seq {
                break;
            }
            // target at the position BEFORE the copied token appears
            y[pos] = c;
            t[pos + 1] = c;
            pos += 1;
        }
    }

    /// Compress: a content block, a MASK block (forcing the state to carry
    /// the content), then reproduce the content after SEP.
    fn compress(&mut self, t: &mut [i32], y: &mut [i32]) {
        let seq = self.seq;
        let n = (seq - 3) / 3;
        let content: Vec<i32> = (0..n).map(|_| val(&mut self.rng)).collect();
        let mut pos = 0;
        t[pos] = BOS;
        pos += 1;
        for &c in &content {
            t[pos] = c;
            pos += 1;
        }
        for _ in 0..n {
            t[pos] = MASK;
            pos += 1;
        }
        t[pos] = SEP;
        for &c in &content {
            if pos + 1 >= seq {
                break;
            }
            y[pos] = c;
            t[pos + 1] = c;
            pos += 1;
        }
    }

    /// A batch of examples flattened to (B*seq) token/target vectors.
    pub fn batch(&mut self, b: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(b * self.seq);
        let mut tgts = Vec::with_capacity(b * self.seq);
        for _ in 0..b {
            let ex = self.example();
            toks.extend_from_slice(&ex.tokens);
            tgts.extend_from_slice(&ex.targets);
        }
        (toks, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(task: MadTask) -> MadGen {
        MadGen::new(task, 128, 42)
    }

    #[test]
    fn all_tasks_emit_valid_examples() {
        for task in MadTask::all() {
            let mut g = gen(task);
            for _ in 0..20 {
                let ex = g.example();
                assert_eq!(ex.tokens.len(), 128, "{task:?}");
                assert_eq!(ex.targets.len(), 128);
                assert!(
                    ex.tokens.iter().all(|&t| (0..VOCAB as i32).contains(&t)),
                    "{task:?} token out of vocab"
                );
                let scored = ex.targets.iter().filter(|&&t| t >= 0).count();
                assert!(scored > 0, "{task:?} has no scored positions");
                // Next-token convention: a scored target at position t must
                // equal the *following* input token (teacher forcing), never
                // the token at t itself (that would let the model copy its
                // own input — the bug this test pins down).
                for t in 0..ex.tokens.len() {
                    if ex.targets[t] >= 0 {
                        if t + 1 < ex.tokens.len() && ex.tokens[t + 1] != PAD {
                            assert_eq!(
                                ex.targets[t],
                                ex.tokens[t + 1],
                                "{task:?}: target at {t} must be the NEXT input \
                                 (never the token at t — that would be copyable)"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn recall_answer_matches_last_binding() {
        let mut g = gen(MadTask::InContextRecall);
        for _ in 0..50 {
            let ex = g.example();
            // Find QUERY position, asked key, and answer.
            let qpos = ex.tokens.iter().position(|&t| t == QUERY).unwrap();
            let asked = ex.tokens[qpos + 1];
            let answer = ex.targets[qpos + 1];
            assert!(answer >= VAL_BASE);
            // teacher-forced answer token follows the asked key
            assert_eq!(ex.tokens[qpos + 2], answer);
            // Scan bindings: last value bound to `asked` must equal answer.
            let mut last = None;
            let mut i = 1;
            while i + 1 < qpos {
                let (a, b) = (ex.tokens[i], ex.tokens[i + 1]);
                if a >= KEY_BASE && a < VAL_BASE && b >= VAL_BASE {
                    if a == asked {
                        last = Some(b);
                    }
                    i += 2;
                } else {
                    i += 1;
                }
            }
            assert_eq!(last, Some(answer));
        }
    }

    #[test]
    fn memorize_map_is_stable() {
        let m1 = memorize_map(7);
        let m2 = memorize_map(7);
        assert_eq!(m1, m2);
        let mut g = MadGen::new(MadTask::Memorize, 64, 7);
        let ex = g.example();
        for i in 1..ex.tokens.len() - 1 {
            let k = ex.tokens[i];
            if (KEY_BASE..VAL_BASE).contains(&k) && ex.targets[i + 1] >= 0 {
                assert_eq!(ex.targets[i + 1], m1[(k - KEY_BASE) as usize]);
            }
        }
    }

    #[test]
    fn selective_copy_preserves_order() {
        let mut g = gen(MadTask::SelectiveCopy);
        let ex = g.example();
        let copy_pos = ex.tokens.iter().position(|&t| t == COPY).unwrap();
        let content: Vec<i32> = ex.tokens[1..copy_pos]
            .iter()
            .copied()
            .filter(|&t| t >= VAL_BASE)
            .collect();
        let copied: Vec<i32> = ex.targets[copy_pos..].iter().copied().filter(|&t| t >= 0).collect();
        assert!(!copied.is_empty());
        assert_eq!(&content[..copied.len()], &copied[..]);
    }

    #[test]
    fn batches_flatten() {
        let mut g = gen(MadTask::Compress);
        let (t, y) = g.batch(4);
        assert_eq!(t.len(), 4 * 128);
        assert_eq!(y.len(), 4 * 128);
    }
}
