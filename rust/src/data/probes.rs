//! Synthetic downstream probes — Table 1 accuracy-column stand-ins.
//!
//! The paper evaluates zero-shot suites (LAMBADA, PIQA, BoolQ, ...) that all
//! reduce to "did the model keep enough context to score the right
//! continuation".  With a synthetic corpus those exact suites are
//! meaningless, so we build probes with *known ground truth* over the same
//! lexicon the model was trained on (see `corpus.rs`):
//!
//! * [`ProbeKind::FinalWord`]    (LAMBADA-like) — a document whose last
//!   token is the value of a fact introduced earlier; score exact-match of
//!   the argmax next token at the final position.
//! * [`ProbeKind::MultiChoice`]  (PIQA/ARC-like) — compare model loss on the
//!   correct restatement vs. a corrupted one; accuracy = fraction where the
//!   true completion scores lower loss.
//! * [`ProbeKind::BoolQuery`]    (BoolQ-like) — "is the <attr> of <entity>
//!   <value>? yes/no" with balanced labels; score yes/no token argmax.
//!
//! Each probe emits fixed-shape `(tokens, targets)` batches compatible with
//! the LM `eval` artifact (masked positions = -1), so the evaluator needs no
//! new graphs.

use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::tokenizer::Bpe;
use crate::util::rng::Rng;

/// Probe families (Table 1 accuracy columns, collapsed to three mechanisms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProbeKind {
    FinalWord,
    MultiChoice,
    BoolQuery,
}

impl ProbeKind {
    pub fn all() -> [ProbeKind; 3] {
        [ProbeKind::FinalWord, ProbeKind::MultiChoice, ProbeKind::BoolQuery]
    }

    pub fn name(self) -> &'static str {
        match self {
            ProbeKind::FinalWord => "final_word(lambada-like)",
            ProbeKind::MultiChoice => "multi_choice(piqa-like)",
            ProbeKind::BoolQuery => "bool_query(boolq-like)",
        }
    }
}

/// One scored probe item: token ids + the positions/targets that are scored,
/// plus item grouping for multi-choice (items sharing `group` are compared).
#[derive(Clone, Debug)]
pub struct ProbeItem {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub group: usize,
    /// For MultiChoice: true if this is the correct candidate of its group.
    pub is_correct: bool,
}

/// Probe set builder over the shared corpus lexicon.
pub struct Probes {
    corpus: Corpus,
    rng: Rng,
    seq: usize,
}

impl Probes {
    pub fn new(seed: u64, seq: usize) -> Self {
        // Probe documents must FIT in `seq` tokens (byte-level worst case),
        // so the probe corpus uses fewer facts and less filler than the
        // training corpus; lexicon identity is what matters for transfer.
        let cfg = CorpusConfig {
            facts_per_doc: 2,
            filler_sentences: (seq / 200).clamp(1, 4),
            ..CorpusConfig::default()
        };
        Probes { corpus: Corpus::new(seed ^ 0x50524F4245, cfg), rng: Rng::new(seed), seq }
    }

    fn encode_fit(&self, bpe: &Bpe, text: &str) -> Vec<i32> {
        let mut ids: Vec<i32> = bpe.encode(text).iter().map(|&x| x as i32).collect();
        ids.truncate(self.seq);
        ids
    }

    /// Pad a sequence to `seq` with trailing zeros (target -1 everywhere pad).
    fn pad(&self, mut ids: Vec<i32>, scored_from: usize) -> (Vec<i32>, Vec<i32>) {
        let n = ids.len().min(self.seq);
        ids.resize(self.seq, 0);
        let mut targets = vec![-1i32; self.seq];
        // next-token targets on scored region [scored_from, n-1)
        for t in scored_from..n.saturating_sub(1) {
            targets[t] = ids[t + 1];
        }
        (ids, targets)
    }

    /// FinalWord: context introduces facts + filler, ends with
    /// "recall the <attr> of <entity> is" — final-word prediction scored.
    pub fn final_word(&mut self, bpe: &Bpe, n_items: usize) -> Vec<ProbeItem> {
        let mut items = Vec::with_capacity(n_items);
        for g in 0..n_items {
            let (doc, facts) = self.corpus.document();
            let (e, a, v) = facts[self.rng.range(0, facts.len())];
            let stem = format!(
                "{doc}recall the {} of {} is",
                self.corpus.attribute(a),
                self.corpus.entity(e)
            );
            let full = format!("{stem} {}.", self.corpus.value(v));
            let stem_len = bpe.encode(&stem).len();
            let ids = self.encode_fit(bpe, &full);
            if stem_len + 1 >= ids.len() {
                continue; // truncated answer; skip
            }
            let (tokens, targets) = self.pad(ids, stem_len.saturating_sub(1));
            items.push(ProbeItem { tokens, targets, group: g, is_correct: true });
        }
        items
    }

    /// MultiChoice: same stem, two candidate values; correct one should get
    /// lower masked loss.
    pub fn multi_choice(&mut self, bpe: &Bpe, n_groups: usize) -> Vec<ProbeItem> {
        let mut items = Vec::new();
        for g in 0..n_groups {
            let (doc, facts) = self.corpus.document();
            let (e, a, v) = facts[self.rng.range(0, facts.len())];
            let mut wrong = self.rng.range(0, self.corpus.n_values());
            if wrong == v {
                wrong = (wrong + 1) % self.corpus.n_values();
            }
            for (cand, is_correct) in [(v, true), (wrong, false)] {
                let stem = format!(
                    "{doc}recall the {} of {} is",
                    self.corpus.attribute(a),
                    self.corpus.entity(e)
                );
                let full = format!("{stem} {}.", self.corpus.value(cand));
                let stem_len = bpe.encode(&stem).len();
                let ids = self.encode_fit(bpe, &full);
                if stem_len + 1 >= ids.len() {
                    continue;
                }
                let (tokens, targets) = self.pad(ids, stem_len.saturating_sub(1));
                items.push(ProbeItem { tokens, targets, group: g, is_correct });
            }
        }
        items
    }

    /// BoolQuery: "is the <attr> of <entity> <value>? yes." / "... no."
    /// Balanced positives/negatives; the yes/no word is scored.
    pub fn bool_query(&mut self, bpe: &Bpe, n_items: usize) -> Vec<ProbeItem> {
        let mut items = Vec::with_capacity(n_items);
        for g in 0..n_items {
            let (doc, facts) = self.corpus.document();
            let (e, a, v) = facts[self.rng.range(0, facts.len())];
            let truthy = self.rng.bernoulli(0.5);
            let shown = if truthy {
                v
            } else {
                let mut w = self.rng.range(0, self.corpus.n_values());
                if w == v {
                    w = (w + 1) % self.corpus.n_values();
                }
                w
            };
            let stem = format!(
                "{doc}is the {} of {} {}? answer",
                self.corpus.attribute(a),
                self.corpus.entity(e),
                self.corpus.value(shown)
            );
            let full = format!("{stem} {}.", if truthy { "yes" } else { "no" });
            let stem_len = bpe.encode(&stem).len();
            let ids = self.encode_fit(bpe, &full);
            if stem_len + 1 >= ids.len() {
                continue;
            }
            let (tokens, targets) = self.pad(ids, stem_len.saturating_sub(1));
            items.push(ProbeItem { tokens, targets, group: g, is_correct: true });
        }
        items
    }

    pub fn build(&mut self, kind: ProbeKind, bpe: &Bpe, n: usize) -> Vec<ProbeItem> {
        match kind {
            ProbeKind::FinalWord => self.final_word(bpe, n),
            ProbeKind::MultiChoice => self.multi_choice(bpe, n),
            ProbeKind::BoolQuery => self.bool_query(bpe, n),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bpe() -> Bpe {
        Bpe::bytes_only()
    }

    #[test]
    fn final_word_items_scored_near_end() {
        let mut p = Probes::new(1, 512);
        let items = p.final_word(&bpe(), 5);
        assert!(!items.is_empty());
        for it in &items {
            assert_eq!(it.tokens.len(), 512);
            let scored: Vec<usize> =
                (0..512).filter(|&t| it.targets[t] >= 0).collect();
            assert!(!scored.is_empty());
            // targets are next-token consistent
            for &t in &scored {
                assert_eq!(it.targets[t], it.tokens[t + 1]);
            }
        }
    }

    #[test]
    fn multi_choice_groups_paired() {
        let mut p = Probes::new(2, 512);
        let items = p.multi_choice(&bpe(), 6);
        for g in 0..6 {
            let group: Vec<_> = items.iter().filter(|i| i.group == g).collect();
            if group.is_empty() {
                continue;
            }
            assert_eq!(group.len(), 2, "group {g}");
            assert_eq!(group.iter().filter(|i| i.is_correct).count(), 1);
        }
    }

    #[test]
    fn bool_query_roughly_balanced() {
        let mut p = Probes::new(3, 512);
        let items = p.bool_query(&bpe(), 40);
        let yes = items
            .iter()
            .filter(|i| {
                let txt: Vec<u8> = i.tokens.iter().map(|&t| t as u8).collect();
                String::from_utf8_lossy(&txt).contains("answer yes")
            })
            .count();
        assert!(yes > 5 && yes < 35, "yes count {yes} of {}", items.len());
    }

    #[test]
    fn deterministic_per_seed() {
        let b = bpe();
        let mut p1 = Probes::new(9, 256);
        let mut p2 = Probes::new(9, 256);
        let a = p1.final_word(&b, 3);
        let c = p2.final_word(&b, 3);
        assert_eq!(a.len(), c.len());
        for (x, y) in a.iter().zip(c.iter()) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
