//! Finite-difference gradient check of the full LM loss under every
//! matmul dispatch tier the host supports, plus a tight cross-tier
//! gradient comparison against the scalar leg.
//!
//! Deliberately a single #[test] in its own binary: it flips the global
//! `force_kernel` hook, which would race the bit-exactness assertions in
//! other test binaries if they shared a process.

#![forbid(unsafe_code)]

use efla::runtime::cpu::config::family_config;
use efla::runtime::cpu::exec::Executor;
use efla::runtime::cpu::model::lm_loss;
use efla::runtime::cpu::params::ParamSet;
use efla::tensor::{gemm, Kernel, Tensor};
use efla::util::rng::Rng;

/// Analytic gradients for the current dispatch tier.
fn grads_and_loss(
    cfg: &efla::runtime::cpu::config::CpuModelCfg,
    params: &ParamSet,
    exec: &Executor,
    toks: &[i32],
    tgts: &[i32],
    b: usize,
    l: usize,
) -> (Vec<Tensor>, f32) {
    let mut grads = params.zeros_like();
    let stats = lm_loss(cfg, params, exec, toks, tgts, b, l, Some(&mut grads)).unwrap();
    (grads, stats.loss_mean)
}

#[test]
fn lm_gradients_match_finite_differences_under_every_tier() {
    let cfg = family_config("lm_tiny_efla").unwrap();
    let (b, l) = (1usize, 6usize);
    let exec = Executor::serial();
    let mut rng = Rng::new(77);
    let toks: Vec<i32> = (0..b * l).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
    let tgts: Vec<i32> = (0..b * l).map(|_| rng.below(cfg.vocab as u64) as i32).collect();

    let mut per_tier: Vec<(Kernel, Vec<Tensor>)> = Vec::new();
    for tier in [Kernel::Scalar, Kernel::Avx2Fma, Kernel::Avx512, Kernel::Neon] {
        if gemm::force_kernel(Some(tier)) != tier {
            continue; // host lacks this tier: its leg never runs
        }
        let mut params = ParamSet::init(&cfg, 5);
        let (grads, _) = grads_and_loss(&cfg, &params, &exec, &toks, &tgts, b, l);

        // Central finite differences over scattered entries of the tied
        // embedding and the first mixer projection; parameters are
        // perturbed in place and restored exactly from the saved value.
        let h = 2e-2f32;
        let mut checked_nonzero = 0usize;
        for name in ["embed", "layer0.wq"] {
            let pi = params.idx(name);
            let n_elems = params.tensor(pi).len();
            for idx in (0..n_elems).step_by((n_elems / 7).max(1)) {
                let orig = params.tensor(pi).data()[idx];
                params.tensor_mut(pi).data_mut()[idx] = orig + h;
                let lp =
                    lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, None).unwrap().loss_mean;
                params.tensor_mut(pi).data_mut()[idx] = orig - h;
                let lm =
                    lm_loss(&cfg, &params, &exec, &toks, &tgts, b, l, None).unwrap().loss_mean;
                params.tensor_mut(pi).data_mut()[idx] = orig;
                let fd = (lp as f64 - lm as f64) / (2.0 * h as f64);
                let analytic = grads[pi].data()[idx] as f64;
                assert!(
                    (analytic - fd).abs() < 2e-2 * (1.0 + fd.abs()),
                    "{tier:?} {name}[{idx}]: analytic {analytic} vs fd {fd}"
                );
                if analytic.abs() > 1e-4 {
                    checked_nonzero += 1;
                }
            }
        }
        assert!(checked_nonzero > 0, "{tier:?}: grad check never saw a nonzero gradient");
        per_tier.push((tier, grads));
    }
    gemm::force_kernel(None);

    // Every SIMD tier that ran must agree tightly with the scalar leg —
    // the SIMD kernels only re-round, never re-derive. (per_tier[0] is
    // always the scalar leg: forcing Scalar succeeds on every host.)
    let (_, ref gs) = per_tier[0];
    for (tier, gv) in per_tier[1..].iter() {
        for (i, (a, c)) in gs.iter().zip(gv.iter()).enumerate() {
            let scale = a.data().iter().fold(0.0f32, |m, x| m.max(x.abs())).max(1.0);
            let diff = a.max_abs_diff(c);
            assert!(
                diff <= 1e-3 * scale,
                "grad tensor {i}: scalar vs {tier:?} diff {diff} (scale {scale})"
            );
        }
    }
}
