//! Integration tests of the replica-sharded router (`efla route`).
//!
//! Each test stands up real in-process replicas — one serving front end
//! per thread, each owning its own single-thread CPU session — behind a
//! [`Router`], and drives faults through the replicas' deterministic
//! [`FaultInjector`] handles. The contracts pinned here:
//!
//! * proxying is invisible: greedy tokens through the router are
//!   bit-identical to hitting a replica directly;
//! * injected 500s fail over to another replica without a client-visible
//!   error;
//! * when every replica is down the router sheds with 503 + Retry-After
//!   instead of hanging, and its own /healthz + /stats keep answering;
//! * a stream that broke after the first forwarded token is terminated
//!   with an error line and NEVER retried;
//! * a request deadline bounds the whole retry budget (504), and the
//!   service recovers once the fault clears;
//! * an ejected replica is re-admitted by the health prober after the
//!   fault clears.

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use efla::coordinator::server::ServerConfig;
use efla::coordinator::session::Session;
use efla::runtime::CpuBackend;
use efla::serve::fault::{FaultInjector, FaultSpec};
use efla::serve::router::{Router, RouterConfig};
use efla::serve::{http, Frontend};
use efla::util::json::{self, Json};

/// A running router + replica topology, addressed by the client closure.
struct Cluster {
    router: String,
    replicas: Vec<String>,
    faults: Vec<Arc<FaultInjector>>,
}

/// Bind `n` replicas and a router over them, run everything on scoped
/// threads, wait until the prober saw every replica healthy, then hand
/// the cluster to the client closure. All loops stop when the closure
/// returns (or panics).
fn with_cluster<F, T>(n: usize, cfg: RouterConfig, f: F) -> T
where
    F: FnOnce(&Cluster) -> T,
{
    let mut frontends = Vec::new();
    let mut addrs = Vec::new();
    let mut flags = Vec::new();
    let mut faults = Vec::new();
    for _ in 0..n {
        let fe = Frontend::bind("127.0.0.1:0").unwrap();
        addrs.push(fe.local_addr().unwrap().to_string());
        flags.push(fe.shutdown_flag());
        faults.push(fe.fault_injector());
        frontends.push(fe);
    }
    let router = Router::bind("127.0.0.1:0", addrs.clone(), cfg).unwrap();
    let raddr = router.local_addr().unwrap().to_string();
    flags.push(router.shutdown_flag());
    std::thread::scope(|s| {
        for fe in frontends {
            s.spawn(move || {
                let backend = CpuBackend::with_threads(1);
                let session = Session::init(&backend, "lm_tiny_efla", 7).unwrap();
                fe.run(&session, ServerConfig::default(), 42).unwrap();
            });
        }
        s.spawn(move || router.run().unwrap());
        // Stop every serve loop even when a client assertion panics —
        // otherwise the scope would join forever.
        struct StopGuard(Vec<Arc<AtomicBool>>);
        impl Drop for StopGuard {
            fn drop(&mut self) {
                for f in &self.0 {
                    f.store(true, Ordering::SeqCst);
                }
            }
        }
        let _guard = StopGuard(flags);
        let cluster = Cluster { router: raddr, replicas: addrs, faults };
        wait_until_probed(&cluster.router, n);
        f(&cluster)
    })
}

/// Poll the router's /stats until all `n` replicas answered at least one
/// health probe (so requests cannot race the first probe cycle).
fn wait_until_probed(router: &str, n: usize) {
    let t0 = Instant::now();
    loop {
        if let Ok(resp) = http::request(router, "GET", "/stats", b"") {
            let j = json::parse(&resp.text()).unwrap();
            let live = j
                .get("replicas")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter(|r| r.get("probes_ok").as_f64().unwrap_or(0.0) >= 1.0)
                .count();
            if live == n {
                return;
            }
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "replicas never became healthy");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Poll the router's /stats until replica `idx` reports breaker `state`.
fn wait_for_state(router: &str, idx: usize, state: &str) {
    let t0 = Instant::now();
    loop {
        let resp = http::request(router, "GET", "/stats", b"").unwrap();
        let j = json::parse(&resp.text()).unwrap();
        let got = j.get("replicas").as_arr().unwrap()[idx]
            .get("state")
            .as_str()
            .unwrap()
            .to_string();
        if got == state {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "replica {idx} never reached {state:?} (at {got:?})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Fast knobs so breaker transitions happen in test time, not wall time.
fn fast_cfg() -> RouterConfig {
    RouterConfig {
        health_interval_ms: 25,
        health_timeout_ms: 250,
        backoff_base_ms: 5,
        backoff_cap_ms: 40,
        cooldown_ms: 200,
        seed: 3,
        ..RouterConfig::default()
    }
}

fn gen_body(id: u64, max_tokens: usize, stream: bool, extra: &str) -> String {
    format!(
        "{{\"id\":{id},\"tokens\":[5,6,7,8],\"max_tokens\":{max_tokens},\
         \"stream\":{stream}{extra}}}"
    )
}

fn tokens_of(j: &Json) -> Vec<i64> {
    j.get("tokens").as_arr().unwrap().iter().map(|v| v.as_i64().unwrap()).collect()
}

fn router_stats(router: &str) -> Json {
    let resp = http::request(router, "GET", "/stats", b"").unwrap();
    assert_eq!(resp.status, 200);
    json::parse(&resp.text()).unwrap()
}

#[test]
fn router_proxies_bit_identically_to_a_direct_replica() {
    with_cluster(2, fast_cfg(), |c| {
        let direct = http::request(
            &c.replicas[0],
            "POST",
            "/v1/generate",
            gen_body(1, 5, false, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(direct.status, 200, "{}", direct.text());
        let direct_toks = tokens_of(&json::parse(&direct.text()).unwrap());

        // The same prompt through the router, repeatedly: every answer
        // must be bit-identical to the direct hit (the router adds no
        // model state of its own, and the replicas share seed + family).
        for id in 2..6u64 {
            let resp = http::request(
                &c.router,
                "POST",
                "/v1/generate",
                gen_body(id, 5, false, "").as_bytes(),
            )
            .unwrap();
            assert_eq!(resp.status, 200, "request {id}: {}", resp.text());
            let j = json::parse(&resp.text()).unwrap();
            assert_eq!(j.get("id").as_i64(), Some(id as i64));
            assert_eq!(tokens_of(&j), direct_toks, "request {id} diverged through the router");
        }

        let h = http::request(&c.router, "GET", "/healthz", b"").unwrap();
        assert_eq!(h.status, 200);
        let hj = json::parse(&h.text()).unwrap();
        assert_eq!(hj.get("ok").as_bool(), Some(true));
        assert_eq!(hj.get("replicas").as_usize(), Some(2));
        assert_eq!(hj.get("available").as_usize(), Some(2));

        let st = router_stats(&c.router);
        assert!(st.get("requests").as_f64().unwrap() >= 4.0);
        assert!(st.get("proxied_ok").as_f64().unwrap() >= 4.0);
        assert_eq!(st.get("failed").as_f64(), Some(0.0));
        assert_eq!(st.get("shed").as_f64(), Some(0.0));
        assert!(
            st.get("aggregate").get("tokens_processed").as_f64().is_some(),
            "aggregate stats block missing: {st:?}"
        );
        // Client errors relay verbatim (retrying elsewhere cannot help).
        let bad = http::request(&c.router, "POST", "/v1/generate", b"{}").unwrap();
        assert_eq!(bad.status, 400, "{}", bad.text());
        let missing = http::request(&c.router, "GET", "/nope", b"").unwrap();
        assert_eq!(missing.status, 404);
    });
}

#[test]
fn router_fails_over_injected_500s_without_client_errors() {
    with_cluster(2, fast_cfg(), |c| {
        // Replica 0 now answers every generate with an injected 500; the
        // prober still sees its /healthz as fine, so the router keeps
        // offering it traffic and must fail over per request.
        c.faults[0].set_spec(FaultSpec::parse("error_rate=1").unwrap());
        let mut outs = Vec::new();
        for id in 0..4u64 {
            let resp = http::request(
                &c.router,
                "POST",
                "/v1/generate",
                gen_body(id, 4, false, "").as_bytes(),
            )
            .unwrap();
            assert_eq!(resp.status, 200, "request {id} must fail over: {}", resp.text());
            outs.push(tokens_of(&json::parse(&resp.text()).unwrap()));
        }
        for o in &outs[1..] {
            assert_eq!(o, &outs[0], "failover must not change greedy tokens");
        }
        let st = router_stats(&c.router);
        assert!(st.get("retries").as_f64().unwrap() >= 1.0, "no retry recorded: {st:?}");
        assert!(st.get("upstream_errors").as_f64().unwrap() >= 1.0);
        assert_eq!(st.get("failed").as_f64(), Some(0.0), "clients saw no failure: {st:?}");
    });
}

#[test]
fn router_sheds_when_every_replica_is_down() {
    // One replica, huge cooldown: once ejected nothing is routable and
    // no half-open probe can sneak the request through.
    let cfg = RouterConfig { eject_after: 2, cooldown_ms: 60_000, ..fast_cfg() };
    with_cluster(1, cfg, |c| {
        c.faults[0].set_spec(FaultSpec::parse("refuse").unwrap());
        wait_for_state(&c.router, 0, "ejected");

        let h = http::request(&c.router, "GET", "/healthz", b"").unwrap();
        assert_eq!(h.status, 200, "the router itself stays healthy");
        let hj = json::parse(&h.text()).unwrap();
        assert_eq!(hj.get("available").as_usize(), Some(0));

        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 4, false, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 503, "{}", resp.text());
        assert_eq!(resp.header("retry-after"), Some("1"), "shed must carry Retry-After");
        assert!(resp.text().contains("saturated or ejected"), "{}", resp.text());
        let st = router_stats(&c.router);
        assert!(st.get("shed").as_f64().unwrap() >= 1.0);
        assert!(st.get("ejections").as_f64().unwrap() >= 1.0);
    });
}

#[test]
fn router_never_retries_a_stream_broken_after_first_token() {
    // BOTH replicas cut streams, so a (wrong) retry would be observable
    // as a second broken stream or a restarted generation.
    with_cluster(2, fast_cfg(), |c| {
        for fault in &c.faults {
            fault.set_spec(FaultSpec::parse("cut_stream_after=2").unwrap());
        }
        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 6, true, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "head was committed before the cut: {}", resp.text());
        let text = resp.text();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 2, "expected token line(s) + error line: {text:?}");
        let first = json::parse(lines[0]).unwrap();
        assert!(first.get("token").as_i64().is_some(), "first line is a token: {text:?}");
        let last = json::parse(lines.last().unwrap()).unwrap();
        let err = last.get("error").as_str().unwrap_or_default().to_string();
        assert!(err.contains("upstream stream broke"), "terminating error line: {text:?}");
        assert_eq!(last.get("done").as_bool(), Some(true));

        let st = router_stats(&c.router);
        assert_eq!(st.get("streams_broken").as_f64(), Some(1.0), "{st:?}");
        assert_eq!(st.get("retries").as_f64(), Some(0.0), "broken streams must not retry");
    });
}

#[test]
fn router_answers_504_past_the_deadline_and_recovers() {
    // eject_after is high so the stalled replica stays routable for the
    // whole test — the 504 must come from the request deadline, not from
    // the breaker running out of replicas.
    let cfg = RouterConfig { eject_after: 50, ..fast_cfg() };
    with_cluster(1, cfg, |c| {
        c.faults[0].set_spec(FaultSpec::parse("stall_ms=2000").unwrap());
        let t0 = Instant::now();
        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 4, false, ",\"timeout_ms\":300").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 504, "{}", resp.text());
        assert!(resp.text().contains("deadline"), "{}", resp.text());
        assert!(
            t0.elapsed() < Duration::from_millis(1900),
            "504 must beat the 2s replica stall: took {:?}",
            t0.elapsed()
        );
        let st = router_stats(&c.router);
        assert!(st.get("timeouts").as_f64().unwrap() >= 1.0, "{st:?}");

        // Clear the fault: the same client path must go back to 200.
        c.faults[0].set_spec(FaultSpec::default());
        let t0 = Instant::now();
        loop {
            let resp = http::request(
                &c.router,
                "POST",
                "/v1/generate",
                gen_body(2, 4, false, "").as_bytes(),
            )
            .unwrap();
            if resp.status == 200 {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "service never recovered");
            std::thread::sleep(Duration::from_millis(50));
        }
    });
}

#[test]
fn router_readmits_an_ejected_replica_once_it_heals() {
    let cfg = RouterConfig { eject_after: 2, ..fast_cfg() };
    with_cluster(1, cfg, |c| {
        c.faults[0].set_spec(FaultSpec::parse("refuse").unwrap());
        wait_for_state(&c.router, 0, "ejected");
        c.faults[0].set_spec(FaultSpec::default());
        // The prober's next successful /healthz closes the breaker.
        wait_for_state(&c.router, 0, "healthy");
        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 4, false, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "{}", resp.text());
        let st = router_stats(&c.router);
        assert!(st.get("ejections").as_f64().unwrap() >= 1.0, "{st:?}");
    });
}
