//! Integration tests of the replica-sharded router (`efla route`).
//!
//! Each test stands up real in-process replicas — one serving front end
//! per thread, each owning its own single-thread CPU session — behind a
//! [`Router`], and drives faults through the replicas' deterministic
//! [`FaultInjector`] handles. The contracts pinned here:
//!
//! * proxying is invisible: greedy tokens through the router are
//!   bit-identical to hitting a replica directly;
//! * injected 500s fail over to another replica without a client-visible
//!   error;
//! * when every replica is down the router sheds with 503 + Retry-After
//!   instead of hanging, and its own /healthz + /stats keep answering;
//! * a stream that broke after the first forwarded token is terminated
//!   with an error line and NEVER retried;
//! * a request deadline bounds the whole retry budget (504), and the
//!   service recovers once the fault clears;
//! * an ejected replica is re-admitted by the health prober after the
//!   fault clears;
//! * sessioned requests stick to their rendezvous home replica (cache
//!   hits on every later turn), fall back when the home is ejected, and
//!   migrate the parked state to the fallback replica — or cold-prefill
//!   correctly when the migration source is unreachable.

#![forbid(unsafe_code)]

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use efla::coordinator::server::ServerConfig;
use efla::coordinator::session::Session;
use efla::runtime::CpuBackend;
use efla::serve::fault::{FaultInjector, FaultSpec};
use efla::serve::router::{rendezvous_pick, Router, RouterConfig};
use efla::serve::{http, Frontend};
use efla::util::json::{self, Json};

/// A running router + replica topology, addressed by the client closure.
struct Cluster {
    router: String,
    replicas: Vec<String>,
    faults: Vec<Arc<FaultInjector>>,
}

/// Bind `n` replicas and a router over them, run everything on scoped
/// threads, wait until the prober saw every replica healthy, then hand
/// the cluster to the client closure. All loops stop when the closure
/// returns (or panics).
fn with_cluster<F, T>(n: usize, cfg: RouterConfig, f: F) -> T
where
    F: FnOnce(&Cluster) -> T,
{
    with_cluster_cfg(n, cfg, ServerConfig::default(), f)
}

/// [`with_cluster`] with a custom per-replica [`ServerConfig`] (the
/// affinity tests arm each replica's session state cache).
fn with_cluster_cfg<F, T>(n: usize, cfg: RouterConfig, server_cfg: ServerConfig, f: F) -> T
where
    F: FnOnce(&Cluster) -> T,
{
    let mut frontends = Vec::new();
    let mut addrs = Vec::new();
    let mut flags = Vec::new();
    let mut faults = Vec::new();
    for _ in 0..n {
        let fe = Frontend::bind("127.0.0.1:0").unwrap();
        addrs.push(fe.local_addr().unwrap().to_string());
        flags.push(fe.shutdown_flag());
        faults.push(fe.fault_injector());
        frontends.push(fe);
    }
    let router = Router::bind("127.0.0.1:0", addrs.clone(), cfg).unwrap();
    let raddr = router.local_addr().unwrap().to_string();
    flags.push(router.shutdown_flag());
    std::thread::scope(|s| {
        for fe in frontends {
            let server_cfg = server_cfg.clone();
            s.spawn(move || {
                let backend = CpuBackend::with_threads(1);
                let session = Session::init(&backend, "lm_tiny_efla", 7).unwrap();
                fe.run(&session, server_cfg, 42).unwrap();
            });
        }
        s.spawn(move || router.run().unwrap());
        // Stop every serve loop even when a client assertion panics —
        // otherwise the scope would join forever.
        struct StopGuard(Vec<Arc<AtomicBool>>);
        impl Drop for StopGuard {
            fn drop(&mut self) {
                for f in &self.0 {
                    f.store(true, Ordering::SeqCst);
                }
            }
        }
        let _guard = StopGuard(flags);
        let cluster = Cluster { router: raddr, replicas: addrs, faults };
        wait_until_probed(&cluster.router, n);
        f(&cluster)
    })
}

/// Poll the router's /stats until all `n` replicas answered at least one
/// health probe (so requests cannot race the first probe cycle).
fn wait_until_probed(router: &str, n: usize) {
    let t0 = Instant::now();
    loop {
        if let Ok(resp) = http::request(router, "GET", "/stats", b"") {
            let j = json::parse(&resp.text()).unwrap();
            let live = j
                .get("replicas")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter(|r| r.get("probes_ok").as_f64().unwrap_or(0.0) >= 1.0)
                .count();
            if live == n {
                return;
            }
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "replicas never became healthy");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Poll the router's /stats until replica `idx` reports breaker `state`.
fn wait_for_state(router: &str, idx: usize, state: &str) {
    let t0 = Instant::now();
    loop {
        let resp = http::request(router, "GET", "/stats", b"").unwrap();
        let j = json::parse(&resp.text()).unwrap();
        let got = j.get("replicas").as_arr().unwrap()[idx]
            .get("state")
            .as_str()
            .unwrap()
            .to_string();
        if got == state {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "replica {idx} never reached {state:?} (at {got:?})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Fast knobs so breaker transitions happen in test time, not wall time.
fn fast_cfg() -> RouterConfig {
    RouterConfig {
        health_interval_ms: 25,
        health_timeout_ms: 250,
        backoff_base_ms: 5,
        backoff_cap_ms: 40,
        cooldown_ms: 200,
        seed: 3,
        ..RouterConfig::default()
    }
}

fn gen_body(id: u64, max_tokens: usize, stream: bool, extra: &str) -> String {
    format!(
        "{{\"id\":{id},\"tokens\":[5,6,7,8],\"max_tokens\":{max_tokens},\
         \"stream\":{stream}{extra}}}"
    )
}

fn tokens_of(j: &Json) -> Vec<i64> {
    j.get("tokens").as_arr().unwrap().iter().map(|v| v.as_i64().unwrap()).collect()
}

fn router_stats(router: &str) -> Json {
    let resp = http::request(router, "GET", "/stats", b"").unwrap();
    assert_eq!(resp.status, 200);
    json::parse(&resp.text()).unwrap()
}

#[test]
fn router_proxies_bit_identically_to_a_direct_replica() {
    with_cluster(2, fast_cfg(), |c| {
        let direct = http::request(
            &c.replicas[0],
            "POST",
            "/v1/generate",
            gen_body(1, 5, false, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(direct.status, 200, "{}", direct.text());
        let direct_toks = tokens_of(&json::parse(&direct.text()).unwrap());

        // The same prompt through the router, repeatedly: every answer
        // must be bit-identical to the direct hit (the router adds no
        // model state of its own, and the replicas share seed + family).
        for id in 2..6u64 {
            let resp = http::request(
                &c.router,
                "POST",
                "/v1/generate",
                gen_body(id, 5, false, "").as_bytes(),
            )
            .unwrap();
            assert_eq!(resp.status, 200, "request {id}: {}", resp.text());
            let j = json::parse(&resp.text()).unwrap();
            assert_eq!(j.get("id").as_i64(), Some(id as i64));
            assert_eq!(tokens_of(&j), direct_toks, "request {id} diverged through the router");
        }

        let h = http::request(&c.router, "GET", "/healthz", b"").unwrap();
        assert_eq!(h.status, 200);
        let hj = json::parse(&h.text()).unwrap();
        assert_eq!(hj.get("ok").as_bool(), Some(true));
        assert_eq!(hj.get("replicas").as_usize(), Some(2));
        assert_eq!(hj.get("available").as_usize(), Some(2));

        let st = router_stats(&c.router);
        assert!(st.get("requests").as_f64().unwrap() >= 4.0);
        assert!(st.get("proxied_ok").as_f64().unwrap() >= 4.0);
        assert_eq!(st.get("failed").as_f64(), Some(0.0));
        assert_eq!(st.get("shed").as_f64(), Some(0.0));
        assert!(
            st.get("aggregate").get("tokens_processed").as_f64().is_some(),
            "aggregate stats block missing: {st:?}"
        );
        // Client errors relay verbatim (retrying elsewhere cannot help).
        let bad = http::request(&c.router, "POST", "/v1/generate", b"{}").unwrap();
        assert_eq!(bad.status, 400, "{}", bad.text());
        let missing = http::request(&c.router, "GET", "/nope", b"").unwrap();
        assert_eq!(missing.status, 404);
    });
}

#[test]
fn router_fails_over_injected_500s_without_client_errors() {
    with_cluster(2, fast_cfg(), |c| {
        // Replica 0 now answers every generate with an injected 500; the
        // prober still sees its /healthz as fine, so the router keeps
        // offering it traffic and must fail over per request.
        c.faults[0].set_spec(FaultSpec::parse("error_rate=1").unwrap());
        let mut outs = Vec::new();
        for id in 0..4u64 {
            let resp = http::request(
                &c.router,
                "POST",
                "/v1/generate",
                gen_body(id, 4, false, "").as_bytes(),
            )
            .unwrap();
            assert_eq!(resp.status, 200, "request {id} must fail over: {}", resp.text());
            outs.push(tokens_of(&json::parse(&resp.text()).unwrap()));
        }
        for o in &outs[1..] {
            assert_eq!(o, &outs[0], "failover must not change greedy tokens");
        }
        let st = router_stats(&c.router);
        assert!(st.get("retries").as_f64().unwrap() >= 1.0, "no retry recorded: {st:?}");
        assert!(st.get("upstream_errors").as_f64().unwrap() >= 1.0);
        assert_eq!(st.get("failed").as_f64(), Some(0.0), "clients saw no failure: {st:?}");
    });
}

#[test]
fn router_sheds_when_every_replica_is_down() {
    // One replica, huge cooldown: once ejected nothing is routable and
    // no half-open probe can sneak the request through.
    let cfg = RouterConfig { eject_after: 2, cooldown_ms: 60_000, ..fast_cfg() };
    with_cluster(1, cfg, |c| {
        c.faults[0].set_spec(FaultSpec::parse("refuse").unwrap());
        wait_for_state(&c.router, 0, "ejected");

        let h = http::request(&c.router, "GET", "/healthz", b"").unwrap();
        assert_eq!(h.status, 200, "the router itself stays healthy");
        let hj = json::parse(&h.text()).unwrap();
        assert_eq!(hj.get("available").as_usize(), Some(0));

        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 4, false, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 503, "{}", resp.text());
        assert_eq!(resp.header("retry-after"), Some("1"), "shed must carry Retry-After");
        assert!(resp.text().contains("saturated or ejected"), "{}", resp.text());
        let st = router_stats(&c.router);
        assert!(st.get("shed").as_f64().unwrap() >= 1.0);
        assert!(st.get("ejections").as_f64().unwrap() >= 1.0);
    });
}

#[test]
fn router_never_retries_a_stream_broken_after_first_token() {
    // BOTH replicas cut streams, so a (wrong) retry would be observable
    // as a second broken stream or a restarted generation.
    with_cluster(2, fast_cfg(), |c| {
        for fault in &c.faults {
            fault.set_spec(FaultSpec::parse("cut_stream_after=2").unwrap());
        }
        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 6, true, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "head was committed before the cut: {}", resp.text());
        let text = resp.text();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.len() >= 2, "expected token line(s) + error line: {text:?}");
        let first = json::parse(lines[0]).unwrap();
        assert!(first.get("token").as_i64().is_some(), "first line is a token: {text:?}");
        let last = json::parse(lines.last().unwrap()).unwrap();
        let err = last.get("error").as_str().unwrap_or_default().to_string();
        assert!(err.contains("upstream stream broke"), "terminating error line: {text:?}");
        assert_eq!(last.get("done").as_bool(), Some(true));

        let st = router_stats(&c.router);
        assert_eq!(st.get("streams_broken").as_f64(), Some(1.0), "{st:?}");
        assert_eq!(st.get("retries").as_f64(), Some(0.0), "broken streams must not retry");
    });
}

#[test]
fn router_answers_504_past_the_deadline_and_recovers() {
    // eject_after is high so the stalled replica stays routable for the
    // whole test — the 504 must come from the request deadline, not from
    // the breaker running out of replicas.
    let cfg = RouterConfig { eject_after: 50, ..fast_cfg() };
    with_cluster(1, cfg, |c| {
        c.faults[0].set_spec(FaultSpec::parse("stall_ms=2000").unwrap());
        let t0 = Instant::now();
        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 4, false, ",\"timeout_ms\":300").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 504, "{}", resp.text());
        assert!(resp.text().contains("deadline"), "{}", resp.text());
        assert!(
            t0.elapsed() < Duration::from_millis(1900),
            "504 must beat the 2s replica stall: took {:?}",
            t0.elapsed()
        );
        let st = router_stats(&c.router);
        assert!(st.get("timeouts").as_f64().unwrap() >= 1.0, "{st:?}");

        // Clear the fault: the same client path must go back to 200.
        c.faults[0].set_spec(FaultSpec::default());
        let t0 = Instant::now();
        loop {
            let resp = http::request(
                &c.router,
                "POST",
                "/v1/generate",
                gen_body(2, 4, false, "").as_bytes(),
            )
            .unwrap();
            if resp.status == 200 {
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "service never recovered");
            std::thread::sleep(Duration::from_millis(50));
        }
    });
}

/// A [`ServerConfig`] with the per-replica session state cache armed.
fn cached_server_cfg() -> ServerConfig {
    ServerConfig { state_cache_bytes: 8 << 20, ..ServerConfig::default() }
}

/// A generate body with an explicit token prompt and a session key.
fn session_body(id: u64, toks: &[i64], max_tokens: usize, session: Option<&str>) -> String {
    let list: Vec<String> = toks.iter().map(|t| t.to_string()).collect();
    let sid = match session {
        Some(s) => format!(",\"session_id\":\"{s}\""),
        None => String::new(),
    };
    format!("{{\"id\":{id},\"tokens\":[{}],\"max_tokens\":{max_tokens}{sid}}}", list.join(","))
}

/// POST one turn and return its greedy tokens (asserting 200).
fn turn(addr: &str, body: &str) -> Vec<i64> {
    let resp = http::request(addr, "POST", "/v1/generate", body.as_bytes()).unwrap();
    assert_eq!(resp.status, 200, "{}", resp.text());
    tokens_of(&json::parse(&resp.text()).unwrap())
}

/// Poll a replica's /stats until its state-cache hit counter reaches
/// `want` (the engine publishes stats a beat after answering, so an
/// immediate read can race the snapshot).
fn wait_for_cache_hits(addr: &str, want: f64) {
    let t0 = Instant::now();
    loop {
        let resp = http::request(addr, "GET", "/stats", b"").unwrap();
        let j = json::parse(&resp.text()).unwrap();
        if j.get("state_cache").get("hits").as_f64() == Some(want) {
            return;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "cache hits never reached {want}: {}",
            resp.text()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// The `routing` counter block of the router's /stats.
fn routing_stats(router: &str) -> Json {
    let st = router_stats(router);
    assert_eq!(st.get("schema_version").as_usize(), Some(2), "{st:?}");
    st.get("routing").clone()
}

#[test]
fn affinity_routes_a_session_to_its_home_replica() {
    with_cluster_cfg(3, fast_cfg(), cached_server_cfg(), |c| {
        let sid = "affine-session";
        let home = rendezvous_pick(sid, &c.replicas).unwrap();

        // Three turns of one conversation, each prompt extending the
        // previous transcript (prompt + generated tokens + one new
        // token), so turns 2 and 3 are state-cache hits *if* they land
        // on the same replica — which is exactly what affinity buys.
        let mut prompt = vec![5i64, 6, 7, 8];
        for turn_no in 0..3u64 {
            let toks = turn(&c.router, &session_body(10 + turn_no, &prompt, 4, Some(sid)));
            prompt.extend(toks);
            prompt.push(9);
        }
        wait_for_cache_hits(&c.replicas[home], 2.0);

        let r = routing_stats(&c.router);
        assert_eq!(r.get("affinity").as_bool(), Some(true));
        assert_eq!(r.get("affinity_hits").as_f64(), Some(3.0), "{r:?}");
        assert_eq!(r.get("affinity_fallbacks").as_f64(), Some(0.0), "{r:?}");
        assert_eq!(r.get("migrations_ok").as_f64(), Some(0.0), "{r:?}");
        assert_eq!(r.get("migrations_failed").as_f64(), Some(0.0), "{r:?}");

        // The replica's own stats are versioned too, and the two other
        // replicas never saw the session.
        let hj = router_stats(&c.replicas[home]);
        assert_eq!(hj.get("schema_version").as_usize(), Some(2));
        assert_eq!(hj.get("state_cache").get("misses").as_f64(), Some(1.0), "{hj:?}");
        for (i, addr) in c.replicas.iter().enumerate() {
            if i == home {
                continue;
            }
            let j = router_stats(addr);
            assert_eq!(j.get("completed").as_f64(), Some(0.0), "replica {i} saw traffic");
        }
    });
}

#[test]
fn ejected_home_falls_back_and_migrates_the_parked_state() {
    with_cluster_cfg(2, fast_cfg(), cached_server_cfg(), |c| {
        let sid = "failover-session";
        let home = rendezvous_pick(sid, &c.replicas).unwrap();
        let other = 1 - home;

        // Turn 1 lands on the home and parks the session state there.
        let mut prompt = vec![5i64, 6, 7, 8];
        let toks = turn(&c.router, &session_body(21, &prompt, 4, Some(sid)));
        prompt.extend(toks);
        prompt.push(9);

        // Cold greedy reference for turn 2: same full prompt, no
        // session, straight to the fallback replica. Greedy decoding is
        // deterministic, so this is also what "staying put" would have
        // produced.
        let reference = turn(&c.replicas[other], &session_body(22, &prompt, 4, None));

        // Stall the home hard enough that health probes (250ms timeout)
        // fail and eject it — but the replica stays *alive*, so the
        // consuming state export (120s client timeout) still succeeds.
        c.faults[home].set_spec(FaultSpec::parse("stall_ms=2000").unwrap());
        wait_for_state(&c.router, home, "ejected");

        // Turn 2: home unroutable -> fallback, with state handoff.
        let migrated = turn(&c.router, &session_body(23, &prompt, 4, Some(sid)));
        assert_eq!(migrated, reference, "migrated turn diverged from cold recompute");

        // The fallback replica answered turn 2 from the *imported*
        // state: a hit without any prior miss for this session here.
        wait_for_cache_hits(&c.replicas[other], 1.0);
        let r = routing_stats(&c.router);
        assert_eq!(r.get("affinity_hits").as_f64(), Some(1.0), "{r:?}");
        assert_eq!(r.get("affinity_fallbacks").as_f64(), Some(1.0), "{r:?}");
        assert_eq!(r.get("migrations_ok").as_f64(), Some(1.0), "{r:?}");
        assert_eq!(r.get("migrations_failed").as_f64(), Some(0.0), "{r:?}");
    });
}

#[test]
fn failed_migration_falls_back_to_a_correct_cold_prefill() {
    with_cluster_cfg(2, fast_cfg(), cached_server_cfg(), |c| {
        let sid = "lost-state-session";
        let home = rendezvous_pick(sid, &c.replicas).unwrap();
        let other = 1 - home;

        let mut prompt = vec![5i64, 6, 7, 8];
        let toks = turn(&c.router, &session_body(31, &prompt, 4, Some(sid)));
        prompt.extend(toks);
        prompt.push(9);
        let reference = turn(&c.replicas[other], &session_body(32, &prompt, 4, None));

        // The home now refuses connections outright: ejected AND
        // unreachable, so the state export cannot succeed.
        c.faults[home].set_spec(FaultSpec::parse("refuse").unwrap());
        wait_for_state(&c.router, home, "ejected");

        let cold = turn(&c.router, &session_body(33, &prompt, 4, Some(sid)));
        assert_eq!(cold, reference, "cold-prefill fallback must stay correct");

        let r = routing_stats(&c.router);
        assert_eq!(r.get("migrations_ok").as_f64(), Some(0.0), "{r:?}");
        assert_eq!(r.get("migrations_failed").as_f64(), Some(1.0), "{r:?}");
        // The fallback replica cold-prefilled: one miss, no hit. (Poll:
        // the engine publishes stats a beat after answering.)
        let t0 = Instant::now();
        let j = loop {
            let j = router_stats(&c.replicas[other]);
            if j.get("state_cache").get("misses").as_f64().unwrap_or(0.0) >= 1.0 {
                break j;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "miss never recorded: {j:?}");
            std::thread::sleep(Duration::from_millis(20));
        };
        assert_eq!(j.get("state_cache").get("hits").as_f64(), Some(0.0), "{j:?}");
    });
}

#[test]
fn router_readmits_an_ejected_replica_once_it_heals() {
    let cfg = RouterConfig { eject_after: 2, ..fast_cfg() };
    with_cluster(1, cfg, |c| {
        c.faults[0].set_spec(FaultSpec::parse("refuse").unwrap());
        wait_for_state(&c.router, 0, "ejected");
        c.faults[0].set_spec(FaultSpec::default());
        // The prober's next successful /healthz closes the breaker.
        wait_for_state(&c.router, 0, "healthy");
        let resp = http::request(
            &c.router,
            "POST",
            "/v1/generate",
            gen_body(1, 4, false, "").as_bytes(),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "{}", resp.text());
        let st = router_stats(&c.router);
        assert!(st.get("ejections").as_f64().unwrap() >= 1.0, "{st:?}");
    });
}
