//! Curated kernel subset for the CI Miri leg.
//!
//! Miri interprets every load/store, so this file sticks to tiny shapes
//! (L <= 8, D <= 4) and the scalar kernel tier (`EFLA_FORCE_SCALAR=1` is
//! forwarded by the job; the tests also pin it explicitly so a native
//! `cargo test` run is deterministic). The point is undefined-behavior
//! coverage of the kernel entry points the serving stack leans on — the
//! heavier numerical checks live in `properties.rs` and `simd_parity.rs`.

#![forbid(unsafe_code)]

use efla::attention::{chunkwise_delta, sequential_delta, DeltaState, Gate};
use efla::tensor::gemm;
use efla::tensor::{
    active_kernel, axpy, dot, force_kernel, matmul_into, matmul_nt_into, matmul_tn_into, Kernel,
    Scratch, Tensor, ENV_FORCE_SCALAR,
};
use efla::util::rng::Rng;

fn pin_scalar() {
    force_kernel(Some(Kernel::Scalar));
}

fn naive_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..n {
                out[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    out
}

#[test]
fn scalar_matmul_family_matches_naive_loops() {
    pin_scalar();
    let (m, k, n) = (3, 4, 2);
    let mut rng = Rng::new(41);
    let a = rng.normal_vec(m * k, 0.0, 1.0);
    let b = rng.normal_vec(k * n, 0.0, 1.0);
    let want = naive_matmul(&a, &b, m, k, n);

    let mut out = vec![0.0f32; m * n];
    matmul_into(&a, &b, &mut out, m, k, n);
    for (x, y) in out.iter().zip(want.iter()) {
        assert!((x - y).abs() < 1e-5);
    }

    // b^T laid out (n, k): matmul_nt over it must agree.
    let mut bt = vec![0.0f32; n * k];
    for kk in 0..k {
        for j in 0..n {
            bt[j * k + kk] = b[kk * n + j];
        }
    }
    let mut out_nt = vec![0.0f32; m * n];
    matmul_nt_into(&a, &bt, &mut out_nt, m, k, n);
    for (x, y) in out_nt.iter().zip(want.iter()) {
        assert!((x - y).abs() < 1e-5);
    }

    // tn transposes its (m, k) lhs logically: out (k, n) = a^T @ b2 with
    // b2 (m, n). Expected value via an explicitly transposed copy.
    let b2 = rng.normal_vec(m * n, 0.0, 1.0);
    let mut at = vec![0.0f32; k * m];
    for i in 0..m {
        for kk in 0..k {
            at[kk * m + i] = a[i * k + kk];
        }
    }
    let want_tn = naive_matmul(&at, &b2, k, m, n);
    let mut out_tn = vec![0.0f32; k * n];
    matmul_tn_into(&a, &b2, &mut out_tn, m, k, n);
    for (x, y) in out_tn.iter().zip(want_tn.iter()) {
        assert!((x - y).abs() < 1e-5);
    }
}

#[test]
fn scalar_dot_and_axpy_match_reference() {
    pin_scalar();
    let mut rng = Rng::new(42);
    let x = rng.normal_vec(7, 0.0, 1.0);
    let y = rng.normal_vec(7, 0.0, 1.0);

    let want: f32 = x.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
    assert!((dot(&x, &y) - want).abs() < 1e-5);

    let mut acc = y.clone();
    axpy(0.5, &x, &mut acc);
    for i in 0..7 {
        assert!((acc[i] - (y[i] + 0.5 * x[i])).abs() < 1e-6);
    }
}

#[test]
fn chunkwise_matches_sequential_at_tiny_shapes() {
    pin_scalar();
    let (l, d) = (6, 3);
    let mut rng = Rng::new(43);
    let q = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.5));
    let k = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.5));
    let v = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.5));
    let beta: Vec<f32> = (0..l).map(|_| 0.1 + 0.8 * rng.f32()).collect();

    let (o_seq, s_seq) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
    for chunk in [1, 2, 4] {
        let (o_ch, s_ch) = chunkwise_delta(Gate::Efla, &q, &k, &v, &beta, chunk);
        assert!(o_ch.max_abs_diff(&o_seq) < 5e-5, "chunk {chunk}");
        assert!(s_ch.max_abs_diff(&s_seq) < 5e-5, "chunk {chunk}");
    }
}

#[test]
fn delta_state_streaming_matches_batch() {
    pin_scalar();
    let (l, d) = (5, 3);
    let mut rng = Rng::new(44);
    let q = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.5));
    let k = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.5));
    let v = Tensor::from_vec(&[l, d], rng.normal_vec(l * d, 0.0, 0.5));
    let beta: Vec<f32> = (0..l).map(|_| 0.1 + 0.8 * rng.f32()).collect();

    let (o_batch, s_batch) = sequential_delta(Gate::Efla, &q, &k, &v, &beta);
    let mut state = DeltaState::new(d, d);
    let mut out = vec![0.0f32; d];
    for t in 0..l {
        state.step(Gate::Efla, q.row(t), k.row(t), v.row(t), beta[t], &mut out);
        for j in 0..d {
            assert!((out[j] - o_batch.get(&[t, j])).abs() < 1e-5, "token {t}");
        }
    }
    for (a, b) in state.state().iter().zip(s_batch.data().iter()) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn scratch_buffers_come_back_zeroed() {
    let mut sc = Scratch::new();
    let mut buf = sc.take(8);
    assert_eq!(buf, vec![0.0f32; 8]);
    buf.iter_mut().for_each(|x| *x = 7.0);
    sc.put(buf);
    assert_eq!(sc.pooled(), 1);
    // Reused allocation, shorter length: still all zeros.
    let again = sc.take(5);
    assert_eq!(again, vec![0.0f32; 5]);
}

#[test]
fn forced_tier_audit_and_batched_class_occupancy_at_tiny_shapes() {
    // One #[test] on purpose: the tier audit is the only place in this
    // binary that flips the global `force_kernel` hook away from scalar,
    // and the occupancy check's bitwise asserts below must never race a
    // mid-flight tier switch from a sibling test thread.
    //
    // Part 1 — drive every SIMD tier's packed and small entry points at
    // shapes full of remainder tiles (m % MR != 0, n % NR != 0),
    // comparing against the naive loops at tolerance. Under Miri the
    // forced tiers resolve to Scalar (feature detection reports the
    // baseline) and the legs are vacuous; natively this exercises the
    // packing remainder handling of whichever tiers the host supports.
    let mut rng = Rng::new(46);
    for tier in [Kernel::Avx512, Kernel::Avx2Fma, Kernel::Neon] {
        if force_kernel(Some(tier)) != tier {
            continue; // tier unsupported here: nothing new to audit
        }
        for &(m, k, n) in &[(1usize, 3usize, 2usize), (5, 4, 7), (7, 9, 5)] {
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let want = naive_matmul(&a, &b, m, k, n);
            for class in [gemm::MatmulClass::Packed, gemm::MatmulClass::Small] {
                let mut out = vec![0.0f32; m * n];
                gemm::matmul_into_class(class, &a, &b, &mut out, m, k, n);
                for (i, (x, y)) in out.iter().zip(want.iter()).enumerate() {
                    assert!((x - y).abs() < 1e-4, "{tier:?} {class:?} {m}x{k}x{n} i={i}");
                }
            }
            let d = dot(&a[..k], &b[..k]);
            let dref: f32 = a[..k].iter().zip(b[..k].iter()).map(|(x, y)| x * y).sum();
            assert!((d - dref).abs() < 1e-4, "{tier:?} dot k={k}");
            let mut y = b[..k].to_vec();
            axpy(0.5, &a[..k], &mut y);
            for i in 0..k {
                assert!((y[i] - (b[i] + 0.5 * a[i])).abs() < 1e-5, "{tier:?} axpy i={i}");
            }
        }
    }
    pin_scalar(); // back to the tier every other test in this binary expects

    // Part 2 — the slot-batched serving contract at Miri-friendly sizes:
    // with the class keyed on the slot capacity, any busy prefix of the
    // slot block reproduces the full batch's rows bit-for-bit.
    let (slots, k, n) = (4usize, 3, 2);
    let mut rng = Rng::new(45);
    let a = rng.normal_vec(slots * k, 0.0, 1.0);
    let b = rng.normal_vec(k * n, 0.0, 1.0);
    let bt = rng.normal_vec(n * k, 0.0, 1.0);
    let class = gemm::serving_class(slots, k, n);
    let nt_class = gemm::serving_nt_class(slots, k, n);
    let mut full = vec![0.0f32; slots * n];
    gemm::matmul_into_class(class, &a, &b, &mut full, slots, k, n);
    let mut full_nt = vec![0.0f32; slots * n];
    gemm::matmul_nt_into_class(nt_class, &a, &bt, &mut full_nt, slots, k, n);
    for busy in 1..=slots {
        let mut part = vec![0.0f32; busy * n];
        gemm::matmul_into_class(class, &a[..busy * k], &b, &mut part, busy, k, n);
        assert_eq!(part[..], full[..busy * n], "nn busy={busy}");
        let mut part_nt = vec![0.0f32; busy * n];
        gemm::matmul_nt_into_class(nt_class, &a[..busy * k], &bt, &mut part_nt, busy, k, n);
        assert_eq!(part_nt[..], full_nt[..busy * n], "nt busy={busy}");
    }
}

#[test]
fn force_scalar_env_pins_the_dispatcher() {
    // The Miri job exports EFLA_FORCE_SCALAR=1 (forwarded via MIRIFLAGS);
    // under that contract the dispatcher must resolve to the scalar tier.
    if std::env::var(ENV_FORCE_SCALAR).is_ok_and(|v| !v.is_empty() && v != "0") {
        force_kernel(None); // drop any pin, re-resolve from the env
        assert_eq!(active_kernel(), Kernel::Scalar);
    }
    pin_scalar(); // leave the global in the state the other tests expect
}
