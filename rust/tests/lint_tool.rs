//! Integration tests for the `efla-lint` static-analysis pass.
//!
//! Each seeded fixture under `tests/lint_fixtures/` must fail with exactly
//! its rule id, the clean fixture must pass every rule, and the repository
//! source tree itself must scan violation-free — the same check the CI
//! `static-analysis` job runs through the `efla-lint` binary.

#![forbid(unsafe_code)]

use std::fs;

use efla::lint::{self, Rule, Violation};

/// Read a fixture file from `tests/lint_fixtures/`.
fn fixture(name: &str) -> String {
    let path = lint::repo_root().join("rust/tests").join(lint::FIXTURE_DIR).join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn rules(vs: &[Violation]) -> Vec<Rule> {
    vs.iter().map(|v| v.rule).collect()
}

#[test]
fn fixture_unsafe_without_safety_fires_efl001() {
    // Scanned as an allowlisted module so the allowlist rule stays quiet
    // and the missing SAFETY comment is the only finding.
    let vs = lint::scan_source("rust/src/tensor/gemm.rs", &fixture("unsafe_without_safety.rs"));
    assert_eq!(rules(&vs), vec![Rule::SafetyComment]);
    assert_eq!(vs[0].rule.id(), "EFL001");
}

#[test]
fn fixture_unsafe_outside_allowlist_fires_efl002() {
    let vs = lint::scan_source("rust/src/data/loader.rs", &fixture("unsafe_outside_allowlist.rs"));
    assert_eq!(rules(&vs), vec![Rule::UnsafeAllowlist]);
    assert_eq!(vs[0].rule.id(), "EFL002");
}

#[test]
fn fixture_missing_forbid_fires_efl003() {
    // forbid-header is a tree-level rule, so drive it through lint_sources.
    let files =
        vec![("rust/src/util/missing_forbid.rs".to_string(), fixture("missing_forbid.rs"))];
    let vs = lint::lint_sources(&files);
    assert_eq!(rules(&vs), vec![Rule::ForbidHeader]);
    assert_eq!(vs[0].rule.id(), "EFL003");
}

#[test]
fn fixture_float_partial_cmp_fires_efl004() {
    let vs = lint::scan_source("rust/src/util/stats.rs", &fixture("float_partial_cmp.rs"));
    assert_eq!(rules(&vs), vec![Rule::FloatOrd]);
    assert_eq!(vs[0].rule.id(), "EFL004");
}

#[test]
fn fixture_no_alloc_breach_fires_efl005() {
    let vs = lint::scan_source("rust/src/runtime/cpu/ops.rs", &fixture("no_alloc_breach.rs"));
    assert_eq!(rules(&vs), vec![Rule::NoAlloc]);
    assert_eq!(vs[0].rule.id(), "EFL005");
}

#[test]
fn fixture_state_cache_restore_alloc_fires_efl005() {
    // The restore hot path of the session state cache is tagged
    // `lint: no-alloc` in `runtime/cpu/mod.rs`; this fixture is the same
    // shape with a staging allocation, and must fire.
    let vs =
        lint::scan_source("rust/src/runtime/cpu/mod.rs", &fixture("state_cache_restore_alloc.rs"));
    assert_eq!(rules(&vs), vec![Rule::NoAlloc]);
    assert_eq!(vs[0].rule.id(), "EFL005");
}

#[test]
fn fixture_serving_unpinned_matmul_fires_efl006() {
    let vs = lint::scan_source("rust/src/serve/engine.rs", &fixture("serving_unpinned_matmul.rs"));
    assert_eq!(rules(&vs), vec![Rule::ServingPin]);
    assert_eq!(vs[0].rule.id(), "EFL006");
}

#[test]
fn fixture_serving_unpinned_batched_matmul_fires_efl006() {
    // The allowlist matches whole identifiers: the retired single-row
    // wrapper (a prefix of the batched name) must still fire in serve/.
    let vs = lint::scan_source(
        "rust/src/serve/engine.rs",
        &fixture("serving_unpinned_batched_matmul.rs"),
    );
    assert_eq!(rules(&vs), vec![Rule::ServingPin]);
    assert_eq!(vs[0].rule.id(), "EFL006");
    assert!(vs[0].msg.contains("`matmul_acc_serving`"), "{}", vs[0].msg);
}

#[test]
fn fixture_clean_passes_every_rule() {
    let src = fixture("clean.rs");
    // Per-file rules under both a serving and a non-serving path.
    assert!(lint::scan_source("rust/src/serve/engine.rs", &src).is_empty());
    assert!(lint::scan_source("rust/src/util/stats.rs", &src).is_empty());
    // Tree-level rule: the file carries its own forbid header.
    let files = vec![("rust/tests/clean.rs".to_string(), src)];
    assert!(lint::lint_sources(&files).is_empty());
}

#[test]
fn repository_tree_is_lint_clean() {
    let files = lint::collect_tree(&lint::repo_root()).expect("walk repo tree");
    assert!(!files.is_empty(), "lint roots must contain sources");
    let vs = lint::lint_sources(&files);
    for v in &vs {
        eprintln!("{v}");
    }
    assert!(vs.is_empty(), "{} lint violation(s) in the repository tree", vs.len());
}

#[test]
fn fixture_walk_skips_fixture_directory() {
    // The deliberately-violating fixtures must never reach a tree scan.
    let files = lint::collect_tree(&lint::repo_root()).expect("walk repo tree");
    assert!(files.iter().all(|(p, _)| !p.contains(lint::FIXTURE_DIR)));
}
