// Fixture: EFL001 safety-comment. Scanned as an allowlisted module, so
// the only finding must be the missing SAFETY comment on the unsafe block.

pub fn read_first(p: *const f32) -> f32 {
    unsafe { *p }
}
