#![forbid(unsafe_code)]

// Fixture: EFL004 float-ord. NaN makes this sort panic; total_cmp is the
// required spelling.

pub fn sort_losses(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
