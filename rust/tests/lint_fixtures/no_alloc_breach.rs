#![forbid(unsafe_code)]

// Fixture: EFL005 no-alloc. The tagged function allocates a Vec inside
// its body without an allow escape.

// lint: no-alloc
pub fn hot_step(out: &mut [f32]) {
    let tmp = vec![0.0f32; out.len()];
    out.copy_from_slice(&tmp);
}
