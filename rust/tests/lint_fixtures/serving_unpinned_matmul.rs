#![forbid(unsafe_code)]

// Fixture: EFL006 serving-pin. Scanned under a serve/ path, the direct
// matmul_into call must be flagged: only the slot-batched
// `*_acc_serving_batched` wrappers keep a row's bits independent of the
// batch shape.

pub fn project(a: &[f32], b: &[f32], out: &mut [f32]) {
    ops::matmul_into(a, b, out, 1, 4, 4);
}
