#![forbid(unsafe_code)]

// Fixture: a file every rule accepts — forbid header, total_cmp ordering,
// a tagged no-alloc fn that stays on caller buffers, and a waived
// startup-time allocation.

pub fn sort_losses(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

// lint: no-alloc
pub fn hot_step(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x.iter()) {
        *o = *v * 2.0;
    }
}

// lint: no-alloc
pub fn warm_start(n: usize) -> Vec<f32> {
    let pool = vec![0.0f32; n]; // lint: allow(no-alloc) -- startup only
    pool
}
