#![forbid(unsafe_code)]

// Fixture: EFL006 serving-pin, allowlist generalization. The retired
// single-row wrapper name is a prefix of the batched one; the rule must
// match whole identifiers against the declared allowlist, so this call
// fires even though no hardcoded ban list ever named it.

pub fn project(e: &Exec, a: &[f32], b: &[f32], out: &mut [f32]) {
    ops::matmul_acc_serving(e, a, b, out, 1, 4, 4);
}
