#![forbid(unsafe_code)]

// Fixture: EFL005 breach on the state-cache restore hot path — staging
// the cached row through a fresh Vec instead of copying in place.

// lint: no-alloc
pub fn restore_row(dst: &mut [f32], cached: &[f32]) {
    let staged = cached.to_vec();
    dst.copy_from_slice(&staged);
}
