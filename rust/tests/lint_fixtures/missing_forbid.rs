// Fixture: EFL003 forbid-header. No `#![forbid(unsafe_code)]` of its own
// and (as presented to the linter) no covering ancestor mod.rs.

pub fn noop() {}
