// Fixture: EFL002 unsafe-allowlist. The SAFETY comment is present, so
// scanning this under a non-allowlisted path must yield exactly the
// allowlist finding — and no escape hatch can waive it.

pub fn read_first(p: *const f32) -> f32 {
    // SAFETY: the caller promises p points at a live f32.
    unsafe { *p }
}
